#!/usr/bin/env bash
# Tier-1 CI: the full test suite, runnable from any checkout with no env
# setup (pyproject.toml's pythonpath handles src/; the explicit PYTHONPATH
# below keeps the ROADMAP.md invocation working on pytest < 7 too).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"

#!/usr/bin/env bash
# Tier-1 CI: the full test suite + a smoke-scale benchmark pass, runnable
# from any checkout with no env setup (pyproject.toml's pythonpath handles
# src/; the explicit PYTHONPATH below keeps the ROADMAP.md invocation
# working on pytest < 7 too).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Smoke-scale end-to-end benchmark (engine section only): catches benchmark
# bitrot — a benchmark that no longer runs fails CI instead of rotting.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run engine > /dev/null
echo "ci: smoke-scale engine benchmark OK"

# Smoke-scale partition-based group-by sweep: exercises the high-cardinality
# strategy end to end and leaves BENCH_groupby.json (name -> us_per_call)
# as the perf trajectory future PRs regress against. The sweep must also
# record the partition-vs-sort speedup ratios (measured and modeled) so the
# trajectory captures the sort-free planner's win, not just raw times.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run groupby/partition > /dev/null
test -s BENCH_groupby.json
python - <<'PY'
import json
rows = json.load(open("BENCH_groupby.json"))
for kind in ("speedup_vs_sort_measured", "speedup_vs_sort_modeled"):
    keys = [k for k in rows if k.endswith(kind)]
    assert keys, f"BENCH_groupby.json is missing {kind} trajectory keys"
    assert all(rows[k] > 0 for k in keys), (kind, keys)
PY
echo "ci: smoke-scale groupby/partition benchmark OK (BENCH_groupby.json + speedup keys)"

# Smoke-scale fused group-join benchmark: exercises the probe+accumulate
# path (fused vs join-then-group-by) end to end and leaves
# BENCH_groupjoin.json as its perf trajectory.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run groupjoin > /dev/null
test -s BENCH_groupjoin.json
echo "ci: smoke-scale groupjoin benchmark OK (BENCH_groupjoin.json)"

#!/usr/bin/env bash
# Tier-1 CI: the full test suite + a smoke-scale benchmark pass, runnable
# from any checkout with no env setup (pyproject.toml's pythonpath handles
# src/; the explicit PYTHONPATH below keeps the ROADMAP.md invocation
# working on pytest < 7 too).
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Lint gate (pycodestyle+pyflakes+import-order via pyproject's ruff
# config). The CI container cannot pip-install; run whenever ruff exists.
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks
    echo "ci: ruff lint OK"
else
    echo "ci: ruff not installed; skipping lint gate"
fi

# Static-analysis hard gate: every production operator entry point, Pallas
# kernel, and optimizer-chosen plan must honor its priced contract
# (repro.analysis sweeps them and exits non-zero on any ContractViolation).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.analysis --out ANALYSIS.json > /dev/null
test -s ANALYSIS.json
python - <<'PY'
import json
rep = json.load(open("ANALYSIS.json"))
assert rep["summary"]["violations"] == 0, rep["summary"]
assert rep["operators"] and rep["kernels"] and rep["engine"]
PY
echo "ci: repro.analysis contract sweep OK (ANALYSIS.json, 0 violations)"

# Observability smoke: run the standard traced workload, then assert the
# emitted artifacts against their schemas — every trace node must carry
# predicted + measured + residual, and CALIBRATION.json must hold both a
# device profile and non-empty residual EWMAs for the traced backend
# (DESIGN.md §12).
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.obs --smoke > /dev/null
test -s TRACE.json
test -s CALIBRATION.json
python - <<'PY'
import json
tr = json.load(open("TRACE.json"))
assert tr["backend"] and tr["queries"], "TRACE.json missing backend/queries"
for name, q in tr["queries"].items():
    assert q["nodes"], (name, "no nodes")
    for node in q["nodes"]:
        for key in ("predicted_s", "measured_s", "residual",
                    "op", "rows_out", "path"):
            assert key in node, (name, node.get("op"), "missing", key)
        assert node["measured_s"] > 0, (name, node["op"], "unmeasured")
cal = json.load(open("CALIBRATION.json"))
ent = cal[tr["backend"]]
assert ent["profiles"], "CALIBRATION.json entry has no device profile"
assert ent["residuals"], "CALIBRATION.json entry has no residual EWMAs"
assert all("ewma" in r and "count" in r for r in ent["residuals"].values())
PY
echo "ci: obs traced smoke OK (TRACE.json + CALIBRATION.json schemas)"

# Fault-injection smoke (DESIGN.md §13): one forced overflow per escalation
# ladder, one forced pallas-arm failure per fused kernel path, and one forced
# executor failure. The run must complete with results identical to the
# fault-free oracles AND the resilience.* counters must be non-zero — a
# recovery path that silently didn't run is a failure.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.resilience --smoke > RESILIENCE_SMOKE.json
python - <<'PY'
import json
rep = json.load(open("RESILIENCE_SMOKE.json"))
assert rep["ok"] and not rep["failures"], rep["failures"]
assert all(c["ok"] for c in rep["cases"]), rep["cases"]
for name in ("resilience.ladder_escalations", "resilience.kernel_fallbacks",
             "resilience.plan_degradations", "resilience.oom_injected",
             "resilience.faults_fired"):
    assert rep["metrics"].get(name, 0) > 0, (name, rep["metrics"])
PY
echo "ci: resilience fault-injection smoke OK (RESILIENCE_SMOKE.json, all counters moved)"

# Smoke-scale end-to-end benchmark (engine section only): catches benchmark
# bitrot — a benchmark that no longer runs fails CI instead of rotting.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run engine > /dev/null
echo "ci: smoke-scale engine benchmark OK"

# Smoke-scale partition-based group-by sweep: exercises the high-cardinality
# strategy end to end and leaves BENCH_groupby.json (name -> us_per_call)
# as the perf trajectory future PRs regress against. The sweep must also
# record the partition-vs-sort speedup ratios (measured and modeled) so the
# trajectory captures the sort-free planner's win, not just raw times.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run groupby/partition > /dev/null
test -s BENCH_groupby.json
python - <<'PY'
import json
rows = json.load(open("BENCH_groupby.json"))
for kind in ("speedup_vs_sort_measured", "speedup_vs_sort_modeled"):
    keys = [k for k in rows if k.endswith(kind)]
    assert keys, f"BENCH_groupby.json is missing {kind} trajectory keys"
    assert all(rows[k] > 0 for k in keys), (kind, keys)
# per-strategy residual summaries (measured/modeled) feed the calibration
# trajectory: one per (cardinality, strategy) point
res = [k for k in rows if k.endswith("/residual")]
assert res, "BENCH_groupby.json is missing per-strategy residual keys"
assert all(rows[k] > 0 for k in res), res
# every timing trajectory carries its structural fingerprint (plan budget
# + peak live bytes) so perf and plan-shape regressions are separable
fps = [k for k in rows if k.endswith("__structure")]
assert fps, "BENCH_groupby.json is missing __structure fingerprints"
assert all("budget" in rows[k] and "peak_live_bytes" in rows[k] for k in fps)
PY
echo "ci: smoke-scale groupby/partition benchmark OK (BENCH_groupby.json + speedup keys)"

# Smoke-scale fused group-join benchmark: exercises the probe+accumulate
# path (fused vs join-then-group-by) end to end and leaves
# BENCH_groupjoin.json as its perf trajectory.
REPRO_BENCH_SCALE=0.02 PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.run groupjoin > /dev/null
test -s BENCH_groupjoin.json
python - <<'PY'
import json
rows = json.load(open("BENCH_groupjoin.json"))
fps = [k for k in rows if k.endswith("__structure")]
assert fps, "BENCH_groupjoin.json is missing __structure fingerprints"
assert all("budget" in rows[k] and "peak_live_bytes" in rows[k] for k in fps)
# fused and unfused paths both carry measured/modeled residual summaries
for kind in ("/fused/residual", "/unfused/residual"):
    keys = [k for k in rows if k.endswith(kind)]
    assert keys, f"BENCH_groupjoin.json is missing {kind} keys"
    assert all(rows[k] > 0 for k in keys), (kind, keys)
PY
echo "ci: smoke-scale groupjoin benchmark OK (BENCH_groupjoin.json + fingerprints)"

# Smoke-scale chaos/soak gate (DESIGN.md §14): the query-serving runtime
# under every fault family. Delivered results must be bit-identical to
# fault-free oracles, failures confined to the faulted signature, and the
# breaker/saturation counters consistent with the injected faults. Leaves
# BENCH_serve.json (warm p50/p99 + throughput) as the serving trajectory.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.serve --chaos --smoke > /dev/null
test -s BENCH_serve.json
python - <<'PY'
import json
rep = json.load(open("BENCH_serve.json"))
assert rep["ok"] and not rep["failures"], rep["failures"]
base = rep["baseline"]
for key in ("p50_s", "p95_s", "p99_s", "throughput_qps"):
    assert base[key] > 0, (key, base)
assert base["plan_cache_hits"] > base["plans_compiled"], base
for fam, f in rep["families"].items():
    assert f["wrong_results"] == 0 and f["contaminated"] == 0, (fam, f)
    assert f["confinement"], (fam, "no confinement evidence")
# breaker counters must match the injected faults: hard failures open and
# then recover the breaker; compile-time pallas faults degrade without it
fams = rep["families"]
assert fams["raise"]["counters"]["qserve.failed"] == fams["raise"]["expected_failed"] > 0
assert fams["raise"]["counters"]["qserve.breaker_opens"] >= 1
assert fams["raise"]["counters"]["qserve.breaker_closes"] >= 1
assert fams["pallas"]["counters"]["resilience.kernel_fallbacks"] > 0
assert fams["pallas"]["counters"].get("qserve.breaker_opens", 0) == 0
assert fams["estimates"]["counters"]["qserve.saturations"] > 0
assert fams["overflow"]["counters"]["resilience.ladder_escalations"] > 0
assert rep["pressure"]["shed"] == 6 and rep["pressure"]["deadline"] == 2
assert rep["pressure"]["rejected"] == 2
# memory governor (DESIGN.md §15): big splittable queries served through
# the morsel driver under a tight byte budget, unsplittable ones rejected
# with the typed error, reservations never over the budget, zero wrong
# results on either path
mem = rep["memory"]
assert mem["chunked_runs"] > 0, mem
assert mem["mem_rejections"] > 0, mem
assert mem["reserved_le_budget"] is True, mem
assert mem["wrong_results"] == 0, mem
assert mem["oom_injected"] > 0, mem
PY
echo "ci: smoke-scale serve chaos soak OK (BENCH_serve.json, all families clean)"

"""Paper-figure benchmark drivers (see run.py for the entry point)."""

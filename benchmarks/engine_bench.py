"""End-to-end engine benchmarks: TPC-style multi-join + group-by queries,
planner-on vs fixed-algorithm baselines.

Validates the engine acceptance bar: the planner-chosen physical plan
(engine-estimated statistics, Fig. 18 + cost-model selection) must be no
slower than the worst fixed-algorithm plan, and ideally tracks the best.
Also times plan optimization itself (stats collection + ordering) and the
primitive-profile calibration."""
from __future__ import annotations

import time

import jax

from repro.data import relgen
from repro.engine import Catalog, optimize, scan
from repro.obs import metrics

from .common import N_BASE, emit, time_fn

FIXED = [("smj", "gfur"), ("smj", "gftr"), ("phj", "gfur"), ("phj", "gftr")]


def _star_query(n_fact: int, n_dim: int):
    fact, dims, fks, dks = relgen.generate_star(
        n_fact, n_dim, 2, payloads_per_dim=2, seed=0
    )
    cat = Catalog({"fact": fact, "dim0": dims[0], "dim1": dims[1]})
    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1")
         .group_by("fk0", p1_0="sum", p0_0="max"))
    return q, cat


def _time_plan(plan, iters=5, warmup=2):
    tables = dict(plan.catalog.tables)
    fn = jax.jit(lambda tb: plan.run(tb, jit=False))
    return time_fn(fn, tables, iters=iters, warmup=warmup)


def _time_plans_interleaved(tagged_plans, iters=7, warmup=2):
    """Median us per plan, with the timing rounds interleaved across plans
    so clock/thermal drift hits every candidate equally — consecutive
    per-candidate blocks can drift >10% between the first and last block,
    which is larger than the planner-vs-baseline gaps being compared."""
    runs = []
    for tag, plan in tagged_plans:
        tables = dict(plan.catalog.tables)
        fn = jax.jit(lambda tb, p=plan: p.run(tb, jit=False))
        for _ in range(warmup):
            jax.block_until_ready(fn(tables))
        runs.append((tag, fn, tables, []))
    for _ in range(iters):
        for tag, fn, tables, ts in runs:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(tables))
            ts.append(time.perf_counter() - t0)
    return {tag: metrics.percentiles(ts, (50,))["p50"] * 1e6
            for tag, _, _, ts in runs}


def tpc_star_query():
    """Two PK-FK joins + grouped aggregation; planner vs fixed baselines."""
    n_fact, n_dim = 2 * N_BASE, max(N_BASE // 4, 512)
    q, cat = _star_query(n_fact, n_dim)

    t0 = time.perf_counter()
    planned = optimize(q, cat)
    emit("engine/star/optimize_wall", (time.perf_counter() - t0) * 1e6,
         f"predicted={planned.total_cost*1e6:.0f}us")

    # compile everything first, then interleave timing rounds so the
    # planner-vs-baseline comparison is apples to apples
    candidates = [("planned", planned)]
    for alg, pat in FIXED:
        tag = f"fixed/{alg.upper()}-{'OM' if pat == 'gftr' else 'UM'}"
        candidates.append((tag, optimize(q, cat, force_join=(alg, pat))))
    times = _time_plans_interleaved(candidates)

    us_planned = times["planned"]
    emit("engine/star/planned", us_planned,
         f"{n_fact/(us_planned/1e6)/1e6:.2f} Mrows/s")
    fixed_times = [times[t] for t, _ in candidates[1:]]
    for (tag, _), us in zip(candidates[1:], fixed_times):
        emit(f"engine/star/{tag}", us, "")
    worst, best = max(fixed_times), min(fixed_times)
    emit("engine/star/planner_vs_worst", 0.0,
         f"planned={us_planned:.0f}us worst={worst:.0f}us "
         f"not_slower={us_planned <= worst * 1.05}")
    emit("engine/star/planner_vs_best", 0.0,
         f"gap_to_best={us_planned/best:.2f}x")


def filtered_topk_query():
    """Filter + join + group-by + order-by-limit through the full stack."""
    w = relgen.JoinWorkload("engine", N_BASE // 2, N_BASE, 2, 2,
                            match_ratio=0.5)
    R, S = relgen.generate(w)
    cat = Catalog({"R": R, "S": S})
    q = (scan("S")
         .filter("s1", ">=", 0)
         .join(scan("R"), key="k")
         .group_by("k", r1="sum")
         .order_by("r1_sum", limit=64, descending=True))
    planned = optimize(q, cat)
    us = _time_plan(planned)
    emit("engine/topk/planned", us, f"{(w.n_r+w.n_s)/(us/1e6)/1e6:.2f} Mtuples/s")


def calibration():
    """PrimitiveProfile.measure(): wall time + measured constants."""
    from repro.core.planner import PrimitiveProfile

    t0 = time.perf_counter()
    prof = PrimitiveProfile.measure(n=1 << 16)
    emit("engine/calibrate/measure_wall", (time.perf_counter() - t0) * 1e6,
         f"seq_bw={prof.seq_bw/1e9:.1f}GB/s "
         f"unclustered_pen={prof.unclustered_penalty:.1f}x")

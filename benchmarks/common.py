"""Benchmark utilities: timing, CSV emission, workload sizing.

CPU-container note: the paper's GPU throughputs (GB/s) are not reproducible
here; benchmarks validate the paper's *relative* claims (GFTR vs GFUR,
PHJ vs SMJ, skew robustness, memory ordering) at CPU-feasible sizes and
emit `name,us_per_call,derived` CSV rows, where `derived` carries the
figure-relevant ratio (speedup, GB/s-equivalent, bytes)."""
from __future__ import annotations

import os

from repro.obs.trace import median_wall

# default row counts (CPU-feasible; override with REPRO_BENCH_SCALE env)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
N_BASE = int((1 << 18) * SCALE)  # 262k rows ~ "1G"-analogue unit

ROWS = []

# structural fingerprints (repro.analysis): {row_name/__structure: {budget,
# peak_live_bytes}}, merged into the BENCH_*.json trajectories so a perf
# regression can be told apart from a *plan-shape* regression (a timing
# delta with an unchanged fingerprint is machine noise or a runtime change;
# a changed fingerprint means a different plan compiled).
FINGERPRINTS = {}


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def fingerprint(name: str, fn, *args):
    """Record fn's compiled-plan fingerprint under `name/__structure`."""
    from repro.analysis import audit_fn

    rep = audit_fn(fn, *args)
    FINGERPRINTS[f"{name}/__structure"] = {
        "budget": rep.budget.as_dict(),
        "peak_live_bytes": int(rep.peak_live_bytes),
    }


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time (us) of jit-compiled fn(*args), measured by the
    shared obs timing primitive (`repro.obs.trace.timed_call`: explicit
    block_until_ready on every output leaf, median-of-k) — benchmark
    numbers and trace numbers come from the same stopwatch."""
    return median_wall(fn, *args, iters=iters, warmup=warmup) * 1e6


def join_throughput(n_r: int, n_s: int, us: float) -> str:
    """Paper metric: (|R|+|S|) tuples / total time."""
    return f"{(n_r + n_s) / (us / 1e6) / 1e6:.1f} Mtuples/s"

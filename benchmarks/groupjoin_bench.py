"""Fused group-join benchmarks: aggregate during the probe vs materialize
the join and re-read it.

Matched workloads (pk_fk join + group on a probe-side key, same aggregates,
same accumulator capacity) run both ways; every row reports the measured
speedup and the cost-model-predicted speedup side by side, so the perf
trajectory can regress both the implementation and the model that the
engine's fusion pass trusts (`predict_groupjoin_time`)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (JoinStats, Table, group_aggregate, join, phj_groupjoin,
                        predict_groupby_time, predict_groupjoin_time, predict_join_time)

from .common import N_BASE, emit, fingerprint, time_fn


def _workload(rng, n_r, n_s, n_groups, extra_probe_cols=1):
    """pk_fk build side + probe side carrying a group key, an aggregate
    input, and `extra_probe_cols` rider payloads (the columns an unfused
    join materializes even though the group-by never reads them)."""
    rk = rng.permutation(n_r).astype(np.int32)
    R = Table({"k": jnp.asarray(rk),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    s = {"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
         "g": jnp.asarray(rng.integers(0, n_groups, n_s).astype(np.int32)),
         "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))}
    for j in range(extra_probe_cols):
        s[f"x{j}"] = jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))
    return R, Table(s)


def _unfused(R, S, aggs, num_groups, strategy):
    T, _ = join(R, S, key="k", algorithm="phj", pattern="gftr",
                out_size=S.num_rows, mode="pk_fk")
    return group_aggregate(T.select(("g",) + tuple(aggs)), key="g",
                           aggs=aggs, num_groups=num_groups,
                           strategy=strategy)


def _model_times(n_r, n_s, r_pay, s_pay, n_aggs, strategy, build_aggs):
    """(unfused_s, fused_s) predicted by the §5.4 cost model."""
    st = JoinStats(n_r=n_r, n_s=n_s, r_payload_cols=r_pay,
                   s_payload_cols=s_pay, match_ratio=1.0)
    unfused = (predict_join_time(st, "phj", "gftr")["total"]
               + predict_groupby_time(n_s, n_aggs, strategy))
    fused = predict_groupjoin_time(st, n_aggs, strategy,
                                   build_aggs=build_aggs)["total"]
    return unfused, fused


def fused_vs_unfused():
    """Fused probe+accumulate vs join-then-group-by, sweeping group
    cardinality and the accumulator strategy (matched on both sides).

    The scatter rows are the cleanest read of the fusion itself: the
    accumulator is nearly free on both sides, so the measured delta IS the
    skipped join materialization. The sort/partition_hash rows show the
    same delta under accumulators whose XLA-on-CPU realization (comparison
    sorts) dominates both pipelines — the model column prices the paper's
    radix-pass structure, where the materialization share is larger."""
    n_s = 2 * N_BASE
    n_r = max(n_s // 8, 2)
    rng = np.random.default_rng(0)
    aggs = {"rv": "sum", "sv": "mean"}
    for n_groups, extra, strategy in ((64, 1, "scatter"),
                                      (4096, 1, "sort"),
                                      (64, 1, "partition_hash")):
        R, S = _workload(rng, n_r, n_s, n_groups, extra)
        cap = 2 * n_groups
        f_un = jax.jit(functools.partial(_unfused, aggs=aggs, num_groups=cap,
                                         strategy=strategy))
        f_fu = jax.jit(functools.partial(
            phj_groupjoin, key="k", group_key="g", aggs=aggs, num_groups=cap,
            agg_strategy=strategy))
        us_un = time_fn(f_un, R, S)
        us_fu = time_fn(f_fu, R, S)
        fingerprint(f"groupjoin/G{n_groups}/x{extra}/{strategy}/fused",
                    f_fu, R, S)
        fingerprint(f"groupjoin/G{n_groups}/x{extra}/{strategy}/unfused",
                    f_un, R, S)
        model_un, model_fu = _model_times(
            n_r, n_s, 1, 2 + extra, len(aggs), strategy,
            build_aggs=1)  # rv comes from the build side
        model = model_un / model_fu
        emit(f"groupjoin/G{n_groups}/x{extra}/{strategy}/fused", us_fu,
             f"unfused {us_un:.0f}us; measured {us_un/us_fu:.2f}x; "
             f"model {model:.2f}x")
        # per-path residuals (measured/modeled absolute times): what the
        # calibration loop's EWMAs track — see repro.obs.residuals
        emit(f"groupjoin/G{n_groups}/x{extra}/{strategy}/fused/residual",
             us_fu / (model_fu * 1e6),
             f"measured {us_fu:.0f}us / model {model_fu*1e6:.0f}us")
        emit(f"groupjoin/G{n_groups}/x{extra}/{strategy}/unfused/residual",
             us_un / (model_un * 1e6),
             f"measured {us_un:.0f}us / model {model_un*1e6:.0f}us")


def engine_fusion():
    """The engine's fusion decision end to end: optimize a fusible query,
    report the chosen plan + its predicted cost, and time the fused plan
    against the same query with fusion disabled (forced operator
    baseline)."""
    from repro.engine import Catalog, optimize, scan

    n_s = 2 * N_BASE
    n_r = max(n_s // 8, 2)
    rng = np.random.default_rng(1)
    # dense group domain: both the fused and the forced-unfused plan pick
    # the scatter accumulator, so the measured delta is the materialization
    R, S = _workload(rng, n_r, n_s, 256)
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="mean")
    plan = optimize(q, cat, measure_profile=False)
    fused = "GroupJoin[" in plan.explain()
    baseline = optimize(q, cat, measure_profile=False,
                        force_join=("phj", "gftr"))
    us_plan = time_fn(lambda: plan.run())
    us_base = time_fn(lambda: baseline.run())
    from repro.engine import executor

    fingerprint("groupjoin/engine/planned",
                lambda tb: executor.execute(plan.root, tb),
                {"R": R, "S": S})
    emit("groupjoin/engine/planned", us_plan,
         f"{'fused' if fused else 'unfused'}; predicted "
         f"{plan.total_cost*1e6:.0f}us; forced-unfused {us_base:.0f}us; "
         f"measured {us_base/us_plan:.2f}x")

"""Framework-integration benchmarks: the paper's pattern inside the ML
system — MoE dispatch (sort/GFTR vs einsum/GFUR-analogue), the feature-join
input pipeline, and Pallas-kernel vs XLA primitive comparisons."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.data.pipeline import (FeatureJoinConfig, assemble_batch, history_aggregates,
                                 make_dim_tables, make_fact_batch)
from repro.kernels import ops as kops
from repro.models import moe as MOE
from repro.models.params import init_from_template

from .common import N_BASE, emit, time_fn


def moe_dispatch():
    """GFTR sort-dispatch vs dense einsum dispatch, tokens x experts sweep."""
    d = 128
    for T, E, k in ((4096, 8, 2), (4096, 60, 4), (16384, 8, 2)):
        for disp in ("sort", "einsum"):
            cfg = MoEConfig(num_experts=E, top_k=k, d_expert=256, dispatch=disp,
                            capacity_factor=1.25)
            p = init_from_template(MOE.moe_tmpl(d, cfg), jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (1, T, d)) * 0.1
            f = jax.jit(lambda p, x: MOE.apply_moe(p, x, cfg)[0])
            us = time_fn(f, p, x)
            emit(f"moe/T{T}_E{E}_k{k}/{disp}", us, f"{T/(us/1e6)/1e3:.0f} Ktok/s")


def feature_join_pipeline():
    """End-to-end on-device relational input pipeline (paper §1 use case)."""
    for pat in ("gftr", "gfur"):
        cfg = FeatureJoinConfig(algorithm="phj", pattern=pat)
        U, I = make_dim_tables(cfg)
        b, s = 8, 256
        fact = make_fact_batch(cfg, b, s, 0)
        f = jax.jit(functools.partial(assemble_batch, cfg, U, I, batch=b, seq=s))
        us = time_fn(lambda fa: f(fa)[0]["tokens"], fact)
        emit(f"pipeline/feature_join/{pat}", us, f"{b*s/(us/1e6)/1e3:.0f} Ktok/s")
    cfg = FeatureJoinConfig()
    fact = make_fact_batch(cfg, 8, 256, 0)
    g = jax.jit(functools.partial(history_aggregates, cfg))
    us = time_fn(g, fact)
    emit("pipeline/history_groupby", us, "per-user label mean")


def kernel_vs_xla():
    """Pallas kernels (interpret mode on CPU) vs XLA primitives —
    correctness-bearing comparison; wall times on CPU favor XLA since
    interpret mode executes the kernel body in Python."""
    n = N_BASE // 4
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.integers(0, 256, n).astype(np.int32))
    us_x = time_fn(lambda x: kops.histogram(x, 256, "xla"), d)
    emit("kernels/histogram/xla", us_x, "")
    us_p = time_fn(lambda x: kops.histogram(x, 256, "pallas"), d)
    emit("kernels/histogram/pallas-interpret", us_p, "validated==xla")

    b = jnp.sort(jnp.asarray(rng.integers(0, 1 << 29, n).astype(np.int32)))
    p = jnp.sort(jnp.asarray(rng.integers(0, 1 << 29, n).astype(np.int32)))
    emit("kernels/merge_lb/xla",
         time_fn(lambda a, c: kops.merge_lower_bound(a, c, "xla"), b, p), "")
    emit("kernels/merge_lb/pallas-interpret",
         time_fn(lambda a, c: kops.merge_lower_bound(a, c, "pallas"), b, p), "validated==xla")

"""Join benchmarks — one function per paper figure/table (§5).

Every function returns after emitting its CSV rows; sizes are scaled to CPU
(common.N_BASE rows ~ the paper's 1G unit)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import by_name, join, join_sequence
from repro.core import primitives as prim
from repro.core.memmodel import peak_memory_bytes
from repro.core.planner import JoinStats, choose_algorithm, predict_join_time
from repro.data import relgen

from .common import N_BASE, emit, join_throughput, time_fn

ALGS = ["SMJ-UM", "SMJ-OM", "PHJ-UM", "PHJ-OM"]


def _run(R, S, name, mode="pk_fk", out_size=None):
    kw = by_name(name)
    f = jax.jit(functools.partial(join, mode=mode, out_size=out_size, **kw))
    return time_fn(f, R, S)


def fig1_time_breakdown():
    """Fig. 1: wide-join cost with materialization (PHJ-UM vs PHJ-OM vs
    NPHJ), 1G:2G-analogue with 2 payload columns per side."""
    w = relgen.JoinWorkload("fig1", N_BASE, 2 * N_BASE, 2, 2)
    R, S = relgen.generate(w)
    for name in ("PHJ-UM", "PHJ-OM", "SMJ-UM", "SMJ-OM"):
        us = _run(R, S, name)
        emit(f"fig1/{name}", us, join_throughput(w.n_r, w.n_s, us))
    f = jax.jit(functools.partial(join, algorithm="nphj"))
    emit("fig1/NPHJ(cuDF-analogue)", time_fn(f, R, S), "baseline")


def table4_fig7_gather():
    """Table 4 / Fig. 7: clustered vs unclustered GATHER, with and without
    the transform cost.

    The random-access penalty is hardware-dependent: the paper measures
    ~8.5x on A100 (warp-level sector waste); a CPU's LLC blunts it unless
    the working set exceeds cache, so we (a) measure at an LLC-exceeding
    size, and (b) emit the v5e-projected totals through the planner's
    primitive-profile model — which is exactly the paper's own §5.4
    "profile the primitives, then decide" methodology."""
    n = max(4 * N_BASE, 1 << 24)  # >= 64MB working set, beyond LLC
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.integers(0, 1 << 30, n).astype(np.int32))
    idx_clustered = jnp.arange(n, dtype=jnp.int32)  # monotone (GFTR-style)
    idx_unclustered = jnp.asarray(rng.permutation(n).astype(np.int32))

    g = jax.jit(lambda s, i: jnp.take(s, i, axis=0))
    us_u = time_fn(g, src, idx_unclustered)
    us_c = time_fn(g, src, idx_clustered)
    emit("table4/unclustered_gather", us_u, f"{n*4/ (us_u/1e6)/1e9:.2f} GB/s")
    emit("table4/clustered_gather", us_c, f"speedup={us_u/us_c:.2f}x (paper: 8.5x on A100)")

    # Fig 7: add the transform cost to the clustered side (measured, CPU)
    m = 4 * N_BASE
    keys = jnp.asarray(rng.permutation(m).astype(np.int32))
    vals = src[:m]
    sort_t = jax.jit(lambda k, v: prim.sort_pairs(k, v))
    us_sort = time_fn(sort_t, keys, vals)
    part_t = jax.jit(lambda k, v: prim.radix_partition(k, v, start_bit=0, num_bits=8)[:2])
    us_part = time_fn(part_t, keys, vals)
    us_u_m = time_fn(g, vals, jnp.asarray(rng.permutation(m).astype(np.int32)))
    us_c_m = time_fn(g, vals, jnp.arange(m, dtype=jnp.int32))
    emit("fig7/cpu/unclustered(total)", us_u_m, "GFUR pattern")
    emit("fig7/cpu/sort+clustered", us_sort + us_c_m,
         f"vs_unclustered={us_u_m/(us_sort+us_c_m):.2f}x")
    emit("fig7/cpu/partition+clustered", us_part + us_c_m,
         f"vs_unclustered={us_u_m/(us_part+us_c_m):.2f}x")

    # Fig 7, v5e-projected via the primitive-profile cost model
    from repro.core.planner import PrimitiveProfile
    prof = PrimitiveProfile()
    t_u = prof.gather_cost(m, 4, clustered=False)
    t_sort = prof.sort_cost(m, 4, 4) + prof.gather_cost(m, 4, clustered=True)
    t_part = prof.partition_cost(m, 4, 4, 16) + prof.gather_cost(m, 4, clustered=True)
    emit("fig7/v5e-model/unclustered", t_u * 1e6, "")
    emit("fig7/v5e-model/sort+clustered", t_sort * 1e6,
         f"vs_unclustered={t_u/t_sort:.2f}x (paper A100: 1.23x)")
    emit("fig7/v5e-model/partition+clustered", t_part * 1e6,
         f"vs_unclustered={t_u/t_part:.2f}x (paper A100: 1.79x)")


def fig8_9_narrow():
    """Fig. 8/9: narrow joins (1 payload per side), sizes sweep."""
    for mult in (1, 2, 4):
        w = relgen.JoinWorkload(f"narrow{mult}", mult * N_BASE // 2, mult * N_BASE, 1, 1)
        R, S = relgen.generate(w)
        for name in ALGS + ["NPHJ"]:
            if name == "NPHJ":
                f = jax.jit(functools.partial(join, algorithm="nphj"))
                us = time_fn(f, R, S)
            else:
                us = _run(R, S, name)
            emit(f"fig8/narrow_x{mult}/{name}", us, join_throughput(w.n_r, w.n_s, us))


def fig10_wide():
    """Fig. 10: wide joins (2 payloads per side)."""
    w = relgen.JoinWorkload("wide", N_BASE // 2, N_BASE, 2, 2)
    R, S = relgen.generate(w)
    base = None
    for name in ALGS:
        us = _run(R, S, name)
        if name == "PHJ-UM":
            base = us
        emit(f"fig10/{name}", us, join_throughput(w.n_r, w.n_s, us))
    if base:
        emit("fig10/PHJ-OM_vs_PHJ-UM", 0.0,
             f"speedup={base/_run(R, S, 'PHJ-OM'):.2f}x (paper: ~2.3x on GPU)")


def fig11_size_ratio():
    """Fig. 11: |R|/|S| sweep with |S| fixed."""
    n_s = 2 * N_BASE
    for ratio in (16, 4, 1):
        w = relgen.JoinWorkload(f"ratio{ratio}", n_s // ratio, n_s, 2, 2)
        R, S = relgen.generate(w)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-OM"):
            us = _run(R, S, name)
            emit(f"fig11/R_1over{ratio}/{name}", us, join_throughput(w.n_r, w.n_s, us))


def fig12_payload_cols():
    """Fig. 12: payload-column count sweep."""
    for cols in (1, 2, 4):
        w = relgen.JoinWorkload(f"cols{cols}", N_BASE, N_BASE, cols, cols)
        R, S = relgen.generate(w)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-OM"):
            us = _run(R, S, name)
            emit(f"fig12/{cols}cols/{name}", us, join_throughput(w.n_r, w.n_s, us))


def fig13_match_ratio():
    """Fig. 13: match-ratio sweep — *-OM wins only at high ratios."""
    for mr in (1.0, 0.5, 0.1):
        w = relgen.JoinWorkload(f"mr{mr}", N_BASE, N_BASE, 2, 2, match_ratio=mr)
        R, S = relgen.generate(w)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-UM", "SMJ-OM"):
            us = _run(R, S, name)
            emit(f"fig13/match{int(mr*100)}pct/{name}", us,
                 join_throughput(w.n_r, w.n_s, us))


def fig14_skew():
    """Fig. 14: Zipf FK skew — RADIX-PARTITION-based algorithms stay flat."""
    for z in (0.0, 1.05, 1.5):
        w = relgen.JoinWorkload(f"zipf{z}", N_BASE, N_BASE, 2, 2, zipf=z)
        R, S = relgen.generate(w)
        for name in ("PHJ-OM", "SMJ-UM", "SMJ-OM"):
            us = _run(R, S, name)
            emit(f"fig14/zipf{z}/{name}", us, join_throughput(w.n_r, w.n_s, us))


def fig15_dtypes():
    """Fig. 15: 4B vs 8B keys/payloads (needs x64, enabled by run.py)."""
    combos = [("int32", "int32"), ("int32", "int64"), ("int64", "int64")]
    for kd, pd in combos:
        w = relgen.JoinWorkload(f"dt{kd}{pd}", N_BASE // 2, N_BASE, 2, 2,
                                key_dtype=kd, payload_dtype=pd)
        R, S = relgen.generate(w)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-OM"):
            us = _run(R, S, name)
            emit(f"fig15/{kd[-2:]}Bk_{pd[-2:]}Bp/{name}", us,
                 join_throughput(w.n_r, w.n_s, us))


def table5_memory():
    """Table 5: peak memory, analytic model (Tables 1-2) per dtype combo."""
    for pat in ("gfur", "gftr"):
        for itemsize, tag in ((4, "4B"), (8, "8B")):
            b = peak_memory_bytes(pat, N_BASE, itemsize)
            emit(f"table5/{pat}/{tag}", 0.0, f"peak={b/1e6:.1f}MB")
    ordered = peak_memory_bytes("gftr", N_BASE, 4) <= peak_memory_bytes("gfur", N_BASE, 4)
    emit("table5/ordering", 0.0, f"gftr<=gfur: {ordered}")


def fig16_join_sequences():
    """Fig. 16: N-way star joins."""
    for n_joins in (2, 4, 8):
        fact, dims, fks, dks = relgen.generate_star(N_BASE, N_BASE // 4, n_joins)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-OM"):
            kw = by_name(name)
            f = jax.jit(functools.partial(
                join_sequence, fk_cols=fks, dim_keys=dks, **kw))
            us = time_fn(f, fact, dims)
            emit(f"fig16/{n_joins}joins/{name}", us,
                 f"{N_BASE / (us/1e6) / 1e6:.2f} Mrows/s")


def fig17_tpc():
    """Fig. 17: TPC-H/DS join extracts (Table 6), scaled."""
    for jid in ("J1", "J2", "J3", "J4", "J5"):
        # J5 is a 12.5x-expansion m:n self join — scale it down further so
        # the chunked expansion stays CPU-feasible.
        R, S, mode = relgen.generate_tpc(jid, scale=(1 / 2048 if jid == "J5" else 1 / 256))
        out_size = S.num_rows * (16 if mode == "mn" else 1)
        for name in ("PHJ-UM", "PHJ-OM", "SMJ-UM", "SMJ-OM"):
            us = _run(R, S, name, mode=mode, out_size=out_size)
            emit(f"fig17/{jid}/{name}", us, join_throughput(R.num_rows, S.num_rows, us))


def fig18_planner():
    """Fig. 18: decision-tree picks vs measured best."""
    cases = [
        JoinStats(N_BASE, N_BASE, 1, 1, 1.0, 0.0),
        JoinStats(N_BASE, N_BASE, 3, 3, 1.0, 0.0),
        JoinStats(N_BASE, N_BASE, 3, 3, 0.1, 0.0),
        JoinStats(N_BASE, N_BASE, 3, 3, 1.0, 1.5),
        JoinStats(N_BASE, N_BASE, 3, 3, 1.0, 0.0, 8, 8),
    ]
    for st in cases:
        alg, pat, why = choose_algorithm(st)
        pred = predict_join_time(st, alg, pat)
        emit(f"fig18/pick[{st.r_payload_cols}p,mr{st.match_ratio},z{st.zipf},{st.key_bytes}B]",
             pred["total"] * 1e6, f"{alg}-{pat} ({why[:40]})")

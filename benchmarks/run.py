"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

Runs either way:
    python benchmarks/run.py [section-prefix]
    python -m benchmarks.run [section-prefix]
    python -m benchmarks.run --list      # print section tags, run nothing

Machine-readable perf trajectories are written next to the CSV output
(cwd) whenever their sections run, so successive PRs can regress against
them: ``BENCH_groupby.json`` (``groupby/*``), ``BENCH_joins.json``
(``fig*``/``table*`` join sections), ``BENCH_groupjoin.json``
(``groupjoin/*`` fused-path sections) — each ``{name: us_per_call}``.
The serving trajectory ``BENCH_serve.json`` (warm p50/p99 + throughput +
degradation counters) is written by ``python -m repro.serve --chaos``,
not by this driver — see DESIGN.md §14.

Scale with REPRO_BENCH_SCALE (default 1.0 ~ 262k-row unit; the paper's GPU
runs use 2^27 rows — same code, larger constant)."""
import os
import sys
import time

# 8-byte key/payload experiments (paper §5.2.5) need x64 before jax init.
os.environ.setdefault("JAX_ENABLE_X64", "1")

# Script mode (`python benchmarks/run.py`) has no parent package, so the
# relative imports below must be absolute and the repo root importable.
# Both modes get src/ on the path so `repro` resolves without a PYTHONPATH
# export.
_repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_paths = [os.path.join(_repo, "src")]
if __package__ in (None, ""):
    _paths.insert(0, _repo)
for _p in _paths:
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    t0 = time.time()
    from benchmarks import (joins, groupby_bench, groupjoin_bench,
                            integration_bench, engine_bench)
    from benchmarks.common import ROWS

    sections = [
        ("fig1", joins.fig1_time_breakdown),
        ("table4+fig7", joins.table4_fig7_gather),
        ("fig8/9", joins.fig8_9_narrow),
        ("fig10", joins.fig10_wide),
        ("fig11", joins.fig11_size_ratio),
        ("fig12", joins.fig12_payload_cols),
        ("fig13", joins.fig13_match_ratio),
        ("fig14", joins.fig14_skew),
        ("fig15", joins.fig15_dtypes),
        ("table5", joins.table5_memory),
        ("fig16", joins.fig16_join_sequences),
        ("fig17", joins.fig17_tpc),
        ("fig18", joins.fig18_planner),
        ("groupjoin/fused", groupjoin_bench.fused_vs_unfused),
        ("groupjoin/engine", groupjoin_bench.engine_fusion),
        ("groupby/cardinality", groupby_bench.cardinality_sweep),
        ("groupby/skew", groupby_bench.skew_sweep),
        ("groupby/wide", groupby_bench.wide_payload),
        ("groupby/partition", groupby_bench.partition_sweep),
        ("moe_dispatch", integration_bench.moe_dispatch),
        ("feature_pipeline", integration_bench.feature_join_pipeline),
        ("kernels", integration_bench.kernel_vs_xla),
        ("engine/star", engine_bench.tpc_star_query),
        ("engine/topk", engine_bench.filtered_topk_query),
        ("engine/calibrate", engine_bench.calibration),
    ]
    args = sys.argv[1:]
    if "--list" in args:
        for tag, _ in sections:
            print(tag)
        return
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for tag, fn in sections:
        if only and not tag.startswith(only):
            continue
        print(f"# --- {tag} ---")
        fn()
    print(f"# total_wall_s,{time.time()-t0:.1f},{len(ROWS)} rows")

    # machine-readable perf trajectories, one file per operator family
    # ({name: us_per_call}); a file is written whenever any of its rows ran
    files = {
        "BENCH_groupby.json": lambda n: n.startswith("groupby"),
        "BENCH_joins.json": lambda n: n.startswith(("fig", "table")),
        "BENCH_groupjoin.json": lambda n: n.startswith("groupjoin"),
    }
    from benchmarks.common import FINGERPRINTS

    for fname, pred in files.items():
        rows = {name: us for name, us, _ in ROWS if pred(name)}
        # ride the structural fingerprints (primitive budget + peak live
        # bytes per plan) along with the timings they describe
        rows.update({k: v for k, v in FINGERPRINTS.items() if pred(k)})
        if rows:
            import json

            with open(fname, "w") as f:
                json.dump(rows, f, indent=2, sort_keys=True)
            print(f"# wrote {fname},{len(rows)},rows")


if __name__ == "__main__":
    main()

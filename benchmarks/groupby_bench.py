"""Grouped-aggregation benchmarks [extension-per-assigned-title]:
strategy x cardinality x skew, mirroring the join matrix."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Table, group_aggregate
from repro.obs import metrics

from .common import N_BASE, emit, fingerprint, time_fn


def cardinality_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for g in (64, 4096, 262144):
        keys = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash", "scatter"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=max(2 * g, 256), strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/G{g}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def skew_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for z, tag in ((0.0, "uniform"), (1.5, "zipf1.5")):
        if z:
            keys = jnp.asarray(((rng.zipf(z, n) - 1) % 4096).astype(np.int32))
        else:
            keys = jnp.asarray(rng.integers(0, 4096, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=8192, strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/{tag}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def wide_payload():
    """GFTR-style lazy per-column transform for multi-agg group-bys."""
    n = N_BASE
    rng = np.random.default_rng(2)
    cols = {"k": jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))}
    for j in range(4):
        cols[f"v{j}"] = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = Table(cols)
    aggs = {f"v{j}": op for j, op in zip(range(4), ("sum", "mean", "min", "max"))}
    for strat in ("sort", "partition_hash"):
        f = jax.jit(functools.partial(group_aggregate, key="k", aggs=aggs,
                                      num_groups=2048, strategy=strat))
        us = time_fn(f, t)
        emit(f"groupby/wide4/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def partition_sweep():
    """High-cardinality crossover: the partition-based algorithm vs sort vs
    partition_hash as group count approaches row count (DESIGN.md §8/§10).

    Each point gets measured wall times per strategy PLUS two trajectory
    ratios (sort time / partition time, >= 1 means partition wins):
    `speedup_vs_sort_measured` is what THIS container does with the
    sort-free rank-pipeline plan; `speedup_vs_sort_modeled` prices the
    paper's pass structure with the device profile (partition's passes
    scale with log2(partitions) at the partition-pass rate, sort's with the
    key width at the sort rate — the crossover the engine's chooser acts
    on). The partition rows also carry the modeled speedup at 8-byte keys,
    where the pass asymmetry is decisive."""
    from repro.core import predict_groupby_time

    n = 2 * N_BASE
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for g in (4096, max(n // 8, 2), max(n // 2, 2)):
        keys = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        distinct = int(jnp.sum(jnp.bincount(keys, length=g) > 0))
        # Interleaved median-of-7: these rows feed the recorded speedup
        # trajectory, and the strategies must share any transient machine
        # load — timing them seconds apart lets one contention window skew
        # a ratio in either direction.
        strats = ("sort", "partition", "partition_hash")
        fns = {}
        for strat in strats:
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=2 * distinct + 64, strategy=strat))
            jax.block_until_ready(f(t))  # compile + warm outside the timing
            fns[strat] = f
            fingerprint(f"groupby/partition/G{g}/{strat}", f, t)
        samples = {s: [] for s in strats}
        for _ in range(7):
            for strat in strats:
                t0 = time.perf_counter()
                jax.block_until_ready(fns[strat](t))
                samples[strat].append((time.perf_counter() - t0) * 1e6)
        us_by = {s: metrics.percentiles(v, (50,))["p50"]
                 for s, v in samples.items()}
        for strat in strats:
            us = us_by[strat]
            model_us = predict_groupby_time(n, 1, strat) * 1e6
            derived = f"model {model_us:.0f}us; {n/(us/1e6)/1e6:.1f} Mrows/s"
            # per-strategy residual (measured/modeled): the trajectory the
            # calibration loop's EWMAs track — see repro.obs.residuals
            emit(f"groupby/partition/G{g}/{strat}/residual", us / model_us,
                 f"measured {us:.0f}us / model {model_us:.0f}us")
            if strat == "partition":
                s8 = (predict_groupby_time(n, 1, "sort", key_bytes=8)
                      / predict_groupby_time(n, 1, "partition", key_bytes=8))
                derived += f"; model-vs-sort {s8:.2f}x (8B)"
            emit(f"groupby/partition/G{g}/{strat}", us, derived)
        s4 = (predict_groupby_time(n, 1, "sort")
              / predict_groupby_time(n, 1, "partition"))
        emit(f"groupby/partition/G{g}/speedup_vs_sort_measured",
             us_by["sort"] / us_by["partition"],
             "sort_us/partition_us; >=1 means partition wins")
        emit(f"groupby/partition/G{g}/speedup_vs_sort_modeled", s4,
             "predicted sort/partition at 4B keys (device profile)")

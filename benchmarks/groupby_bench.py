"""Grouped-aggregation benchmarks [extension-per-assigned-title]:
strategy x cardinality x skew, mirroring the join matrix."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Table, group_aggregate

from .common import N_BASE, emit, time_fn


def cardinality_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for g in (64, 4096, 262144):
        keys = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash", "scatter"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=max(2 * g, 256), strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/G{g}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def skew_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for z, tag in ((0.0, "uniform"), (1.5, "zipf1.5")):
        if z:
            keys = jnp.asarray(((rng.zipf(z, n) - 1) % 4096).astype(np.int32))
        else:
            keys = jnp.asarray(rng.integers(0, 4096, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=8192, strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/{tag}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def wide_payload():
    """GFTR-style lazy per-column transform for multi-agg group-bys."""
    n = N_BASE
    rng = np.random.default_rng(2)
    cols = {"k": jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))}
    for j in range(4):
        cols[f"v{j}"] = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = Table(cols)
    aggs = {f"v{j}": op for j, op in zip(range(4), ("sum", "mean", "min", "max"))}
    for strat in ("sort", "partition_hash"):
        f = jax.jit(functools.partial(group_aggregate, key="k", aggs=aggs,
                                      num_groups=2048, strategy=strat))
        us = time_fn(f, t)
        emit(f"groupby/wide4/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")

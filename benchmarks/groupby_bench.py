"""Grouped-aggregation benchmarks [extension-per-assigned-title]:
strategy x cardinality x skew, mirroring the join matrix."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Table, group_aggregate

from .common import N_BASE, emit, time_fn


def cardinality_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for g in (64, 4096, 262144):
        keys = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash", "scatter"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=max(2 * g, 256), strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/G{g}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def skew_sweep():
    n = 2 * N_BASE
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for z, tag in ((0.0, "uniform"), (1.5, "zipf1.5")):
        if z:
            keys = jnp.asarray(((rng.zipf(z, n) - 1) % 4096).astype(np.int32))
        else:
            keys = jnp.asarray(rng.integers(0, 4096, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        for strat in ("sort", "partition_hash"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=8192, strategy=strat))
            us = time_fn(f, t)
            emit(f"groupby/{tag}/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def wide_payload():
    """GFTR-style lazy per-column transform for multi-agg group-bys."""
    n = N_BASE
    rng = np.random.default_rng(2)
    cols = {"k": jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))}
    for j in range(4):
        cols[f"v{j}"] = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = Table(cols)
    aggs = {f"v{j}": op for j, op in zip(range(4), ("sum", "mean", "min", "max"))}
    for strat in ("sort", "partition_hash"):
        f = jax.jit(functools.partial(group_aggregate, key="k", aggs=aggs,
                                      num_groups=2048, strategy=strat))
        us = time_fn(f, t)
        emit(f"groupby/wide4/{strat}", us, f"{n/(us/1e6)/1e6:.1f} Mrows/s")


def partition_sweep():
    """High-cardinality crossover: the partition-based algorithm vs sort vs
    partition_hash as group count approaches row count (DESIGN.md §8).

    Two readings per point. Measured wall time is what THIS container does —
    XLA-on-CPU realizes every radix pass as a comparison sort, so the pass-
    count asymmetry that favors partition on GPU/TPU radix hardware is
    invisible and partition pays its blocked-aggregation overhead for
    nothing. The `model` field prices the paper's pass structure with the
    device profile (the same production-path/modeled-pass split as
    sort_pairs vs radix_sort_pairs): partition's passes scale with
    log2(partitions), sort's with the key width, which is the crossover the
    engine's chooser acts on. The partition rows carry the modeled speedup
    over sort at 4- and 8-byte keys."""
    from repro.core import predict_groupby_time

    n = 2 * N_BASE
    rng = np.random.default_rng(3)
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    for g in (4096, max(n // 8, 2), max(n // 2, 2)):
        keys = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
        t = Table({"k": keys, "v": vals})
        distinct = int(jnp.sum(jnp.bincount(keys, length=g) > 0))
        for strat in ("sort", "partition", "partition_hash"):
            f = jax.jit(functools.partial(
                group_aggregate, key="k", aggs={"v": "sum"},
                num_groups=2 * distinct + 64, strategy=strat))
            us = time_fn(f, t)
            model_us = predict_groupby_time(n, 1, strat) * 1e6
            derived = f"model {model_us:.0f}us; {n/(us/1e6)/1e6:.1f} Mrows/s"
            if strat == "partition":
                s4 = (predict_groupby_time(n, 1, "sort")
                      / predict_groupby_time(n, 1, "partition"))
                s8 = (predict_groupby_time(n, 1, "sort", key_bytes=8)
                      / predict_groupby_time(n, 1, "partition", key_bytes=8))
                derived += f"; model-vs-sort {s4:.2f}x (4B) {s8:.2f}x (8B)"
            emit(f"groupby/partition/G{g}/{strat}", us, derived)

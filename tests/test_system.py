"""End-to-end behaviour tests: training reduces loss across families, the
relational pipeline feeds training, launchers run, planner/memmodel hold."""
from __future__ import annotations

import pytest

from repro.core import JoinStats, choose_algorithm, choose_smj_pattern
from repro.core.memmodel import gftr_ledger, gfur_ledger, peak_memory
from repro.core.planner import PrimitiveProfile, predict_join_time
from repro.launch.train import main as train_main


@pytest.mark.parametrize("arch", ["olmo-1b", "xlstm-125m", "mixtral-8x7b"])
def test_training_reduces_loss(arch):
    report = train_main([
        "--arch", arch, "--steps", "30", "--batch", "4", "--seq", "32",
        "--lr", "3e-3",
    ])
    assert report.losses[-1] < report.losses[0] - 0.05


def test_train_resume_via_launcher(tmp_path):
    ck = str(tmp_path / "ck")
    train_main(["--arch", "olmo-1b", "--steps", "10", "--batch", "2",
                "--seq", "16", "--ckpt-dir", ck, "--ckpt-every", "5"])
    rep = train_main(["--arch", "olmo-1b", "--steps", "20", "--batch", "2",
                      "--seq", "16", "--ckpt-dir", ck, "--ckpt-every", "5"])
    assert rep.resumed_from == 10
    assert rep.steps_run == 10


def test_ml_pipeline_example():
    from examples.ml_pipeline import main as pipeline_main
    pipeline_main(["--steps", "40", "--batch", "2", "--seq", "32"])


def test_planner_decisions_follow_paper():
    # Fig. 18a
    assert choose_algorithm(JoinStats(1000, 1000, 1, 1))[:2] == ("phj", "gftr")
    assert choose_algorithm(JoinStats(1000, 1000, 3, 3, match_ratio=0.1))[:2] == ("phj", "gfur")
    assert choose_algorithm(JoinStats(1000, 1000, 3, 3, zipf=1.5))[:2] == ("phj", "gftr")
    assert choose_algorithm(JoinStats(1000, 1000, 3, 3, key_bytes=8))[:2] == ("phj", "gftr")
    # Fig. 18b (SMJ only)
    assert choose_smj_pattern(JoinStats(1000, 1000, 3, 3))[0] == "gftr"
    assert choose_smj_pattern(JoinStats(1000, 1000, 3, 3, payload_bytes=8))[0] == "gfur"


def test_memmodel_matches_paper_tables():
    """Table 1/2 peak cells and the paper's conclusion (GFTR <= GFUR)."""
    g1 = gfur_ledger(1.0, 1.0)
    g2 = gftr_ledger(1.0, 1.0)
    assert max(r.peak for r in g1) == 6.0
    assert max(r.peak for r in g2) == 6.0
    assert g1[1].peak == 6.0 and g2[1].peak == 5.0  # M_t + 5Mc vs M_t + 4Mc
    assert peak_memory("gftr") <= peak_memory("gfur")


def test_cost_model_reproduces_fig7_tradeoff():
    """On v5e constants, the profile model reproduces the paper's Fig. 7
    ordering: partition+clustered > sort+clustered > unclustered for wide
    high-match joins."""
    prof = PrimitiveProfile()
    n = 1 << 20
    t_u = prof.gather_cost(n, 4, clustered=False)
    t_sort = prof.sort_cost(n, 4, 4) + prof.gather_cost(n, 4, clustered=True)
    t_part = prof.partition_cost(n, 4, 4, 16) + prof.gather_cost(n, 4, clustered=True)
    assert t_part < t_sort < t_u


def test_predict_join_time_phases():
    st = JoinStats(1 << 20, 1 << 21, 2, 2)
    t = predict_join_time(st, "phj", "gftr")
    assert set(t) == {"transform", "find", "materialize", "total"}
    assert t["total"] > 0
    # GFUR's materialization must dominate GFTR's for wide high-match joins
    t_um = predict_join_time(st, "phj", "gfur")
    assert t_um["materialize"] > t["materialize"]

"""The static analyzer itself: primitive budgets through every sub-jaxpr
kind, the liveness watermark, the dtype contract, the Pallas kernel lint,
env-knob validation, and — most importantly — the negative space: tiny
deliberately-violating programs must each trip their specific
`ContractViolation` subclass, and `explain(verify=True)` must catch an
injected priced-vs-compiled divergence end to end."""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import (DtypePromotionViolation, FloatScatterViolation, GridAliasViolation,
                            MaterializationViolation, OperatorContract, PrimitiveBudget,
                            SortBudgetViolation, VmemBudgetViolation, audit_fn, budget_of,
                            count_sorts, kernel_lint)


# ---------------------------------------------------------------------------
# budget counting
# ---------------------------------------------------------------------------
def test_budget_counts_primitives():
    def fn(x):
        srt = jnp.sort(x)
        idx = jnp.argsort(x)  # lowers to sort as well
        gath = jnp.take(srt, idx)
        scat = jnp.zeros_like(x).at[idx].set(gath)
        sadd = jnp.zeros_like(x).at[idx].add(gath)
        return scat + sadd

    b = budget_of(fn, jnp.arange(16.0))
    assert b.sorts == 2
    assert b.gathers == 1
    assert b.scatters == 1
    assert b.scatter_adds == 1
    assert b.float_scatter_adds == 1  # float operand -> flagged as float


def test_budget_recurses_into_pjit_scan_cond_while():
    def fn(x):
        y = jax.jit(jnp.sort)(x)  # pjit body

        def body(c, t):
            return c + jnp.sort(t), t

        c, _ = jax.lax.scan(body, y, jnp.stack([x, x]))  # scan body
        c = jax.lax.cond(c.sum() > 0, jnp.sort, lambda a: a, c)  # branches
        return jax.lax.while_loop(
            lambda s: s.sum() > 1e9, lambda s: jnp.sort(s), c)  # while body

    b = budget_of(fn, jnp.arange(8.0))
    # one per nesting level; scan/while bodies count ONCE (static shape,
    # like the cost model prices them), cond counts each branch's content
    assert b.sorts == 4


def test_budget_add_sub_compose():
    a = PrimitiveBudget(sorts=2, gathers=3)
    b = PrimitiveBudget(sorts=1, gathers=1, scatters=5)
    assert (a + b).sorts == 3 and (a + b).scatters == 5
    assert (a - b).sorts == 1 and (a - b).gathers == 2


def test_count_sorts_accepts_fn_and_jaxpr():
    fn = lambda x: jnp.sort(x)  # noqa: E731
    assert count_sorts(fn, jnp.arange(8.0)) == 1
    closed = jax.make_jaxpr(fn)(jnp.arange(8.0))
    assert count_sorts(closed) == 1
    assert count_sorts(closed.jaxpr) == 1  # raw Jaxpr too (old helper API)


def test_pallas_call_counted_and_body_walked():
    from repro.kernels.histogram import histogram_pallas

    b = budget_of(functools.partial(histogram_pallas, num_bins=16),
                  jnp.arange(1024, dtype=jnp.int32) % 16)
    assert b.pallas_calls == 1


# ---------------------------------------------------------------------------
# liveness watermark
# ---------------------------------------------------------------------------
def test_liveness_peak_sees_large_intermediate():
    def fn(x):
        big = jnp.tile(x, 4096)  # 8 * 4096 * 4B = 128 KiB intermediate
        return big.sum()

    rep = audit_fn(fn, jnp.arange(8, dtype=jnp.float32))
    assert rep.peak_live_bytes >= 8 * 4096 * 4
    assert rep.out_bytes == 4  # scalar out


def test_liveness_peak_drops_dead_values():
    def fn(x):
        a = x * 2  # dead after b
        b = a + 1
        return b.sum()

    rep = audit_fn(fn, jnp.arange(1024, dtype=jnp.float32))
    # never more than ~3 arrays of x's size live at once
    assert rep.peak_live_bytes <= 3 * 1024 * 4 + 64


# ---------------------------------------------------------------------------
# negative space: each violation class fires on its minimal trigger
# ---------------------------------------------------------------------------
def test_sneaky_sort_trips_sort_budget():
    """A 'sort-free' contract over a plan that sneaks one in."""
    def sneaky(x):
        return jnp.take(x, jnp.argsort(x))  # a hidden sort

    rep = audit_fn(sneaky, jnp.arange(32, dtype=jnp.int32))
    contract = analysis.join_contract("phj")  # priced: zero sorts
    with pytest.raises(SortBudgetViolation):
        analysis.enforce(contract, rep)


def test_f64_promotion_trips_dtype_contract():
    jax.config.update("jax_enable_x64", True)
    try:
        def promotes(x):
            return x.astype(jnp.float64) * 2.0  # silent widening

        rep = audit_fn(promotes, jnp.arange(8, dtype=jnp.float32))
        assert rep.promotions
        with pytest.raises(DtypePromotionViolation):
            analysis.enforce(OperatorContract(name="int32-pipeline"), rep)

        # deliberate 64-bit inputs stay legal (8-byte key experiments)
        rep64 = audit_fn(lambda x: x * 2, jnp.arange(8, dtype=jnp.int64))
        assert not rep64.promotions
    finally:
        jax.config.update("jax_enable_x64", False)


def test_float_scatter_add_outside_approved_paths_trips():
    def accumulates(v):
        return jnp.zeros((8,), jnp.float32).at[v.astype(jnp.int32) % 8].add(v)

    rep = audit_fn(accumulates, jnp.arange(32, dtype=jnp.float32))
    contract = analysis.join_contract("phj")  # joins: no float accumulation
    with pytest.raises(FloatScatterViolation):
        analysis.enforce(contract, rep)


def test_over_vmem_block_spec_trips_lint():
    def big_block(x):
        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((8192, 1024), jnp.float32),
            grid=(2,),
            in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
            interpret=True,
        )(x)

    x = jnp.zeros((8192, 1024), jnp.float32)  # trace-only, never executed
    reports = kernel_lint.lint_fn(big_block, x)
    assert any(isinstance(v, VmemBudgetViolation)
               for r in reports for v in r.violations)
    with pytest.raises(VmemBudgetViolation):
        kernel_lint.enforce(reports)


def test_aliased_grid_output_trips_lint_unless_declared():
    def aliased(x):
        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),  # every step
            interpret=True,
        )(x)

    x = jnp.zeros((32, 128), jnp.float32)
    reports = kernel_lint.lint_fn(aliased, x)
    assert any(isinstance(v, GridAliasViolation)
               for r in reports for v in r.violations)
    # the same kernel with accumulation declared is a stated contract
    declared = kernel_lint.lint_fn(aliased, x, allow_output_revisit=True)
    assert not any(r.violations for r in declared)
    assert declared[0].aliased_output_blocks == 1


def test_materialization_bound_trips_on_fat_residency():
    def materializes(x):
        fat = jnp.tile(x, 8192)  # 32 MiB live off a 4 KiB input
        return fat.sum()

    rep = audit_fn(materializes, jnp.arange(1024, dtype=jnp.float32))
    contract = OperatorContract(name="fused", live_multiplier=4.0,
                                live_slack_bytes=1 << 20)
    with pytest.raises(MaterializationViolation):
        analysis.enforce(contract, rep)


# ---------------------------------------------------------------------------
# production kernels lint clean
# ---------------------------------------------------------------------------
def test_production_kernels_lint_clean():
    reports = analysis.lint_production_kernels()
    assert reports, "registry must cover the production kernels"
    for rep in reports:
        assert not rep.violations, (rep.name, rep.violations)
        assert rep.vmem_bytes <= rep.vmem_budget
    # histogram's sequential accumulation is exercised AND declared
    hist = [r for r in reports if r.name.startswith("histogram")]
    assert hist and hist[0].aliased_output_blocks >= 1


# ---------------------------------------------------------------------------
# env-knob validation (read-time, never frozen at import)
# ---------------------------------------------------------------------------
def test_partition_plan_impl_env_validated(monkeypatch):
    from repro.core import primitives as prim
    from repro.kernels import ops as kops

    monkeypatch.setenv("REPRO_PARTITION_PLAN_IMPL", "fancy")
    with pytest.raises(ValueError, match="pallas/xla"):
        kops.partition_plan_impl()
    with pytest.raises(ValueError, match="REPRO_PARTITION_PLAN_IMPL"):
        kops.PARTITION_PLAN_IMPL  # noqa: B018 - the legacy attribute too
    digits = jnp.arange(32, dtype=jnp.int32) % 4
    with pytest.raises(ValueError, match="REPRO_PARTITION_PLAN_IMPL"):
        prim.plan_partition_permutation(digits, 4)  # impl=None resolves env
    # explicit impl= bypasses the env entirely
    prim.plan_partition_permutation(digits, 4, impl="pallas")
    monkeypatch.setenv("REPRO_PARTITION_PLAN_IMPL", "xla")
    assert kops.partition_plan_impl() == "xla"


def test_pallas_interpret_env_validated(monkeypatch):
    from repro.kernels import common

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        common.default_interpret()
    with pytest.raises(ValueError, match="allowed"):
        common.resolve_interpret(None)
    # an explicit flag still wins without consulting the env
    assert common.resolve_interpret(True) is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "YES")  # case-insensitive
    assert common.default_interpret() is True


# ---------------------------------------------------------------------------
# engine integration: explain(verify=True) end to end
# ---------------------------------------------------------------------------
def _plan(rng):
    from repro.core import Table
    from repro.engine import Catalog, optimize, scan

    n_r, n_s = 256, 2048
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(rng.integers(0, 32, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    cat = Catalog({"R": R, "S": S})
    q = (scan("S").join(scan("R"), key="k")
         .group_by("g", rv="sum", sv="mean"))
    return optimize(q, cat, measure_profile=False,
                    force_join=("phj", "gftr"))


def test_explain_verify_renders_priced_vs_compiled(rng):
    plan = _plan(rng)
    text = plan.explain(verify=True)
    assert "priced[" in text and "compiled[" in text
    assert "peak-live=" in text
    assert "DIVERGED" not in text
    # plain explain stays cheap and unannotated
    assert "priced[" not in plan.explain()


def test_explain_verify_raises_on_injected_violation(rng, monkeypatch):
    """Flip the partition planner to its sort-based reference arm under a
    plan the model priced as sort-free: the compiled jaxpr now contains
    sorts the contract forbids, and verify must catch the divergence."""
    plan = _plan(rng)
    monkeypatch.setenv("REPRO_PARTITION_PLAN_IMPL", "xla")
    with pytest.raises(SortBudgetViolation):
        plan.explain(verify=True)


def test_executor_audit_attributes_node_budgets(rng):
    from repro.engine import executor

    plan = _plan(rng)
    plan_audit = executor.audit(plan)
    assert not plan_audit.violations
    kinds = {type(e.node).__name__: e for e in plan_audit.entries}
    assert "PJoin" in kinds and "PGroupBy" in kinds
    # the join's own budget is sort-free even though the subtree includes
    # scans; the group-by's own budget excludes the join's gathers
    assert kinds["PJoin"].own_budget.sorts == 0
    assert kinds["PGroupBy"].own_budget.gathers \
        <= kinds["PGroupBy"].report.budget.gathers
    d = plan_audit.as_dict()
    assert d["nodes"] and d["budget"]["sorts"] == 0

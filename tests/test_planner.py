"""Branch-complete planner tests (Fig. 18 decision trees, group-by
chooser, profile calibration) + statistics estimators against known
synthetic distributions from repro.data.relgen."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import JoinStats, choose_algorithm, choose_smj_pattern
from repro.core.groupby import choose_groupby_strategy
from repro.core.planner import PrimitiveProfile, predict_groupby_time, predict_join_time
from repro.data import relgen
from repro.engine import stats as est


# ---------------------------------------------------------------------------
# Fig. 18a: choose_algorithm — one case per branch, in source order
# ---------------------------------------------------------------------------
ALG_BRANCHES = [
    # (stats, expected (algorithm, pattern), rationale fragment)
    (JoinStats(1000, 1000, 1, 1, match_ratio=0.1), ("phj", "gfur"), "narrow + low match"),
    (JoinStats(1000, 1000, 1, 1), ("phj", "gftr"), "narrow"),
    (JoinStats(1000, 1000, 3, 3, match_ratio=0.1), ("phj", "gfur"), "wide + low match"),
    (JoinStats(1000, 1000, 3, 3, zipf=1.5), ("phj", "gftr"), "skewed FKs"),
    (JoinStats(1000, 1000, 3, 3, key_bytes=8), ("phj", "gftr"), "8-byte"),
    (JoinStats(1000, 1000, 3, 3, payload_bytes=8), ("phj", "gftr"), "8-byte"),
    (JoinStats(1000, 1000, 3, 3), ("phj", "gftr"), "high match ratio"),
]


@pytest.mark.parametrize("st,expected,fragment", ALG_BRANCHES)
def test_choose_algorithm_branches(st, expected, fragment):
    alg, pattern, why = choose_algorithm(st)
    assert (alg, pattern) == expected
    assert fragment in why


def test_choose_algorithm_branches_are_distinct():
    """Every branch is actually reachable: the rationales must differ
    across the non-duplicate cases."""
    whys = {choose_algorithm(st)[2] for st, _, _ in ALG_BRANCHES}
    assert len(whys) >= 5


# ---------------------------------------------------------------------------
# Fig. 18b: choose_smj_pattern — one case per branch
# ---------------------------------------------------------------------------
SMJ_BRANCHES = [
    (JoinStats(1000, 1000, 1, 1), "gfur", "narrow"),
    (JoinStats(1000, 1000, 3, 3, match_ratio=0.1), "gfur", "low match"),
    (JoinStats(1000, 1000, 3, 3, key_bytes=8), "gfur", "8-byte"),
    (JoinStats(1000, 1000, 3, 3, payload_bytes=8), "gfur", "8-byte"),
    (JoinStats(1000, 1000, 3, 3, zipf=1.5), "gfur", "skew"),
    (JoinStats(1000, 1000, 3, 3), "gftr", "wide + high match"),
]


@pytest.mark.parametrize("st,expected,fragment", SMJ_BRANCHES)
def test_choose_smj_pattern_branches(st, expected, fragment):
    pattern, why = choose_smj_pattern(st)
    assert pattern == expected
    assert fragment in why


# ---------------------------------------------------------------------------
# Group-by strategy chooser
# ---------------------------------------------------------------------------
def test_groupby_chooser_dense_domain_scatter():
    s, why = choose_groupby_strategy(100_000, 1000, key_min=0, key_max=1023)
    assert s == "scatter" and "dense" in why


def test_groupby_chooser_skew_partition_hash():
    s, why = choose_groupby_strategy(100_000, 50_000, zipf=1.5)
    assert s == "partition_hash" and "skew" in why


def test_groupby_chooser_duplication_partition_hash():
    # sparse domain (negative mins disqualify scatter), heavy duplication
    s, why = choose_groupby_strategy(100_000, 1000, key_min=-5, key_max=1 << 30)
    assert s == "partition_hash"


def test_groupby_chooser_high_cardinality_partition():
    """The paper's partition-based algorithm owns the high-cardinality,
    integer-key regime (radix passes scale with log(groups), not key width)."""
    s, why = choose_groupby_strategy(100_000, 60_000, key_min=0, key_max=1 << 30)
    assert s == "partition" and "cardinality" in why


def test_groupby_chooser_high_cardinality_float_keys_sort():
    """Non-integer keys cannot be radix-bucketed by value hash; sort stays
    the robust high-cardinality fallback."""
    s, why = choose_groupby_strategy(100_000, 60_000, key_min=0.0,
                                     key_max=1e9, integer_key=False)
    assert s == "sort"


# ---------------------------------------------------------------------------
# Group-by cost model
# ---------------------------------------------------------------------------
def test_predict_groupby_time_all_strategies_finite():
    prof = PrimitiveProfile()
    for strat in ("sort", "sort_pallas", "partition", "partition_hash",
                  "scatter"):
        t = predict_groupby_time(1 << 18, 2, strat, prof)
        assert np.isfinite(t) and t > 0, (strat, t)
    with pytest.raises(ValueError):
        predict_groupby_time(1000, 1, "nope")


def test_predict_groupby_partition_passes_scale_with_cardinality_not_key_width():
    """The modeled crossover: sort pays key-width-many radix passes (8 for
    int64), partition pays ceil(log2(partitions)/8) regardless of key width
    — so widening the key must widen sort's cost but not partition's."""
    prof = PrimitiveProfile()
    n = 1 << 20
    assert (predict_groupby_time(n, 2, "partition", prof, key_bytes=8)
            < predict_groupby_time(n, 2, "sort", prof, key_bytes=8))
    d_part = (predict_groupby_time(n, 2, "partition", prof, key_bytes=8)
              - predict_groupby_time(n, 2, "partition", prof, key_bytes=4))
    d_sort = (predict_groupby_time(n, 2, "sort", prof, key_bytes=8)
              - predict_groupby_time(n, 2, "sort", prof, key_bytes=4))
    assert d_part < d_sort  # only the pass structure, not one gather, widens


def test_predict_join_time_gftr_lazy_transform_is_single_gather():
    """One-permutation materialization: an extra gftr payload column is
    charged as exactly one n-row permutation gather + one clustered output
    gather — what the implementation now does — not the key+payload
    re-sort/re-partition the executable paths no longer run."""
    prof = PrimitiveProfile()
    st_ = JoinStats(1 << 18, 1 << 18, 3, 3)
    extra_col = (predict_join_time(st_, "phj", "gftr", prof)["materialize"]
                 - predict_join_time(
                     dataclasses.replace(st_, r_payload_cols=2),
                     "phj", "gftr", prof)["materialize"])
    gather = prof.gather_cost(st_.n_r, st_.payload_bytes, clustered=False)
    out_gather = prof.gather_cost(int(st_.n_s * st_.match_ratio),
                                  st_.payload_bytes, clustered=True)
    assert abs(extra_col - (gather + out_gather)) < 1e-12


# ---------------------------------------------------------------------------
# PrimitiveProfile.measure — calibration sanity
# ---------------------------------------------------------------------------
def test_primitive_profile_measure():
    prof = PrimitiveProfile.measure(n=1 << 14, iters=1, warmup=1)
    for f in dataclasses.fields(prof):
        v = getattr(prof, f.name)
        assert np.isfinite(v) and v > 0, (f.name, v)
    # model invariants the planner relies on
    assert prof.unclustered_penalty >= prof.clustered_penalty >= 1.0
    # the measured profile must price every phase of every pattern finitely
    st = JoinStats(1 << 16, 1 << 17, 2, 2)
    for pattern in ("gftr", "gfur"):
        t = predict_join_time(st, "phj", pattern, prof)
        assert t["total"] > 0 and np.isfinite(t["total"]), (pattern, t)


# ---------------------------------------------------------------------------
# Statistics estimators vs relgen ground truth
# ---------------------------------------------------------------------------
def test_distinct_estimate_unique_keys():
    w = relgen.JoinWorkload("d", 20_000, 1000, 1, 1)
    R, _ = relgen.generate(w)  # R keys are a permutation: exactly n distinct
    d = est.estimate_distinct(R["k"])
    assert abs(d - 20_000) / 20_000 < 0.12


def test_distinct_estimate_duplicated_keys():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 500, 50_000).astype(np.int32))
    d = est.estimate_distinct(keys)
    assert abs(d - 500) / 500 < 0.15


def test_distinct_estimate_float_column():
    """Floats must be bitcast before hashing — a value-cast would collapse
    [0, 1) floats to a single bucket."""
    rng = np.random.default_rng(3)
    col = jnp.asarray(rng.random(20_000).astype(np.float32))  # ~all distinct
    d = est.estimate_distinct(col)
    assert abs(d - 20_000) / 20_000 < 0.12


def test_match_ratio_estimate():
    for mr in (1.0, 0.5, 0.1):
        w = relgen.JoinWorkload("m", 30_000, 60_000, 1, 1, match_ratio=mr)
        R, S = relgen.generate(w)
        got = est.estimate_match_ratio(R["k"], S["k"])
        assert abs(got - mr) < 0.08, (mr, got)


def test_zipf_estimate_separates_skew_from_uniform():
    w_u = relgen.JoinWorkload("u", 30_000, 60_000, 1, 1, zipf=0.0)
    w_z = relgen.JoinWorkload("z", 30_000, 60_000, 1, 1, zipf=1.5)
    _, S_u = relgen.generate(w_u)
    _, S_z = relgen.generate(w_z)
    z_u = est.estimate_zipf(S_u["k"])
    z_z = est.estimate_zipf(S_z["k"])
    assert z_u < 0.5, z_u
    assert z_z > 0.8, z_z
    assert z_z > z_u + 0.5


def test_selectivity_estimate():
    rng = np.random.default_rng(1)
    col = jnp.asarray(rng.integers(0, 1000, 50_000).astype(np.int32))
    sel = est.estimate_selectivity(col, "<", 250)
    assert abs(sel - 0.25) < 0.05


def test_synthesize_join_stats_dtypes():
    js = est.synthesize_join_stats(
        n_build=100, n_probe=200, build_payload_cols=2, probe_payload_cols=1,
        match_ratio=0.5, zipf=1.2, key_dtype=jnp.int32,
        payload_dtypes=[jnp.int32, jnp.int64],
    )
    assert js.key_bytes == 4 and js.payload_bytes == 8
    assert js.n_r == 100 and js.n_s == 200 and js.wide
    # and the synthesized stats drive the decision tree directly
    assert choose_algorithm(js)[0] in ("phj", "smj", "nphj")

"""Join correctness: every (algorithm x pattern x mode) against a python
oracle, across match ratios, duplicates, skew, sizes, and dtypes.

The oracle is an exact dict-based join; results are compared as sorted
multisets of full rows, so ordering differences between implementations are
irrelevant but any wrong/missing/duplicated row fails."""
from __future__ import annotations

import collections

from hypothesis import given, settings, strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KEY_SENTINEL, Table, join, join_sequence

ALGS_PATTERNS = [
    ("smj", "gfur"), ("smj", "gftr"),
    ("phj", "gfur"), ("phj", "gftr"),
    ("nphj", "gftr"),
]


def oracle_join(rkeys, rpays, skeys, spays):
    """Exact inner PK-FK/m:n join -> sorted list of row tuples."""
    rmap = collections.defaultdict(list)
    for i, k in enumerate(rkeys):
        rmap[int(k)].append(i)
    rows = []
    for j, k in enumerate(skeys):
        for i in rmap.get(int(k), ()):
            rows.append((int(k),) + tuple(int(p[i]) for p in rpays)
                        + tuple(int(p[j]) for p in spays))
    return sorted(rows)


def result_rows(T, count, r_cols, s_cols):
    c = int(count)
    cols = [np.asarray(T["k"][:c])] + [np.asarray(T[n][:c]) for n in r_cols + s_cols]
    return sorted(zip(*[c_.tolist() for c_ in cols]))


def make_tables(rng, n_r, n_s, r_pay, s_pay, match_ratio=1.0, dup_build=False,
                dtype=np.int32):
    rkeys = rng.permutation(n_r).astype(dtype)
    if dup_build:
        rkeys = rng.integers(0, max(n_r // 4, 1), n_r).astype(dtype)
    if match_ratio < 1.0:
        drop = rng.random(n_r) < (1 - match_ratio)
        rkeys = np.where(drop, (np.arange(n_r) + 10 * n_r + 7).astype(dtype), rkeys)
    skeys = rng.integers(0, n_r, n_s).astype(dtype)
    R = {"k": jnp.asarray(rkeys)}
    rp = []
    for i in range(r_pay):
        R[f"r{i}"] = jnp.asarray(rng.integers(0, 1 << 20, n_r).astype(dtype))
        rp.append(np.asarray(R[f"r{i}"]))
    S = {"k": jnp.asarray(skeys)}
    sp = []
    for i in range(s_pay):
        S[f"s{i}"] = jnp.asarray(rng.integers(0, 1 << 20, n_s).astype(dtype))
        sp.append(np.asarray(S[f"s{i}"]))
    return Table(R), Table(S), rkeys, rp, skeys, sp


@pytest.mark.parametrize("alg,pattern", ALGS_PATTERNS)
@pytest.mark.parametrize("match_ratio", [1.0, 0.5, 0.0])
def test_pk_fk_join(alg, pattern, match_ratio, rng):
    R, S, rk, rp, sk, sp = make_tables(rng, 700, 1900, 2, 1, match_ratio)
    expected = oracle_join(rk, rp, sk, sp)
    T, count = join(R, S, algorithm=alg, pattern=pattern, out_size=1900)
    got = result_rows(T, count, ["r0", "r1"], ["s0"])
    assert int(count) == len(expected)
    assert got == expected
    # padding rows carry the sentinel
    assert bool((np.asarray(T["k"][int(count):]) == KEY_SENTINEL).all())


@pytest.mark.parametrize("alg", ["smj", "phj"])
@pytest.mark.parametrize("pattern", ["gfur", "gftr"])
def test_mn_join_with_duplicates(alg, pattern, rng):
    R, S, rk, rp, sk, sp = make_tables(rng, 400, 600, 1, 1, dup_build=True)
    expected = oracle_join(rk, rp, sk, sp)
    T, count = join(R, S, algorithm=alg, pattern=pattern, mode="mn",
                    out_size=len(expected) + 64)
    got = result_rows(T, count, ["r0"], ["s0"])
    assert int(count) == len(expected)
    assert got == expected


@pytest.mark.parametrize("alg,pattern", ALGS_PATTERNS)
def test_skewed_foreign_keys(alg, pattern, rng):
    n_r, n_s = 500, 3000
    rkeys = rng.permutation(n_r).astype(np.int32)
    ranks = rng.zipf(1.5, n_s).astype(np.int64)
    skeys = ((ranks - 1) % n_r).astype(np.int32)
    R = Table({"k": jnp.asarray(rkeys), "r0": jnp.asarray(rkeys * 3)})
    S = Table({"k": jnp.asarray(skeys), "s0": jnp.asarray(skeys * 7)})
    expected = oracle_join(rkeys, [np.asarray(R["r0"])], skeys, [np.asarray(S["s0"])])
    T, count = join(R, S, algorithm=alg, pattern=pattern, out_size=n_s)
    assert result_rows(T, count, ["r0"], ["s0"]) == expected


def test_out_size_truncation(rng):
    R, S, rk, rp, sk, sp = make_tables(rng, 100, 500, 1, 1)
    T, count = join(R, S, algorithm="phj", pattern="gftr", out_size=64)
    assert int(count) == 64  # clamped to capacity
    assert T["k"].shape[0] == 64


def test_empty_payloads_narrow_join(rng):
    """Narrow join (keys only on one side)."""
    R, S, rk, rp, sk, sp = make_tables(rng, 300, 800, 0, 1)
    expected = oracle_join(rk, [], sk, sp)
    T, count = join(R, S, algorithm="smj", pattern="gftr")
    assert result_rows(T, count, [], ["s0"]) == expected


def test_kernel_backed_paths_match_xla(rng):
    R, S, rk, rp, sk, sp = make_tables(rng, 800, 2200, 2, 1)
    expected = oracle_join(rk, rp, sk, sp)
    T1, c1 = join(R, S, algorithm="smj", pattern="gftr", find_impl="pallas")
    T2, c2 = join(R, S, algorithm="phj", pattern="gftr",
                  probe_impl="pallas", gather_impl="pallas")
    assert result_rows(T1, c1, ["r0", "r1"], ["s0"]) == expected
    assert result_rows(T2, c2, ["r0", "r1"], ["s0"]) == expected


@settings(max_examples=15, deadline=None)
@given(
    n_r=st.integers(8, 300),
    n_s=st.integers(8, 500),
    r_pay=st.integers(0, 3),
    seed=st.integers(0, 2**31 - 1),
    alg_pat=st.sampled_from([("smj", "gftr"), ("phj", "gftr"), ("phj", "gfur")]),
)
def test_join_property(n_r, n_s, r_pay, seed, alg_pat):
    """Property: for any sizes/payload counts/seed, join == oracle."""
    rng = np.random.default_rng(seed)
    alg, pattern = alg_pat
    R, S, rk, rp, sk, sp = make_tables(rng, n_r, n_s, r_pay, 1)
    expected = oracle_join(rk, rp, sk, sp)
    T, count = join(R, S, algorithm=alg, pattern=pattern, out_size=n_s)
    got = result_rows(T, count, [f"r{i}" for i in range(r_pay)], ["s0"])
    assert got == expected


def test_join_sequence_star(rng):
    n_f, n_d, N = 1000, 200, 3
    fact_cols = {"label": jnp.arange(n_f, dtype=jnp.int32)}
    fks = []
    for i in range(N):
        fact_cols[f"fk{i}"] = jnp.asarray(rng.integers(0, n_d, n_f).astype(np.int32))
        fks.append(f"fk{i}")
    fact = Table(fact_cols)
    dims, dks = [], []
    for i in range(N):
        dk = rng.permutation(n_d).astype(np.int32)
        dims.append(Table({f"k{i}": jnp.asarray(dk),
                           f"p{i}": jnp.asarray(dk * (i + 2))}))
        dks.append(f"k{i}")
    T, count = join_sequence(fact, dims, fk_cols=fks, dim_keys=dks,
                             algorithm="phj", pattern="gftr")
    assert int(count) == n_f
    lab = np.asarray(T["label"])
    for i in range(N):
        fk = np.asarray(fact_cols[f"fk{i}"])[lab]
        assert (np.asarray(T[f"p{i}"]) == fk * (i + 2)).all()


def test_phj_checked_escalates_on_duplicate_heavy_build(rng):
    """Build side with few distinct keys overflows the default blocks; the
    checked driver escalates fan-out / relies on big blocks and stays exact."""
    from repro.core import phj_join_checked, phj_overflowed

    rk = rng.integers(0, 8, 2000).astype(np.int32)
    sk = rng.integers(0, 8, 500).astype(np.int32)
    R = Table({"k": jnp.asarray(rk), "r0": jnp.arange(2000, dtype=jnp.int32)})
    S = Table({"k": jnp.asarray(sk), "s0": jnp.arange(500, dtype=jnp.int32)})
    ovf, _ = phj_overflowed(R)
    assert ovf
    expected = oracle_join(rk, [np.asarray(R["r0"])], sk, [np.asarray(S["s0"])])
    T, c = phj_join_checked(R, S, mode="mn", out_size=len(expected) + 64,
                            build_block=2048)
    assert result_rows(T, c, ["r0"], ["s0"]) == expected

"""Optimizer, checkpointing, fault-tolerant loop, grad compression."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import (dequantize_int8, ef_compress_decompress, init_ef_state,
                                    quantize_int8)
from repro.train import checkpoint as CKPT
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import AdamW, cosine_schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clipping_and_gnorm():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.asarray([3.0, 4.0, 0.0])}, state, params)
    assert abs(float(gnorm) - 5.0) < 1e-5


def test_adamw_master_weights_bf16():
    """bf16 params + f32 master track the f32-only trajectory closely."""
    opt32 = AdamW(lr=0.05, weight_decay=0.0, clip_norm=None, master_weights=False)
    optbf = AdamW(lr=0.05, weight_decay=0.0, clip_norm=None, master_weights=True)
    p32 = {"w": jnp.full((4,), 2.0, jnp.float32)}
    pbf = {"w": jnp.full((4,), 2.0, jnp.bfloat16)}
    s32, sbf = opt32.init(p32), optbf.init(pbf)
    for _ in range(100):
        p32, s32, _ = opt32.update({"w": 2 * p32["w"]}, s32, p32)
        pbf, sbf, _ = optbf.update({"w": 2 * pbf["w"].astype(jnp.float32)}, sbf, pbf)
    assert float(jnp.abs(sbf.master["w"] - p32["w"]).max()) < 5e-2


def test_cosine_schedule():
    f = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(f(jnp.int32(100))) - 0.1) < 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = ({"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.int32)}},
             jnp.int32(7))
    CKPT.save_checkpoint(tmp_path, 12, state, extra={"note": "x"})
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), state)
    restored, step, extra = CKPT.restore_checkpoint(tmp_path, like)
    assert step == 12 and extra == {"note": "x"}
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    state = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        CKPT.save_checkpoint(tmp_path, s, state, keep=2)
    assert CKPT.latest_step(tmp_path) == 5
    kept = sorted(d.name for d in tmp_path.iterdir() if d.name.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_survives_stale_pointer(tmp_path):
    state = {"w": jnp.zeros(3)}
    CKPT.save_checkpoint(tmp_path, 3, state)
    # simulate a crash that wrote LATEST but not the directory
    (tmp_path / "LATEST").write_text("step_00000099")
    assert CKPT.latest_step(tmp_path) == 3


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------
def _toy_step():
    opt = AdamW(lr=0.05, weight_decay=0.0, clip_norm=None)

    def step(params, opt_state, batch):
        def loss_fn(p):
            return jnp.mean((p["w"] * batch["x"] - batch["y"]) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(g, opt_state, params)
        return params, opt_state, {"loss": loss}

    return opt, jax.jit(step)


def _toy_data(step):
    rng = np.random.default_rng(step)
    x = jnp.asarray(rng.normal(size=8).astype(np.float32))
    return {"x": x, "y": 3.0 * x}


def test_loop_loss_drops_and_resume_equivalence(tmp_path):
    opt, step = _toy_step()
    params = {"w": jnp.zeros(8)}

    # one continuous 40-step run
    p1, s1, rep1 = train_loop(step, params, opt.init(params), _toy_data,
                              LoopConfig(total_steps=40), log=lambda *_: None)
    assert rep1.losses[-1] < rep1.losses[0]

    # 20 steps, "crash", resume to 40 — must match bitwise
    ck = str(tmp_path / "ck")
    p2, s2, _ = train_loop(step, params, opt.init(params), _toy_data,
                           LoopConfig(total_steps=20, ckpt_dir=ck, ckpt_every=10),
                           log=lambda *_: None)
    p3, s3, rep3 = train_loop(step, params, opt.init(params), _toy_data,
                              LoopConfig(total_steps=40, ckpt_dir=ck, ckpt_every=10),
                              log=lambda *_: None)
    assert rep3.resumed_from == 20
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p3["w"]))


def test_loop_straggler_detection(monkeypatch):
    opt, step = _toy_step()
    params = {"w": jnp.zeros(8)}
    import time as _t

    calls = {"n": 0}
    real_step = step

    def slow_step(p, s, b):
        calls["n"] += 1
        if calls["n"] == 15:
            _t.sleep(0.5)  # inject one straggler step
        return real_step(p, s, b)

    _, _, rep = train_loop(slow_step, params, opt.init(params), _toy_data,
                           LoopConfig(total_steps=20, straggler_factor=3.0),
                           log=lambda *_: None)
    assert any(s[0] == 15 for s in rep.straggler_steps)


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------
def test_int8_quantize_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=1000).astype(np.float32))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.51 + 1e-7


def test_ef_compression_converges():
    """EF-int8 SGD reaches the optimum a plain-quantized SGD cannot."""
    w = jnp.asarray([1.0, -2.0, 0.5])
    target = jnp.asarray([0.3, 0.7, -0.2])
    ef = init_ef_state({"w": w})
    lr = 0.05
    params = {"w": w}
    for _ in range(400):
        g = {"w": params["w"] - target}
        g_c, ef = ef_compress_decompress(g, ef)
        params = {"w": params["w"] - lr * g_c["w"]}
    assert float(jnp.abs(params["w"] - target).max()) < 1e-2


def test_async_checkpointer(tmp_path):
    import jax.numpy as jnp
    import numpy as np
    from repro.train.checkpoint import AsyncCheckpointer, restore_checkpoint

    ck = AsyncCheckpointer(tmp_path, keep=2)
    state = {"w": jnp.arange(10.0)}
    for s in (1, 2, 3):
        ck.save(s, jax.tree_util.tree_map(lambda x: x + s, state))
    ck.wait()
    restored, step, _ = restore_checkpoint(tmp_path, state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(10.0) + 3)

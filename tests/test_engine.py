"""Query-engine end-to-end tests: logical API -> engine-estimated stats ->
optimized physical plan -> jit execution, checked against NumPy references.

Payload sums use wraparound-aware comparison where relgen payloads (~2^31)
can overflow the device's int32 accumulators."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.table import KEY_SENTINEL, Table
from repro.data import relgen
from repro.engine import Catalog, optimize, output_columns, scan
from repro.engine import logical as L

# profile measurement is exercised in test_planner; keep these tests fast
OPT = dict(measure_profile=False)


def _rows(table: Table, count, cols):
    """Valid rows as a sorted list of tuples (order-insensitive compare)."""
    n = int(count)
    mat = [np.asarray(table[c])[:n] for c in cols]
    return sorted(zip(*[m.tolist() for m in mat]))


# ---------------------------------------------------------------------------
# Logical IR
# ---------------------------------------------------------------------------
def test_fluent_api_builds_expected_tree():
    q = (scan("fact")
         .join(scan("dim"), left_key="fk", right_key="k")
         .group_by("fk", p="sum")
         .order_by("p_sum", limit=5, descending=True))
    assert isinstance(q, L.OrderByLimit)
    assert isinstance(q.child, L.GroupBy)
    assert isinstance(q.child.child, L.Join)
    assert q.child.child.left_key == "fk"


def test_output_columns_validates_references():
    schemas = {"a": ("k", "x"), "b": ("k", "y")}
    q = scan("a").join(scan("b"), key="k")
    assert set(output_columns(q, schemas)) == {"k", "x", "y"}
    with pytest.raises(KeyError):
        output_columns(scan("a").filter("nope", "<", 1), schemas)
    with pytest.raises(ValueError):
        # payload collision: both sides carry x
        output_columns(scan("a").join(scan("a"), key="k"), schemas)
    with pytest.raises(ValueError):
        scan("a").filter("x", "~~", 3)


# ---------------------------------------------------------------------------
# Single join, estimated match ratio, vs NumPy reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("match_ratio", [1.0, 0.5])
def test_single_join_matches_numpy(match_ratio):
    w = relgen.JoinWorkload("t", 4000, 8000, 2, 1, match_ratio=match_ratio)
    R, S = relgen.generate(w)
    cat = Catalog({"R": R, "S": S})
    plan = optimize(scan("R").join(scan("S"), key="k"), cat, **OPT)
    T, count = plan.run()

    rmap = {int(k): (int(a), int(b))
            for k, a, b in zip(*map(np.asarray, (R["k"], R["r1"], R["r2"])))}
    ref = sorted(
        (int(k), *rmap[int(k)], int(s))
        for k, s in zip(np.asarray(S["k"]), np.asarray(S["s1"]))
        if int(k) in rmap
    )
    assert abs(int(count) - len(ref)) == 0
    assert _rows(T, count, ("k", "r1", "r2", "s1")) == ref
    # the planner sized the output from its own estimate, not worst case
    root = plan.root
    assert root.capacity >= len(ref)
    assert root.join_stats.match_ratio == pytest.approx(match_ratio, abs=0.1)


def test_join_alias_keeps_both_key_columns():
    fact, dims, _, _ = relgen.generate_star(2000, 500, 1)
    cat = Catalog({"fact": fact, "dim0": dims[0]})
    plan = optimize(
        scan("fact").join(scan("dim0"), left_key="fk0", right_key="k0"),
        cat, **OPT)
    T, count = plan.run()
    assert "fk0" in T.column_names and "k0" in T.column_names
    n = int(count)
    np.testing.assert_array_equal(np.asarray(T["fk0"])[:n],
                                  np.asarray(T["k0"])[:n])


# ---------------------------------------------------------------------------
# The acceptance query: two joins + grouped aggregation, under jit
# ---------------------------------------------------------------------------
def test_two_joins_plus_groupby_matches_numpy_under_jit():
    fact, dims, fks, dks = relgen.generate_star(20_000, 4000, 2,
                                                payloads_per_dim=1, seed=3)
    cat = Catalog({"fact": fact, "dim0": dims[0], "dim1": dims[1]})
    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1")
         .group_by("fk0", p1_0="sum", p0_0="count"))
    plan = optimize(q, cat, **OPT)

    # explain() reports per-operator algorithm, pattern, and predicted cost.
    # The outer GroupBy(Join) pair may legally fuse into a GroupJoin node
    # (PR 4); either shape must render its choice and cost.
    text = plan.explain()
    assert ("GroupBy[" in text) or ("GroupJoin[" in text)
    assert "Join[" in text
    assert ("-OM" in text) or ("-UM" in text)
    assert "cost=" in text and "why:" in text
    assert plan.total_cost > 0

    # executes under jax.jit (jit=True is the default path)
    G, cnt = plan.run(jit=True)

    # NumPy reference
    f = {k: np.asarray(v) for k, v in fact.columns.items()}
    d1 = {k: np.asarray(v) for k, v in dims[1].columns.items()}
    p1_of = dict(zip(d1["k1"].tolist(), d1["p1_0"].tolist()))
    ref_sum, ref_cnt = {}, {}
    for k, fk1 in zip(f["fk0"].tolist(), f["fk1"].tolist()):
        ref_sum[k] = ref_sum.get(k, 0) + p1_of[fk1]
        ref_cnt[k] = ref_cnt.get(k, 0) + 1
    assert int(cnt) == len(ref_sum)

    ks = np.asarray(G["fk0"])
    sums = np.asarray(G["p1_0_sum"])
    cnts = np.asarray(G["p0_0_count"])
    seen = 0
    for i in range(len(ks)):
        k = int(ks[i])
        if k == KEY_SENTINEL:
            continue
        seen += 1
        assert (int(sums[i]) - ref_sum[k]) % (1 << 32) == 0, k  # int32 wrap
        assert int(cnts[i]) == ref_cnt[k], k
    assert seen == len(ref_sum)


def test_plan_reuse_across_same_shape_tables():
    """One optimized plan runs over fresh same-shape tables (serving reuse)."""
    w = relgen.JoinWorkload("t", 2000, 4000, 1, 1)
    R1, S1 = relgen.generate(w)
    R2, S2 = relgen.generate(relgen.JoinWorkload("t", 2000, 4000, 1, 1, seed=9))
    cat = Catalog({"R": R1, "S": S1})
    plan = optimize(scan("R").join(scan("S"), key="k"), cat, **OPT)
    _, c1 = plan.run()
    _, c2 = plan.run({"R": R2, "S": S2})
    assert int(c1) == 4000 and int(c2) == 4000


# ---------------------------------------------------------------------------
# Filter, project, order-by-limit through the executor
# ---------------------------------------------------------------------------
def test_filter_then_join_matches_numpy():
    w = relgen.JoinWorkload("t", 3000, 6000, 1, 1)
    R, S = relgen.generate(w)
    thresh = int(np.median(np.asarray(S["s1"])))
    cat = Catalog({"R": R, "S": S})
    q = scan("S").filter("s1", "<", thresh).join(scan("R"), key="k")
    plan = optimize(q, cat, **OPT)
    T, count = plan.run()

    rmap = dict(zip(np.asarray(R["k"]).tolist(), np.asarray(R["r1"]).tolist()))
    ref = sorted(
        (int(k), int(s), rmap[int(k)])
        for k, s in zip(np.asarray(S["k"]), np.asarray(S["s1"]))
        if int(s) < thresh and int(k) in rmap
    )
    assert _rows(T, count, ("k", "s1", "r1")) == ref
    # the filter's capacity came from the sampled selectivity, not |S|
    assert plan.root.probe.capacity < S.num_rows


def test_project_and_order_by_limit():
    rng = np.random.default_rng(5)
    vals = rng.permutation(1000).astype(np.int32)
    t = Table({"k": jnp.arange(1000, dtype=jnp.int32), "v": jnp.asarray(vals),
               "w": jnp.zeros(1000, jnp.int32)})
    cat = Catalog({"t": t})
    q = scan("t").project("k", "v").order_by("v", limit=10, descending=True)
    plan = optimize(q, cat, **OPT)
    T, count = plan.run()
    assert int(count) == 10
    assert set(T.column_names) == {"k", "v"}
    got = np.asarray(T["v"])[:10]
    np.testing.assert_array_equal(got, np.sort(vals)[::-1][:10])


def test_filter_on_derived_column_keeps_all_survivors():
    """Selectivity of a derived (aggregate) column cannot be sampled; the
    capacity must not shrink, or survivors would be silently dropped."""
    rng = np.random.default_rng(11)
    t = Table({"k": jnp.asarray(rng.integers(0, 300, 5000).astype(np.int32)),
               "v": jnp.ones(5000, jnp.float32)})
    cat = Catalog({"t": t})
    # every group sum is positive -> every group survives the filter
    q = scan("t").group_by("k", v="sum").filter("v_sum", ">", 0.0)
    plan = optimize(q, cat, **OPT)
    _, count = plan.run()
    assert int(count) == len(set(np.asarray(t["k"]).tolist()))


def test_auto_join_with_duplicate_build_keys_uses_mn():
    """~10% duplicated keys on the smaller side: a sketch would still call
    it 'unique' and lose the duplicate matches through the pk_fk path; the
    exact check must route this to m:n and keep every match."""
    rng = np.random.default_rng(13)
    keys = np.arange(900, dtype=np.int32)
    keys = np.concatenate([keys, keys[:100]])  # 10% duplicates
    rng.shuffle(keys)
    R = Table({"k": jnp.asarray(keys),
               "r": jnp.asarray(np.arange(1000, dtype=np.int32))})
    skeys = rng.integers(0, 900, 3000).astype(np.int32)
    S = Table({"k": jnp.asarray(skeys),
               "s": jnp.asarray(np.arange(3000, dtype=np.int32))})
    cat = Catalog({"R": R, "S": S})
    plan = optimize(scan("R").join(scan("S"), key="k"), cat, safety=2.0, **OPT)
    assert plan.root.mode == "mn"
    _, count = plan.run()
    counts_r = np.bincount(keys, minlength=900)
    ref_n = int(sum(counts_r[k] for k in skeys))
    assert int(count) == ref_n


def test_run_caches_compiled_plan():
    w = relgen.JoinWorkload("t", 1000, 2000, 1, 1)
    R, S = relgen.generate(w)
    cat = Catalog({"R": R, "S": S})
    plan = optimize(scan("R").join(scan("S"), key="k"), cat, **OPT)
    assert plan.compiled is None
    plan.run()
    first = plan.compiled
    assert first is not None
    plan.run()
    assert plan.compiled is first  # no re-trace on repeated runs


def test_mn_join_matches_numpy():
    rng = np.random.default_rng(7)
    ka = rng.integers(0, 50, 400).astype(np.int32)
    kb = rng.integers(0, 50, 600).astype(np.int32)
    A = Table({"k": jnp.asarray(ka), "a": jnp.asarray(np.arange(400, dtype=np.int32))})
    B = Table({"k": jnp.asarray(kb), "b": jnp.asarray(np.arange(600, dtype=np.int32))})
    cat = Catalog({"A": A, "B": B})
    plan = optimize(scan("A").join(scan("B"), key="k", mode="mn"), cat,
                    safety=2.0, **OPT)
    assert plan.root.mode == "mn"
    T, count = plan.run()
    ref = sorted((int(k), int(a), int(b))
                 for k, a in zip(ka, range(400))
                 for k2, b in zip(kb, range(600)) if k == k2)
    assert _rows(T, count, ("k", "a", "b")) == ref


def test_scatter_groupby_composes_with_downstream_ops():
    """Scatter output must be a dense prefix like the other strategies:
    with holes in the key domain (only even keys), a downstream top-k must
    still see every real group, not the first `count` domain slots."""
    keys = np.repeat(np.arange(0, 64, 2, dtype=np.int32), 4)  # evens only
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(keys.astype(np.float32))})
    cat = Catalog({"t": t})
    plan = optimize(
        scan("t").group_by("k", v="sum").order_by("v_sum", limit=5,
                                                  descending=True),
        cat, **OPT)
    assert plan.root.child.strategy == "scatter"
    T, count = plan.run()
    assert int(count) == 5
    # ground truth: largest keys have the largest sums (sum = 4*k)
    np.testing.assert_array_equal(np.asarray(T["k"])[:5], [62, 60, 58, 56, 54])
    np.testing.assert_array_equal(np.asarray(T["v_sum"])[:5],
                                  [248.0, 240.0, 232.0, 224.0, 216.0])


def test_order_by_descending_int_min_overflow_safe():
    """Arithmetic negation wraps INT32_MIN back onto itself; the executor
    must not return the column minimum as the top-1."""
    vals = np.array([5, -2147483648, 17, 3], dtype=np.int32)
    t = Table({"k": jnp.arange(4, dtype=jnp.int32), "v": jnp.asarray(vals)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").order_by("v", limit=2, descending=True), cat, **OPT)
    T, count = plan.run()
    np.testing.assert_array_equal(np.asarray(T["v"])[:2], [17, 5])


def test_mn_join_correlated_multiplicity_not_truncated():
    """A heavy-hitter key breaks the independence cardinality estimate by
    orders of magnitude; the exact base-column estimator must size the
    capacity so no matches are dropped."""
    rng = np.random.default_rng(17)
    bkeys = np.concatenate([np.arange(100, dtype=np.int32),
                            np.zeros(400, dtype=np.int32)])  # key 0: 401 rows
    rng.shuffle(bkeys)
    pkeys = np.zeros(200, dtype=np.int32)  # every probe row hits key 0
    A = Table({"k": jnp.asarray(bkeys), "a": jnp.arange(500, dtype=jnp.int32)})
    B = Table({"k": jnp.asarray(pkeys), "b": jnp.arange(200, dtype=jnp.int32)})
    cat = Catalog({"A": A, "B": B})
    plan = optimize(scan("A").join(scan("B"), key="k"), cat, **OPT)
    assert plan.root.mode == "mn"
    ref_n = 200 * 401
    assert plan.root.capacity >= ref_n  # independence estimate would give ~24k
    _, count = plan.run()
    assert int(count) == ref_n


def test_groupby_build_side_keeps_full_match_ratio():
    """A GroupBy shrinks rows but keeps every key value: the retention
    scaling must use the distinct-count ratio, not the row ratio, or the
    join capacity collapses by rows/groups and truncates the output."""
    rng = np.random.default_rng(19)
    detail_keys = np.repeat(np.arange(2000, dtype=np.int32), 10)  # 10 rows/key
    rng.shuffle(detail_keys)
    detail = Table({"k": jnp.asarray(detail_keys),
                    "v": jnp.ones(20_000, jnp.float32)})
    probe_keys = rng.integers(0, 2000, 30_000).astype(np.int32)
    probe = Table({"k": jnp.asarray(probe_keys),
                   "p": jnp.arange(30_000, dtype=jnp.int32)})
    cat = Catalog({"detail": detail, "probe": probe})
    q = scan("detail").group_by("k", v="sum").join(scan("probe"), key="k")
    plan = optimize(q, cat, **OPT)
    _, count = plan.run()
    assert int(count) == 30_000  # every probe row matches a group


def test_groupby_partition_guarded_by_provable_multiplicity():
    """A single heavy key hides from the sampled zipf/distinct sketches, but
    the plain partition path would silently drop its block overhang — the
    planner must demand the exact max-multiplicity proof (like the m:n PHJ
    guard) and fall back to the always-exact sort."""
    rng = np.random.default_rng(11)
    keys = np.concatenate([np.arange(18_000, dtype=np.int64) * 97 % (1 << 30),
                           np.full(2_000, 5, np.int64)]).astype(np.int32)
    rng.shuffle(keys)
    t = Table({"k": jnp.asarray(keys), "v": jnp.ones(keys.size, jnp.float32)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").group_by("k", v="sum"), cat, **OPT)
    assert plan.root.strategy == "sort", plan.root.rationale
    assert "multiplicity" in plan.root.rationale
    _, count = plan.run()
    assert int(count) == len(set(keys.tolist()))


def test_groupby_partition_block_scales_with_proven_multiplicity():
    """A provable multiplicity within the safety bound keeps the partition
    strategy but scales the padded block: m duplicates of a key co-hash, so
    the executor must run with row_block >= PARTITION_ROW_BLOCK * m for the
    overflow tail to stay negligible — and the result must be exact."""
    from repro.core.groupby import PARTITION_ROW_BLOCK

    rng = np.random.default_rng(5)
    base = (rng.permutation(3000).astype(np.int64) * 1315423911 % (1 << 30))
    keys = np.repeat(base, 6).astype(np.int32)  # exact multiplicity 6
    rng.shuffle(keys)
    t = Table({"k": jnp.asarray(keys), "v": jnp.ones(keys.size, jnp.float32)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").group_by("k", v="sum"), cat, **OPT)
    assert plan.root.strategy == "partition", plan.root.rationale
    kw = dict(plan.root.agg_kw)
    assert kw.get("row_block") == PARTITION_ROW_BLOCK * 8  # next pow2 of 6
    _, count = plan.run()
    assert int(count) == len(set(keys.tolist()))


def test_groupby_float_keys_never_scatter():
    """Float keys would be int-floored by the scatter accumulator, merging
    distinct groups; the planner must route them to a sort-based strategy."""
    rng = np.random.default_rng(23)
    fkeys = (rng.integers(0, 500, 20_000).astype(np.float32) / 50.0)  # [0,10)
    t = Table({"k": jnp.asarray(fkeys), "v": jnp.ones(20_000, jnp.float32)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").group_by("k", v="sum"), cat, **OPT)
    assert plan.root.strategy != "scatter"
    _, count = plan.run()
    assert int(count) == len(set(fkeys.tolist()))


def test_correlated_filter_and_join_not_truncated():
    """A probe filter perfectly correlated with match likelihood: base
    match ratio (0.1) x filter selectivity (0.1) would size the capacity
    100x too small; predicate pushdown into the match-ratio sample must
    recover the post-filter ratio (~1.0)."""
    rng = np.random.default_rng(29)
    bkeys = np.arange(1000, dtype=np.int32)
    probe_keys = rng.integers(0, 10_000, 50_000).astype(np.int32)
    R = Table({"k": jnp.asarray(bkeys), "r": jnp.asarray(bkeys * 2)})
    S = Table({"k": jnp.asarray(probe_keys),
               "s": jnp.arange(50_000, dtype=jnp.int32)})
    cat = Catalog({"R": R, "S": S})
    q = scan("S").filter("k", "<", 1000).join(scan("R"), key="k")
    plan = optimize(q, cat, **OPT)
    ref_n = int(np.sum(probe_keys < 1000))
    assert plan.root.capacity >= ref_n
    _, count = plan.run()
    assert int(count) == ref_n


def test_stacked_correlated_filters_not_truncated():
    """Two filters selecting the SAME rows: joint sampling must not
    multiply their selectivities (0.25 vs 0.5)."""
    vals = np.arange(10_000, dtype=np.int32)
    t = Table({"k": jnp.asarray(vals), "v": jnp.asarray(vals)})
    cat = Catalog({"t": t})
    q = scan("t").filter("k", "<", 5000).filter("v", "<", 5000)
    plan = optimize(q, cat, **OPT)
    assert plan.root.capacity >= 5000
    _, count = plan.run()
    assert int(count) == 5000


def test_join_alias_origin_does_not_fake_uniqueness():
    """After a pk_fk join, the build-key alias holds duplicated probe
    values; its origin must point at the probe base column, or a later
    join 'proves' it unique and drops duplicate matches via pk_fk."""
    rng = np.random.default_rng(31)
    fact = Table({"fk": jnp.asarray(rng.integers(0, 100, 1000).astype(np.int32)),
                  "f": jnp.arange(1000, dtype=jnp.int32)})
    dim = Table({"kd": jnp.arange(100, dtype=jnp.int32),
                 "d": jnp.arange(100, dtype=jnp.int32) * 3})
    # T: 2 rows per key -> the second join must expand, not dedupe
    tkeys = np.repeat(np.arange(100, dtype=np.int32), 2)
    T = Table({"kt": jnp.asarray(tkeys), "t": jnp.arange(200, dtype=jnp.int32)})
    cat = Catalog({"fact": fact, "dim": dim, "T": T})
    # the filter breaks join-tree flattening, forcing the outer join to see
    # the intermediate as one side
    q = (scan("fact")
         .join(scan("dim"), left_key="fk", right_key="kd")
         .filter("f", ">=", 0)
         .join(scan("T"), left_key="kd", right_key="kt"))
    plan = optimize(q, cat, safety=2.0, **OPT)
    _, count = plan.run()
    assert int(count) == 2000  # 1000 fact rows x 2 T rows per key


def test_filtered_duplicated_keys_groupby_keeps_all_groups():
    """Filter keeps ~10% of rows but ~every key survives (each key has
    ~100 rows): the group capacity must not shrink by the selectivity."""
    rng = np.random.default_rng(37)
    keys = rng.integers(0, 1000, 100_000).astype(np.int32) * 1000  # sparse
    sel_col = rng.integers(0, 10, 100_000).astype(np.int32)
    t = Table({"k": jnp.asarray(keys), "f": jnp.asarray(sel_col),
               "v": jnp.ones(100_000, jnp.float32)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").filter("f", "==", 3).group_by("k", v="sum"),
                    cat, **OPT)
    _, count = plan.run()
    ref = len(set(keys[sel_col == 3].tolist()))
    assert int(count) == ref


def test_filter_after_groupby_under_skew_not_truncated():
    """Group-by reshapes the row distribution: a base-row sample says 10%
    (heavy key 0 dominates rows) but ~all GROUPS pass the filter; the
    capacity must not shrink from the wrong-weighted sample."""
    keys = np.concatenate([np.zeros(9000, dtype=np.int32),
                           np.arange(1, 1000, dtype=np.int32)])
    t = Table({"k": jnp.asarray(keys), "v": jnp.ones(keys.size, jnp.float32)})
    cat = Catalog({"t": t})
    plan = optimize(scan("t").group_by("k", v="sum").filter("k", ">=", 1),
                    cat, **OPT)
    _, count = plan.run()
    assert int(count) == 999


def test_mn_join_with_correlated_filter_not_truncated():
    """A filter that selects exactly the heavy-multiplicity rows: uniform
    retention scaling of the exact m:n count would be 10x short; the
    predicate must be pushed into the exact count."""
    a_keys = np.concatenate([np.arange(1, 10_000 - 999, dtype=np.int32),
                             np.zeros(1000, dtype=np.int32)])
    flag = (a_keys == 0).astype(np.int32)
    A = Table({"k": jnp.asarray(a_keys), "flag": jnp.asarray(flag),
               "a": jnp.arange(a_keys.size, dtype=jnp.int32)})
    b_keys = np.zeros(1000, dtype=np.int32)
    B = Table({"k": jnp.asarray(b_keys), "b": jnp.arange(1000, dtype=jnp.int32)})
    cat = Catalog({"A": A, "B": B})
    q = scan("A").filter("flag", "==", 1).join(scan("B"), key="k", mode="mn")
    plan = optimize(q, cat, **OPT)
    ref_n = 1000 * 1000
    assert plan.root.capacity >= ref_n
    _, count = plan.run()
    assert int(count) == ref_n


def test_chained_mn_joins_account_for_fanout():
    """The second m:n join's build side is a fanned-out intermediate: base
    -table counts undercount it, so the bound must come from the other
    side's multiplicity (or worst case), not the base tables."""
    A = Table({"k": jnp.asarray(np.array([0] * 5 + [1, 2, 3, 4, 5], np.int32)),
               "a": jnp.arange(10, dtype=jnp.int32)})
    B = Table({"k": jnp.asarray(np.array([0] * 4 + [1, 2, 3, 4, 5, 6], np.int32)),
               "b": jnp.arange(10, dtype=jnp.int32)})
    C = Table({"k": jnp.asarray(np.array([0] * 3 + [1, 2], np.int32)),
               "c": jnp.arange(5, dtype=jnp.int32)})
    cat = Catalog({"A": A, "B": B, "C": C})
    q = (scan("A").join(scan("B"), key="k", mode="mn")
         .filter("a", ">=", 0)  # breaks flattening: C joins the intermediate
         .join(scan("C"), key="k", mode="mn"))
    plan = optimize(q, cat, **OPT)
    ka = np.array([0] * 5 + [1, 2, 3, 4, 5])
    kb = np.array([0] * 4 + [1, 2, 3, 4, 5, 6])
    kc = np.array([0] * 3 + [1, 2])
    ref_n = sum(int((ka == k).sum() * (kb == k).sum() * (kc == k).sum())
                for k in range(7))
    _, count = plan.run()
    assert int(count) == ref_n


def test_register_invalidates_mn_cardinality_cache():
    """Re-registering a table must drop its cached m:n counts, or a plan
    over the new data reuses stale (smaller) capacities."""
    A1 = Table({"k": jnp.zeros(10, jnp.int32), "a": jnp.arange(10, dtype=jnp.int32)})
    B = Table({"k": jnp.zeros(10, jnp.int32), "b": jnp.arange(10, dtype=jnp.int32)})
    cat = Catalog({"A": A1, "B": B})
    q = scan("A").join(scan("B"), key="k", mode="mn")
    p1 = optimize(q, cat, **OPT)
    assert p1.root.capacity >= 100
    A2 = Table({"k": jnp.zeros(40, jnp.int32), "a": jnp.arange(40, dtype=jnp.int32)})
    cat.register("A", A2)
    p2 = optimize(q, cat, **OPT)
    assert p2.root.capacity >= 400  # stale cache would keep ~100
    _, count = p2.run()
    assert int(count) == 400


def test_catalog_memoizes_match_ratio():
    fact, dims, _, _ = relgen.generate_star(5000, 1000, 2)
    cat = Catalog({"fact": fact, "dim0": dims[0], "dim1": dims[1]})
    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1"))
    optimize(q, cat, **OPT)
    # one estimate per distinct base-column pair, despite the greedy loop
    # and _make_join re-asking
    assert len(cat._mr) == 2, sorted(cat._mr)
    optimize(q, cat, **OPT)  # re-planning reuses every pair estimate
    assert len(cat._mr) == 2


def test_lazy_stats_skip_payload_columns():
    """Only columns the plan consults (keys) get sketched; wide-table
    payload columns must not pay for distinct/zipf sketches."""
    fact, dims, _, _ = relgen.generate_star(5000, 1000, 1, payloads_per_dim=3)
    cat = Catalog({"fact": fact, "dim0": dims[0]})
    optimize(scan("fact").join(scan("dim0"), left_key="fk0", right_key="k0"),
             cat, **OPT)
    sketched = {c for _, c in cat._col_stats}
    assert "fk0" in sketched or "k0" in sketched
    assert not {"p0_0", "p0_1", "p0_2", "payload"} & sketched, sketched


# ---------------------------------------------------------------------------
# Optimizer decisions
# ---------------------------------------------------------------------------
def test_greedy_join_order_puts_selective_join_first():
    """Dim0 joins away 90% of the fact rows; the optimizer must schedule it
    before the non-selective dim1 join."""
    n_fact, n_dim = 20_000, 2000
    fact, dims, fks, dks = relgen.generate_star(n_fact, n_dim, 2, seed=1)
    # make dim0 selective: keep only 10% of its keys
    d0 = dims[0].head(n_dim // 10)
    cat = Catalog({"fact": fact, "dim0": d0, "dim1": dims[1]})
    q = (scan("fact")
         .join(scan("dim1"), left_key="fk1", right_key="k1")  # user: bad order
         .join(scan("dim0"), left_key="fk0", right_key="k0"))
    plan = optimize(q, cat, **OPT)
    # inner (first-executed) join must be the selective dim0 one
    inner = plan.root.probe if hasattr(plan.root, "probe") else None
    assert inner is not None
    assert plan.root.build.table == "dim1"  # outer joins the big dim last
    assert inner.build.table == "dim0"
    # and the result is still correct
    T, count = plan.run()
    f = {k: np.asarray(v) for k, v in fact.columns.items()}
    keep = set(np.asarray(d0["k0"]).tolist())
    ref_n = sum(1 for x in f["fk0"].tolist() if x in keep)
    assert int(count) == ref_n


def test_forced_baseline_overrides_choice():
    w = relgen.JoinWorkload("t", 2000, 4000, 2, 2)
    R, S = relgen.generate(w)
    cat = Catalog({"R": R, "S": S})
    q = scan("R").join(scan("S"), key="k")
    planned = optimize(q, cat, **OPT)
    forced = optimize(q, cat, force_join=("smj", "gfur"), **OPT)
    assert forced.root.algorithm == "smj" and forced.root.pattern == "gfur"
    t1, c1 = planned.run()
    t2, c2 = forced.run()
    assert int(c1) == int(c2)
    cols = tuple(sorted(t1.column_names))
    assert _rows(t1, c1, cols) == _rows(t2, c2, cols)


def test_groupby_strategy_reacts_to_key_domain():
    rng = np.random.default_rng(2)
    dense = Table({"k": jnp.asarray(rng.integers(0, 256, 20_000).astype(np.int32)),
                   "v": jnp.ones(20_000, jnp.float32)})
    sparse_keys = (rng.integers(0, 1 << 30, 20_000)).astype(np.int32)
    sparse = Table({"k": jnp.asarray(sparse_keys),
                    "v": jnp.ones(20_000, jnp.float32)})
    cat = Catalog({"dense": dense, "sparse": sparse})
    p_dense = optimize(scan("dense").group_by("k", v="sum"), cat, **OPT)
    p_sparse = optimize(scan("sparse").group_by("k", v="sum"), cat, **OPT)
    assert p_dense.root.strategy == "scatter"
    # sparse high-cardinality integer keys: the paper's partition-based
    # algorithm — and its plain (jit-safe) path must be exact end to end
    assert p_sparse.root.strategy == "partition"
    # both produce correct group counts
    _, c_dense = p_dense.run()
    assert int(c_dense) == len(set(np.asarray(dense["k"]).tolist()))
    _, c_sparse = p_sparse.run()
    assert int(c_sparse) == len(set(sparse_keys.tolist()))


def test_eager_run_traced_by_outer_jit_skips_ladders():
    """`run(jit=False)` wrapped in an OUTER jax.jit (how the benchmarks
    time the interpreted plan as one executable) must not try to run the
    checked ladders: their overflow checks are host-side bool()s,
    impossible on tracers. The plain drivers run instead, bit-identically
    to the eager checked result."""
    import jax

    R, S = relgen.generate(relgen.JoinWorkload("t", 400, 1500, 1, 1, seed=9))
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("k", s1="sum")
    plan = optimize(q, cat, force_join=("phj", "gfur"), **OPT)
    eager_t, eager_n = plan.run(jit=False)  # concrete: ladders engage
    tables = dict(plan.catalog.tables)
    jit_t, jit_n = jax.jit(lambda tb: plan.run(tb, jit=False))(tables)
    cols = eager_t.column_names
    assert _rows(jit_t, jit_n, cols) == _rows(eager_t, eager_n, cols)

"""Resilience subsystem tests (DESIGN.md §13): escalation ladders,
deterministic fault injection, hostile inputs, and graceful degradation at
the kernel, executor, and serve layers.

Escalated knobs change row order (partition bits) and padded shape
(capacity), never the multiset of valid rows — results are compared as
canonicalized valid rows (sorted tuples over sorted columns)."""
from __future__ import annotations

import collections

from hypothesis import given, settings, strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KEY_SENTINEL, Table, group_aggregate
from repro.core.groupby import groupby_partition_checked
from repro.core.groupjoin import groupjoin_checked, phj_groupjoin
from repro.core.hash_join import phj_join, phj_join_checked
from repro.kernels import ops as kops
from repro.obs import metrics
from repro.resilience import (EscalationExhausted, EscalationStep, Ladder,
                              escalation, faults)


def canon(table, count):
    """Valid rows, order/shape-insensitive (integer payloads only)."""
    n = int(count)
    cols = sorted(table.column_names)
    mats = [np.asarray(table[c])[:n] for c in cols]
    return tuple(cols), sorted(zip(*[m.tolist() for m in mats]))


def make_join_tables(rng, n_r=256, n_s=1024):
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "v": jnp.asarray(rng.integers(0, 99, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "w": jnp.asarray(rng.integers(0, 9, n_s).astype(np.int32))})
    return R, S


# ---------------------------------------------------------------------------
# REPRO_FAULTS grammar: validated at read time, per call
# ---------------------------------------------------------------------------
def test_parse_accepts_full_grammar():
    plan = faults.parse("overflow:phj@0, pallas:*, raise:executor.run@1+3,"
                        "estimates:/16, seed:7")
    kinds = [s.kind for s in plan.specs]
    assert kinds == ["overflow", "pallas", "raise", "estimates", "seed"]
    assert plan.seed == 7
    assert plan.specs[0].when == frozenset({0})
    assert plan.specs[1].when is None  # every occurrence
    assert plan.specs[2].when == frozenset({1, 3})
    assert plan.specs[3].factor == pytest.approx(1 / 16)
    assert faults.parse("  ").specs == ()


@pytest.mark.parametrize("bad", [
    "overflow:phj",          # missing @<when>
    "overflow:@0",           # missing ladder name
    "pallas:",               # missing site
    "raise:*",               # wildcard raise is rejected
    "estimates:16",          # missing x|/ prefix
    "estimates:x0",          # factor must be > 0
    "estimates:xnope",
    "seed:abc",
    "overflow:phj@-1",       # negative occurrence
    "overflow:phj@one",
    "typo:phj@0",            # unknown kind
    "justaword",             # no ':'
])
def test_parse_rejects_bad_specs_naming_grammar(bad):
    with pytest.raises(ValueError) as exc:
        faults.parse(bad)
    msg = str(exc.value)
    assert faults.ENV_VAR in msg and "overflow:<ladder>@<when>" in msg


def test_env_var_validated_per_call_never_frozen(monkeypatch, rng):
    """The env var is (re)parsed at every injection-site call — setting a
    bad value AFTER import must raise, and fixing it must recover,
    matching the REPRO_PALLAS_INTERPRET read-time convention."""
    R, S = make_join_tables(rng)
    monkeypatch.setenv(faults.ENV_VAR, "overflow:nonsense")
    with pytest.raises(ValueError):
        phj_join_checked(R, S, key="k")
    monkeypatch.setenv(faults.ENV_VAR, "overflow:phj@0")
    out, rep = phj_join_checked(R, S, key="k", with_report=True)
    assert rep.escalated and rep.converged
    monkeypatch.delenv(faults.ENV_VAR)
    _, rep2 = phj_join_checked(R, S, key="k", with_report=True)
    assert not rep2.escalated


def test_inject_context_wins_over_env(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "overflow:phj@all")
    with faults.inject(""):
        assert not faults.overflow_forced("phj", 0)
    assert faults.overflow_forced("phj", 0)


def test_occurrence_counters_reset_per_activation():
    with faults.inject("pallas:somesite@0"):
        with pytest.raises(faults.FaultInjected):
            faults.check_pallas("somesite")
        faults.check_pallas("somesite")  # occurrence 1: not armed
    with faults.inject("pallas:somesite@0"):
        with pytest.raises(faults.FaultInjected):  # counters restarted
            faults.check_pallas("somesite")


# ---------------------------------------------------------------------------
# escalation ladder unit behavior
# ---------------------------------------------------------------------------
def _toy_ladder(max_attempts=8, cap_max_times=4):
    return Ladder("toy", [
        EscalationStep("cap", lambda kn, d: {**kn, "cap": kn["cap"] * 2},
                       max_times=cap_max_times),
        EscalationStep("fallback", lambda kn, d: {**kn, "exact": True},
                       max_times=1),
    ], max_attempts=max_attempts)


def test_ladder_converges_with_report():
    def check(kn):
        ok = bool(kn["cap"] >= 100 or kn.get("exact"))
        return ok, "" if ok else f"cap {kn['cap']} < 100", None

    rep = _toy_ladder().resolve({"cap": 16}, check)
    assert rep.converged and rep.escalated
    assert rep.final_knobs["cap"] == 128
    assert rep.steps_applied == {"cap": 3}
    assert [a.ok for a in rep.attempts] == [False, False, False, True]
    assert "converged" in rep.summary()


def test_ladder_rung_yields_to_next():
    """A rung returning None passes the attempt to the next rung instead
    of burning it."""
    def check(kn):
        return bool(kn.get("exact")), "needs exact", None

    rep = Ladder("toy", [
        EscalationStep("useless", lambda kn, d: None),
        EscalationStep("fallback", lambda kn, d: {**kn, "exact": True},
                       max_times=1),
    ]).resolve({"cap": 1}, check)
    assert rep.converged and rep.steps_applied == {"fallback": 1}


def test_ladder_exhaustion_is_typed_and_carries_report():
    def never_ok(kn):
        return False, "hopeless", None

    before = metrics.counter("resilience.ladder_exhausted").value
    with pytest.raises(EscalationExhausted) as exc:
        _toy_ladder(max_attempts=3).resolve({"cap": 1}, never_ok)
    rep = exc.value.report
    assert not rep.converged and len(rep.attempts) == 3
    assert "EXHAUSTED" in rep.summary()
    assert metrics.counter("resilience.ladder_exhausted").value == before + 1


def test_escalation_feeds_metrics():
    def check(kn):
        return kn["cap"] >= 2, "", None

    before = metrics.counter("core.overflow_escalations").value
    _toy_ladder().resolve({"cap": 1}, check)
    assert metrics.counter("core.overflow_escalations").value == before + 1


# ---------------------------------------------------------------------------
# the three production ladders: natural / forced / exhausted
# ---------------------------------------------------------------------------
def test_phj_ladder_forced_overflow_matches_oracle(rng):
    R, S = make_join_tables(rng)
    oracle = canon(*phj_join_checked(R, S, key="k"))
    with faults.inject("overflow:phj@0"):
        out, rep = phj_join_checked(R, S, key="k", with_report=True)
    assert rep.escalated and rep.converged and rep.wasted_checks == 1
    assert canon(*out) == oracle
    with pytest.raises(EscalationExhausted):
        with faults.inject("overflow:phj@all"):
            phj_join_checked(R, S, key="k")


def test_phj_ladder_smj_fallback_on_unsplittable_skew(rng):
    """One key's duplicates co-hash at any fan-out: bits cannot help, the
    ladder must fall through to sort-merge and still be exact."""
    R = Table({"k": jnp.asarray(np.zeros(600, np.int32)),
               "v": jnp.asarray(np.arange(600, dtype=np.int32))})
    S = Table({"k": jnp.asarray(np.zeros(50, np.int32)),
               "w": jnp.asarray(np.arange(50, dtype=np.int32))})
    out, rep = phj_join_checked(R, S, key="k", mode="mn",
                                out_size=600 * 50, with_report=True)
    assert rep.converged and rep.final_knobs["algorithm"] == "smj"
    assert int(out[1]) == 600 * 50


def test_groupjoin_ladder_grows_capacity_to_required(rng):
    R, S = make_join_tables(rng)
    kw = dict(key="k", group_key="k", aggs={"w": "sum"}, num_groups=256)
    oracle = canon(*groupjoin_checked(R, S, **kw))
    # capacity 4x under-provisioned: the ladder must grow it, not the bits
    out, rep = groupjoin_checked(R, S, with_report=True,
                                 **{**kw, "num_groups": 64})
    assert rep.escalated and rep.final_knobs["num_groups"] >= 64
    assert canon(*out) == oracle
    with faults.inject("overflow:groupjoin@0"):
        out2, rep2 = groupjoin_checked(R, S, with_report=True, **kw)
    assert rep2.escalated and canon(*out2) == oracle


def test_groupby_partition_ladder_forced_and_exhausted(rng):
    S = Table({"k": jnp.asarray(rng.integers(0, 256, 1024).astype(np.int32)),
               "w": jnp.asarray(rng.integers(0, 9, 1024).astype(np.int32))})
    kw = dict(key="k", aggs={"w": "sum"}, num_groups=256)
    oracle = canon(*groupby_partition_checked(S, **kw))
    with faults.inject("overflow:groupby_partition@0"):
        out, rep = groupby_partition_checked(S, with_report=True, **kw)
    assert rep.escalated and canon(*out) == oracle
    with pytest.raises(EscalationExhausted):
        with faults.inject("overflow:groupby_partition@all"):
            groupby_partition_checked(S, **kw)


# ---------------------------------------------------------------------------
# property: ladders converge under adversarially corrupted estimates
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(factor=st.sampled_from([2, 4, 16, 64]), seed=st.integers(0, 10))
def test_ladders_converge_under_underestimates(factor, seed):
    """Distinct-count under-estimated up to 64x: every ladder must reach a
    fitting geometry within its attempt cap (growing bits/capacity/block,
    or falling back to an exact strategy) and match the oracle."""
    rng = np.random.default_rng(seed)
    n_r, n_s = 512, 1024
    R, S = make_join_tables(rng, n_r, n_s)

    # phj: partition bits chosen as if R had n_r/factor rows
    from repro.core.hash_join import choose_partition_bits
    bad_bits = choose_partition_bits(max(n_r // factor, 1), 64)
    oracle = canon(*phj_join_checked(R, S, key="k"))
    out, rep = phj_join_checked(R, S, key="k", build_block=64,
                                partition_bits=bad_bits, with_report=True)
    assert rep.converged and canon(*out) == oracle

    # groupjoin: accumulator capacity under-provisioned by `factor`
    kw = dict(key="k", group_key="k", aggs={"w": "sum"})
    oracle = canon(*groupjoin_checked(R, S, num_groups=n_r, **kw))
    out, rep = groupjoin_checked(R, S, num_groups=max(n_r // factor, 1),
                                 with_report=True, **kw)
    assert rep.converged and canon(*out) == oracle

    # groupby_partition: row block sized as if partitions were `factor`x
    # lighter
    gkw = dict(key="k", aggs={"w": "sum"}, num_groups=n_r)
    oracle = canon(*groupby_partition_checked(S, **gkw))
    out, rep = groupby_partition_checked(
        S, row_block=max(128 // factor, 8), partition_bits=0,
        with_report=True, **gkw)
    assert rep.converged and canon(*out) == oracle


# ---------------------------------------------------------------------------
# zero-overhead contract: disabled faults contribute nothing to the jaxpr
# ---------------------------------------------------------------------------
def test_fault_hooks_are_jaxpr_invisible(monkeypatch, rng):
    """With no faults active, tracing through the injection sites must
    yield the exact jaxpr of a build with every hook compiled out — the
    hooks are host-side and contribute nothing to the graph."""
    assert not faults.active()
    R, S = make_join_tables(rng, 128, 256)
    G = Table({"k": jnp.asarray(rng.integers(0, 32, 256).astype(np.int32)),
               "w": jnp.asarray(rng.integers(0, 9, 256).astype(np.int32))})

    def ops():
        j = phj_join(R, S, key="k", out_size=256)
        g = group_aggregate(G, key="k", aggs={"w": "sum"}, num_groups=64,
                            strategy="partition")
        return j[1] + g[1]

    base = str(jax.make_jaxpr(ops)())
    monkeypatch.setattr(faults, "active", lambda: False)
    monkeypatch.setattr(faults, "check_pallas", lambda site: None)
    monkeypatch.setattr(faults, "check_site", lambda site: None)
    monkeypatch.setattr(faults, "overflow_forced", lambda *a: False)
    monkeypatch.setattr(faults, "estimate_factor", lambda site="": 1.0)
    assert str(jax.make_jaxpr(ops)()) == base


# ---------------------------------------------------------------------------
# pallas -> xla degradation: every kernels/ops.py dispatch
# ---------------------------------------------------------------------------
def _site_cases(rng):
    digits = jnp.asarray(rng.integers(0, 16, 2048).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 1 << 20, 2048).astype(np.int32))
    build = jnp.sort(jnp.asarray(
        rng.choice(1 << 16, 1024, replace=False).astype(np.int32)))
    probe = jnp.sort(jnp.asarray(
        rng.integers(0, 1 << 16, 2048).astype(np.int32)))
    src = jnp.asarray(rng.integers(0, 99, 4096).astype(np.int32))
    # clustered, monotone indices: impl='pallas' skips the span check, so
    # the data must genuinely satisfy the windowed kernel's precondition
    idx = jnp.repeat(jnp.arange(1024, dtype=jnp.int32) * 2, 2)
    skeys = jnp.sort(jnp.asarray(rng.integers(0, 64, 2048).astype(np.int32)))
    vals = jnp.asarray(rng.random(2048).astype(np.float32))
    return {
        "histogram": lambda: kops.histogram(digits, 16, "pallas"),
        "partition_ranks": lambda: kops.partition_ranks(digits, 16, "pallas"),
        "partition_plan": lambda: kops.partition_plan(digits, 16,
                                                      impl="pallas"),
        "sort_plan": lambda: kops.sort_plan(keys, "radix"),
        "merge_lower_bound": lambda: kops.merge_lower_bound(build, probe,
                                                            "pallas"),
        "clustered_gather": lambda: kops.clustered_gather(src, idx, "pallas"),
        "groupby_sorted_sum": lambda: kops.groupby_sorted_sum(skeys, vals,
                                                              64, "pallas"),
    }


@pytest.mark.parametrize("site", [
    "histogram", "partition_ranks", "partition_plan", "sort_plan",
    "merge_lower_bound", "clustered_gather", "groupby_sorted_sum",
])
def test_pallas_arm_failure_degrades_to_identical_xla(site, rng):
    fn = _site_cases(rng)[site]
    oracle = jax.tree_util.tree_map(np.asarray, fn())
    before = metrics.counter(f"resilience.kernel_fallbacks.{site}").value
    with faults.inject(f"pallas:{site}"):
        got = jax.tree_util.tree_map(np.asarray, fn())
    for a, b in zip(jax.tree_util.tree_leaves(oracle),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, b)
    assert metrics.counter(
        f"resilience.kernel_fallbacks.{site}").value > before


def test_hash_probe_and_groupjoin_probe_agg_degrade(rng):
    """The two fused probe kernels, driven through their operators."""
    R, S = make_join_tables(rng)
    oracle = canon(*phj_join(R, S, key="k", out_size=2048,
                             probe_impl="pallas"))
    with faults.inject("pallas:hash_probe"):
        got = canon(*phj_join(R, S, key="k", out_size=2048,
                              probe_impl="pallas"))
    assert got == oracle

    kw = dict(key="k", group_key="k", aggs={"w": "sum"}, num_groups=256)
    oracle = canon(*phj_groupjoin(R, S, probe_impl="pallas", **kw))
    with faults.inject("pallas:groupjoin_probe_agg"):
        got = canon(*phj_groupjoin(R, S, probe_impl="pallas", **kw))
    assert got == oracle


# ---------------------------------------------------------------------------
# hostile inputs: sentinel-colliding keys, empty relations, one group
# ---------------------------------------------------------------------------
GB_STRATEGIES = ("sort", "partition", "partition_hash", "scatter",
                 "sort_pallas")


def _gb_oracle(keys, vals):
    acc = collections.defaultdict(int)
    for k, v in zip(keys.tolist(), vals.tolist()):
        if k != KEY_SENTINEL:
            acc[k] += v
    return sorted((k, s) for k, s in acc.items())


def _gb_rows(out):
    (t, c) = out
    n = int(c)
    ks = np.asarray(t["k"])[:n]
    ss = np.asarray(t["v_sum"])[:n]
    return sorted((int(k), int(s)) for k, s in zip(ks, ss)
                  if k != KEY_SENTINEL)


@pytest.mark.parametrize("strategy", GB_STRATEGIES)
def test_groupby_sentinel_colliding_keys(strategy, rng):
    """Rows whose key equals the padding sentinel must be dropped exactly
    — never aggregated, never corrupting neighbors."""
    keys = rng.integers(0, 32, 512).astype(np.int32)
    keys[::7] = KEY_SENTINEL
    vals = rng.integers(0, 99, 512).astype(np.int32)
    T = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    out = group_aggregate(T, key="k", aggs={"v": "sum"}, num_groups=64,
                          strategy=strategy)
    assert _gb_rows(out) == _gb_oracle(keys, vals)


@pytest.mark.parametrize("strategy", GB_STRATEGIES)
def test_groupby_empty_relation(strategy):
    T = Table({"k": jnp.zeros((0,), jnp.int32),
               "v": jnp.zeros((0,), jnp.int32)})
    t, c = group_aggregate(T, key="k", aggs={"v": "sum"}, num_groups=16,
                           strategy=strategy)
    assert int(c) == 0


@pytest.mark.parametrize("strategy", GB_STRATEGIES)
def test_groupby_all_rows_one_group(strategy, rng):
    """Maximal skew: every row in one group. The static-shape partition
    strategy cannot adapt inside jit — its overflow must be *detectable*
    and its resilient entry point (the checked ladder) exact; every other
    strategy must be exact as-is."""
    vals = rng.integers(0, 99, 1024).astype(np.int32)
    T = Table({"k": jnp.full((1024,), 3, jnp.int32), "v": jnp.asarray(vals)})
    expected = [(3, int(vals.sum()))]
    if strategy == "partition":
        from repro.core.groupby import groupby_partition_overflowed

        over, _, mx = groupby_partition_overflowed(T["k"])
        assert over and int(mx) == 1024  # never silent
        t, c = groupby_partition_checked(T, key="k", aggs={"v": "sum"},
                                         num_groups=16)
    else:
        t, c = group_aggregate(T, key="k", aggs={"v": "sum"}, num_groups=16,
                               strategy=strategy)
    assert _gb_rows((t, c)) == expected


def test_phj_sentinel_colliding_keys(rng):
    """Sentinel keys on either side must not match anything — including
    each other — and must not perturb real matches (they are isolated in
    their own partition, never co-resident with real keys)."""
    R, S = make_join_tables(rng, 128, 512)
    rk = np.asarray(R["k"]).copy()
    rk[::5] = KEY_SENTINEL
    sk = np.asarray(S["k"]).copy()
    sk[::3] = KEY_SENTINEL
    Rh = Table({"k": jnp.asarray(rk), "v": R["v"]})
    Sh = Table({"k": jnp.asarray(sk), "w": S["w"]})
    out, count = phj_join_checked(Rh, Sh, key="k", out_size=1024)
    rmap = {int(k): int(v) for k, v in zip(rk, np.asarray(R["v"]))
            if k != KEY_SENTINEL}
    oracle = sorted((int(k), rmap[int(k)], int(w))
                    for k, w in zip(sk, np.asarray(S["w"]))
                    if int(k) in rmap)
    got = sorted(zip(*[np.asarray(out[c])[:int(count)].tolist()
                       for c in ("k", "v", "w")]))
    assert got == oracle


def test_phj_empty_relations(rng):
    R, S = make_join_tables(rng, 64, 128)
    empty_r = Table({"k": jnp.zeros((0,), jnp.int32),
                     "v": jnp.zeros((0,), jnp.int32)})
    empty_s = Table({"k": jnp.zeros((0,), jnp.int32),
                     "w": jnp.zeros((0,), jnp.int32)})
    for a, b in ((empty_r, S), (R, empty_s), (empty_r, empty_s)):
        out, count = phj_join_checked(a, b, key="k", out_size=128)
        assert int(count) == 0


def test_phj_all_probes_one_key(rng):
    """Every probe row hits one build key: maximal partition skew on the
    probe side."""
    R, S = make_join_tables(rng, 128, 512)
    Sh = Table({"k": jnp.full((512,), 7, jnp.int32), "w": S["w"]})
    out, count = phj_join_checked(R, Sh, key="k", out_size=512)
    assert int(count) == 512
    assert set(np.asarray(out["k"])[:512].tolist()) == {7}


def test_groupjoin_empty_relations(rng):
    R, S = make_join_tables(rng, 64, 128)
    empty_s = Table({"k": jnp.zeros((0,), jnp.int32),
                     "w": jnp.zeros((0,), jnp.int32)})
    t, c = groupjoin_checked(R, empty_s, key="k", group_key="k",
                             aggs={"w": "sum"}, num_groups=64)
    assert int(c) == 0


# ---------------------------------------------------------------------------
# estimate corruption (stats layer)
# ---------------------------------------------------------------------------
def test_estimate_factor_unseeded_is_exact():
    with faults.inject("estimates:/8"):
        assert faults.estimate_factor("distinct") == pytest.approx(1 / 8)
    assert faults.estimate_factor("distinct") == 1.0


def test_estimate_factor_seeded_is_deterministic_and_bounded():
    with faults.inject("estimates:/8,seed:3"):
        a = faults.estimate_factor("distinct")
        b = faults.estimate_factor("distinct")
        other = faults.estimate_factor("rows")
    assert a == b
    assert 1 / 16 <= a <= 1 / 4  # log2 jitter within [f/2, f*2]
    assert other != a


def test_stats_distinct_estimate_corrupted(rng):
    from repro.engine.stats import estimate_distinct

    col = jnp.asarray(rng.permutation(4096).astype(np.int32))
    clean = estimate_distinct(col)
    with faults.inject("estimates:/4"):
        corrupt = estimate_distinct(col)
    assert corrupt == pytest.approx(clean / 4, rel=0.26)


# ---------------------------------------------------------------------------
# executor: degrade-once re-plan
# ---------------------------------------------------------------------------
def _star_plan():
    from repro.data import relgen
    from repro.engine import Catalog, optimize, scan

    w = relgen.JoinWorkload("t", 500, 2000, 2, 1, match_ratio=1.0)
    R, S = relgen.generate(w)
    cat = Catalog({"R": R, "S": S})
    q = scan("R").join(scan("S"), key="k").group_by("k", s1="sum")
    return lambda: optimize(q, cat, measure_profile=False)


def test_executor_degrades_once_and_matches(rng):
    mk = _star_plan()
    oracle = canon(*mk().run())
    plan = mk()
    before = metrics.counter("resilience.plan_degradations").value
    with faults.inject("raise:executor.run@0"):
        got = canon(*plan.run())
    assert got == oracle
    assert plan.degraded_plan is not None
    assert plan.degraded_plan.degraded.startswith("DEGRADED[")
    assert "DEGRADED[" in plan.degraded_plan.explain()
    assert metrics.counter("resilience.plan_degradations").value == before + 1


def test_executor_persistent_failure_reraises():
    plan = _star_plan()()
    with pytest.raises(faults.FaultInjected):
        with faults.inject("raise:executor.run@all"):
            plan.run()


def test_executor_programming_errors_not_degraded(monkeypatch):
    from repro.engine import executor

    plan = _star_plan()()
    def boom(node, tables):
        raise TypeError("a bug, not an overflow")
    monkeypatch.setattr(executor, "execute", boom)
    with pytest.raises(TypeError):
        plan.run(jit=False)
    assert plan.degraded_plan is None


def test_degrade_plan_transforms_structure():
    from repro.engine import physical as P

    plan = _star_plan()()
    deg = P.degrade_plan(plan, "test-reason")
    assert deg.degraded == "DEGRADED[test-reason]"

    def walk(a, b):
        if isinstance(b, (P.PJoin, P.PGroupBy, P.PGroupJoin, P.PFilter)):
            assert b.capacity >= 2 * a.capacity
        if isinstance(b, P.PGroupBy):
            assert b.strategy == "sort"
        if isinstance(b, P.PGroupJoin):
            assert b.agg_strategy == "sort"
        if isinstance(b, P.PJoin):
            assert b.algorithm != "phj"
        if isinstance(b, P.POrderByLimit):
            assert b.capacity == a.capacity  # the limit IS the semantics
        for ka, kb in zip(a.children(), b.children()):
            walk(ka, kb)

    walk(plan.root, deg.root)


def test_trace_escalations_render_in_explain():
    plan = _star_plan()()
    with faults.inject("overflow:phj@0"):
        t, c, tr = plan.run(trace=True)
    assert tr.escalations and any(r.operator == "phj" for r in tr.escalations)
    txt = plan.explain(actuals=tr)
    assert "escalation: phj" in txt


# ---------------------------------------------------------------------------
# serve: poisoned-query isolation, shedding, deadlines
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs.base import get_reduced_config
    from repro.models import model as M

    cfg = get_reduced_config("olmo-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(serve_setup, **kw):
    from repro.serve.engine import ServeEngine

    cfg, params = serve_setup
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("eos_id", -1)
    kw.setdefault("retry_backoff_s", 0.0)
    return ServeEngine(cfg, params, **kw)


def test_serve_poisoned_query_fails_alone(serve_setup, rng):
    from repro.models import model as M
    from repro.serve.engine import Request

    cfg, params = serve_setup
    eng = _engine(serve_setup, step_retries=1)
    real = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))

    def step_fn(p, c, t, pos):
        if any(r is not None and r.rid == 2 for r in eng.slot_req):
            raise RuntimeError("poisoned query")
        return real(p, c, t, pos)

    eng._step = step_fn
    reqs = [Request(rid=i, max_tokens=4, retries_left=1,
                    prompt=rng.integers(3, cfg.vocab_size, 3).tolist())
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert reqs[2].done and reqs[2].error == "poisoned"
    for r in reqs:
        if r.rid != 2:
            assert r.done and r.error == "" and len(r.out) == 4


def test_serve_step_retry_recovers_transient(serve_setup, rng):
    """A step that fails once then succeeds is absorbed by the retry
    budget: no eviction, every request completes."""
    from repro.models import model as M
    from repro.serve.engine import Request

    cfg, params = serve_setup
    eng = _engine(serve_setup, step_retries=2)
    real = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    calls = {"n": 0}

    def flaky(p, c, t, pos):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return real(p, c, t, pos)

    eng._step = flaky
    before = metrics.counter("resilience.serve_retries").value
    r = Request(rid=0, max_tokens=3,
                prompt=rng.integers(3, cfg.vocab_size, 3).tolist())
    eng.submit(r)
    eng.run()
    assert r.done and r.error == "" and len(r.out) == 3
    assert metrics.counter("resilience.serve_retries").value == before + 1


def test_serve_load_shedding(serve_setup):
    from repro.serve.engine import Request

    eng = _engine(serve_setup, max_batch=1, max_queue=2)
    before = metrics.counter("resilience.serve_shed").value
    reqs = [Request(rid=i, prompt=[3, 4], max_tokens=2) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    shed = [r for r in reqs if r.error == "shed"]
    assert len(shed) == 3 and all(r.done for r in shed)
    assert metrics.counter("resilience.serve_shed").value == before + 3
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 2 for r in reqs if r.error == "")


def test_serve_deadline_eviction(serve_setup):
    from repro.serve.engine import Request

    eng = _engine(serve_setup, max_batch=1)
    slow = Request(rid=0, prompt=[3, 4, 5], max_tokens=50, deadline_ticks=4)
    queued = Request(rid=1, prompt=[3, 4], max_tokens=2, deadline_ticks=2)
    eng.submit(slow)
    eng.submit(queued)
    eng.run()
    assert slow.done and slow.error == "deadline"
    # rid 1's deadline (tick 2) passed while it waited in the queue
    assert queued.done and queued.error == "deadline"


def test_serve_fault_site(serve_setup):
    from repro.serve.engine import Request

    eng = _engine(serve_setup, max_batch=1, step_retries=0)
    r = Request(rid=9, prompt=[3, 4], max_tokens=2, retries_left=0)
    eng.submit(r)
    with faults.inject("raise:serve.step@all"):
        eng.run()
    assert r.done and r.error == "poisoned"


# ---------------------------------------------------------------------------
# degradation events are observable
# ---------------------------------------------------------------------------
def test_degradations_recorded_in_ring(rng):
    since = escalation.current_seq()
    digits = jnp.asarray(rng.integers(0, 16, 512).astype(np.int32))
    with faults.inject("pallas:histogram"):
        kops.histogram(digits, 16, "pallas")
    events = escalation.recent_degradations(since)
    assert any(d["component"] == "kernels.histogram" for d in events)


def test_serve_deadline_expires_on_admission_tick(serve_setup):
    """A queued request whose deadline lands on the EXACT tick a slot
    frees up is evicted by the deadline sweep, not admitted: sweep runs
    before admission every tick."""
    from repro.serve.engine import Request

    def occupied_engine():
        eng = _engine(serve_setup, max_batch=1)
        eng.submit(Request(rid=0, prompt=[3, 4, 5], max_tokens=4))
        return eng

    # reference run: when would the victim be admitted?
    eng = occupied_engine()
    ref = Request(rid=1, prompt=[3, 4], max_tokens=2)
    eng.submit(ref)
    eng.run()
    assert ref.done and ref.error == ""
    admit_tick = ref.submit_tick + ref.ticks_queued

    # deadline == admission tick: the sweep must win the race
    eng = occupied_engine()
    victim = Request(rid=1, prompt=[3, 4], max_tokens=2,
                     deadline_ticks=admit_tick)
    eng.submit(victim)
    eng.run()
    assert victim.done and victim.error == "deadline"
    assert victim.out == [] and victim.done_tick == admit_tick

    # a deadline past its completion point and it runs untouched
    eng = occupied_engine()
    ok = Request(rid=1, prompt=[3, 4], max_tokens=2,
                 deadline_ticks=admit_tick + 10)
    eng.submit(ok)
    eng.run()
    assert ok.done and ok.error == "" and len(ok.out) == 2


def test_serve_requeued_request_reruns_full_prefill(serve_setup, rng):
    """A request evicted mid-decode and requeued must re-run its FULL
    prefill with cleared output: its final output equals a fresh engine's
    (no cache or output state leaks from the failed run)."""
    from repro.models import model as M
    from repro.serve.engine import Request

    cfg, params = serve_setup
    prompt = rng.integers(3, cfg.vocab_size, 3).tolist()

    eng_ref = _engine(serve_setup, max_batch=1)
    r_ref = Request(rid=0, prompt=list(prompt), max_tokens=4)
    eng_ref.submit(r_ref)
    eng_ref.run()
    assert r_ref.done and len(r_ref.out) == 4

    eng = _engine(serve_setup, max_batch=1, step_retries=0)
    real = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
    calls = {"n": 0}

    def step_fn(p, c, t, pos):
        calls["n"] += 1
        if calls["n"] == 5:  # two decode outputs exist; then the step dies
            raise RuntimeError("mid-decode fault")
        return real(p, c, t, pos)

    eng._step = step_fn
    r = Request(rid=1, prompt=list(prompt), max_tokens=4, retries_left=1)
    eng.submit(r)
    eng.run()
    assert r.done and r.error == "" and r.retries_left == 0
    assert r.ticks_retrying >= 1
    assert r.out == r_ref.out, (r.out, r_ref.out)


def test_serve_latency_breakdown(serve_setup, rng):
    from repro.serve.engine import Request, ServeEngine

    cfg, _ = serve_setup
    eng = _engine(serve_setup, max_batch=1)
    reqs = [Request(rid=i, max_tokens=3,
                    prompt=rng.integers(3, cfg.vocab_size, 3).tolist())
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done and r.error == ""
        assert r.ticks_running > 0 and r.ticks_retrying == 0
        # ticks are conserved: queued + running spans submit..done
        assert r.ticks_queued + r.ticks_running == r.done_tick - r.submit_tick + 1
    # single slot: each successor queues at least as long as the last
    waits = [r.ticks_queued for r in reqs]
    assert waits == sorted(waits) and waits[-1] > waits[0]
    summary = ServeEngine.latency_summary()
    for stage in ("ticks_queued", "ticks_running", "ticks_retrying"):
        assert summary[stage]["count"] >= 3
        assert {"p50", "p95", "p99"} <= set(summary[stage])

import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses via run_subtest below.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The CI container cannot pip-install hypothesis; fall back to the vendored
# seeded-numpy shim so the property tests still collect and run offline.
# The real package wins whenever it is importable.
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro._vendor import hypothesis_fallback

    hypothesis_fallback.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def run_subtest(code: str, devices: int = 8, timeout: int = 300) -> str:
    """Run `code` in a fresh process with N fake devices; returns stdout.
    Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\nstdout:\n{res.stdout}"
            f"\nstderr:\n{res.stderr[-3000:]}"
        )
    return res.stdout

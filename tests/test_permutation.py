"""One-permutation materialization layer (DESIGN.md §8): composed multi-pass
permutations equal the direct stable partition, apply_permutation matches the
payload-carrying primitives, and every sort/partition path hands back int32
layout arrays (hypothesis properties + fixed cases)."""
from __future__ import annotations

from hypothesis import given, settings, strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import primitives as prim
from repro.kernels import ops as kops


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), bits=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_multi_pass_plan_equals_direct_stable_partition(n, bits, seed):
    """Composing stable <=8-bit passes (carrying only digit+iota) must equal
    the single stable partition on all bits — the §4.3 stability argument the
    whole layer rests on — and the production (sort-free rank pipeline)
    plan must equal both: the stable partition permutation is unique."""
    rng = np.random.default_rng(seed)
    digits = jnp.asarray(rng.integers(0, 1 << bits, n).astype(np.int32))
    direct, off_d, sz_d = prim.plan_partition_permutation(
        digits, 1 << bits, impl="xla")
    composed, off_c, sz_c = prim.plan_partition_permutation(
        digits, 1 << bits, max_pass_bits=8, impl="xla")
    ranked, off_r, sz_r = prim.plan_partition_permutation(
        digits, 1 << bits, impl="pallas")
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(composed))
    np.testing.assert_array_equal(np.asarray(off_d), np.asarray(off_c))
    np.testing.assert_array_equal(np.asarray(sz_d), np.asarray(sz_c))
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(ranked))
    np.testing.assert_array_equal(np.asarray(off_d), np.asarray(off_r))
    np.testing.assert_array_equal(np.asarray(sz_d), np.asarray(sz_r))
    # and all equal numpy's stable argsort
    np.testing.assert_array_equal(
        np.asarray(direct), np.argsort(np.asarray(digits), kind="stable"))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 1500), total_bits=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_one_permutation_multi_pass_partition(n, total_bits, seed):
    """multi_pass_radix_partition (now one gather per column) must equal the
    payload-free plan applied per column."""
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
    v1 = jnp.arange(n, dtype=jnp.int32)
    v2 = jnp.asarray(rng.normal(size=n).astype(np.float32))
    ko, v1o, v2o, off, sz = prim.multi_pass_radix_partition(
        keys, v1, v2, total_bits=total_bits)
    digits = prim.radix_digits(keys, 0, total_bits)
    perm, off2, sz2 = prim.plan_partition_permutation(digits, 1 << total_bits)
    np.testing.assert_array_equal(np.asarray(ko),
                                  np.asarray(prim.apply_permutation(perm, keys)))
    np.testing.assert_array_equal(np.asarray(v1o),
                                  np.asarray(prim.apply_permutation(perm, v1)))
    np.testing.assert_array_equal(np.asarray(v2o),
                                  np.asarray(prim.apply_permutation(perm, v2)))
    np.testing.assert_array_equal(np.asarray(off), np.asarray(off2))
    # stability: within each partition original positions stay increasing
    d_out = np.asarray(prim.radix_digits(ko, 0, total_bits))
    v1_np = np.asarray(v1o)
    assert (np.diff(d_out) >= 0).all()
    for p in np.unique(d_out):
        seg = v1_np[d_out == p]
        assert (np.diff(seg) > 0).all() if len(seg) > 1 else True


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 2**31 - 1))
def test_plan_sort_permutation_matches_sort_pairs(n, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, max(n // 3, 2), n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    sk, perm = prim.plan_sort_permutation(keys)
    sk2, sv2 = prim.sort_pairs(keys, vals)
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sk2))
    np.testing.assert_array_equal(
        np.asarray(prim.apply_permutation(perm, vals)), np.asarray(sv2))
    # a second payload costs one gather and agrees with a joint sort
    vals2 = jnp.arange(n, dtype=jnp.int32)
    _, _, sv3 = prim.sort_pairs(keys, vals, vals2)
    np.testing.assert_array_equal(
        np.asarray(prim.apply_permutation(perm, vals2)), np.asarray(sv3))


def test_apply_permutation_return_shape(rng):
    perm = jnp.asarray([2, 0, 1], jnp.int32)
    a = jnp.asarray([10, 20, 30], jnp.int32)
    b = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    single = prim.apply_permutation(perm, a)
    assert isinstance(single, jnp.ndarray)
    pair = prim.apply_permutation(perm, a, b)
    assert isinstance(pair, tuple) and len(pair) == 2


# ---------------------------------------------------------------------------
# "One gather per column" is measurable: however wide the payload, each
# sort/partition path traces exactly as many sort ops as it has key plans
# ---------------------------------------------------------------------------
# (the recursive sort counter lives in repro.analysis now — one
# implementation, shared by tests, the executor audit, and the CLI gate)
from repro.analysis import count_sorts as _count_sorts  # noqa: E402


def _wide_tables(rng, n=512, cols=4):
    import jax.numpy as jnp
    from repro.core import Table

    def make(seed):
        r = np.random.default_rng(seed)
        d = {"k": jnp.asarray(r.integers(0, 64, n).astype(np.int32))}
        for j in range(cols):
            d[f"v{seed}{j}"] = jnp.asarray(r.normal(size=n).astype(np.float32))
        return Table(d)

    return make(1), make(2)


def test_groupby_sort_plans_one_sort_regardless_of_payload_width(rng):
    import jax
    from repro.core import group_aggregate

    t, _ = _wide_tables(rng)
    aggs = {c: "sum" for c in t.column_names if c != "k"}
    jaxpr = jax.make_jaxpr(lambda tb: group_aggregate(
        tb, key="k", aggs=aggs, num_groups=128, strategy="sort"))(t)
    assert _count_sorts(jaxpr.jaxpr) == 1


def test_smj_gftr_plans_one_sort_per_side_regardless_of_payload_width(rng):
    import jax
    from repro.core import smj_join

    R, S = _wide_tables(rng)
    jaxpr = jax.make_jaxpr(lambda a, b: smj_join(
        a, b, key="k", pattern="gftr", mode="mn", out_size=2048))(R, S)
    assert _count_sorts(jaxpr.jaxpr) == 2


def test_phj_is_sort_free_regardless_of_payload_width(rng):
    """The kernel-backed partition planner removed PHJ's last sorts: both
    sides' plans are histogram/rank pipelines now (DESIGN.md §10)."""
    import jax
    from repro.core import phj_join

    R, S = _wide_tables(rng)
    jaxpr = jax.make_jaxpr(lambda a, b: phj_join(
        a, b, key="k", pattern="gftr", mode="mn", out_size=2048))(R, S)
    assert _count_sorts(jaxpr.jaxpr) == 0


def test_groupby_partition_plans_zero_sorts_plus_block_local(rng):
    import jax
    from repro.core import group_aggregate

    t, _ = _wide_tables(rng)
    aggs = {c: "sum" for c in t.column_names if c != "k"}
    jaxpr = jax.make_jaxpr(lambda tb: group_aggregate(
        tb, key="k", aggs=aggs, num_groups=128, strategy="partition"))(t)
    # the partition PLAN is sort-free; the single remaining sort is the
    # block-local (VMEM-resident) grouping sort, and payload width never
    # adds sorts — every aggregate input rides the one variadic block sort
    assert _count_sorts(jaxpr.jaxpr) == 1


def test_partition_plan_default_emits_zero_sort_primitives(rng):
    """The tentpole pin: the production `plan_partition_permutation` — with
    carry columns, with the sentinel-tail fan-out, and past one pass's bin
    budget — compiles to a jaxpr with NO sort primitive at all."""
    import jax

    digits = jnp.asarray(rng.integers(0, 257, 1024).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 99, 1024).astype(np.int32))
    jx = jax.make_jaxpr(lambda d, k: prim.plan_partition_permutation(
        d, 257, carry=(k,)))(digits, keys)
    assert _count_sorts(jx.jaxpr) == 0
    # >256 partitions: LSD multi-pass composition, still sort-free
    wide = jnp.asarray(rng.integers(0, 1 << 12, 1024).astype(np.int32))
    jx2 = jax.make_jaxpr(
        lambda d: prim.plan_partition_permutation(d, 1 << 12))(wide)
    assert _count_sorts(jx2.jaxpr) == 0
    # and multi_pass_radix_partition rides the same sort-free path
    jx3 = jax.make_jaxpr(lambda k: prim.multi_pass_radix_partition(
        k, total_bits=12))(keys)
    assert _count_sorts(jx3.jaxpr) == 0


# ---------------------------------------------------------------------------
# Pallas/XLA planner parity: (perm, carried, offsets, sizes) across
# cardinality x skew x sentinel grids, and the >256-partition composition
# ---------------------------------------------------------------------------
def _parity_digits(rng, n, num_partitions, dist):
    if dist == "uniform":
        d = rng.integers(0, num_partitions, n)
    elif dist == "skew":  # heavy hitters: most digits collapse onto a few
        d = (rng.zipf(1.3, n) - 1) % num_partitions
    elif dist == "sentinel":  # groupby shape: a pad block floods the top
        d = np.concatenate([rng.integers(0, num_partitions - 1, n // 2),
                            np.full(n - n // 2, num_partitions - 1)])
    else:  # single partition
        d = np.full(n, min(3, num_partitions - 1))
    return jnp.asarray(d.astype(np.int32))


@pytest.mark.parametrize("dist", ["uniform", "skew", "sentinel", "single"])
@pytest.mark.parametrize("n,num_partitions", [
    (1, 2), (600, 64), (1000, 257), (2000, 1 << 10), (1500, 1 << 12)])
def test_partition_plan_pallas_xla_parity(rng, n, num_partitions, dist):
    digits = _parity_digits(rng, n, num_partitions, dist)
    carry = (jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32)),
             jnp.asarray(rng.normal(size=n).astype(np.float32)))
    gp, gc, go, gs = prim.plan_partition_permutation(
        digits, num_partitions, carry=carry, impl="pallas")
    xp, xc, xo, xs = prim.plan_partition_permutation(
        digits, num_partitions, carry=carry, impl="xla")
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(xp))
    for a, b in zip(gc, xc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(go), np.asarray(xo))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(xs))


def test_partition_plan_forced_kernel_pipeline_parity(rng):
    """The real pallas_call pipeline (block histograms -> block x digit
    prefix -> rank kernel), multi-pass composed, equals the sort arm — the
    TPU code path exercised in interpret mode, not just its dense twin."""
    digits = jnp.asarray(rng.integers(0, 300, 700).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 1 << 16, 700).astype(np.int32))
    kp, (kc,), ko, ks_ = kops.partition_plan(
        digits, 300, carry=(keys,), impl="pallas", pass_impl="kernel")
    xp, (xc,), xo, xs = kops.partition_plan(
        digits, 300, carry=(keys,), impl="xla")
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(xp))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(xc))
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(xo))
    np.testing.assert_array_equal(np.asarray(ks_), np.asarray(xs))


def test_sort_plan_radix_equals_xla_sort(rng):
    """The sort-free full-key sort plan (sign-biased LSD rank passes) equals
    XLA's stable sort bit-for-bit — negative keys included."""
    keys = jnp.asarray(
        rng.integers(-(1 << 31), (1 << 31) - 1, 1200).astype(np.int64)
        .astype(np.int32))
    sk_r, pr = prim.plan_sort_permutation(keys, impl="radix")
    sk_x, px = prim.plan_sort_permutation(keys, impl="xla")
    np.testing.assert_array_equal(np.asarray(sk_r), np.asarray(sk_x))
    np.testing.assert_array_equal(np.asarray(pr), np.asarray(px))
    # unsigned keys take NO sign bias: full uint32 range, high bit set
    ukeys = jnp.asarray(np.array([0, 0x80000000, 5, 0xFFFFFFFF, 0x7FFFFFFF],
                                 np.uint32))
    usk_r, upr = prim.plan_sort_permutation(ukeys, impl="radix")
    usk_x, upx = prim.plan_sort_permutation(ukeys, impl="xla")
    np.testing.assert_array_equal(np.asarray(usk_r), np.asarray(usk_x))
    np.testing.assert_array_equal(np.asarray(upr), np.asarray(upx))
    import jax

    jx = jax.make_jaxpr(
        lambda k: prim.plan_sort_permutation(k, impl="radix"))(keys)
    assert _count_sorts(jx.jaxpr) == 0


# ---------------------------------------------------------------------------
# Layout dtype contract: offsets/sizes are int32 on every path
# ---------------------------------------------------------------------------
def _assert_int32(*arrays):
    for a in arrays:
        assert a.dtype == jnp.int32, a.dtype


def test_layout_dtypes_are_int32(rng):
    digits = jnp.asarray(rng.integers(0, 64, 500).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 1 << 16, 500).astype(np.int32))

    perm, off, sz = prim.partition_permutation(digits, 64)
    _assert_int32(perm, off, sz)
    for impl in ("pallas", "xla"):
        perm, off, sz = prim.plan_partition_permutation(digits, 64, impl=impl)
        _assert_int32(perm, off, sz)
        perm, off, sz = prim.plan_partition_permutation(
            digits, 64, max_pass_bits=4, impl=impl)
        _assert_int32(perm, off, sz)
    *_, off, sz = prim.multi_pass_radix_partition(keys, total_bits=12)
    _assert_int32(off, sz)
    *_, off, sz = prim.radix_partition(keys, start_bit=0, num_bits=6)
    _assert_int32(off, sz)
    for impl in ("pallas", "xla"):
        dest, off, sz = kops.partition_ranks(digits, 64, impl)
        _assert_int32(dest, off, sz)
    _, perm = prim.plan_sort_permutation(keys)
    _assert_int32(perm)
    _, perm = prim.plan_sort_permutation(keys, impl="radix")
    _assert_int32(perm)

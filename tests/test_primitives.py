"""Core primitive properties: sort/partition stability, compaction,
expansion, multi-pass radix composition, hash quality (hypothesis)."""
from __future__ import annotations

from hypothesis import given, settings, strategies as st
import jax.numpy as jnp
import numpy as np

from repro.core import primitives as prim
from repro.core.hash_join import choose_partition_bits, hash32


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), bits=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_radix_partition_is_stable(n, bits, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.int32))
    vals = jnp.arange(n, dtype=jnp.int32)  # original positions
    ko, vo, off, sz = prim.radix_partition(keys, vals, start_bit=0, num_bits=bits)
    digits = np.asarray(prim.radix_digits(ko, 0, bits))
    assert (np.diff(digits) >= 0).all()  # partitioned
    # stability: within each partition, original positions are increasing
    vo_np = np.asarray(vo)
    for p in range(1 << bits):
        seg = vo_np[digits == p]
        assert (np.diff(seg) > 0).all() if len(seg) > 1 else True
    # offsets/sizes describe the layout
    assert int(sz.sum()) == n
    np.testing.assert_array_equal(
        np.asarray(off), np.concatenate([[0], np.cumsum(np.asarray(sz))[:-1]])
    )


def test_multi_pass_equals_single_partition(rng):
    keys = jnp.asarray(rng.integers(0, 1 << 20, 3000).astype(np.int32))
    vals = jnp.arange(3000, dtype=jnp.int32)
    # 12 bits in one conceptual partition == two 8+4-bit stable passes
    ko1, vo1, off1, sz1 = prim.multi_pass_radix_partition(keys, vals, total_bits=12)
    digits = prim.radix_digits(keys, 0, 12)
    perm, off2, sz2 = prim.partition_permutation(digits, 1 << 12)
    np.testing.assert_array_equal(np.asarray(ko1), np.asarray(jnp.take(keys, perm)))
    np.testing.assert_array_equal(np.asarray(vo1), np.asarray(jnp.take(vals, perm)))
    np.testing.assert_array_equal(np.asarray(off1), np.asarray(off2))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 1000), cap=st.integers(1, 1200), seed=st.integers(0, 2**31 - 1))
def test_compact_properties(n, cap, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.5)
    vals = jnp.arange(n, dtype=jnp.int32)
    (out,), count = prim.compact(mask, [vals], cap, fill=-7)
    expect = np.asarray(vals)[np.asarray(mask)][:cap]
    c = int(count)
    assert c == min(int(mask.sum()), cap)
    np.testing.assert_array_equal(np.asarray(out[:c]), expect[:c])
    assert (np.asarray(out[c:]) == -7).all()
    # stability: surviving values keep relative order (they're increasing)
    assert (np.diff(np.asarray(out[:c])) > 0).all() if c > 1 else True


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), cap=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1))
def test_expand_offsets_properties(n, cap, seed):
    rng = np.random.default_rng(seed)
    counts = jnp.asarray(rng.integers(0, 6, n).astype(np.int32))
    row, rank, valid, total = prim.expand_offsets(counts, cap)
    cn = np.asarray(counts)
    assert int(total) == cn.sum()
    row, rank, valid = np.asarray(row), np.asarray(rank), np.asarray(valid)
    m = min(cn.sum(), cap)
    assert valid[:m].all() and not valid[m:].any()
    # each valid output row points at a row with rank < counts[row]
    assert (rank[:m] < cn[row[:m]]).all()
    # expansion is row-sorted and rank-sequential within rows
    assert (np.diff(row[:m]) >= 0).all()


def test_hash32_avalanche(rng):
    """Low bits of the hash must be near-uniform even for sequential keys."""
    keys = jnp.arange(1 << 14, dtype=jnp.int32)
    for bits in (4, 8):
        d = np.asarray(hash32(keys) & ((1 << bits) - 1))
        counts = np.bincount(d, minlength=1 << bits)
        assert counts.max() < 2.0 * counts.mean()


def test_choose_partition_bits_bounds():
    for n, blk in ((1000, 256), (1 << 20, 256), (10, 64)):
        bits = choose_partition_bits(n, blk)
        assert 1 <= bits <= 20
        # expected partition size <= blk/2 (headroom against overflow)
        assert n / (1 << bits) <= blk


def test_sort_pairs_multiple_values(rng):
    k = jnp.asarray(rng.integers(0, 100, 500).astype(np.int32))
    v1 = jnp.arange(500, dtype=jnp.int32)
    v2 = jnp.asarray(rng.normal(size=500).astype(np.float32))
    ko, v1o, v2o = prim.sort_pairs(k, v1, v2)
    order = np.lexsort((np.asarray(v1), np.asarray(k)))  # stable by key
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(k)[order])
    np.testing.assert_array_equal(np.asarray(v1o), np.asarray(v1)[order])
    np.testing.assert_array_equal(np.asarray(v2o), np.asarray(v2)[order])


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 1500), hi=st.sampled_from([100, 1 << 16, (1 << 30) - 1]),
       seed=st.integers(0, 2**31 - 1))
def test_radix_sort_pairs_equals_sort(n, hi, seed):
    """The paper-faithful LSD radix sort == XLA's stable sort."""
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.integers(0, hi, n).astype(np.int32))
    v = jnp.arange(n, dtype=jnp.int32)
    ko, vo = prim.radix_sort_pairs(k, v)
    kr, vr = prim.sort_pairs(k, v)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(kr))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vr))

"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
sweeping shapes and dtypes (hypothesis) per the repo contract."""
from __future__ import annotations

from hypothesis import given, settings, strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.histogram import histogram_pallas
from repro.kernels.radix_partition import partition_ranks_pallas
from repro.kernels.segsum import segsum_partials_pallas


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 3000), bins=st.sampled_from([2, 7, 16, 64, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_histogram_sweep(n, bins, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.integers(0, bins, n).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(histogram_pallas(d, bins)), np.asarray(ref.histogram(d, bins))
    )


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 2000), bins=st.sampled_from([2, 8, 32, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_partition_ranks_sweep(n, bins, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.integers(0, bins, n).astype(np.int32))
    dest, off, sz = partition_ranks_pallas(d, bins)
    np.testing.assert_array_equal(np.asarray(dest), np.asarray(ref.partition_ranks(d, bins)))
    # applying the ranks yields a stable partition
    outs = ops.apply_partition(dest, d)
    assert bool((jnp.diff(outs[0]) >= 0).all())


def test_pad_rows_excluded_by_construction(rng):
    """Histogram/rank kernels must exclude PAD_DIGIT rows via the explicit
    mask in `digit_onehot` — any negative digit counts nowhere and gets no
    destination, however the bins are laid out."""
    from repro.kernels.common import digit_onehot
    from repro.kernels.radix_partition import block_histograms_pallas

    d = np.asarray(rng.integers(0, 16, 100).astype(np.int32))
    d[::7] = -1  # explicit pad/sentinel rows inside the data
    dj = jnp.asarray(d)
    assert int(histogram_pallas(dj, 16).sum()) == int((d >= 0).sum())
    assert int(block_histograms_pallas(dj, 16).sum()) == int((d >= 0).sum())
    dest, _, sizes = partition_ranks_pallas(dj, 16)
    assert int(sizes.sum()) == int((d >= 0).sum())
    assert (np.asarray(dest)[d < 0] == -1).all()
    # the shared one-hot core masks any negative digit, not just -1
    oh = np.asarray(digit_onehot(jnp.asarray([-5, 0, 3, -1], jnp.int32), 4))
    np.testing.assert_array_equal(oh.sum(axis=1), [0, 1, 1, 0])


def test_interpret_resolution_env_override(monkeypatch):
    """Backend detection picks interpret off-TPU; REPRO_PALLAS_INTERPRET
    overrides it both ways."""
    from repro.kernels import common

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert common.default_interpret() == (not on_tpu)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert common.default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert common.default_interpret() is True
    assert common.resolve_interpret(None) is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "off")
    assert common.resolve_interpret(None) is False
    assert common.resolve_interpret(True) is True  # explicit flag wins


@settings(max_examples=10, deadline=None)
@given(nb=st.integers(10, 4000), npr=st.integers(10, 4000),
       seed=st.integers(0, 2**31 - 1))
def test_merge_lower_bound_sweep(nb, npr, seed):
    rng = np.random.default_rng(seed)
    b = jnp.sort(jnp.asarray(rng.integers(0, 1 << 20, nb).astype(np.int32)))
    p = jnp.sort(jnp.asarray(rng.integers(0, 1 << 20, npr).astype(np.int32)))
    lb = ops.merge_lower_bound(b, p, "auto", window_rows=256, tile=256)
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(ref.lower_bound(b, p)))


def test_hash_probe_matches_ref(rng):
    from repro.core import primitives as prim
    from repro.core.hash_join import hash32, build_blocks

    nR, nS, p_bits, cap = 1500, 4000, 5, 256
    P = 1 << p_bits
    rkeys = jnp.asarray(rng.permutation(50000)[:nR].astype(np.int32))
    skeys = jnp.asarray(rng.choice(np.asarray(rkeys), nS).astype(np.int32))
    dig_r = (hash32(rkeys) & (P - 1)).astype(jnp.int32)
    dig_s = (hash32(skeys) & (P - 1)).astype(jnp.int32)
    perm_r, off_r, sz_r = prim.partition_permutation(dig_r, P)
    perm_s, off_s, sz_s = prim.partition_permutation(dig_s, P)
    kr, ks = jnp.take(rkeys, perm_r), jnp.take(skeys, perm_s)
    bkeys, _, ovf = build_blocks(kr, off_r, sz_r, cap)
    assert not bool(ovf)
    vid_p, hit_p = ops.hash_probe(bkeys, off_r, ks, off_s, sz_s, "pallas")
    vid_x, hit_x = ops.hash_probe(bkeys, off_r, ks, off_s, sz_s, "xla")
    np.testing.assert_array_equal(np.asarray(hit_p), np.asarray(hit_x))
    np.testing.assert_array_equal(
        np.asarray(jnp.where(hit_p, vid_p, -1)), np.asarray(jnp.where(hit_x, vid_x, -1))
    )
    assert bool(hit_p.all())
    assert bool((jnp.take(kr, vid_p) == ks).all())


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_gather_windowed_dtypes(dtype, rng):
    n = 6000
    if dtype == np.int32:
        src = jnp.asarray(rng.integers(0, (1 << 31) - 1, n).astype(dtype))
    else:
        src = jnp.asarray(rng.normal(size=n).astype(dtype))
    idx = jnp.sort(jnp.asarray(rng.integers(0, n, 3000).astype(np.int32)))
    out = ops.clustered_gather(src, idx, "auto", window_rows=512, tile=512)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(jnp.take(src, idx)))


def test_gather_unclustered_fallback(rng):
    src = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    idx = jnp.asarray(rng.permutation(4096).astype(np.int32))
    out = ops.clustered_gather(src, idx, "auto", window_rows=256, tile=256)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(jnp.take(src, idx)))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 3000), g=st.integers(1, 100), tile=st.sampled_from([64, 256]),
       seed=st.integers(0, 2**31 - 1))
def test_segsum_partials_sweep(n, g, tile, seed):
    rng = np.random.default_rng(seed)
    keys = jnp.sort(jnp.asarray(rng.integers(0, g, n).astype(np.int32)))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    pk, ps, pc = segsum_partials_pallas(keys, vals, tile=tile)
    rk, rs, rc = ref.segsum_partials(keys, vals, tile)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(ps), np.asarray(rs), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))


def test_groupby_sorted_sum_end_to_end(rng):
    keys = jnp.sort(jnp.asarray(rng.integers(0, 77, 5000).astype(np.int32)))
    vals = jnp.asarray(rng.normal(size=5000).astype(np.float32))
    gk, gs, cnt = ops.groupby_sorted_sum(keys, vals, 128, "pallas")
    import collections
    exp = collections.defaultdict(float)
    for k, v in zip(np.asarray(keys), np.asarray(vals)):
        exp[int(k)] += float(v)
    got = {int(k): float(s) for k, s in zip(np.asarray(gk), np.asarray(gs)) if k != -1}
    assert int(cnt) == len(exp)
    for k in exp:
        assert abs(got[k] - exp[k]) < 1e-2

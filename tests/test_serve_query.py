"""Query-serving runtime: bucketing, signatures, admission, breakers,
saturation recovery, and the chaos harness (DESIGN.md §14)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table
from repro.data import relgen
from repro.engine import Catalog, optimize, scan
from repro.obs import metrics
from repro.serve import query as Q


def canon(table, count):
    n = int(count)
    cols = sorted(table.column_names)
    mats = [np.asarray(table[c])[:n] for c in cols]
    return tuple(cols), sorted(zip(*[m.tolist() for m in mats]))


def make_join_tables(n_r, n_s, seed=0):
    R, S = relgen.generate(relgen.JoinWorkload("t", n_r, n_s, 1, 1,
                                               seed=seed))
    return {"R": R, "S": S}


def one_shot(plan, tables):
    return canon(*optimize(plan, Catalog(tables),
                           measure_profile=False).run())


JOIN_PLAN = scan("S").join(scan("R"), key="k")


# ---------------------------------------------------------------------------
# bucketing / padding / signatures
# ---------------------------------------------------------------------------
def test_bucket_rows_power_of_two_floor():
    assert Q.bucket_rows(0) == Q.MIN_BUCKET
    assert Q.bucket_rows(1) == Q.MIN_BUCKET
    assert Q.bucket_rows(64) == 64
    assert Q.bucket_rows(65) == 128
    assert Q.bucket_rows(1500) == 2048
    assert Q.bucket_rows(2048) == 2048


def test_pad_table_preserves_uniqueness_and_wraps_floats():
    t = Table({"k": jnp.asarray(np.array([5, 3, 9], np.int32)),
               "x": jnp.asarray(np.array([1.5, 2.5, 3.5], np.float32))})
    p = Q.pad_table(t, 8)
    assert p.num_rows == 8
    k = np.asarray(p["k"])
    # original rows intact, integer padding continues past the max so the
    # column stays unique (PK-FK proofs survive padding)
    assert k[:3].tolist() == [5, 3, 9]
    assert len(set(k.tolist())) == 8
    assert k[3:].min() > 9
    assert np.asarray(p["x"])[:3].tolist() == [1.5, 2.5, 3.5]
    assert Q.pad_table(t, 3) is t
    with pytest.raises(ValueError):
        Q.pad_table(t, 2)


def test_plan_signature_buckets_collapse_sizes():
    t1 = make_join_tables(400, 1500, seed=1)
    t2 = make_join_tables(450, 1200, seed=2)  # same buckets (512, 2048)
    t3 = make_join_tables(400, 2500, seed=3)  # S in the next bucket
    s1, b1 = Q.plan_signature(JOIN_PLAN, t1)
    s2, _ = Q.plan_signature(JOIN_PLAN, t2)
    s3, _ = Q.plan_signature(JOIN_PLAN, t3)
    assert s1 == s2
    assert s1 != s3
    assert b1 == {"R": 512, "S": 2048}
    # the plan tree (filter constants included) is part of the identity
    f1 = scan("S").filter("s1", "<", 10).join(scan("R"), key="k")
    f2 = scan("S").filter("s1", "<", 11).join(scan("R"), key="k")
    assert Q.plan_signature(f1, t1)[0] != Q.plan_signature(f2, t1)[0]


def test_executor_counts_reuse_one_compiled_plan():
    """The bucketed executable (counts as traced scalars) serves multiple
    datasets padded to the same buckets, bit-identically to per-dataset
    one-shot runs — without touching the count-free compiled slot."""
    datasets = [make_join_tables(400, 1500, seed=4),
                make_join_tables(450, 1200, seed=5)]
    sig, buckets = Q.plan_signature(JOIN_PLAN, datasets[0])
    padded0 = {n: Q.pad_table(t, buckets[n]) for n, t in datasets[0].items()}
    plan = optimize(JOIN_PLAN, Catalog(padded0), measure_profile=False)
    for tb in datasets:
        padded = {n: Q.pad_table(t, buckets[n]) for n, t in tb.items()}
        counts = {n: t.num_rows for n, t in tb.items()}
        got = canon(*plan.run(padded, counts=counts))
        assert got == one_shot(JOIN_PLAN, tb)
    assert plan.compiled_bucketed is not None
    assert plan.compiled is None  # the legacy slot never materialized


# ---------------------------------------------------------------------------
# server: fast path, cache sharing, admission control
# ---------------------------------------------------------------------------
def drive(server, reqs, per_tick=4, max_ticks=500):
    i = 0
    while (i < len(reqs) or server.queue
           or server.deferred) and server.tick < max_ticks:
        for _ in range(per_tick):
            if i < len(reqs):
                server.submit(reqs[i])
                i += 1
        server.step()


def test_server_shares_compiled_plan_across_sizes():
    sizes = [(400, 1500), (450, 1200), (300, 1700)]
    reqs = [Q.QueryRequest(qid=i, plan=JOIN_PLAN,
                           tables=make_join_tables(nr, ns, seed=10 + i))
            for i, (nr, ns) in enumerate(sizes)]
    before = metrics.counter("qserve.plans_compiled").value
    server = Q.QueryServer()
    drive(server, reqs)
    assert metrics.counter("qserve.plans_compiled").value == before + 1
    for req in reqs:
        assert req.done and not req.error and req.path == "fast"
        assert canon(*req.result) == one_shot(JOIN_PLAN, req.tables)
        assert req.signature == reqs[0].signature
        assert req.exec_wall_s > 0 and req.done_tick >= req.submit_tick


def test_server_admission_price_and_shedding():
    tb = make_join_tables(400, 1500, seed=20)
    priced = Q.QueryServer(max_price_s=0.0)
    req = Q.QueryRequest(qid=0, plan=JOIN_PLAN, tables=tb)
    priced.submit(req)
    priced.run()
    assert req.error == "rejected" and req.result is None

    shedder = Q.QueryServer(max_queue=2)
    reqs = [Q.QueryRequest(qid=i, plan=JOIN_PLAN, tables=tb)
            for i in range(5)]
    for r in reqs:
        shedder.submit(r)  # all before any tick: 2 queued, 3 shed
    assert [r.error for r in reqs] == ["", "", "shed", "shed", "shed"]
    shedder.run()
    assert all(not r.error for r in reqs[:2])


def test_server_deadline_expires_on_admission_tick():
    """A queued query whose deadline lands exactly on the tick it would be
    admitted is evicted, not run: the deadline sweep precedes admission."""
    tb = make_join_tables(400, 1500, seed=21)
    server = Q.QueryServer(slots_per_tick=1)
    first = Q.QueryRequest(qid=0, plan=JOIN_PLAN, tables=tb)
    racer = Q.QueryRequest(qid=1, plan=JOIN_PLAN, tables=tb,
                           deadline_ticks=2)  # would be admitted at tick 2
    server.submit(first)
    server.submit(racer)
    server.run()
    assert first.done and not first.error
    assert racer.error == "deadline" and racer.result is None
    assert racer.done_tick == 2 and racer.admit_tick == -1


def test_server_tick_budget_paces_admission():
    tb = make_join_tables(400, 1500, seed=22)
    server = Q.QueryServer(slots_per_tick=4)
    probe = Q.QueryRequest(qid=0, plan=JOIN_PLAN, tables=tb)
    server.submit(probe)
    server.run()
    assert probe.done and probe.price_s > 0
    # budget covers exactly one query per tick: 3 queries take 3 ticks
    budget = Q.QueryServer(slots_per_tick=4,
                           tick_budget_s=probe.price_s * 1.5)
    reqs = [Q.QueryRequest(qid=i, plan=JOIN_PLAN, tables=tb)
            for i in range(3)]
    for r in reqs:
        budget.submit(r)
    budget.run()
    assert [r.admit_tick for r in reqs] == [1, 2, 3]
    assert all(not r.error for r in reqs)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
def test_breaker_state_machine():
    br = Q.CircuitBreaker("sig", threshold=2, cooldown=3, max_cooldown=12)
    assert br.route(1) == "fast"
    br.record_fast_failure(1)
    assert br.state == Q.CLOSED  # one failure is not a pattern
    br.record_fast_failure(2)
    assert br.state == Q.OPEN
    assert br.route(3) == "safe"  # quarantined during cooldown
    assert br.route(5) == "fast" and br.state == Q.HALF_OPEN  # probe
    br.record_fast_failure(5)  # probe failed: reopen, cooldown doubles
    assert br.state == Q.OPEN and br.cooldown == 6
    assert br.route(7) == "safe"
    assert br.route(11) == "fast" and br.state == Q.HALF_OPEN
    br.record_fast_success(11)  # probe succeeded: close, cooldown resets
    assert br.state == Q.CLOSED and br.cooldown == 3
    assert br.route(12) == "fast"


def test_server_breaker_quarantines_and_recovers():
    """First two queries of a signature hard-fail -> breaker opens; while
    open, queries ride the safe path; a half-open probe after the fault
    clears closes it again. Results on every path match the oracle."""
    plan = scan("S").group_by("k", s1="sum")
    mk = lambda i: {"S": relgen.generate(  # noqa: E731
        relgen.JoinWorkload("t", 400, 1500, 1, 1, seed=40 + i))[1]}
    server = Q.QueryServer(breaker_cooldown=2)
    reqs = [Q.QueryRequest(qid=i, plan=plan, tables=mk(i),
                           fault_spec="raise:qserve.execute" if i < 2 else "")
            for i in range(8)]
    drive(server, reqs, per_tick=1)
    assert [r.qid for r in reqs if r.error] == [0, 1]
    paths = [r.path for r in reqs if not r.error]
    assert "safe" in paths  # quarantine actually ran
    assert paths[-1] == "fast"  # and the probe recovered the fast path
    br = server.breakers[reqs[0].signature]
    assert br.state == Q.CLOSED
    for r in reqs[2:]:
        assert canon(*r.result) == one_shot(plan, r.tables)


def test_server_saturation_escalates_to_correct_result():
    """estimates:/32 poisons the cached plan's capacities at planning time;
    saturation detection must catch the silent truncation and the safe
    path must escalate degrade levels until results match the oracle."""
    plan = scan("S").group_by("k", s1="sum")
    # sparse keys: domain 5000 >> distinct, so capacities hinge on the
    # (corrupted) distinct estimate
    mk = lambda i: {"S": relgen.generate(  # noqa: E731
        relgen.JoinWorkload("t", 5000, 1500, 1, 1, seed=50 + i))[1]}
    before = metrics.counter("qserve.saturations").value
    server = Q.QueryServer(breaker_cooldown=2)
    reqs = [Q.QueryRequest(qid=i, plan=plan, tables=mk(i),
                           fault_spec="estimates:/32") for i in range(4)]
    drive(server, reqs, per_tick=1)
    assert metrics.counter("qserve.saturations").value > before
    entry = server.cache[reqs[0].signature]
    assert entry.safe_level > 0  # converged level cached for the signature
    for r in reqs:
        assert r.done and not r.error, (r.qid, r.detail)
        assert canon(*r.result) == one_shot(plan, r.tables)


# ---------------------------------------------------------------------------
# memory governor (DESIGN.md §15): bytes tickets, deferral, morsel runs
# ---------------------------------------------------------------------------
def test_server_mem_rejects_unsplittable_with_typed_error():
    """A query that can NEVER fit the budget (top-k has no morsel axis)
    must be rejected with the typed error — not crash, not defer forever."""
    tables = {"S": relgen.generate(
        relgen.JoinWorkload("t", 5000, 1500, 1, 1, seed=9))[1]}
    plan = scan("S").filter("s1", "<", 1 << 30).order_by("s1", limit=32)
    before = metrics.counter("qserve.mem_rejections").value
    server = Q.QueryServer(measure_profile=False, mem_budget_bytes=4096)
    req = Q.QueryRequest(qid=0, plan=plan, tables=tables)
    server.submit(req)
    server.run()
    assert req.error == "rejected"
    assert "MemoryBudgetExceeded" in req.detail
    assert metrics.counter("qserve.mem_rejections").value == before + 1
    assert server.budget.reserved == 0


def test_server_chunked_run_bit_identical_under_tight_budget():
    """A splittable query whose whole-plan peak exceeds the budget must be
    served through the morsel driver, bit-identical to its oracle."""
    rng = np.random.default_rng(11)
    mk = lambda: {"B": Table(  # noqa: E731
        {f"c{c}": jnp.asarray(rng.integers(0, 100, 30_000).astype(np.int32))
         for c in range(16)})}
    plan = scan("B").filter("c0", "<", 60)
    t0 = mk()
    padded = {n: Q.pad_table(t, Q.bucket_rows(t.num_rows))
              for n, t in t0.items()}
    phys = optimize(plan, Catalog(padded), measure_profile=False)
    from repro.engine import plan_peak_bytes
    whole = plan_peak_bytes(phys, padded,
                            counts={n: t.num_rows for n, t in t0.items()})
    before = metrics.counter("qserve.chunked_runs").value
    server = Q.QueryServer(measure_profile=False,
                           mem_budget_bytes=int(whole * 0.6))
    reqs = [Q.QueryRequest(qid=i, plan=plan, tables=t0 if i == 0 else mk())
            for i in range(2)]
    drive(server, reqs, per_tick=1)
    for r in reqs:
        assert r.done and not r.error, (r.qid, r.detail)
        assert r.morsels >= 2
        assert canon(*r.result) == one_shot(plan, r.tables)
    entry = server.cache[reqs[0].signature]
    assert entry.morsel_factor >= 2  # sized ticket is the MORSEL peak
    assert entry.peak_bytes <= server.budget.total
    assert metrics.counter("qserve.chunked_runs").value == before + 2
    assert server.budget.reserved == 0
    assert server.budget.peak_reserved <= server.budget.total


def test_server_same_tick_contention_defers_not_sheds():
    """Two same-signature queries whose tickets cannot co-reside: the
    second DEFERS (ages in ticks_deferred, keeps no queue slot) and
    completes once the first releases its reservation."""
    tables = make_join_tables(400, 1500, seed=21)
    server0 = Q.QueryServer(measure_profile=False)
    probe = Q.QueryRequest(qid=99, plan=JOIN_PLAN, tables=tables)
    server0.submit(probe)
    server0.run()
    peak = server0.cache[probe.signature].peak_bytes
    assert peak > 0

    before = metrics.counter("qserve.mem_deferrals").value
    server = Q.QueryServer(measure_profile=False, slots_per_tick=2,
                           mem_budget_bytes=int(peak * 1.5))
    reqs = [Q.QueryRequest(qid=i, plan=JOIN_PLAN, tables=tables)
            for i in range(2)]
    drive(server, reqs, per_tick=2)
    for r in reqs:
        assert r.done and not r.error, (r.qid, r.detail)
        assert canon(*r.result) == one_shot(JOIN_PLAN, tables)
    assert metrics.counter("qserve.mem_deferrals").value > before
    assert reqs[1].ticks_deferred > 0
    assert reqs[0].ticks_deferred == 0
    assert server.budget.reserved == 0
    assert server.budget.peak_reserved <= server.budget.total


def test_server_deferred_request_does_not_starve_queue():
    """Regression: a memory-deferred request must NOT occupy a max_queue
    slot. With the old accounting a stuck query wedged a tiny queue and
    every later submission was shed."""
    tables = make_join_tables(350, 1300, seed=31)
    before_shed = metrics.counter("qserve.shed").value
    server = Q.QueryServer(measure_profile=False, max_queue=2,
                           slots_per_tick=2)
    # loses the (injected) allocation race on EVERY admission attempt:
    # permanently deferred until its deadline evicts it
    stuck = Q.QueryRequest(qid=0, plan=JOIN_PLAN, tables=tables,
                           fault_spec="oom:qserve.admit", deadline_ticks=8)
    server.submit(stuck)
    server.step()
    assert stuck in server.deferred and not server.queue
    later = [Q.QueryRequest(qid=1 + i, plan=JOIN_PLAN, tables=tables)
             for i in range(4)]
    for pair in (later[:2], later[2:]):
        for r in pair:
            server.submit(r)  # queue holds 2: at cap, NOT over it
        while server.queue:
            server.step()
    server.run()
    assert metrics.counter("qserve.shed").value == before_shed
    for r in later:
        assert r.done and not r.error, (r.qid, r.detail)
    assert stuck.error == "deadline"
    assert stuck.ticks_deferred > 0
    assert server.budget.reserved == 0


def test_chaos_smoke_single_family():
    """Tiny end-to-end chaos pass (full soak runs in scripts/ci.sh)."""
    from repro.serve import chaos

    rep = chaos.run_chaos(queries_per_family=24, smoke=True,
                          families=("estimates",))
    assert rep["ok"], rep["failures"]
    assert rep["baseline"]["p99_s"] > 0
    assert rep["baseline"]["throughput_qps"] > 0
    fam = rep["families"]["estimates"]
    assert fam["wrong_results"] == 0 and fam["contaminated"] == 0
    assert fam["counters"]["qserve.saturations"] > 0

"""Data pipeline (relational generators + feature-join) and serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced_config
from repro.core import KEY_SENTINEL
from repro.data import relgen
from repro.data.pipeline import (FeatureJoinConfig, assemble_batch, history_aggregates,
                                 make_dim_tables, make_fact_batch)
from repro.data.synthetic import make_batch_fn
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine


# ---------------------------------------------------------------------------
# relational workload generator (paper §5 matrix)
# ---------------------------------------------------------------------------
def test_relgen_match_ratio():
    for mr in (1.0, 0.5, 0.1):
        w = relgen.JoinWorkload("t", 2000, 4000, 1, 1, match_ratio=mr)
        R, S = relgen.generate(w)
        rset = set(np.asarray(R["k"]).tolist())
        hits = sum(1 for k in np.asarray(S["k"]) if int(k) in rset)
        assert abs(hits / 4000 - mr) < 0.06


def test_relgen_zipf_skew():
    w = relgen.JoinWorkload("t", 2000, 8000, 1, 1, zipf=1.5)
    _, S = relgen.generate(w)
    _, counts = np.unique(np.asarray(S["k"]), return_counts=True)
    assert counts.max() / counts.mean() > 10  # heavy head


def test_relgen_dtypes():
    w = relgen.JoinWorkload("t", 500, 500, 1, 1, key_dtype="int32",
                            payload_dtype="int32")
    R, S = relgen.generate(w)
    assert R["k"].dtype == jnp.int32 and R["r1"].dtype == jnp.int32


def test_tpc_extracts():
    for jid in ("J1", "J3", "J5"):
        R, S, mode = relgen.generate_tpc(jid, scale=1 / 2048)
        assert R.num_rows >= 1024 and S.num_rows >= 1024
        assert mode == ("mn" if jid == "J5" else "pk_fk")


def test_star_schema():
    fact, dims, fks, dks = relgen.generate_star(1000, 100, 3)
    assert len(dims) == 3 and all(f in fact for f in fks)


# ---------------------------------------------------------------------------
# feature-join pipeline (paper §1 use case)
# ---------------------------------------------------------------------------
def test_feature_join_pipeline_correct():
    cfg = FeatureJoinConfig(n_users=256, n_items=512)
    U, I = make_dim_tables(cfg)
    fact = make_fact_batch(cfg, 2, 32, step=0)
    batch, joined, count = assemble_batch(cfg, U, I, fact, 2, 32)
    assert int(count) == 64
    assert batch["tokens"].shape == (2, 33)
    # verify joined features against a numpy join
    umap = {int(k): float(v) for k, v in zip(np.asarray(U["uid"]), np.asarray(U["uf0"]))}
    fk = np.asarray(fact["fk_user"])
    got = np.asarray(joined["uf0"])
    fid = np.asarray(joined["_fact_id"])
    assert (fid == np.arange(64)).all()  # restore_order: canonical sample order
    for i in range(64):
        assert abs(got[i] - umap[int(fk[fid[i]])]) < 1e-6


def test_feature_join_patterns_agree():
    cfg_a = FeatureJoinConfig(algorithm="phj", pattern="gftr")
    cfg_b = FeatureJoinConfig(algorithm="smj", pattern="gfur")
    U, I = make_dim_tables(cfg_a)
    fact = make_fact_batch(cfg_a, 2, 16, step=3)
    ba, ja, _ = assemble_batch(cfg_a, U, I, fact, 2, 16)
    bb, jb, _ = assemble_batch(cfg_b, U, I, fact, 2, 16)
    np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))


def test_history_aggregates():
    cfg = FeatureJoinConfig(n_users=64)
    fact = make_fact_batch(cfg, 4, 64, step=0)
    G, count = history_aggregates(cfg, fact, num_groups=256)
    labels = np.asarray(fact["label"]).astype(np.float64)
    users = np.asarray(fact["fk_user"])
    ks = np.asarray(G["k"])
    means = np.asarray(G["label_mean"])
    for i, k in enumerate(ks):
        if k == KEY_SENTINEL:
            continue
        ref = labels[users == int(k)].mean()
        assert abs(means[i] - ref) < 1e-5


def test_synthetic_batches_deterministic():
    f = make_batch_fn(100, 2, 16, seed=7)
    a, b = f(3), f(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = f(4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


# ---------------------------------------------------------------------------
# serving engine: continuous batching
# ---------------------------------------------------------------------------
def test_serve_engine_completes_all_requests(rng):
    cfg = get_reduced_config("olmo-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=3, max_len=64, eos_id=-1)
    reqs = [Request(rid=i, prompt=rng.integers(3, cfg.vocab_size, 4).tolist(),
                    max_tokens=5) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    # slot reuse happened: 7 requests through 3 slots
    assert not eng.queue and all(s is None for s in eng.slot_req)


def test_serve_engine_greedy_determinism(rng):
    """Same prompt twice -> same output (greedy decode, shared cache pos)."""
    cfg = get_reduced_config("granite-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    prompt = rng.integers(3, cfg.vocab_size, 5).tolist()
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64, eos_id=-1)
        r = Request(rid=0, prompt=list(prompt), max_tokens=6)
        eng.submit(r)
        eng.run()
        outs.append(r.out)
    assert outs[0] == outs[1]


def test_slot_reuse_no_leak(rng):
    """A request admitted into a freed slot must produce exactly the output
    it would produce in a fresh engine (no cache leakage from the previous
    occupant, per-slot positions start at 0)."""
    cfg = get_reduced_config("olmo-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    p1 = rng.integers(3, cfg.vocab_size, 6).tolist()
    p2 = rng.integers(3, cfg.vocab_size, 4).tolist()

    # reference: request 2 alone in a fresh engine
    eng_ref = ServeEngine(cfg, params, max_batch=1, max_len=64, eos_id=-1)
    r_ref = Request(rid=0, prompt=list(p2), max_tokens=5)
    eng_ref.submit(r_ref)
    eng_ref.run()

    # same request through a REUSED slot (after request 1 finished in it)
    eng = ServeEngine(cfg, params, max_batch=1, max_len=64, eos_id=-1)
    r1 = Request(rid=1, prompt=list(p1), max_tokens=7)
    r2 = Request(rid=2, prompt=list(p2), max_tokens=5)
    eng.submit(r1)
    eng.submit(r2)
    eng.run()
    assert r1.done and r2.done
    assert r2.out == r_ref.out, (r2.out, r_ref.out)


def test_serve_engine_memory_deferral_accounting(rng):
    """A memory-deferred request must age as DEFERRED — never as running
    and not as plain queue time — and still complete once in-flight work
    releases its bytes ticket; latency_summary() reports the stage."""
    cfg = get_reduced_config("olmo-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, eos_id=-1,
                      mem_budget_bytes=1000)
    prompts = [rng.integers(3, cfg.vocab_size, 3).tolist() for _ in range(2)]
    r1 = Request(rid=0, prompt=prompts[0], max_tokens=4, mem_bytes=800)
    r2 = Request(rid=1, prompt=prompts[1], max_tokens=4, mem_bytes=800)
    eng.submit(r1)
    eng.submit(r2)
    eng.run()
    assert r1.done and r2.done and not r1.error and not r2.error
    # 800 + 800 > 1000: r2 held the queue head until r1 released
    assert r1.ticks_deferred == 0
    assert r2.ticks_deferred > 0
    # deferral never counts as slot residency: both ran the same ticks
    assert r2.ticks_running == r1.ticks_running
    assert eng.budget.reserved == 0
    assert eng.budget.peak_reserved <= 1000
    assert "ticks_deferred" in ServeEngine.latency_summary()


def test_vector_pos_decode_matches_scalar(rng):
    """decode_step with a constant (b,) pos vector == scalar pos."""
    import jax
    cfg = get_reduced_config("granite-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 4)).astype(np.int32))}
    c1 = M.init_cache(cfg, params, b, 16, batch, jnp.float32)
    c2 = jax.tree_util.tree_map(jnp.copy, c1)
    for t in range(3):
        l1, c1 = M.decode_step(cfg, params, c1, batch["tokens"][:, t], jnp.int32(t))
        l2, c2 = M.decode_step(cfg, params, c2, batch["tokens"][:, t],
                               jnp.full((b,), t, jnp.int32))
        assert float(jnp.abs(l1 - l2).max()) < 1e-6

"""Regression tripwire for missing modules: import every module under
src/repro/ and run the quickstart example end-to-end.

The seed repo shipped with six modules importing a package that did not
exist, which killed collection of five unrelated test files. This test
makes any future missing-module (or import-time) regression fail loudly in
exactly one place instead.
"""
from __future__ import annotations

import importlib
import os
import pkgutil
import subprocess
import sys

from conftest import REPO
import pytest

SRC = os.path.join(REPO, "src")

# launch.dryrun pins XLA_FLAGS for a 512-device dry-run as an import side
# effect (by design: it must run before jax initializes). Import it in a
# subprocess so this process's device count stays untouched.
SUBPROCESS_ONLY = {"repro.launch.dryrun"}


def _walk_modules() -> list[str]:
    import repro

    names = ["repro"]
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(info.name)
    return sorted(names)


def test_every_repro_module_imports():
    failures = []
    for name in _walk_modules():
        if name in SUBPROCESS_ONLY:
            continue
        try:
            importlib.import_module(name)
        except Exception as e:  # noqa: BLE001 — collect them all, then fail
            failures.append(f"{name}: {type(e).__name__}: {e}")
    assert not failures, "unimportable modules:\n" + "\n".join(failures)


def test_walk_found_the_tree():
    """The walker itself must see the known subpackages — an empty walk
    would make the import test pass vacuously."""
    names = _walk_modules()
    for pkg in ("repro.core", "repro.kernels", "repro.dist.sharding",
                "repro.models.model", "repro.train.step", "repro.launch.mesh"):
        assert pkg in names, f"{pkg} missing from module walk"
    assert len(names) > 40


@pytest.mark.parametrize("module", sorted(SUBPROCESS_ONLY))
def test_env_mutating_modules_import_in_subprocess(module):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", f"import {module}; print('IMPORTED')"],
        capture_output=True, text=True, timeout=240, env=env, cwd=REPO,
    )
    assert res.returncode == 0 and "IMPORTED" in res.stdout, res.stderr[-2000:]


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        capture_output=True, text=True, timeout=300, env=env, cwd=REPO,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = res.stdout
    # all four join variants + the group-by + the planner verdict printed
    for tag in ("SMJ-UM", "SMJ-OM", "PHJ-UM", "PHJ-OM", "group-by",
                "planner picks"):
        assert tag in out, f"missing {tag!r} in quickstart output:\n{out}"

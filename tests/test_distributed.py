"""Multi-device behavior (8 fake host devices, subprocess-isolated so the
main pytest process keeps 1 device): sharded train/serve step execution,
elastic remesh, pipeline parallelism, compressed DP all-reduce, dry-run on
tiny configs for both mesh layouts."""
from __future__ import annotations

from conftest import run_subtest


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_subtest("""
import jax, jax.numpy as jnp, numpy as np, functools
from repro.configs.base import get_reduced_config
from repro.dist import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train.optimizer import AdamW
from repro.train import step as STEP

cfg = get_reduced_config("olmo-1b")
mesh = make_mesh((2, 4), ("data", "model"))
rules = SH.default_rules()
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = AdamW(lr=1e-2, master_weights=True)
opt_state = opt.init(params)
rngn = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rngn.integers(0, cfg.vocab_size, (4, 17)).astype(np.int32))}

step, psh, bsh = STEP.build_train_step(cfg, mesh, rules, opt, donate=False)
p2, s2, m2 = step(params, opt_state, batch)

# single-device reference
def ref_step(params, opt_state, batch):
    (l, met), g = jax.value_and_grad(functools.partial(M.loss_fn, cfg), has_aux=True)(params, batch)
    p, s, gn = opt.update(g, opt_state, params)
    return p, s, dict(met, loss=l)
p1, s1, m1 = jax.jit(ref_step)(params, opt_state, batch)
dl = abs(float(m1["loss"]) - float(m2["loss"]))
dw = max(float(jnp.abs(a - b).max())
         for a, b in zip(jax.tree_util.tree_leaves(p1),
                         jax.tree_util.tree_leaves(p2)))
print("dloss", dl, "dw", dw)
assert dl < 1e-4 and dw < 5e-3  # Adam amplifies reduction-order noise
print("OK")
""")
    assert "OK" in out


def test_sharded_serve_step_runs():
    out = run_subtest("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced_config
from repro.dist import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train import step as STEP

cfg = get_reduced_config("mixtral-8x7b")
mesh = make_mesh((2, 4), ("data", "model"))
rules = SH.default_rules()
params = M.init_params(cfg, jax.random.PRNGKey(0))
serve, psh, csh, tsh = STEP.build_serve_step(cfg, mesh, rules, b=4, w=32, donate=False)
cache = M.init_cache(cfg, params, 4, 32, {}, jnp.float32)
tok = jnp.zeros((4,), jnp.int32)
logits, cache = serve(params, cache, tok, jnp.int32(0))
assert logits.shape == (4, cfg.padded_vocab) and bool(jnp.isfinite(logits).all())
print("OK")
""")
    assert "OK" in out


def test_dryrun_tiny_both_meshes():
    """The dry-run machinery itself, on reduced configs + 8-device meshes
    (2,4) and (2,2,2) with a pod axis."""
    out = run_subtest("""
import jax, jax.numpy as jnp
from repro.configs.base import get_reduced_config, ShapeSpec
from repro.dist import sharding as SH
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.models.params import abstract_from_template
from repro.train.optimizer import AdamW
from repro.train import step as STEP
from repro.launch.dryrun import abstract_opt_state
from repro.launch import roofline as RL

for arch in ("olmo-1b", "mixtral-8x7b", "zamba2-2.7b", "whisper-large-v3"):
    cfg = get_reduced_config(arch)
    for mesh, mp in ((make_mesh((2, 4), ("data", "model")), False),
                     (make_mesh((2, 2, 2), ("pod", "data", "model")), True)):
        rules = SH.default_rules(multi_pod=mp, seq_shard=True)
        tmpl = M.template(cfg)
        ap = abstract_from_template(tmpl, jnp.bfloat16)
        opt = AdamW(master_weights=True)
        jitted, _, _ = STEP.build_train_step(cfg, mesh, rules, opt, microbatches=2)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 33), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_emb"] = jax.ShapeDtypeStruct(
                (4, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["enc_emb"] = jax.ShapeDtypeStruct((4, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        lowered = jitted.lower(ap, abstract_opt_state(tmpl), batch)
        compiled = lowered.compile()
        assert compiled.memory_analysis() is not None
        colls = RL.parse_collectives(compiled.as_text())
        assert sum(colls.counts.values()) > 0, (arch, mp, "no collectives found")
    print(arch, "ok")
print("OK")
""", timeout=560)
    assert "OK" in out


def test_elastic_remesh_preserves_params():
    out = run_subtest("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.train.optimizer import AdamW
from repro.train.elastic import reshard_state, validate_batch_divisibility

cfg = get_reduced_config("granite-8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = AdamW()
opt_state = opt.init(params)
flat_before = [np.asarray(x) for x in jax.tree_util.tree_leaves(params)]

mesh8 = make_mesh((2, 4), ("data", "model"))
p8, s8 = reshard_state(cfg, params, opt_state, mesh8)
mesh4 = make_mesh((2, 2), ("data", "model"))   # simulate losing 4 devices
p4, s4 = reshard_state(cfg, p8, s8, mesh4)
flat_after = [np.asarray(x) for x in jax.tree_util.tree_leaves(p4)]
for a, b in zip(flat_before, flat_after):
    np.testing.assert_array_equal(a, b)
assert validate_batch_divisibility(8, mesh4)
assert not validate_batch_divisibility(7, mesh4)
print("OK")
""")
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_subtest("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.dist.pipeline import pipeline_forward, split_layers_to_stages

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
L, D = 4, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (8, D))

def stage_fn(wstack, xm):
    def body(h, w):
        return jnp.tanh(h @ w), None
    out, _ = jax.lax.scan(body, xm, wstack)
    return out

stages = split_layers_to_stages(ws, 2)
y_pp = pipeline_forward(stage_fn, stages, x, mesh=mesh, axis="pod", n_micro=4)
y_ref = stage_fn(ws, x)
err = float(jnp.abs(y_pp - y_ref).max())
print("err", err)
assert err < 1e-5
print("OK")
""")
    assert "OK" in out


def test_compressed_dp_psum():
    out = run_subtest("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.dist.collectives import compressed_psum_dp, init_ef_state

mesh = make_mesh((8,), ("data",))
g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
ef = init_ef_state(g)
out, ef2 = compressed_psum_dp(g, ef, mesh, axis="data")
# replicated input -> mean == input (up to int8 quantization error)
err = float(jnp.abs(out["w"] - g["w"]).max())
scale = float(jnp.abs(g["w"]).max()) / 127
print("err", err, "scale", scale)
assert err <= scale * 1.01 + 1e-7
print("OK")
""")
    assert "OK" in out


def test_int64_joins_match_oracle():
    """Paper §5.2.5: 8-byte keys/payloads (x64-enabled subprocess)."""
    out = run_subtest("""
import os
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np, collections
from repro.core import Table, join

rng = np.random.default_rng(0)
n_r, n_s = 500, 1500
rkeys = (rng.permutation(n_r).astype(np.int64) + (1 << 40))
skeys = rkeys[rng.integers(0, n_r, n_s)]
R = Table({"k": jnp.asarray(rkeys), "r0": jnp.asarray(rkeys * 3)})
S = Table({"k": jnp.asarray(skeys), "s0": jnp.asarray(skeys * 7)})
rmap = {int(k): i for i, k in enumerate(rkeys)}
expected = sorted((int(k), int(rkeys[rmap[int(k)]] * 3), int(k) * 7) for k in skeys)
for alg in ("smj", "phj"):
    for pat in ("gftr", "gfur"):
        T, c = join(R, S, algorithm=alg, pattern=pat, out_size=n_s)
        c = int(c)
        got = sorted(zip(np.asarray(T["k"][:c]).tolist(),
                         np.asarray(T["r0"][:c]).tolist(),
                         np.asarray(T["s0"][:c]).tolist()))
        assert c == len(expected) and got == expected, (alg, pat)
print("OK")
""", devices=1)
    assert "OK" in out

"""Per-arch smoke tests (reduced configs, CPU): forward/train step shapes +
finiteness, decode-vs-forward parity (teacher forcing), layer parities."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, cell_is_runnable, get_config, get_reduced_config, list_archs
from repro.models import model as M

ARCHS = list_archs()


def make_batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32))}
    if cfg.family == "vlm":
        batch["vision_emb"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)).astype(np.float32) * 0.1)
    if cfg.family == "audio":
        batch["enc_emb"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_len, cfg.d_model)).astype(np.float32) * 0.1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_reduced_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, b, s, rng)
    logits, aux = M.forward(cfg, params, {**batch, "tokens": batch["tokens"][:, :-1]})
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng):
    """Teacher-forcing parity: step-by-step decode logits == forward logits.
    (MoE uses a high capacity factor so no tokens are dropped.)"""
    cfg = get_reduced_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    if cfg.ssm is not None:
        cfg = cfg.replace(ssm=dataclasses.replace(cfg.ssm, chunk=8))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = make_batch(cfg, b, s, rng)
    tokens = batch["tokens"][:, : s]
    fwd_logits, _ = M.forward(cfg, params, {**batch, "tokens": tokens}, remat=False)

    cache = M.init_cache(cfg, params, b, max_len=32, batch=batch, dtype=jnp.float32)
    errs = []
    for t in range(s):
        logits, cache = M.decode_step(cfg, params, cache, tokens[:, t], jnp.int32(t))
        errs.append(float(jnp.abs(logits - fwd_logits[:, t]).max()))
    assert max(errs) < 5e-2, (arch, errs)


def test_param_counts_match_public_sizes():
    expected = {
        "xlstm-125m": (0.10, 0.17), "qwen2-moe-a2.7b": (13.5, 15.0),
        "mixtral-8x7b": (45.5, 47.5), "zamba2-2.7b": (2.2, 2.9),
        "olmo-1b": (1.0, 1.4), "granite-8b": (7.7, 8.6),
        "starcoder2-7b": (6.9, 7.8), "h2o-danube-3-4b": (3.5, 4.3),
        "llama-3.2-vision-11b": (9.0, 11.5), "whisper-large-v3": (1.3, 1.8),
    }
    for arch, (lo, hi) in expected.items():
        n = M.num_params(get_config(arch)) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_shape_cell_skips_documented():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md §5)."""
    runnable = {a: cell_is_runnable(get_config(a), SHAPES["long_500k"])[0]
                for a in ARCHS}
    assert runnable == {
        "xlstm-125m": True, "zamba2-2.7b": True, "mixtral-8x7b": True,
        "h2o-danube-3-4b": True, "qwen2-moe-a2.7b": False, "olmo-1b": False,
        "granite-8b": False, "starcoder2-7b": False,
        "llama-3.2-vision-11b": False, "whisper-large-v3": False,
    }


def test_blockwise_attention_parity(rng):
    from repro.models import layers as L
    from repro.models.params import init_from_template
    b, s, d, H, KV, hd = 2, 64, 32, 4, 2, 8
    p = init_from_template(L.attn_tmpl(d, H, KV, hd), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32)) * 0.3
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    for ck in (8, 16, 48):
        for window in (None, 24):
            y_blk = L._blockwise_sdpa(q, k, v, pos, n_rep=H // KV, causal=True,
                                      window=window, kv_chunk=ck)
            qp, kp = pos[:, :, None], pos[:, None, :]
            mask = kp <= qp
            if window:
                mask &= kp > qp - window
            y_ref = L._sdpa(q, k, v, mask[:, None], H // KV)
            assert float(jnp.abs(y_blk - y_ref).max()) < 1e-4


def test_ssd_chunked_equals_recurrent(rng):
    from repro.models import ssm
    from repro.models.params import init_from_template
    from repro.configs.base import SSMConfig
    cfg = SSMConfig(state_dim=8, head_dim=4, expand=2, conv_width=4, chunk=8)
    d, b, s = 16, 2, 32
    p = init_from_template(ssm.ssm_tmpl(d, cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32)) * 0.5
    y_par = ssm.apply_ssm(p, x, cfg)
    cache = ssm.init_ssm_cache(b, d, cfg, jnp.float32)
    ys = []
    for t in range(s):
        yt, cache = ssm.apply_ssm_decode(p, x[:, t : t + 1], cache, cfg)
        ys.append(yt)
    assert float(jnp.abs(y_par - jnp.concatenate(ys, 1)).max()) < 1e-3


def test_mlstm_chunked_equals_quadratic(rng):
    from repro.models import xlstm
    from repro.models.params import init_from_template
    from repro.configs.base import XLSTMConfig
    cfg = XLSTMConfig(num_heads=2)
    d, b, s = 16, 2, 40
    p = init_from_template(xlstm.mlstm_tmpl(d, cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32)) * 0.5
    y_quad = xlstm._apply_mlstm_quadratic(p, x, cfg)
    for Q in (8, 13, 40):
        y_chunk = xlstm._apply_mlstm_chunked(p, x, cfg, Q)
        assert float(jnp.abs(y_quad - y_chunk).max()) < 1e-4


def test_moe_grouped_dispatch_equals_global(rng):
    from repro.configs.base import MoEConfig
    from repro.models import moe as MOE
    from repro.models.params import init_from_template
    cfg = MoEConfig(num_experts=4, top_k=2, d_expert=32, capacity_factor=8.0)
    d, T = 16, 64
    p = init_from_template(MOE.moe_tmpl(d, cfg), jax.random.PRNGKey(0))
    x2 = jnp.asarray(rng.normal(size=(T, d)).astype(np.float32)) * 0.3
    eidx, gates, _ = MOE._route(p, x2, cfg.top_k)
    y1 = MOE._dispatch_sort(p, x2, eidx, gates, MOE._capacity(T, 2, 4, 8.0))
    y2 = MOE._dispatch_sort_grouped(p, x2, eidx, gates, k=2, E=4, cf=8.0, groups=4)
    assert float(jnp.abs(y1 - y2).max()) < 1e-5


def test_moe_sort_vs_einsum_dispatch(rng):
    """The GFTR-pattern dispatch and the dense baseline agree when nothing
    is dropped."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as MOE
    from repro.models.params import init_from_template
    cfg_s = MoEConfig(num_experts=4, top_k=2, d_expert=32, capacity_factor=8.0,
                      dispatch="sort")
    cfg_e = dataclasses.replace(cfg_s, dispatch="einsum")
    d = 16
    p = init_from_template(MOE.moe_tmpl(d, cfg_s), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 32, d)).astype(np.float32)) * 0.3
    y_s, _ = MOE.apply_moe(p, x, cfg_s)
    y_e, _ = MOE.apply_moe(p, x, cfg_e)
    assert float(jnp.abs(y_s - y_e).max()) < 1e-4

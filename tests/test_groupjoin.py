"""Fused group-join correctness: phj_groupjoin against a python oracle and
against the unfused join-then-group-by pipeline, overflow escalation
(build-partition bits AND accumulator capacity), the Pallas probe+accumulate
kernel, the cost model's crossover, and the engine's fusion decision on
both sides of it.

Payload values are kept small so float32 accumulator paths are exact and
results can be compared to the NumPy reference with equality."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KEY_SENTINEL, JoinStats, Table, group_aggregate, groupjoin_checked,
                        groupjoin_overflowed, groupjoin_required_groups, join, phj_groupjoin,
                        predict_groupby_time, predict_groupjoin_time, predict_join_time)


def make_workload(rng, n_r, n_s, n_groups, match_ratio=1.0, riders=0):
    """pk_fk build side (unique keys, payload rv) + probe side
    (key, group key g, payload sv, plus `riders` payload columns the
    aggregation never reads — the columns an unfused join must drag
    through its materialization)."""
    rk = rng.permutation(n_r).astype(np.int32)
    if match_ratio < 1.0:
        drop = rng.random(n_r) < (1 - match_ratio)
        rk = np.where(drop, (np.arange(n_r) + 10 * n_r + 7).astype(np.int32), rk)
    sk = rng.integers(0, n_r, n_s).astype(np.int32)
    g = rng.integers(0, n_groups, n_s).astype(np.int32)
    R = Table({"k": jnp.asarray(rk),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    s = {"k": jnp.asarray(sk), "g": jnp.asarray(g),
         "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))}
    for j in range(riders):
        s[f"x{j}"] = jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))
    return R, Table(s)


def oracle(R, S):
    """group -> (rv_sum, sv_sum, count, sv_min) over matched probe rows."""
    rmap = dict(zip(np.asarray(R["k"]).tolist(), np.asarray(R["rv"]).tolist()))
    out = {}
    for k, g, s in zip(np.asarray(S["k"]).tolist(), np.asarray(S["g"]).tolist(),
                       np.asarray(S["sv"]).tolist()):
        if k in rmap:
            e = out.setdefault(g, [0, 0, 0, None])
            e[0] += rmap[k]
            e[1] += s
            e[2] += 1
            e[3] = s if e[3] is None else min(e[3], s)
    return out


def result_map(T, count, cols):
    n = int(count)
    key = T.column_names[0] if "g" not in T.column_names else "g"
    ks = np.asarray(T[key])[:n]
    return {int(ks[i]): tuple(float(np.asarray(T[c])[i]) for c in cols)
            for i in range(n)}


# ---------------------------------------------------------------------------
# Operator correctness vs oracle and vs the unfused pipeline
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["sort", "partition_hash", "scatter"])
@pytest.mark.parametrize("match_ratio", [1.0, 0.5])
def test_groupjoin_matches_oracle(strategy, match_ratio, rng):
    R, S = make_workload(rng, 700, 4000, 48, match_ratio)
    ref = oracle(R, S)
    aggs = {"rv": "sum", "sv": "mean", "k": "count"}
    T, count = phj_groupjoin(R, S, key="k", group_key="g", aggs=aggs,
                             num_groups=64, agg_strategy=strategy)
    assert int(count) == len(ref)
    got = result_map(T, count, ("rv_sum", "sv_mean", "k_count"))
    for g, (rs, ss, c, _) in ref.items():
        grs, gms, gc = got[g]
        assert grs == rs
        assert gms == pytest.approx(ss / c, abs=1e-4)
        assert gc == c
    # padding rows carry the sentinel
    assert bool((np.asarray(T["g"])[int(count):] == KEY_SENTINEL).all())


def test_groupjoin_min_max_and_group_on_join_key(rng):
    R, S = make_workload(rng, 300, 2000, 32)
    T, count = phj_groupjoin(R, S, key="k", group_key="g",
                             aggs={"sv": "min", "rv": "max"}, num_groups=64)
    ref = oracle(R, S)
    got = result_map(T, count, ("sv_min",))
    for g, (_, _, _, mn) in ref.items():
        assert got[g][0] == mn
    # grouping on the join key itself: one group per matched build key
    T2, c2 = phj_groupjoin(R, S, key="k", group_key="k",
                           aggs={"sv": "sum"}, num_groups=512)
    matched_keys = set(np.asarray(R["k"]).tolist()) & set(np.asarray(S["k"]).tolist())
    assert int(c2) == len(matched_keys)


def test_groupjoin_matches_unfused_pipeline_exactly(rng):
    """The fused operator must agree with join-then-group-by row for row
    (same strategy, small values so every accumulator dtype is exact)."""
    R, S = make_workload(rng, 500, 3000, 40)
    aggs = {"rv": "sum", "sv": "sum"}
    for strategy in ("sort", "partition_hash", "scatter"):
        J, _ = join(R, S, key="k", algorithm="phj", pattern="gftr",
                    out_size=S.num_rows, mode="pk_fk")
        G1, c1 = group_aggregate(J.select(("g", "rv", "sv")), key="g",
                                 aggs=aggs, num_groups=64, strategy=strategy)
        G2, c2 = phj_groupjoin(R, S, key="k", group_key="g", aggs=aggs,
                               num_groups=64, agg_strategy=strategy)
        assert int(c1) == int(c2)
        m1 = result_map(G1, c1, ("rv_sum", "sv_sum"))
        m2 = result_map(G2, c2, ("rv_sum", "sv_sum"))
        assert m1 == m2, strategy


def test_groupjoin_under_jit(rng):
    R, S = make_workload(rng, 400, 2500, 30)
    import functools

    f = jax.jit(functools.partial(phj_groupjoin, key="k", group_key="g",
                                  aggs={"sv": "sum"}, num_groups=64))
    T, count = f(R, S)
    ref = oracle(R, S)
    assert int(count) == len(ref)
    got = result_map(T, count, ("sv_sum",))
    assert {g: v[0] for g, v in got.items()} == {g: float(e[1]) for g, e in ref.items()}


# ---------------------------------------------------------------------------
# Pallas probe+accumulate kernel
# ---------------------------------------------------------------------------
def test_groupjoin_pallas_matches_xla(rng):
    R, S = make_workload(rng, 600, 3500, 40, match_ratio=0.8)
    aggs = {"rv": "sum", "sv": "mean", "k": "count"}
    T1, c1 = phj_groupjoin(R, S, key="k", group_key="g", aggs=aggs,
                           num_groups=64, probe_impl="xla")
    T2, c2 = phj_groupjoin(R, S, key="k", group_key="g", aggs=aggs,
                           num_groups=64, probe_impl="pallas")
    assert int(c1) == int(c2)
    cols = ("rv_sum", "sv_mean", "k_count")
    m1, m2 = result_map(T1, c1, cols), result_map(T2, c2, cols)
    assert set(m1) == set(m2)
    for g in m1:
        assert m1[g][0] == m2[g][0]
        assert m1[g][1] == pytest.approx(m2[g][1], abs=1e-4)
        assert m1[g][2] == m2[g][2]


def test_groupjoin_probe_agg_ops_parity(rng):
    """ops-level dispatch: the Pallas kernel arm and the XLA reference arm
    of groupjoin_probe_agg agree on keys, sums, and counts — with probe- and
    build-side value columns riding the same single probe pass."""
    from repro.core.groupjoin import _value_blocks
    from repro.core.hash_join import _digits, build_blocks
    from repro.core import primitives as prim
    from repro.kernels import ops as kops

    n_r, n_s, p_bits = 500, 2000, 4
    rk = jnp.asarray(rng.permutation(n_r).astype(np.int32))
    rv = jnp.asarray(rng.integers(0, 50, n_r).astype(np.int32))
    sk = jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32))
    gk = jnp.asarray(rng.integers(0, 20, n_s).astype(np.int32))
    sv = jnp.asarray(rng.integers(0, 50, n_s).astype(np.int32))
    P = 1 << p_bits
    perm_r, off_r, sz_r = prim.plan_partition_permutation(_digits(rk, p_bits, True), P)
    perm_s, off_s, sz_s = prim.plan_partition_permutation(_digits(sk, p_bits, True), P)
    bkeys, _, _ = build_blocks(prim.apply_permutation(perm_r, rk), off_r, sz_r, 256)
    bvals = _value_blocks(prim.apply_permutation(perm_r, rv), off_r, sz_r, 256)
    ks = prim.apply_permutation(perm_s, sk)
    gks = prim.apply_permutation(perm_s, gk)
    svs = prim.apply_permutation(perm_s, sv).astype(jnp.float32)
    for col_sides, bv, pv in (
        ((("probe", 0), ("build", 0)), bvals[:, None, :], svs[None, :]),
        ((("build", 0),), bvals[:, None, :], None),
        ((), None, None),  # count-only: empty sums, keys+counts intact
    ):
        outs = [kops.groupjoin_probe_agg(
            bkeys, bv, off_r, ks, gks, pv, off_s, sz_s, 32,
            col_sides=col_sides, impl=impl)
            for impl in ("pallas", "xla")]
        assert outs[0][1].shape == (len(col_sides), 32)
        for a, b in zip(outs[0], outs[1]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# Overflow escalation: bits, then accumulator capacity
# ---------------------------------------------------------------------------
def test_groupjoin_checked_escalates_partition_bits(rng):
    """Distinct build keys that co-partition under the default fan-out
    (hash_keys=False, keys congruent mod P): the unchecked run overflows the
    padded build block and loses matches; the checked driver adds bits and
    stays exact."""
    n_r, n_s = 600, 3000
    rk = (np.arange(n_r, dtype=np.int32) * 16)  # all ≡ 0 mod 16 (= default P)
    sk = rk[rng.integers(0, n_r, n_s)].astype(np.int32)
    R = Table({"k": jnp.asarray(rk),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(sk),
               "g": jnp.asarray(rng.integers(0, 16, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    b_ovf, _, g_ovf, _ = groupjoin_overflowed(
        R, S, key="k", group_key="g", num_groups=64, hash_keys=False)
    assert b_ovf and not g_ovf
    ref = oracle(R, S)
    T, count = groupjoin_checked(R, S, key="k", group_key="g",
                                 aggs={"rv": "sum", "sv": "sum"},
                                 num_groups=64, hash_keys=False)
    assert int(count) == len(ref)
    got = result_map(T, count, ("rv_sum", "sv_sum"))
    assert got == {g: (float(e[0]), float(e[1])) for g, e in ref.items()}


def test_groupjoin_checked_grows_accumulator(rng):
    """More groups than the requested capacity: the unchecked run truncates
    (count == num_groups), the checked driver grows the accumulator to the
    exact distinct-group bound and keeps every group."""
    R, S = make_workload(rng, 400, 3000, 150)
    ref = oracle(R, S)
    assert len(ref) == 150  # every group hit at this size
    _, _, g_ovf, required = groupjoin_overflowed(
        R, S, key="k", group_key="g", num_groups=16)
    assert g_ovf and required == 150
    assert groupjoin_required_groups(S, key="k", group_key="g") == 150
    _, trunc = phj_groupjoin(R, S, key="k", group_key="g",
                             aggs={"sv": "sum"}, num_groups=16)
    assert int(trunc) == 16
    T, count = groupjoin_checked(R, S, key="k", group_key="g",
                                 aggs={"sv": "sum"}, num_groups=16)
    assert int(count) == 150
    got = result_map(T, count, ("sv_sum",))
    assert {g: v[0] for g, v in got.items()} == {g: float(e[1]) for g, e in ref.items()}


def test_groupjoin_checked_scatter_covers_sparse_domain(rng):
    """scatter indexes the accumulator by key VALUE: with a sparse group
    domain the distinct-count bound is not enough — the checked driver must
    grow the accumulator to the key domain or silently drop groups."""
    n_r, n_s = 200, 1000
    rk = rng.permutation(n_r).astype(np.int32)
    sk = rng.integers(0, n_r, n_s).astype(np.int32)
    g = (rng.integers(0, 3, n_s).astype(np.int32) * 50000)  # {0, 50k, 100k}
    R = Table({"k": jnp.asarray(rk),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(sk), "g": jnp.asarray(g),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    assert groupjoin_required_groups(S, key="k", group_key="g",
                                     agg_strategy="scatter") == 100001
    T, count = groupjoin_checked(R, S, key="k", group_key="g",
                                 aggs={"sv": "sum"}, num_groups=64,
                                 agg_strategy="scatter")
    ref = oracle(R, S)
    assert int(count) == len(ref) == 3
    got = result_map(T, count, ("sv_sum",))
    assert {g_: v[0] for g_, v in got.items()} == \
        {g_: float(e[1]) for g_, e in ref.items()}


def test_groupjoin_rejects_build_side_group_key(rng):
    R, S = make_workload(rng, 100, 500, 8)
    with pytest.raises(ValueError, match="probe-side"):
        phj_groupjoin(R, S, key="k", group_key="rv", aggs={"sv": "sum"},
                      num_groups=16)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------
def test_predict_groupjoin_time_has_no_materialize_term():
    st = JoinStats(n_r=1 << 16, n_s=1 << 20, r_payload_cols=1,
                   s_payload_cols=2, match_ratio=1.0)
    t = predict_groupjoin_time(st, 2)
    assert set(t) == {"transform", "find", "accumulate", "total"}
    assert t["total"] == pytest.approx(
        t["transform"] + t["find"] + t["accumulate"])
    assert t["total"] > 0


def test_predict_groupjoin_crossover_with_match_ratio():
    """The fusion's structural trade: fused aggregates the whole probe side,
    unfused only the (match_ratio-sized) join output. High match ratio must
    favor fusion, very low must favor the unfused pair — the decision
    boundary the engine's fusion pass prices."""
    def totals(mr):
        st = JoinStats(n_r=1 << 14, n_s=1 << 20, r_payload_cols=1,
                       s_payload_cols=4, match_ratio=mr)
        fused = predict_groupjoin_time(st, 1, "sort")["total"]
        n_out = int(st.n_s * mr)
        unfused = (predict_join_time(st, "phj", "gftr")["total"]
                   + predict_groupby_time(max(n_out, 1), 1, "sort"))
        return fused, unfused

    f_hi, u_hi = totals(1.0)
    f_lo, u_lo = totals(0.05)
    assert f_hi < u_hi
    assert f_lo > u_lo


# ---------------------------------------------------------------------------
# Engine: fusion decision on both sides of the crossover
# ---------------------------------------------------------------------------
OPT = dict(measure_profile=False)


def _engine_ref(R, S):
    rmap = dict(zip(np.asarray(R["k"]).tolist(), np.asarray(R["rv"]).tolist()))
    ref = {}
    for k, g in zip(np.asarray(S["k"]).tolist(), np.asarray(S["g"]).tolist()):
        if k in rmap:
            ref[g] = ref.get(g, 0) + rmap[k]
    return ref


def test_engine_fuses_on_high_match_ratio(rng):
    from repro.engine import Catalog, optimize, scan

    R, S = make_workload(rng, 2000, 20000, 50, riders=2)
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="mean")
    plan = optimize(q, cat, **OPT)
    text = plan.explain()
    assert "GroupJoin[" in text and "cost=" in text, text
    T, count = plan.run()
    ref = _engine_ref(R, S)
    assert int(count) == len(ref)
    got = result_map(T, count, ("rv_sum",))
    assert {g: v[0] for g, v in got.items()} == {g: float(v) for g, v in ref.items()}


def test_engine_rejects_fusion_on_low_match_ratio(rng):
    """Mostly-unmatched probe keys: grouping the tiny join output is
    cheaper than running the accumulator over the whole probe side; the
    cost model must keep the unfused plan, and explain() must show the
    rejected fusion's pricing."""
    from repro.engine import Catalog, optimize, scan

    n_r, n_s = 2000, 20000
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, 40 * n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(rng.integers(0, 50, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="mean")
    plan = optimize(q, cat, **OPT)
    text = plan.explain()
    assert "GroupJoin[" not in text and "fusion rejected" in text, text
    T, count = plan.run()
    ref = _engine_ref(R, S)
    assert int(count) == len(ref)
    got = result_map(T, count, ("rv_sum",))
    assert {g: v[0] for g, v in got.items()} == {g: float(v) for g, v in ref.items()}


def test_engine_fusion_on_build_key_alias(rng):
    """Grouping on the build-side key name (the equal-valued alias of the
    probe key): the fusion must map it to the probe key and name the output
    column after the logical GroupBy key."""
    from repro.engine import Catalog, optimize, scan

    n_r, n_s = 1000, 15000
    R = Table({"kr": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32)),
               "x0": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32)),
               "x1": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    cat = Catalog({"R": R, "S": S})
    q = (scan("S").join(scan("R"), left_key="k", right_key="kr")
         .group_by("kr", sv="sum"))
    plan = optimize(q, cat, **OPT)
    assert "GroupJoin[" in plan.explain(), plan.explain()
    T, count = plan.run()
    assert "kr" in T.column_names
    ref = {}
    for k, s in zip(np.asarray(S["k"]).tolist(), np.asarray(S["sv"]).tolist()):
        ref[k] = ref.get(k, 0) + s
    n = int(count)
    assert n == len(ref)
    ks = np.asarray(T["kr"])[:n]
    vs = np.asarray(T["sv_sum"])[:n]
    assert {int(k): float(v) for k, v in zip(ks, vs)} == \
        {k: float(v) for k, v in ref.items()}


def test_engine_force_join_disables_fusion(rng):
    from repro.engine import Catalog, optimize, scan

    R, S = make_workload(rng, 1000, 10000, 30)
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum")
    plan = optimize(q, cat, force_join=("phj", "gftr"), **OPT)
    assert "GroupJoin[" not in plan.explain()


# ---------------------------------------------------------------------------
# jaxpr-pinned structural claims, via the shared repro.analysis API: the
# fused plan's compiled budget matches what the cost model priced — the
# accumulator's sorts only, zero join-output materialization
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy,max_sorts",
                         [("sort", 1), ("scatter", 0)])
def test_groupjoin_compiled_budget_honors_contract(strategy, max_sorts, rng):
    import functools

    from repro import analysis

    R, S = make_workload(rng, 256, 2048, 32)
    fn = functools.partial(phj_groupjoin, key="k", group_key="g",
                           aggs={"rv": "sum", "sv": "mean"}, num_groups=64,
                           agg_strategy=strategy)
    rep = analysis.audit_fn(fn, R, S)
    assert rep.budget.sorts <= max_sorts
    # the full priced contract (sorts, float scatter-adds, peak-live bound,
    # no silent 64-bit promotion) holds for the compiled trace
    analysis.enforce(analysis.groupjoin_contract(strategy, 2), rep)


def test_unfused_pipeline_trips_materialization_contract(rng):
    """The same query, unfused with a fat join capacity, must violate the
    group-join's peak-live contract — that asymmetry IS the fusion claim."""
    from repro import analysis

    R, S = make_workload(rng, 256, 8192, 32)

    def unfused(R, S):
        T, _ = join(R, S, key="k", algorithm="phj", pattern="gftr",
                    out_size=512 * S.num_rows, mode="mn")
        return group_aggregate(T.select(("g", "rv", "sv")), key="g",
                               aggs={"rv": "sum", "sv": "mean"},
                               num_groups=64, strategy="sort")

    rep = analysis.audit_fn(unfused, R, S)
    with pytest.raises(analysis.MaterializationViolation):
        analysis.enforce(analysis.groupjoin_contract("sort", 2), rep)

"""Memory-governed execution (DESIGN.md §15): byte budget + reservation
ledger, the `oom:` fault family, morsel-driven out-of-core execution, and
the §4.4 memory-model ledger in explain()."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Table
from repro.core import memmodel
from repro.data import relgen
from repro.engine import (Catalog, MemoryBudget, MemoryBudgetExceeded,
                          detect_budget_bytes, is_memory_error, optimize,
                          plan_peak_bytes, run_morsels, scan)
from repro.engine import membudget as MB
from repro.engine import physical as P
from repro.engine.executor import run as xrun
from repro.obs import metrics
from repro.resilience import faults


def canon(table, count):
    n = int(count)
    cols = sorted(table.column_names)
    mats = [np.asarray(table[c])[:n] for c in cols]
    return tuple(cols), sorted(zip(*[m.tolist() for m in mats]))


def make_join_tables(n_r=400, n_s=1600, seed=3):
    R, S = relgen.generate(relgen.JoinWorkload("t", n_r, n_s, 2, 2,
                                               seed=seed))
    return {"R": R, "S": S}


# ---------------------------------------------------------------------------
# budget ledger
# ---------------------------------------------------------------------------
def test_budget_ledger_never_overcommits():
    b = MemoryBudget(100)
    assert b.try_reserve("a", 60)
    assert not b.try_reserve("b", 50)  # 60 + 50 > 100: refused, untouched
    assert b.reserved == 60 and b.available() == 40
    # re-reserving a live tag REPLACES its ticket (idempotent tags)
    assert b.try_reserve("a", 70)
    assert b.reserved == 70
    assert b.release("a") == 70
    assert b.release("a") == 0  # unknown-tag release is a safe no-op
    assert b.reserved == 0
    assert b.peak_reserved == 70  # high-water mark survives releases


def test_budget_rejects_nonpositive_total():
    with pytest.raises(ValueError):
        MemoryBudget(0)


def test_env_override_read_time_validation(monkeypatch):
    monkeypatch.setenv(MB.ENV_VAR, "123456")
    assert detect_budget_bytes() == 123456
    # validated at READ time, every call — like REPRO_PALLAS_INTERPRET
    monkeypatch.setenv(MB.ENV_VAR, "lots")
    with pytest.raises(ValueError, match="allowed"):
        detect_budget_bytes()
    monkeypatch.setenv(MB.ENV_VAR, "-5")
    with pytest.raises(ValueError):
        detect_budget_bytes()
    monkeypatch.delenv(MB.ENV_VAR)
    assert detect_budget_bytes() > 0


def test_is_memory_error_classifier():
    assert is_memory_error(MemoryError("boom"))
    assert is_memory_error(MemoryBudgetExceeded(10, 5))
    assert is_memory_error(RuntimeError("RESOURCE_EXHAUSTED: alloc failed"))
    assert is_memory_error(RuntimeError("Failed to allocate 1GB"))
    assert not is_memory_error(ValueError("bad shape"))


def test_memory_budget_exceeded_is_typed():
    e = MemoryBudgetExceeded(1000, 500, "unsplittable")
    assert isinstance(e, MemoryError)
    assert e.need_bytes == 1000 and e.budget_bytes == 500
    assert "1000" in str(e) and "unsplittable" in str(e)


# ---------------------------------------------------------------------------
# oom: fault family
# ---------------------------------------------------------------------------
def test_oom_fault_grammar_and_type():
    before = metrics.counter("resilience.oom_injected").value
    with faults.inject("oom:executor.run@0"):
        with pytest.raises(faults.OOMInjected) as ei:
            faults.check_oom("executor.run")
        assert isinstance(ei.value, MemoryError)  # routes onto morsel rung
        faults.check_oom("executor.run")  # occurrence 1: no re-fire
        faults.check_oom("qserve.admit")  # other site: never fires
    assert metrics.counter("resilience.oom_injected").value == before + 1


def test_oom_wildcard_site_rejected():
    with pytest.raises(ValueError):
        with faults.inject("oom:*"):
            pass


# ---------------------------------------------------------------------------
# morsel axis + out-of-core driver
# ---------------------------------------------------------------------------
def test_morsel_axis_selection():
    tables = make_join_tables()
    cat = Catalog(tables)
    join = optimize(scan("S").join(scan("R"), key="k"), cat,
                    measure_profile=False)
    assert P.morsel_axis(join.root) == "S"  # probe side splits
    gb = optimize(scan("S").group_by("k", s1="sum"), cat,
                  measure_profile=False)
    assert P.morsel_axis(gb.root) == "S"
    topk = optimize(scan("S").order_by("s1", limit=8), cat,
                    measure_profile=False)
    assert P.morsel_axis(topk.root) is None  # top-k is not splittable


def test_morsel_rows_pow2_lane_rounded():
    assert P.morsel_rows(2048, 2) == 1024
    assert P.morsel_rows(2048, 32) == 64
    assert P.morsel_rows(2048, 4096) == 64  # never below one tile
    assert P.morsel_rows(100, 2) == 64      # lane-rounded up


def test_run_morsels_join_bit_identical():
    tables = make_join_tables()
    plan = optimize(scan("S").join(scan("R"), key="k"), Catalog(tables),
                    measure_profile=False)
    whole = canon(*xrun(plan))
    before = metrics.counter("engine.morsel_runs").value
    for f in (2, 4, 8):
        assert canon(*run_morsels(plan, factor=f)) == whole
    assert metrics.counter("engine.morsel_runs").value > before


def test_run_morsels_unsplittable_raises():
    tables = make_join_tables()
    plan = optimize(scan("S").order_by("s1", limit=8), Catalog(tables),
                    measure_profile=False)
    with pytest.raises(ValueError):
        run_morsels(plan, factor=2)


def test_oom_fault_degrades_onto_morsel_rung():
    tables = make_join_tables()
    q = scan("S").join(scan("R"), key="k").group_by("k", s1="sum")
    oracle = canon(*xrun(optimize(q, Catalog(tables),
                                  measure_profile=False)))
    plan = optimize(q, Catalog(tables), measure_profile=False)
    with faults.inject("oom:executor.run@0"):
        got = canon(*xrun(plan))
    assert got == oracle
    assert plan.degraded_plan is not None
    assert plan.degraded_plan.morsel_factor == 2  # morsel rung, not 2x cap


def test_plan_peak_bytes_positive_and_counts_invariant():
    tables = make_join_tables()
    plan = optimize(scan("S").join(scan("R"), key="k"), Catalog(tables),
                    measure_profile=False)
    peak = plan_peak_bytes(plan)
    assert peak > 0
    counts = {n: t.num_rows for n, t in tables.items()}
    assert plan_peak_bytes(plan, tables, counts=counts) > 0


# ---------------------------------------------------------------------------
# morsel-split group-by: bit identity across every strategy (property)
# ---------------------------------------------------------------------------
GB_STRATEGIES = ("sort", "partition", "partition_hash", "scatter",
                 "sort_pallas")


def _force_strategy(plan, strategy):
    root = dataclasses.replace(plan.root, strategy=strategy)
    return dataclasses.replace(plan, root=root, morsel_plans={})


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([65, 150]),
       shape=st.sampled_from(["uniform", "one_group", "boundary"]))
def test_morsel_groupby_bit_identical_all_strategies(seed, n, shape):
    """Chunked group-by (partial aggregates re-reduced, mean via
    sum+count) must be BIT-identical to the whole-relation run for every
    strategy, at even and uneven-tail widths, including the hostile
    all-rows-one-group and capacity-boundary key shapes."""
    rng = np.random.default_rng(seed)
    if shape == "one_group":
        keys = np.full(n, 3, np.int32)
    elif shape == "boundary":
        keys = rng.choice(np.array([0, 1, 62, 63], np.int32), n)
    else:
        keys = rng.integers(0, 64, n).astype(np.int32)
    t = Table({"k": jnp.asarray(keys),
               "v": jnp.asarray(rng.integers(0, 1000, n).astype(np.int32)),
               "w": jnp.asarray(rng.integers(0, 1000, n).astype(np.int32))})
    cat = Catalog({"S": t})
    q = scan("S").group_by("k", v="sum", w="mean")
    for strategy in GB_STRATEGIES:
        plan = _force_strategy(optimize(q, cat, measure_profile=False),
                               strategy)
        whole = canon(*xrun(plan))
        # factor 2 gives width >= n/2; larger factors clamp to the 64-row
        # tile floor, leaving zero-count tail morsels (skip path)
        for factor in (2, 4):
            got = canon(*run_morsels(plan, factor=factor))
            assert got == whole, (strategy, factor, shape)


# ---------------------------------------------------------------------------
# §4.4 memory-model ledger (GFTR vs GFUR) in explain()
# ---------------------------------------------------------------------------
def test_gftr_peak_never_above_gfur():
    # the paper's modeled conclusion: for any transform scratch >= one
    # column, GFTR's phase peak is <= GFUR's (strict once mt > mc)
    for mt in (1.0, 1.5, 2.0, 4.0):
        assert (memmodel.peak_memory("gftr", mt=mt)
                <= memmodel.peak_memory("gfur", mt=mt))
    assert (memmodel.peak_memory("gftr", mt=2.0)
            < memmodel.peak_memory("gfur", mt=2.0))
    # audited: the same join forced onto each pattern — GFTR may not peak
    # higher than GFUR (XLA fuses the transforms, so equality is common)
    tables = make_join_tables()
    q = scan("S").join(scan("R"), key="k")
    peaks = {}
    for pat in ("gftr", "gfur"):
        plan = optimize(q, Catalog(tables), measure_profile=False,
                        force_join=("phj", pat))
        peaks[pat] = plan_peak_bytes(plan)
    assert peaks["gftr"] <= peaks["gfur"]


def test_explain_renders_memory_ledger():
    tables = make_join_tables()
    plan = optimize(scan("S").join(scan("R"), key="k"), Catalog(tables),
                    measure_profile=False)
    text = plan.explain()
    assert "mem: model[gftr=" in text
    assert "gfur=" in text and "pattern=" in text

"""Observability-layer tests (DESIGN.md §12): span tracer correctness and
zero-overhead contract, residual EWMAs + regret flags, the persistent
calibration store (cross-process round-trip, env-override validation,
per-(backend, n) profile cache), metrics counters, and the CLI."""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Table
from repro.core.planner import PrimitiveProfile
from repro.engine import Catalog, Optimizer, executor, scan
from repro.engine import physical as P
from repro.obs import (CalibrationStore, NodeResidual, ResidualStore, Span,
                       backend_fingerprint, calibration_path, metrics,
                       regret_check, residuals_of)

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@pytest.fixture
def calstore_path(tmp_path, monkeypatch):
    """Point the calibration store at a scratch file so tests never touch
    (or depend on) a real CALIBRATION.json in the cwd."""
    path = tmp_path / "CALIBRATION.json"
    monkeypatch.setenv("REPRO_CALIBRATION_PATH", str(path))
    return path


def _star_plan(n_r=64, n_s=512, seed=0):
    rng = np.random.default_rng(seed)
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 50, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(rng.integers(0, 8, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 50, n_s).astype(np.int32))})
    cat = Catalog({"R": R, "S": S})
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="sum")
    return Optimizer(cat, measure_profile=False).optimize(q)


# ---------------------------------------------------------------------------
# Span tracer
# ---------------------------------------------------------------------------
def test_traced_run_matches_untraced():
    plan = _star_plan()
    t_ref, c_ref = plan.run()
    t_tr, c_tr, trace = plan.run(trace=True)
    assert int(c_tr) == int(c_ref)
    n = int(c_ref)
    for col in t_ref.column_names:
        np.testing.assert_array_equal(np.asarray(t_ref[col])[:n],
                                      np.asarray(t_tr[col])[:n])
    # every physical node produced a span; the root is the group side
    assert trace.root.op in ("groupby", "groupjoin")
    assert all(s.wall_s > 0 for s in trace.spans())
    assert trace.root.rows_out == n


def test_trace_overhead_bound_accounts_for_e2e():
    """Acceptance check: per-node measured times sum to within the trace's
    own overhead bound of the untraced end-to-end time."""
    plan = _star_plan()
    _, _, trace = plan.run(trace=True, trace_iters=3, trace_warmup=1)
    assert trace.e2e_wall_s > 0
    assert abs(trace.sum_wall_s - trace.e2e_wall_s) <= trace.overhead_bound_s


def test_untraced_run_is_zero_overhead():
    """trace=False takes the untraced code path: no Span allocated, and
    the whole-plan jaxpr is identical after a traced run happened."""
    plan = _star_plan()
    tables = dict(plan.catalog.tables)
    jaxpr_before = str(jax.make_jaxpr(
        lambda tb: executor.execute(plan.root, tb))(tables))
    before = Span.allocated
    plan.run()
    plan.run()  # cached-executable path too
    assert Span.allocated == before  # no span objects on the untraced path
    _, _, trace = plan.run(trace=True)
    assert Span.allocated > before  # the traced path does allocate
    assert len(trace.spans()) == Span.allocated - before
    jaxpr_after = str(jax.make_jaxpr(
        lambda tb: executor.execute(plan.root, tb))(tables))
    assert jaxpr_after == jaxpr_before


def test_trace_exports(tmp_path):
    plan = _star_plan()
    _, _, trace = plan.run(trace=True)
    d = trace.as_dict()
    assert d["backend"] == backend_fingerprint()
    for node in d["nodes"]:
        for key in ("op", "path", "strategy", "predicted_s", "measured_s",
                    "residual", "rows_in", "rows_out", "bytes_in",
                    "bytes_out"):
            assert key in node
    tj = tmp_path / "TRACE.json"
    trace.to_json(str(tj))
    assert json.loads(tj.read_text())["nodes"]
    events = trace.chrome_trace()
    assert events and all(e["ph"] == "X" for e in events)
    assert all(e["dur"] > 0 and e["ts"] >= 0 for e in events)
    ct = tmp_path / "TRACE.perfetto.json"
    trace.to_chrome_trace(str(ct))
    assert json.loads(ct.read_text())["traceEvents"]
    # the rendered table carries the predicted-vs-measured comparison
    tbl = trace.table()
    assert "predicted" in tbl and "measured" in tbl and "residual" in tbl


def test_explain_with_actuals_annotates_every_line():
    plan = _star_plan()
    _, _, trace = plan.run(trace=True)
    out = plan.explain(actuals=trace)
    assert "predicted[" in out and "measured[" in out and "residual[" in out
    # unpriced nodes (scans) render a residual placeholder, not a crash
    assert "residual[-]" in out


# ---------------------------------------------------------------------------
# Residuals + regret
# ---------------------------------------------------------------------------
def test_residual_store_ewma_update():
    rs = ResidualStore()
    r = NodeResidual(op="groupby", strategy="partition",
                     predicted_s=1.0, measured_s=2.0)
    rs.update([r])
    assert rs.correction("groupby", "partition") == pytest.approx(2.0)
    rs.update([NodeResidual(op="groupby", strategy="partition",
                            predicted_s=1.0, measured_s=4.0)])
    assert rs.correction("groupby", "partition") == pytest.approx(
        0.7 * 2.0 + 0.3 * 4.0)
    ent = rs.data["groupby/partition"]
    assert ent["count"] == 2 and ent["last"] == pytest.approx(4.0)
    assert rs.correction("groupby", "sort") == 1.0  # unobserved -> neutral
    # round-trips through its dict form
    rs2 = ResidualStore.from_dict(json.loads(json.dumps(rs.as_dict())))
    assert rs2.correction("groupby", "partition") == pytest.approx(
        rs.correction("groupby", "partition"))


def test_residuals_of_skips_unpriced_nodes():
    plan = _star_plan()
    _, _, trace = plan.run(trace=True)
    res = residuals_of(trace)
    assert res and all(r.predicted_s > 0 for r in res)
    assert all(r.ratio > 0 for r in res)
    assert not any(r.op == "scan" for r in res)


def test_regret_check():
    rs = ResidualStore({"groupby/partition": {"ewma": 10.0, "count": 3,
                                              "last": 10.0},
                        "groupby/sort": {"ewma": 1.0, "count": 3,
                                         "last": 1.0}})
    choices = {"partition": 1.0, "sort": 1.1}
    msg = regret_check(rs, "groupby", choices, "partition")
    assert msg.startswith("REGRET:") and "partition" in msg and "sort" in msg
    # the chosen strategy was never observed -> no claim to make
    assert regret_check(ResidualStore(), "groupby", choices, "partition") == ""
    # choice survives correction -> no flag
    ok = ResidualStore({"groupby/partition": {"ewma": 1.0, "count": 1,
                                              "last": 1.0}})
    assert regret_check(ok, "groupby", choices, "partition") == ""


def test_optimizer_attaches_regret_flag():
    """A plan whose predicted winner lost by >2x in the residual store
    carries the REGRET annotation in explain()."""
    n = 2048
    rng = np.random.default_rng(3)
    keys = (rng.permutation(n) * 97).astype(np.int32)
    T = Table({"k": jnp.asarray(keys),
               "v": jnp.asarray(rng.normal(size=n).astype(np.float32))})
    cat = Catalog({"T": T})
    q = scan("T").group_by("k", v="sum")
    neutral = Optimizer(cat, measure_profile=False,
                        residuals=ResidualStore()).optimize(q)
    assert "GroupBy[partition]" in neutral.explain()
    assert "REGRET" not in neutral.explain()
    burned = ResidualStore({"groupby/partition": {"ewma": 50.0, "count": 2,
                                                  "last": 50.0},
                            "groupby/sort": {"ewma": 1.0, "count": 2,
                                             "last": 1.0}})
    plan = Optimizer(cat, measure_profile=False,
                     residuals=burned).optimize(q)
    assert "GroupBy[partition]" in plan.explain()  # advisory: choice stands
    assert "REGRET" in plan.explain()


# ---------------------------------------------------------------------------
# Calibration store
# ---------------------------------------------------------------------------
def test_calibration_path_validation(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_CALIBRATION_PATH", raising=False)
    assert calibration_path() == "CALIBRATION.json"
    ok = tmp_path / "cal.json"
    monkeypatch.setenv("REPRO_CALIBRATION_PATH", str(ok))
    assert calibration_path() == str(ok)
    for bad in ("", "   "):
        monkeypatch.setenv("REPRO_CALIBRATION_PATH", bad)
        with pytest.raises(ValueError, match="REPRO_CALIBRATION_PATH"):
            calibration_path()
    monkeypatch.setenv("REPRO_CALIBRATION_PATH", str(tmp_path))
    with pytest.raises(ValueError, match="directory"):
        calibration_path()
    monkeypatch.setenv("REPRO_CALIBRATION_PATH",
                       str(tmp_path / "no_such_dir" / "cal.json"))
    with pytest.raises(ValueError, match="does not exist"):
        calibration_path()


def test_calibration_store_profile_roundtrip(calstore_path):
    store = CalibrationStore()
    prof = PrimitiveProfile(seq_bw=1e9, sort_pass_bw=2e8,
                            partition_pass_bw=3e8,
                            unclustered_penalty=4.0, clustered_penalty=1.5)
    store.put_profile("fp-a", 4096, prof)
    store.save()
    again = CalibrationStore()
    got = again.get_profile("fp-a", 4096)
    assert got == prof
    assert again.get_profile("fp-a", 8192) is None  # keyed by n
    assert again.get_profile("fp-b", 4096) is None  # keyed by backend
    # schema drift (missing constants) falls back to None, not half a profile
    again.data["fp-a"]["profiles"]["4096"].pop("seq_bw")
    assert again.get_profile("fp-a", 4096) is None
    # corrupt file tolerated: store starts empty
    calstore_path.write_text("{not json")
    assert CalibrationStore().data == {}


def test_calibrated_profile_cache_keyed_by_backend_and_n(calstore_path,
                                                         monkeypatch):
    """Satellite fix: the in-process profile cache must key by (backend, n),
    not be a single global slot — different calibration sizes coexist and
    a repeated call never re-measures."""
    calls = []

    def fake_measure(cls, n=1 << 16, **kw):
        calls.append(n)
        return PrimitiveProfile(seq_bw=float(n), sort_pass_bw=1.0,
                                partition_pass_bw=1.0,
                                unclustered_penalty=1.0,
                                clustered_penalty=1.0)

    monkeypatch.setattr(PrimitiveProfile, "measure",
                        classmethod(fake_measure))
    monkeypatch.setattr(P, "_PROFILE_CACHE", {})
    p1 = P.calibrated_profile(n=1024)
    p2 = P.calibrated_profile(n=2048)
    assert (p1.seq_bw, p2.seq_bw) == (1024.0, 2048.0)
    assert calls == [1024, 2048]
    assert P.calibrated_profile(n=1024) is p1  # cached, not re-measured
    assert calls == [1024, 2048]
    fp = backend_fingerprint()
    assert {(fp, 1024), (fp, 2048)} <= set(P._PROFILE_CACHE)


def test_calibrated_profile_persists_across_processes(calstore_path):
    """Acceptance check: process one measures and persists; process two
    (measurement poisoned) loads the stored profile from CALIBRATION.json
    instead of re-running the microbenchmarks."""
    env = dict(os.environ, PYTHONPATH=SRC,
               REPRO_CALIBRATION_PATH=str(calstore_path))
    first = (
        "from repro.core.planner import PrimitiveProfile\n"
        "from repro.engine import calibrated_profile\n"
        "PrimitiveProfile.measure = classmethod(\n"
        "    lambda cls, n=0, **kw: PrimitiveProfile(seq_bw=123.0,\n"
        "        sort_pass_bw=1.0, partition_pass_bw=1.0,\n"
        "        unclustered_penalty=1.0, clustered_penalty=1.0))\n"
        "print(calibrated_profile(n=4096).seq_bw)\n")
    second = (
        "from repro.core.planner import PrimitiveProfile\n"
        "def boom(*a, **kw): raise AssertionError('re-measured')\n"
        "PrimitiveProfile.measure = classmethod(boom)\n"
        "from repro.engine import calibrated_profile\n"
        "print(calibrated_profile(n=4096).seq_bw)\n")
    for code in (first, second):
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        assert out.stdout.strip().endswith("123.0"), out.stdout
    saved = json.loads(calstore_path.read_text())
    fp = next(iter(saved))
    assert saved[fp]["profiles"]["4096"]["seq_bw"] == 123.0


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------
def test_metrics_registry_basics():
    reg = metrics.MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2)
    reg.histogram("h").observe(1.0)
    reg.histogram("h").observe(3.0)
    snap = reg.snapshot()
    assert snap["a"] == 3
    assert snap["h"]["count"] == 2 and snap["h"]["max"] == 3.0
    with pytest.raises(TypeError):
        reg.histogram("a")  # kind mismatch on an existing name
    reg.reset()
    assert reg.snapshot() == {}


def test_engine_metrics_counters():
    plan = _star_plan(seed=1)
    metrics.reset()
    plan.run()
    plan.run()
    snap = metrics.snapshot()
    assert snap.get("engine.plans_compiled", 0) >= 1
    assert snap.get("engine.plan_cache_hits", 0) >= 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_obs_cli_smoke(tmp_path, monkeypatch, calstore_path):
    """`python -m repro.obs --smoke` end to end: traced workload, TRACE
    files written with full schemas, CALIBRATION.json gains residuals."""
    from repro.obs.__main__ import main

    # pre-seed the profile so the CLI loads it instead of measuring
    store = CalibrationStore()
    store.put_profile(backend_fingerprint(), 1 << 16, PrimitiveProfile())
    store.save()
    monkeypatch.setattr(P, "_PROFILE_CACHE", {})
    monkeypatch.chdir(tmp_path)
    rc = main(["--smoke", "--iters", "1", "--warmup", "1"])
    assert rc == 0
    tr = json.loads((tmp_path / "TRACE.json").read_text())
    assert set(tr["queries"]) == {"star", "highcard_groupby"}
    for q in tr["queries"].values():
        assert all("residual" in n and n["measured_s"] > 0
                   for n in q["nodes"])
    pe = json.loads((tmp_path / "TRACE.perfetto.json").read_text())
    assert pe["traceEvents"]
    cal = json.loads(calstore_path.read_text())
    ent = cal[backend_fingerprint()]
    assert ent["profiles"] and ent["residuals"]
    assert any(k.startswith(("groupby/", "groupjoin/", "join/"))
               for k in ent["residuals"])


def test_metrics_percentiles_nearest_rank():
    vals = list(range(1, 101))  # 1..100
    p = metrics.percentiles(vals, (50, 95, 99))
    assert p == {"p50": 50.0, "p95": 95.0, "p99": 99.0}
    assert metrics.percentiles([], (50,)) == {"p50": 0.0}
    assert metrics.percentiles([7.0], (50, 99)) == {"p50": 7.0, "p99": 7.0}
    # fractional percentile labels format cleanly
    assert metrics.percentiles(vals, (99.9,)) == {"p99.9": 100.0}


def test_histogram_summary_and_bounded_samples():
    h = metrics.Histogram("t")
    assert h.summary() == {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                           "p50": 0.0, "p95": 0.0, "p99": 0.0}
    for v in range(20_000):
        h.observe(float(v))
    # the sample buffer is decimated deterministically, never unbounded
    assert len(h.samples) < metrics.SAMPLE_CAP
    assert h.stride > 1
    s = h.summary()
    assert s["count"] == 20_000 and s["min"] == 0.0 and s["max"] == 19_999.0
    # stride-thinned percentiles stay representative of the full stream
    assert abs(s["p50"] - 10_000) < 1_000
    assert abs(s["p99"] - 19_800) < 1_000
    # as_value (the snapshot shape) is unchanged by the sample buffer
    assert set(h.as_value()) == {"count", "sum", "mean", "min", "max", "last"}

"""Grouped-aggregation correctness: all strategies vs a python oracle,
across cardinalities, skew, and aggregation ops (+ hypothesis property)."""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Table, group_aggregate, KEY_SENTINEL

STRATEGIES = ["sort", "partition_hash", "scatter"]


def oracle(keys, vals):
    agg = collections.defaultdict(lambda: [0.0, 0, np.inf, -np.inf])
    for k, v in zip(keys, vals):
        e = agg[int(k)]
        e[0] += float(v)
        e[1] += 1
        e[2] = min(e[2], float(v))
        e[3] = max(e[3], float(v))
    return agg


def check(G, count, exp, ops=("sum",)):
    got = {}
    ks = np.asarray(G["k"])
    for i, k in enumerate(ks):
        if k == KEY_SENTINEL:
            continue
        got[int(k)] = {op: float(np.asarray(G[f"v_{op}"])[i]) for op in ops}
    assert int(count) == len(exp)
    assert set(got) == set(exp)
    for k, e in exp.items():
        ref = {"sum": e[0], "count": e[1], "min": e[2], "max": e[3],
               "mean": e[0] / e[1]}
        for op in ops:
            assert abs(got[k][op] - ref[op]) < 1e-2 + 1e-4 * abs(ref[op]), (k, op)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("g", [7, 200, 3000])
def test_cardinalities(strategy, g, rng):
    n = 5000
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = group_aggregate(t, key="k", aggs={"v": "sum"},
                               num_groups=2 * g + 64, strategy=strategy)
    check(G, count, oracle(keys, vals))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_ops(strategy, rng):
    n, g = 2000, 50
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    for op in ("sum", "count", "min", "max", "mean"):
        G, count = group_aggregate(t, key="k", aggs={"v": op},
                                   num_groups=128, strategy=strategy)
        check(G, count, oracle(keys, vals), ops=(op,))


@pytest.mark.parametrize("strategy", ["sort", "partition_hash"])
def test_heavy_hitter_skew(strategy, rng):
    """A single key holding 60% of rows must not overflow any block."""
    n = 4000
    keys = rng.integers(0, 500, n).astype(np.int32)
    keys[: int(0.6 * n)] = 13
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = group_aggregate(t, key="k", aggs={"v": "sum"},
                               num_groups=1024, strategy=strategy)
    check(G, count, oracle(keys, vals))


def test_multi_column_aggs(rng):
    n = 1500
    keys = rng.integers(0, 40, n).astype(np.int32)
    v = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(v), "w": jnp.asarray(w)})
    for strategy in STRATEGIES:
        G, count = group_aggregate(t, key="k", aggs={"v": "sum", "w": "max"},
                                   num_groups=128, strategy=strategy)
        exp_v = oracle(keys, v)
        exp_w = oracle(keys, w)
        ks = np.asarray(G["k"])
        for i, k in enumerate(ks):
            if k == KEY_SENTINEL:
                continue
            assert abs(float(G["v_sum"][i]) - exp_v[int(k)][0]) < 1e-2
            assert abs(float(G["w_max"][i]) - exp_w[int(k)][3]) < 1e-4


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), g=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(STRATEGIES))
def test_groupby_property(n, g, seed, strategy):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = group_aggregate(t, key="k", aggs={"v": "sum"},
                               num_groups=2 * g + 64, strategy=strategy)
    check(G, count, oracle(keys, vals))


def test_sort_pallas_strategy(rng):
    """The Pallas-kernel-backed group-by equals the oracle (sum/mean/count)."""
    n, g = 3000, 41
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    for op in ("sum", "mean", "count"):
        G, count = group_aggregate(t, key="k", aggs={"v": op}, num_groups=64,
                                   strategy="sort_pallas")
        check(G, count, oracle(keys, vals), ops=(op,))

"""Grouped-aggregation correctness: all strategies vs a python oracle,
across cardinalities, skew, and aggregation ops (+ hypothesis property)."""
from __future__ import annotations

import collections

from hypothesis import given, settings, strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KEY_SENTINEL, Table, group_aggregate, groupby_partition_checked,
                        groupby_partition_overflowed)

STRATEGIES = ["sort", "partition_hash", "scatter", "partition"]


def agg(t, strategy, **kw):
    """group_aggregate, routing 'partition' through the checked driver: the
    shared grids include heavy duplication, where the plain path's static
    row_block needs the eager overflow escalation."""
    if strategy == "partition":
        return groupby_partition_checked(t, **kw)
    return group_aggregate(t, strategy=strategy, **kw)


def oracle(keys, vals):
    agg = collections.defaultdict(lambda: [0.0, 0, np.inf, -np.inf])
    for k, v in zip(keys, vals):
        e = agg[int(k)]
        e[0] += float(v)
        e[1] += 1
        e[2] = min(e[2], float(v))
        e[3] = max(e[3], float(v))
    return agg


def check(G, count, exp, ops=("sum",)):
    got = {}
    ks = np.asarray(G["k"])
    for i, k in enumerate(ks):
        if k == KEY_SENTINEL:
            continue
        got[int(k)] = {op: float(np.asarray(G[f"v_{op}"])[i]) for op in ops}
    assert int(count) == len(exp)
    assert set(got) == set(exp)
    for k, e in exp.items():
        ref = {"sum": e[0], "count": e[1], "min": e[2], "max": e[3],
               "mean": e[0] / e[1]}
        for op in ops:
            assert abs(got[k][op] - ref[op]) < 1e-2 + 1e-4 * abs(ref[op]), (k, op)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("g", [7, 200, 3000])
def test_cardinalities(strategy, g, rng):
    n = 5000
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = agg(t, strategy, key="k", aggs={"v": "sum"},
                   num_groups=2 * g + 64)
    check(G, count, oracle(keys, vals))


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_all_ops(strategy, rng):
    n, g = 2000, 50
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    for op in ("sum", "count", "min", "max", "mean"):
        G, count = agg(t, strategy, key="k", aggs={"v": op}, num_groups=128)
        check(G, count, oracle(keys, vals), ops=(op,))


@pytest.mark.parametrize("strategy", ["sort", "partition_hash", "partition"])
def test_heavy_hitter_skew(strategy, rng):
    """A single key holding 60% of rows must not overflow any block."""
    n = 4000
    keys = rng.integers(0, 500, n).astype(np.int32)
    keys[: int(0.6 * n)] = 13
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = agg(t, strategy, key="k", aggs={"v": "sum"}, num_groups=1024)
    check(G, count, oracle(keys, vals))


def test_multi_column_aggs(rng):
    n = 1500
    keys = rng.integers(0, 40, n).astype(np.int32)
    v = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(v), "w": jnp.asarray(w)})
    for strategy in STRATEGIES:
        G, count = agg(t, strategy, key="k", aggs={"v": "sum", "w": "max"},
                       num_groups=128)
        exp_v = oracle(keys, v)
        exp_w = oracle(keys, w)
        ks = np.asarray(G["k"])
        for i, k in enumerate(ks):
            if k == KEY_SENTINEL:
                continue
            assert abs(float(G["v_sum"][i]) - exp_v[int(k)][0]) < 1e-2
            assert abs(float(G["w_max"][i]) - exp_w[int(k)][3]) < 1e-4


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), g=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(STRATEGIES))
def test_groupby_property(n, g, seed, strategy):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    G, count = agg(t, strategy, key="k", aggs={"v": "sum"},
                   num_groups=2 * g + 64)
    check(G, count, oracle(keys, vals))


def test_sort_pallas_strategy(rng):
    """The Pallas-kernel-backed group-by equals the oracle (sum/mean/count)."""
    n, g = 3000, 41
    keys = rng.integers(0, g, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    for op in ("sum", "mean", "count"):
        G, count = group_aggregate(t, key="k", aggs={"v": op}, num_groups=64,
                                   strategy="sort_pallas")
        check(G, count, oracle(keys, vals), ops=(op,))


def test_sort_pallas_hoists_count_kernel(rng, monkeypatch):
    """The count pass is key-only and identical across columns: it must run
    at most once, and not at all when no mean/count aggregate needs it."""
    from repro.kernels import ops as kops

    calls = []
    real = kops.groupby_sorted_sum

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(kops, "groupby_sorted_sum", spy)
    n, g = 1000, 20
    keys = rng.integers(0, g, n).astype(np.int32)
    cols = {"k": jnp.asarray(keys)}
    for name in ("v", "w"):
        cols[name] = jnp.asarray(rng.normal(size=n).astype(np.float32))
    t = Table(cols)

    calls.clear()
    group_aggregate(t, key="k", aggs={"v": "sum", "w": "sum"}, num_groups=64,
                    strategy="sort_pallas")
    assert len(calls) == 2  # one value pass per column, NO count pass

    calls.clear()
    group_aggregate(t, key="k", aggs={"v": "mean", "w": "mean"}, num_groups=64,
                    strategy="sort_pallas")
    assert len(calls) == 3  # two value passes + ONE hoisted count pass

    calls.clear()
    G, count = group_aggregate(t, key="k", aggs={"v": "count"}, num_groups=64,
                               strategy="sort_pallas")
    assert len(calls) == 1  # count alone: just the hoisted count pass
    check(G, count, oracle(keys, np.asarray(cols["v"])), ops=("count",))


# ---------------------------------------------------------------------------
# Partition-based group-by (DESIGN.md §8)
# ---------------------------------------------------------------------------
def _norm_rows(G, count, ops):
    """Key-sorted (key, *aggs) rows: partition output is (partition, key)-
    ordered, sort output key-ordered — normalize before comparing."""
    c = int(count)
    ks = np.asarray(G["k"])[:c]
    cols = [np.asarray(G[name])[:c] for name in ops]
    order = np.argsort(ks, kind="stable")
    return [tuple(float(col[i]) for col in [ks] + cols) for i in order]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), g=st.integers(1, 4000), zipf=st.sampled_from([0.0, 1.4]),
       pad=st.integers(0, 500), seed=st.integers(0, 2**31 - 1))
def test_partition_matches_sort_after_key_normalization(n, g, zipf, pad, seed):
    """groupby_partition == groupby_sort (after key-sort normalization)
    across cardinality x skew x sentinel-padding grids."""
    rng = np.random.default_rng(seed)
    if zipf:
        keys = ((rng.zipf(zipf, n) - 1) % g).astype(np.int32)
    else:
        keys = rng.integers(0, g, n).astype(np.int32)
    keys = np.concatenate([keys, np.full(pad, KEY_SENTINEL, np.int32)])
    vals = rng.normal(size=n + pad).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    cap = 2 * min(g, n) + 64
    Gp, cp = groupby_partition_checked(t, key="k", aggs={"v": "sum"},
                                       num_groups=cap)
    Gs, cs = group_aggregate(t, key="k", aggs={"v": "sum"}, num_groups=cap,
                             strategy="sort")
    assert int(cp) == int(cs)
    rp = _norm_rows(Gp, cp, ["v_sum"])
    rs = _norm_rows(Gs, cs, ["v_sum"])
    assert len(rp) == len(rs)
    for (kp, vp), (ks_, vs_) in zip(rp, rs):
        assert kp == ks_
        assert abs(vp - vs_) < 1e-2 + 1e-4 * abs(vs_)


def test_partition_plain_path_high_cardinality(rng):
    """The jit-safe plain path (no eager check) is exact in the regime the
    chooser routes to it: high cardinality, low per-key multiplicity."""
    n = 20_000
    keys = rng.integers(0, 1 << 30, n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    t = Table({"k": jnp.asarray(keys), "v": jnp.asarray(vals)})
    over, p_bits, mx = groupby_partition_overflowed(t["k"])
    assert not over, (p_bits, mx)
    import jax

    f = jax.jit(lambda tb: group_aggregate(
        tb, key="k", aggs={"v": "sum"}, num_groups=n + 64, strategy="partition"))
    G, count = f(t)
    check(G, count, oracle(keys, vals))


def test_partition_overflow_check_detects_heavy_key(rng):
    keys = np.full(5000, 77, np.int32)  # one key, 5000 rows: must overflow
    over, _, mx = groupby_partition_overflowed(jnp.asarray(keys))
    assert over and mx == 5000


def test_partition_layout_grows_block_past_fanout_cap():
    """Past the 16-bit fan-out cap the BLOCK must grow to keep
    E[rows/partition] <= row_block/2 — silently over-filling every partition
    would drop each partition's overhang, not a tail."""
    from repro.core.groupby import _partition_layout

    p_bits, rb = _partition_layout(1 << 22, 64, None)
    assert p_bits == 16
    assert rb >= 2 * (1 << 22) / (1 << 16)  # invariant holds via the block
    # explicit bits pin the caller's geometry (checked driver relies on it)
    assert _partition_layout(1 << 22, 64, 9) == (9, 64)
    # small inputs are untouched
    assert _partition_layout(10_000, 256, None)[1] == 256


def test_partition_float_negative_zero_co_groups(rng):
    """-0.0 and 0.0 compare equal, so they must land in ONE group (as the
    sort path's run-boundary test merges them), not split across hash
    partitions by their differing bit patterns."""
    vals_k = np.array([-0.0, 0.0, 1.5, 2.5] * 50, np.float32)
    t = Table({"k": jnp.asarray(vals_k),
               "v": jnp.ones(vals_k.size, jnp.float32)})
    Gp, cp = groupby_partition_checked(t, key="k", aggs={"v": "sum"},
                                       num_groups=64)
    Gs, cs = group_aggregate(t, key="k", aggs={"v": "sum"}, num_groups=64,
                             strategy="sort")
    assert int(cp) == int(cs) == 3
    sums_p = sorted(float(v) for v, k in
                    zip(np.asarray(Gp["v_sum"]), np.asarray(Gp["k"]))
                    if k != KEY_SENTINEL)
    sums_s = sorted(float(v) for v, k in
                    zip(np.asarray(Gs["v_sum"]), np.asarray(Gs["k"]))
                    if k != KEY_SENTINEL)
    assert sums_p == sums_s == [50.0, 50.0, 100.0]

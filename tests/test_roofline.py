"""Roofline machinery: HLO collective/dot parsing (synthetic HLO), trip-count
recovery, term math, analytic-vs-model cross-checks."""
from __future__ import annotations

import pytest

from repro.configs.base import SHAPES, get_config
from repro.launch import roofline as RL
from repro.models import flops as FL
from repro.models.model import num_params


SYNTH_HLO = """
HloModule test, is_scheduled=true

%region_body.1 (arg: f32[8,128]) -> f32[8,128] {
  %x = f32[8,128]{1,0} parameter(0)
  %ag = f32[16,128]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[8,128]{1,0} all-reduce(%x), to_apply=%sum
  ROOT %r = f32[8,128]{1,0} add(%x, %x)
}

%region_cond.1 (arg: s32[]) -> pred[] {
  %i = s32[] parameter(0)
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128]{1,0} parameter(0)
  %w = (f32[8,128]{1,0}) while(%p), condition=%region_cond.1, body=%region_body.1
  %ag2 = f32[32,128]{1,0} all-gather(%p), dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%p), dimensions={0}
  ROOT %out = f32[8,128]{1,0} copy(%p)
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = RL.parse_collectives(SYNTH_HLO)
    assert stats.counts == {"all-gather": 2, "all-reduce": 1, "reduce-scatter": 1}
    # static: 16*128*4 + 8*128*4 + 32*128*4 + 4*128*4
    assert stats.bytes_static == (16 + 8 + 32 + 4) * 128 * 4


def test_parse_collectives_trip_weighting():
    stats = RL.parse_collectives(SYNTH_HLO)
    # ops inside the while body are x24; entry ops x1
    assert stats.bytes_weighted == ((16 + 8) * 24 + 32 + 4) * 128 * 4


def test_shape_bytes_dtypes():
    assert RL._shape_bytes("bf16[2,3]") == 12
    assert RL._shape_bytes("f32[10]{0}") == 40
    assert RL._shape_bytes("(f32[2], s8[8])") == 16
    assert RL._shape_bytes("pred[]") == 1


def test_roofline_terms_math():
    t = RL.roofline_terms(197e12 * 256, 819e9, 50e9, chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 1.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
    t2 = RL.roofline_terms(1e12, 819e9 * 10, 0, chips=256)
    assert t2["dominant"] == "memory_s"


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b", "zamba2-2.7b"])
def test_analytic_flops_close_to_6nd(arch):
    """Analytic total must bracket 6ND x remat: useful ratio in (0.15, 1.0]."""
    cfg = get_config(arch)
    est = FL.estimate(cfg, SHAPES["train_4k"], {"data": 16, "model": 16})
    ratio = est.model_flops / est.flops_total
    assert 0.15 < ratio <= 1.0, ratio


def test_remat_factor_scales_compute():
    cfg = get_config("olmo-1b")
    e4 = FL.estimate(cfg, SHAPES["train_4k"], {"data": 16, "model": 16},
                     remat_factor=4.0)
    e3 = FL.estimate(cfg, SHAPES["train_4k"], {"data": 16, "model": 16},
                     remat_factor=3.0)
    assert e3.flops_total < e4.flops_total
    # layers scale with the factor; embed/head don't
    layer4 = e4.flops_total - 3 * (2 * 256 * 4096 * cfg.d_model * cfg.padded_vocab)
    layer3 = e3.flops_total - 3 * (2 * 256 * 4096 * cfg.d_model * cfg.padded_vocab)
    assert abs(layer3 / layer4 - 0.75) < 1e-6


def test_decode_estimate_uses_active_params():
    cfg = get_config("mixtral-8x7b")
    est = FL.estimate(cfg, SHAPES["decode_32k"], {"data": 16, "model": 16})
    n_act = est.model_flops / (2.0 * SHAPES["decode_32k"].global_batch)
    assert n_act < 0.4 * num_params(cfg)  # top-2 of 8 experts + attention


def test_sliding_window_caps_decode_cache_cost():
    swa = get_config("mixtral-8x7b")
    est = FL.estimate(swa, SHAPES["long_500k"], {"data": 16, "model": 16})
    assert est.notes["kv_len"] == 4096  # not 524288

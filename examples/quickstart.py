"""Quickstart: the paper's technique in 30 lines.

Builds two relations, runs all four join implementations (SMJ/PHJ x
GFUR/GFTR), a grouped aggregation, and asks the planner (paper Fig. 18)
which algorithm to use.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (Table, join, group_aggregate, JoinStats,
                        choose_algorithm, KEY_SENTINEL)

rng = np.random.default_rng(0)
n_r, n_s = 10_000, 30_000

# R: primary-key side with two payload columns; S: foreign-key side.
R = Table({
    "k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
    "price": jnp.asarray(rng.gamma(2.0, 10.0, n_r).astype(np.float32)),
    "stock": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32)),
})
S = Table({
    "k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
    "qty": jnp.asarray(rng.integers(1, 10, n_s).astype(np.int32)),
})

for alg in ("smj", "phj"):
    for pattern in ("gfur", "gftr"):
        T, count = join(R, S, key="k", algorithm=alg, pattern=pattern)
        print(f"{alg.upper()}-{'OM' if pattern == 'gftr' else 'UM'}: "
              f"{int(count)} matches, first row k={int(T['k'][0])} "
              f"price={float(T['price'][0]):.2f} qty={int(T['qty'][0])}")

# grouped aggregation over the join result (assigned-title extension)
T, count = join(R, S, key="k", algorithm="phj", pattern="gftr")
G, g_count = group_aggregate(
    Table({"k": T["k"], "rev": T["price"] * T["qty"].astype(jnp.float32)}),
    key="k", aggs={"rev": "sum"}, num_groups=16_384, strategy="partition_hash",
)
print(f"group-by: {int(g_count)} groups, "
      f"total revenue {float(jnp.where(G['k'] != KEY_SENTINEL, G['rev_sum'], 0).sum()):.0f}")

# the paper's decision tree (Fig. 18)
stats = JoinStats(n_r=n_r, n_s=n_s, r_payload_cols=2, s_payload_cols=1,
                  match_ratio=1.0, zipf=0.0)
alg, pattern, why = choose_algorithm(stats)
print(f"planner picks: {alg.upper()}-{'OM' if pattern == 'gftr' else 'UM'} — {why}")

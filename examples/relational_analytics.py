"""TPC-style analytics through the query engine: declarative plans over
scaled TPC-H/DS extracts (paper Table 6) and a star schema, optimized with
engine-estimated statistics (no hand-written JoinStats) and executed under
jax.jit. `explain()` shows the per-operator algorithm/pattern choice and
the cost model's prediction.

    PYTHONPATH=src python examples/relational_analytics.py
"""
import jax.numpy as jnp

from repro.data import relgen
from repro.engine import Catalog, optimize, scan


def main():
    # -- single TPC extracts: R join S, planner-selected algorithm ---------
    for jid in ("J1", "J3", "J4"):
        R, S, mode = relgen.generate_tpc(jid, scale=1 / 1024)
        cat = Catalog({"R": R, "S": S})
        plan = optimize(scan("R").join(scan("S"), key="k", mode=mode), cat)
        T, count = plan.run()
        join_line = next(l for l in plan.explain().splitlines() if "Join[" in l)
        print(f"{jid}: |R|={R.num_rows} |S|={S.num_rows} -> {int(count)} rows")
        print(f"    {join_line.strip()}")

    # -- end-to-end: two joins + grouped aggregation + top-k ---------------
    fact, dims, fks, dks = relgen.generate_star(1 << 15, 1 << 12, 2,
                                                payloads_per_dim=1)
    cat = Catalog({"fact": fact, "dim0": dims[0], "dim1": dims[1]})
    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1")
         .group_by("fk0", p1_0="sum")
         .order_by("p1_0_sum", limit=8, descending=True))
    plan = optimize(q, cat)
    print("\nstar query:")
    print(plan.explain())
    G, g_cnt = plan.run()
    print(f"top-8 of {int(g_cnt)} surviving rows; "
          f"best group sum={int(jnp.max(G['p1_0_sum']))}")


if __name__ == "__main__":
    main()

"""TPC-style analytics on device: scaled TPC-H/DS join extracts (paper
Table 6) + grouped aggregation, with planner-selected algorithms.

    PYTHONPATH=src python examples/relational_analytics.py
"""
import jax.numpy as jnp

from repro.core import (Table, join, group_aggregate, JoinStats,
                        choose_algorithm, KEY_SENTINEL)
from repro.data import relgen

for jid in ("J1", "J3", "J4"):
    R, S, mode = relgen.generate_tpc(jid, scale=1 / 1024)
    stats = JoinStats(R.num_rows, S.num_rows,
                      len(R.column_names) - 1, len(S.column_names) - 1)
    alg, pattern, why = choose_algorithm(stats)
    T, count = join(R, S, algorithm=alg, pattern=pattern, mode=mode)
    print(f"{jid}: |R|={R.num_rows} |S|={S.num_rows} -> {int(count)} rows "
          f"via {alg.upper()}-{'OM' if pattern=='gftr' else 'UM'} ({why[:50]})")

# group-by over the last join's output
pay = [c for c in T.column_names if c != "k"][0]
G, g_cnt = group_aggregate(
    Table({"k": T["k"] % 1024, "v": T[pay].astype(jnp.float32)}),
    key="k", aggs={"v": "mean"}, num_groups=2048, strategy="partition_hash")
print(f"group-by on join output: {int(g_cnt)} groups")

"""Serve batched requests through the continuous-batching engine:

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --requests 8
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])

"""Train a language model (any assigned arch) with the fault-tolerant loop:

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 100
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --steps 50

Uses reduced configs on CPU (--full for TPU-scale). Checkpoints to
--ckpt-dir and resumes automatically if re-run."""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:])

"""End-to-end driver (deliverable b): relational preprocessing ON DEVICE
feeding LM training — the paper's §1 motivating use case.

Per step: a fact table of (user, item, label) events is joined against two
feature dimension tables with the GFTR-optimized PHJ join, per-user history
aggregates come from the partition-hash group-by, the joined features are
tokenized, and an xLSTM LM trains on the stream. Everything after the
synthetic event generator runs in jit on device.

    PYTHONPATH=src python examples/ml_pipeline.py --steps 200
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.data.pipeline import (FeatureJoinConfig, assemble_batch,
                                 history_aggregates, make_dim_tables,
                                 make_fact_batch)
from repro.models import model as M
from repro.train.optimizer import AdamW, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pattern", default="gftr", choices=["gftr", "gfur"])
    args = ap.parse_args(argv)

    pcfg = FeatureJoinConfig(algorithm="phj", pattern=args.pattern, vocab=512)
    U, I = make_dim_tables(pcfg)
    mcfg = get_reduced_config("xlstm-125m").replace(vocab_size=pcfg.vocab)
    params = M.init_params(mcfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=cosine_schedule(3e-3, 10, args.steps), master_weights=False)
    opt_state = opt.init(params)

    @jax.jit
    def pipeline_step(params, opt_state, fact):
        batch, _joined, _cnt = assemble_batch(pcfg, U, I, fact, args.batch, args.seq)
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(M.loss_fn, mcfg), has_aux=True)(params, batch)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        fact = make_fact_batch(pcfg, args.batch, args.seq, step)
        params, opt_state, loss = pipeline_step(params, opt_state, fact)
        losses.append(float(loss))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f}")

    G, count = history_aggregates(pcfg, fact)
    print(f"\njoin({args.pattern})+train: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} steps, {time.time()-t0:.1f}s)")
    print(f"per-user history aggregates: {int(count)} users")
    assert losses[-1] < losses[0], "pipeline training must reduce loss"


if __name__ == "__main__":
    main()

"""Reproduction of "Efficiently Processing Joins and Grouped Aggregations
on GPUs" on the JAX/Pallas stack, grown toward a production-scale sharded
system (see ROADMAP.md).

Subpackages (import side-effect free; nothing here touches jax device
state):

  core      join/group-by algorithms, planner, memory model
  engine    cost-based relational query engine (plan IR, statistics,
            optimizer, jit executor) over core's operators
  kernels   Pallas kernels (interpret=True on CPU)
  dist      sharding rules, compressed collectives, pipeline parallelism
  models    architecture zoo over one template/forward/decode API
  train     optimizer, loop, checkpointing, elastic remesh
  launch    mesh construction, dry-run, roofline, launchers
  data      synthetic relational + LM data pipelines
  serve     decode-serving engine
  configs   architecture configs (full + CPU-reduced)
"""

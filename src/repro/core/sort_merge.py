"""Sort-merge join: SMJ-UM (GFUR pattern, §3.1) and SMJ-OM (GFTR, §4.2).

Phases (paper §2.2):
  transformation  – sort (key, payload/ID) pairs (SORT-PAIRS primitive)
  match finding   – merge join over sorted keys. Merge Path's job on the GPU
                    is per-thread load balance; on TPU the equivalent is a
                    vectorized lower-bound search (one sweep for PK-FK, two
                    for m:n — exactly the paper's single/double Merge Path
                    application, §3.1), tiled in the Pallas kernel.
  materialization – GATHER payload columns. GFUR gathers from the *original*
                    relations with permuted physical IDs (unclustered); GFTR
                    gathers from the *sorted* relations with monotone virtual
                    IDs (clustered) — Algorithm 1 of the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import primitives as prim
from .table import KEY_SENTINEL, Table


# ---------------------------------------------------------------------------
# Match finding over sorted key columns
# ---------------------------------------------------------------------------
def merge_find_pk_fk(kr_sorted: jax.Array, ks_sorted: jax.Array):
    """PK-FK merge: one lower-bound sweep (paper §3.1: 'we only need to apply
    the Merge Path algorithm once').

    Returns (vid_r, matched): for each S' row j, the position of its match in
    R' (virtual ID) and whether it matched. Both outputs are monotone in j,
    so downstream IDs stay clustered — the property GFTR needs (§4.1).
    """
    n_r = kr_sorted.shape[0]
    lb = jnp.searchsorted(kr_sorted, ks_sorted, side="left").astype(jnp.int32)
    lb_c = jnp.minimum(lb, n_r - 1)
    matched = (jnp.take(kr_sorted, lb_c) == ks_sorted) & (lb < n_r)
    matched &= ks_sorted != KEY_SENTINEL
    return lb_c, matched


def merge_find_mn(kr_sorted: jax.Array, ks_sorted: jax.Array, capacity: int):
    """General m:n merge: lower+upper bound sweeps (the paper's two Merge
    Path applications) + expansion.

    Returns (vid_r, vid_s, valid, total) of length `capacity`.
    """
    lb = jnp.searchsorted(kr_sorted, ks_sorted, side="left").astype(jnp.int32)
    ub = jnp.searchsorted(kr_sorted, ks_sorted, side="right").astype(jnp.int32)
    counts = jnp.where(ks_sorted == KEY_SENTINEL, 0, ub - lb)
    row, rank, valid, total = prim.expand_offsets(counts, capacity)
    vid_s = row
    vid_r = jnp.take(lb, row) + rank
    return vid_r, vid_s, valid, total


# ---------------------------------------------------------------------------
# Join drivers
# ---------------------------------------------------------------------------
def _split_payloads(t: Table, key: str):
    return [n for n in t.column_names if n != key]


def smj_join(
    R: Table,
    S: Table,
    *,
    key: str = "k",
    pattern: str = "gftr",  # "gftr" (SMJ-OM) | "gfur" (SMJ-UM)
    out_size: int | None = None,
    mode: str = "pk_fk",  # "pk_fk" | "mn"
    find_impl: str = "xla",  # "xla" | "pallas" (windowed lower-bound kernel)
):
    """End-to-end sort-merge join. Returns (Table, valid_count).

    Output columns: key + R payloads + S payloads; rows >= valid_count are
    padding (key == KEY_SENTINEL).
    """
    if out_size is None:
        out_size = S.num_rows if mode == "pk_fk" else S.num_rows * 2
    r_pay, s_pay = _split_payloads(R, key), _split_payloads(S, key)

    if pattern == "gfur":
        return _smj_gfur(R, S, key, r_pay, s_pay, out_size, mode, find_impl)
    if pattern == "gftr":
        return _smj_gftr(R, S, key, r_pay, s_pay, out_size, mode, find_impl)
    raise ValueError(f"unknown pattern {pattern!r}")


def _find(kr, ks, mode, out_size, find_impl="xla"):
    """Shared match-find + compaction producing clustered (vid_r, vid_s)."""
    if mode == "pk_fk":
        if find_impl == "pallas":
            from repro.kernels import ops as _kops

            n_r = kr.shape[0]
            lb = _kops.merge_lower_bound(kr, ks, "auto")
            lb_c = jnp.minimum(lb, n_r - 1)
            matched = (jnp.take(kr, lb_c) == ks) & (lb < n_r) & (ks != KEY_SENTINEL)
            vid_r = lb_c
        else:
            vid_r, matched = merge_find_pk_fk(kr, ks)
        vid_s = jnp.arange(ks.shape[0], dtype=jnp.int32)
        (keys_o, vr_o, vs_o), count = prim.compact(
            matched, [ks, vid_r, vid_s], out_size, fill=KEY_SENTINEL
        )
        valid = jnp.arange(out_size) < count
        return keys_o, vr_o, vs_o, valid, count
    vid_r, vid_s, valid, total = merge_find_mn(kr, ks, out_size)
    keys_o = jnp.where(valid, jnp.take(ks, vid_s), KEY_SENTINEL)
    return keys_o, vid_r, vid_s, valid, jnp.minimum(total, out_size)


def _smj_gfur(R, S, key, r_pay, s_pay, out_size, mode, find_impl="xla"):
    # Transformation: sort only (key, physical ID) — the "narrow" transform.
    id_r = jnp.arange(R.num_rows, dtype=jnp.int32)
    id_s = jnp.arange(S.num_rows, dtype=jnp.int32)
    kr, pid_r = prim.sort_pairs(R[key], id_r)
    ks, pid_s = prim.sort_pairs(S[key], id_s)
    # Match finding (virtual ids w.r.t. sorted arrays) ...
    keys_o, vr, vs, valid, count = _find(kr, ks, mode, out_size, find_impl)
    # ... translated to *physical* IDs of the untransformed relations: the
    # permutation makes them unclustered — this is GFUR's flaw (§3.3).
    ID_R = jnp.where(valid, jnp.take(pid_r, vr), -1)
    ID_S = jnp.where(valid, jnp.take(pid_s, vs), -1)
    cols = {key: keys_o}
    for n in r_pay:  # unclustered gathers from original R
        cols[n] = prim.gather(R[n], ID_R, fill=0)
    for n in s_pay:  # unclustered gathers from original S
        cols[n] = prim.gather(S[n], ID_S, fill=0)
    return Table(cols), count


def _smj_gftr(R, S, key, r_pay, s_pay, out_size, mode, find_impl="xla"):
    # Algorithm 1 with the one-permutation refinement (DESIGN.md §8): the
    # key sort is planned ONCE per relation, and every payload column —
    # first or lazy — is transformed with a single apply_permutation gather.
    kr, perm_r = prim.plan_sort_permutation(R[key])
    ks, perm_s = prim.plan_sort_permutation(S[key])
    tr = {n: prim.apply_permutation(perm_r, R[n]) for n in r_pay[:1]}
    ts = {n: prim.apply_permutation(perm_s, S[n]) for n in s_pay[:1]}
    transform_r = lambda n: prim.apply_permutation(perm_r, R[n])
    transform_s = lambda n: prim.apply_permutation(perm_s, S[n])

    # Match finding on sorted keys with *virtual* tuple IDs (line 3).
    keys_o, vid_r, vid_s, valid, count = _find(kr, ks, mode, out_size, find_impl)
    ID_R = jnp.where(valid, vid_r, -1)
    ID_S = jnp.where(valid, vid_s, -1)

    # Materialization phase (lines 4-9): clustered gathers from transformed
    # relations, transforming remaining payload columns one at a time.
    cols = {key: keys_o}
    for i, n in enumerate(r_pay):
        src = tr[n] if i == 0 else transform_r(n)
        cols[n] = prim.gather(src, ID_R, fill=0)
    for i, n in enumerate(s_pay):
        src = ts[n] if i == 0 else transform_s(n)
        cols[n] = prim.gather(src, ID_S, fill=0)
    return Table(cols), count

"""GPU-primitive analogues on TPU/XLA (paper §2.3).

The paper builds its joins from three vendor primitives:

  SORT-PAIRS(kin, vin, ...)      -> CUB LSD radix sort (8 bits / pass)
  RADIX-PARTITION(kin, vin, i, j)-> stable partition on radix bits [i, j)
  GATHER(in, map, out)           -> out[i] = in[map[i]]

TPU adaptation (DESIGN.md §2, §10): the *stability/determinism* requirement
that the paper had to engineer around CUDA atomics comes for free here — the
partition permutation is derived from prefix-sum ranks (production) or a
stable sort (reference arm), never from write races. `sort_pairs` uses XLA's
tuned TPU sort in the production path; partition plans default to the
kernel-backed histogram/prefix/rank pipeline (`kernels.ops.partition_plan`),
which is linear per pass and emits zero sort primitives;
`radix_sort_pairs` reproduces the paper's LSD pass structure exactly (one
stable partition per 8-bit digit) and is what the cost model counts.

All primitives are shape-polymorphic pure functions safe under jit/vmap.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

RADIX_BITS_PER_PASS = 8  # paper §2.3: Ampere RADIX-PARTITION does max 8 bits

# Production arm for full key-sort plans. XLA's tuned sort is the deliberate
# default (the paper's vendor SORT-PAIRS choice); 'radix' runs the same
# kernel-backed rank passes the partition planner uses, making SMJ's GFTR
# transform sort-free as well.
DEFAULT_SORT_PLAN_IMPL = "xla"


# ---------------------------------------------------------------------------
# SORT-PAIRS
# ---------------------------------------------------------------------------
def sort_pairs(keys: jax.Array, *values: jax.Array):
    """Stable key-value sort (CUB SORT-PAIRS analogue) via XLA's native sort.

    Returns (sorted_keys, *values_permuted_alike).
    """
    res = jax.lax.sort((keys,) + tuple(values), num_keys=1, is_stable=True)
    return res if values else res[0]


def argsort_stable(keys: jax.Array) -> jax.Array:
    """Stable argsort; out[i] = index of i-th smallest key."""
    iota = jnp.arange(keys.shape[0], dtype=jnp.int32)
    _, perm = jax.lax.sort((keys, iota), num_keys=1, is_stable=True)
    return perm


# ---------------------------------------------------------------------------
# One-permutation materialization layer (DESIGN.md §8)
#
# Algorithm 1's "transform lazily" only pays off if the transform itself is
# cheap: re-running the full sort/partition once per payload column turns one
# data-movement plan into O(C) of them. These planners run the sort/partition
# machinery ONCE, carrying only (key-or-digit, iota), and return a composed
# permutation; `apply_permutation` then materializes any number of payload
# columns at exactly one gather each.
# ---------------------------------------------------------------------------
def apply_permutation(perm: jax.Array, *cols: jax.Array):
    """Materialize a planned permutation: out[i] = col[perm[i]] per column —
    one gather per column, the entire per-column transform cost.

    Returns a single array for one column, a tuple for several (sort_pairs
    idiom)."""
    outs = tuple(jnp.take(c, perm, axis=0) for c in cols)
    return outs if len(cols) != 1 else outs[0]


def plan_sort_permutation(keys: jax.Array, *, impl: str | None = None):
    """Plan a stable key sort once, payloads later.

    Returns (sorted_keys, perm) where perm is the composed gather map:
    `apply_permutation(perm, col)` equals `sort_pairs(keys, col)[1]` for any
    payload column, without re-sorting.

    impl='xla' (default): XLA's tuned native sort — the deliberate
    production arm for full key sorts, mirroring the paper's use of the
    vendor SORT-PAIRS (§2.3). impl='radix': the kernel-backed sort-free
    rank passes over the full key pattern (int32 keys), equal to the XLA
    sort bit-for-bit; flip `DEFAULT_SORT_PLAN_IMPL` (or pass impl=) to run
    SMJ's GFTR transform entirely sort-free on radix hardware."""
    from repro.kernels import ops as kops

    impl = DEFAULT_SORT_PLAN_IMPL if impl is None else impl
    return kops.sort_plan(keys, impl)


def plan_partition_permutation(digits: jax.Array, num_partitions: int, *,
                               max_pass_bits: int | None = None,
                               carry: Sequence[jax.Array] = (),
                               impl: str | None = None):
    """Plan a stable radix partition once, payloads later.

    Returns (perm, offsets, sizes) — or (perm, carried, offsets, sizes) when
    `carry` is non-empty — with all layout arrays int32:
      perm[j]    = source row landing at output position j (gather form)
      offsets[p] = first output position of partition p
      sizes[p]   = rows in partition p

    impl='pallas' (the default, via `kernels.ops.PARTITION_PLAN_IMPL`) runs
    the sort-free rank pipeline: per-pass histogram -> exclusive prefix ->
    stable ranks, LSD-composed for fan-outs past one pass — linear work per
    pass, zero XLA sort primitives (jaxpr-pinned). PHJ, the partition
    group-by, multi_pass_radix_partition, and the fused group-join all ride
    it through this one entry point. impl='xla' keeps the stable-sort
    reference arm: `max_pass_bits=None` computes the permutation with one
    XLA stable sort; an integer runs the paper's multi-pass structure —
    stable passes of <= max_pass_bits bits, LSD order — and composes them
    into the same single permutation (equality is the §4.3 stability
    argument; both arms are parity-tested in tests/test_permutation.py).
    Either way, payload columns cost one `apply_permutation` gather each,
    never one gather per pass.

    `carry` columns come back already partitioned (Algorithm 1's
    key-rides-along idiom): the XLA arm carries them through its sort, the
    rank arm materializes each with one gather through the composed
    permutation — same contract, same values. Carry the column(s) the next
    phase reads immediately (e.g. the group key)."""
    from repro.kernels import ops as kops

    impl = kops.partition_plan_impl() if impl is None else impl
    perm, carried, offsets, sizes = kops.partition_plan(
        digits, num_partitions, carry=carry, max_pass_bits=max_pass_bits,
        impl=impl)
    if carry:
        return perm, carried, offsets, sizes
    return perm, offsets, sizes


# ---------------------------------------------------------------------------
# RADIX-PARTITION
# ---------------------------------------------------------------------------
def radix_digits(keys: jax.Array, start_bit: int, num_bits: int) -> jax.Array:
    """Extract the radix digit (bits [start_bit, start_bit+num_bits))."""
    mask = (1 << num_bits) - 1
    return (
        (keys.astype(jnp.uint32 if keys.dtype.itemsize <= 4 else jnp.uint64) >> start_bit)
        & mask
    ).astype(jnp.int32)


def partition_permutation(digits: jax.Array, num_partitions: int):
    """Stable-partition permutation & layout for given digits.

    Returns (perm, offsets, sizes):
      perm[j]    = source row that lands at output position j (gather form)
      offsets[p] = first output position of partition p (exclusive prefix sum)
      sizes[p]   = number of rows in partition p

    Deterministic by construction (stable sort on digit) — this is the TPU
    equivalent of the paper's §4.3 requirement that partitioning be stable so
    the same permutation applies to every payload column.

    offsets/sizes are int32 on every path (the Pallas rank kernel, the XLA
    ref, and this planner agree — see tests/test_permutation.py).
    """
    return plan_partition_permutation(digits, num_partitions)


def radix_partition(
    keys: jax.Array,
    *values: jax.Array,
    start_bit: int,
    num_bits: int,
):
    """RADIX-PARTITION primitive: stable partition of (keys, values...) by the
    radix digit. Partitions are stored contiguously (no fragmentation, unlike
    bucket chaining — paper §4.3). Returns (keys_out, *values_out, offsets,
    sizes)."""
    digits = radix_digits(keys, start_bit, num_bits)
    perm, offsets, sizes = partition_permutation(digits, 1 << num_bits)
    outs = tuple(jnp.take(a, perm, axis=0) for a in (keys,) + values)
    return outs + (offsets, sizes)


def multi_pass_radix_partition(
    keys: jax.Array,
    *values: jax.Array,
    total_bits: int,
    start_bit: int = 0,
):
    """Multi-pass RADIX-PARTITION (paper §3.2/§4.3: >256 partitions require
    multiple passes of <=8 bits). LSD order: later passes use higher bits, and
    stability makes the composition a single stable partition on all
    `total_bits` bits.

    One-permutation materialization: the passes carry only (digit, iota) and
    compose into a single permutation; every column — key and payloads alike
    — is then gathered exactly once, instead of once per pass (which made
    wide partitions cost O(passes * C) materializations).

    Returns (keys_out, *values_out, offsets, sizes) for the full fan-out.
    """
    digits = radix_digits(keys, start_bit, total_bits)
    perm, offsets, sizes = plan_partition_permutation(
        digits, 1 << total_bits, max_pass_bits=RADIX_BITS_PER_PASS
    )
    outs = apply_permutation(perm, keys, *values)
    if not values:
        outs = (outs,)
    return outs + (offsets, sizes)


def num_radix_passes(total_bits: int) -> int:
    """Pass count for the analytic cost model (paper: 15-16 bits -> 2 passes)."""
    return -(-total_bits // RADIX_BITS_PER_PASS)


def radix_sort_pairs(keys: jax.Array, *values: jax.Array, key_bits: int | None = None):
    """Paper-faithful LSD radix sort built from stable RADIX-PARTITION passes
    (8 bits per pass — CUB SORT-PAIRS' structure, §4.2's '17 sequential
    passes' cost shape). Non-negative keys. Equivalent to sort_pairs; the
    production path uses XLA's sort, this one exists so the pass structure
    the cost model charges for is real, executable code."""
    if key_bits is None:
        key_bits = 8 * keys.dtype.itemsize - 1  # non-negative keys
    arrs = (keys,) + values
    bit = 0
    while bit < key_bits:
        bits = min(RADIX_BITS_PER_PASS, key_bits - bit)
        res = radix_partition(arrs[0], *arrs[1:], start_bit=bit, num_bits=bits)
        arrs = res[:-2]
        bit += bits
    return arrs if values else arrs[0]


# ---------------------------------------------------------------------------
# GATHER
# ---------------------------------------------------------------------------
def gather(src: jax.Array, idx: jax.Array, *, fill=None) -> jax.Array:
    """GATHER primitive: out[i] = src[idx[i]]; idx < 0 or >= len -> fill (if
    given) else clipped. Whether this is clustered or unclustered depends
    entirely on `idx` — the paper's central observation."""
    out = jnp.take(src, jnp.clip(idx, 0, src.shape[0] - 1), axis=0)
    if fill is not None:
        valid = (idx >= 0) & (idx < src.shape[0])
        out = jnp.where(valid.reshape(valid.shape + (1,) * (out.ndim - 1)), out, fill)
    return out


def histogram(x: jax.Array, num_bins: int) -> jax.Array:
    return jnp.bincount(x, length=num_bins)


# ---------------------------------------------------------------------------
# Compaction (static-capacity stream compaction)
# ---------------------------------------------------------------------------
def compact(mask: jax.Array, arrays: Sequence[jax.Array], capacity: int, fill=0):
    """Stable stream compaction: rows where mask is True are moved to the
    front (preserving order) of capacity-sized outputs; returns
    (compacted_arrays, valid_count). Rows beyond `capacity` are dropped.

    Stability matters: it preserves the clustering of tuple-ID columns that
    GFTR relies on (monotone inputs stay monotone).
    """
    n = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1  # output slot per valid row
    count = jnp.minimum(pos[-1] + 1 if n else 0, capacity)
    dest = jnp.where(mask & (pos < capacity), pos, capacity)  # OOB -> dropped
    outs = []
    for a in arrays:
        out = jnp.full((capacity + 1,) + a.shape[1:], fill, a.dtype)
        out = out.at[dest].set(a, mode="drop")
        outs.append(out[:capacity])
    return outs, count


def expand_offsets(counts: jax.Array, capacity: int):
    """Expansion helper for m:n matches: given per-row match counts, returns
    (row_of_output, rank_within_row, valid, total) for `capacity` output rows.

    out t belongs to input row j = max{j : offsets[j] <= t} and is its
    (t - offsets[j])-th match.
    """
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts.astype(jnp.int32))]
    )
    total = offsets[-1]
    t = jnp.arange(capacity, dtype=jnp.int32)
    row = jnp.searchsorted(offsets, t, side="right").astype(jnp.int32) - 1
    rank = t - offsets[jnp.clip(row, 0, counts.shape[0] - 1)]
    valid = t < total
    return jnp.clip(row, 0, counts.shape[0] - 1), rank, valid, total

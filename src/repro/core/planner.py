"""Join-algorithm planner: the paper's Fig. 18 decision trees + a
primitive-profile cost model (§5.4: "it is crucial to profile the primitives
beforehand ... weigh clustered GATHERs with additional transformation cost
against unclustered GATHERs").

The decision tree is the paper's summary heuristic; the cost model predicts
per-phase byte traffic from profiled primitive throughputs and is what a
query optimizer would consume.
"""
from __future__ import annotations

import dataclasses

from . import primitives as prim


@dataclasses.dataclass(frozen=True)
class JoinStats:
    """Workload descriptors available to an optimizer."""

    n_r: int
    n_s: int
    r_payload_cols: int
    s_payload_cols: int
    match_ratio: float = 1.0  # fraction of S rows with a partner
    zipf: float = 0.0  # FK skew
    key_bytes: int = 4
    payload_bytes: int = 4

    @property
    def wide(self) -> bool:
        return self.r_payload_cols > 1 or self.s_payload_cols > 1


def choose_algorithm(stats: JoinStats) -> tuple[str, str, str]:
    """Fig. 18a decision tree. Returns (algorithm, pattern, rationale)."""
    # Narrow joins: PHJ-* (transform cost identical; Fig. 9) — PHJ-UM for
    # low match ratios, PHJ-OM otherwise (Fig. 13).
    if not stats.wide:
        if stats.match_ratio < 0.25:
            return "phj", "gfur", "narrow + low match ratio -> PHJ-UM (Fig. 13)"
        return "phj", "gftr", "narrow -> PHJ-* (Fig. 9); OM for robustness to skew (Fig. 14)"
    # Wide joins.
    if stats.match_ratio < 0.25:
        return "phj", "gfur", "wide + low match ratio: materialization cheap -> PHJ-UM (Fig. 13)"
    if stats.zipf > 1.0:
        # PHJ-OM's RADIX-PARTITION is skew-robust; bucket-chaining (not
        # implemented here) degrades; SMJ-UM is the runner-up (Fig. 14).
        return "phj", "gftr", "wide + skewed FKs -> PHJ-OM (Fig. 14)"
    if stats.key_bytes >= 8 or stats.payload_bytes >= 8:
        # SMJ-OM loses its edge with 8-byte data (Fig. 15 / §5.3); PHJ-OM
        # keeps it.
        return "phj", "gftr", "8-byte data: sorting too costly for SMJ-OM -> PHJ-OM (Fig. 15)"
    return "phj", "gftr", "wide + high match ratio -> *-OM; PHJ-OM dominates (Fig. 10)"


def choose_smj_pattern(stats: JoinStats) -> tuple[str, str]:
    """Fig. 18b: SMJ-OM vs SMJ-UM only."""
    if not stats.wide:
        return "gfur", "narrow: SMJ-OM == SMJ-UM (Fig. 9)"
    if stats.match_ratio < 0.25:
        return "gfur", "low match ratio (Fig. 13)"
    if stats.key_bytes >= 8 or stats.payload_bytes >= 8:
        return "gfur", "8-byte sorting cost kills SMJ-OM's edge (Fig. 15)"
    if stats.zipf > 1.0:
        return "gfur", "skew: SMJ-UM competitive via low materialization (Fig. 14)"
    return "gftr", "wide + high match -> SMJ-OM (Fig. 10)"


# ---------------------------------------------------------------------------
# Primitive-profile cost model (bytes moved per phase)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PrimitiveProfile:
    """Measured throughputs (bytes/sec) for the three primitives on the
    target part, plus the random-access penalty of unclustered gathers
    (paper Table 4: ~4.5 lines/load unclustered vs 1.5 clustered => ~3x
    bytes, ~8.5x cycles)."""

    # Calibrated so the model reproduces the paper's Fig. 7 A100 ratios
    # (sort+clustered ~1.2x, partition+clustered ~1.8-2x vs unclustered)
    # when fed v5e constants; re-profile per part (paper §5.4).
    seq_bw: float = 819e9  # sequential HBM stream (v5e)
    sort_pass_bw: float = 819e9  # rd+wr bytes already counted x2 per pass
    # A partition pass is NOT a sort pass: it is histogram + prefix + stable
    # rank + move (kernels.ops.partition_plan) — streaming dense work with
    # no compare-exchange network. Profiled separately so the planner prices
    # the pipeline that actually runs; the v5e default assumes pass parity
    # with the tuned sort (conservative — measure() replaces it).
    partition_pass_bw: float = 819e9
    unclustered_penalty: float = 20.0  # effective slowdown per random-gathered byte
    clustered_penalty: float = 1.3

    @classmethod
    def measure(cls, n: int = 1 << 16, key_bytes: int = 4, iters: int = 3,
                warmup: int = 1) -> "PrimitiveProfile":
        """Calibrate the profile from timed device microbenchmarks (§5.4:
        "profile the primitives beforehand").

        Times a sequential stream, a SORT-PAIRS, and clustered/unclustered
        GATHERs at `n` rows on the local device, then backs the four model
        constants out of the measured wall times. Penalties are clamped so
        the model stays physical (unclustered >= clustered >= 1) even when a
        host LLC blunts the random-access gap at small `n`.
        """
        import time

        import jax
        import jax.numpy as jnp
        import numpy as np

        def timed(f, *args):
            f = jax.jit(f)
            for _ in range(warmup):
                jax.block_until_ready(f(*args))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(f(*args))
                ts.append(time.perf_counter() - t0)
            ts.sort()
            return max(ts[len(ts) // 2], 1e-9)

        rng = np.random.default_rng(0)
        kdt = jnp.int32 if key_bytes <= 4 else jnp.int64
        keys = jnp.asarray(rng.permutation(n)).astype(kdt)
        vals = jnp.asarray(rng.integers(0, 1 << 30, n).astype(np.int32))
        idx_seq = jnp.arange(n, dtype=jnp.int32)
        idx_rand = jnp.asarray(rng.permutation(n).astype(np.int32))

        # Sequential stream: read + write n*4 bytes each.
        t_seq = timed(lambda v: v + 1, vals)
        seq_bw = 2 * n * 4 / t_seq
        # SORT-PAIRS: charge the LSD pass structure the cost model assumes.
        passes = prim.num_radix_passes(8 * key_bytes)
        t_sort = timed(lambda k, v: prim.sort_pairs(k, v), keys, vals)
        sort_pass_bw = passes * n * (key_bytes + 4) * 2 / t_sort
        # RADIX-PARTITION: time the production (kernel-backed, sort-free)
        # plan at an 8-bit fan-out and back the per-pass bandwidth out of
        # the same (digit + perm) x rd/wr byte convention partition_cost
        # charges — the split sort/partition calibration the planner needs
        # to price the crossover honestly (paper §5.4).
        digits = jnp.asarray(rng.integers(0, 256, n).astype(np.int32))
        t_part = timed(
            lambda d: prim.plan_partition_permutation(d, 256)[0], digits)
        partition_pass_bw = prim.num_radix_passes(8) * n * 8 * 2 / t_part
        # GATHER: effective slowdown per gathered byte vs the sequential BW.
        gather_bytes = n * 4
        t_clu = timed(lambda v, i: jnp.take(v, i, axis=0), vals, idx_seq)
        t_unc = timed(lambda v, i: jnp.take(v, i, axis=0), vals, idx_rand)
        clustered = max(t_clu * seq_bw / gather_bytes, 1.0)
        unclustered = max(t_unc * seq_bw / gather_bytes, clustered)
        return cls(seq_bw=seq_bw, sort_pass_bw=sort_pass_bw,
                   partition_pass_bw=partition_pass_bw,
                   unclustered_penalty=unclustered, clustered_penalty=clustered)

    def sort_cost(self, n, key_b, val_b):
        passes = prim.num_radix_passes(8 * key_b)  # 8 bits/pass over key width
        return passes * n * (key_b + val_b) * 2 / self.sort_pass_bw

    def partition_cost(self, n, key_b, val_b, total_bits):
        """A partition pass is histogram + rank + move at partition-pass
        bandwidth — pass count scales with the FAN-OUT bits, never the key
        width, and the rate is profiled separately from the sort network
        (the split the kernel-backed planner makes real)."""
        passes = prim.num_radix_passes(total_bits)
        return passes * n * (key_b + val_b) * 2 / self.partition_pass_bw

    def gather_cost(self, n, val_b, clustered):
        pen = self.clustered_penalty if clustered else self.unclustered_penalty
        return n * val_b * pen / self.seq_bw


def predict_join_time(stats: JoinStats, algorithm: str, pattern: str,
                      profile: PrimitiveProfile | None = None,
                      partition_bits: int = 16) -> dict[str, float]:
    """Analytic per-phase time (seconds on the profiled part). Mirrors the
    paper's §4.2 '18 sequential passes replace one random scan' arithmetic."""
    p = profile or PrimitiveProfile()
    kb, vb = stats.key_bytes, stats.payload_bytes
    n_out = int(stats.n_s * stats.match_ratio)
    t = {"transform": 0.0, "find": 0.0, "materialize": 0.0}

    trans = p.sort_cost if algorithm == "smj" else (
        lambda n, k, v: p.partition_cost(n, k, v, partition_bits)
    )
    if algorithm == "nphj":
        t["find"] = (stats.n_r + stats.n_s) * kb * p.unclustered_penalty / p.seq_bw
    else:
        # key+first payload (gftr) or key+ID (gfur) transform for both sides
        t["transform"] = trans(stats.n_r, kb, vb if pattern == "gftr" else 4)
        t["transform"] += trans(stats.n_s, kb, vb if pattern == "gftr" else 4)
        t["find"] = (stats.n_r + stats.n_s) * kb / p.seq_bw  # streaming merge/probe

    clustered = pattern == "gftr" and algorithm != "nphj"
    for ncols, n_side in ((stats.r_payload_cols, stats.n_r), (stats.s_payload_cols, stats.n_s)):
        for i in range(ncols):
            if pattern == "gftr" and i >= 1:
                # lazy transform via the planned permutation: one unclustered
                # gather of the column, not a key+payload re-sort/partition
                # (one-permutation materialization, DESIGN.md §8)
                t["materialize"] += p.gather_cost(n_side, vb, clustered=False)
            t["materialize"] += p.gather_cost(n_out, vb, clustered)
    t["total"] = sum(t.values())
    return t


def predict_groupby_time(n_rows: int, n_aggs: int, strategy: str,
                         profile: PrimitiveProfile | None = None, *,
                         key_bytes: int = 4, val_bytes: int = 4,
                         row_block: int | None = None) -> float:
    """Analytic grouped-aggregation time (seconds) per strategy, matching
    the executable paths in core.groupby:

      sort            one (key, iota) sort — radix passes scale with the
                      KEY WIDTH, at the sort network's profiled rate — +
                      per column: one permutation gather + a streaming
                      segmented reduce
      partition       sort-free rank passes over (digit, key, iota) — pass
                      count scales with log2(partitions), independent of
                      key width, at the separately profiled partition-pass
                      rate (histogram + rank + move,
                      kernels.ops.partition_plan; the carried key moves at
                      pass rate, the RADIX-PARTITION(kin, vin) contract) —
                      + one gather per payload column into the blocked
                      layout + a streaming block-local reduce per column
                      (the VMEM-resident accumulator emits distinct groups,
                      not slots, so its HBM traffic is ~n)
      partition_hash  streaming tile-partial pass + sorted combine over the
                      collapsed partials (~n/4)
      scatter         per column: one unclustered accumulator scatter

    The sort/partition asymmetry is the paper's crossover: at high group
    cardinality partition replaces key-width-many sort passes with
    ceil((p_bits+1)/8) histogram/rank passes that move only (digit, perm)
    bytes — decisive for 8-byte keys and already ahead at 4 bytes once the
    fan-out needs <= 2 passes. The partition and sort terms are split onto
    separate profiled bandwidths so calibration prices the pipeline that
    actually runs.
    """
    p = profile or PrimitiveProfile()
    kb, vb = key_bytes, val_bytes
    if strategy in ("sort", "sort_pallas"):
        t = p.sort_cost(n_rows, kb, 4)  # key + iota, once
        t += n_aggs * p.gather_cost(n_rows, vb, clustered=False)
        t += (1 + n_aggs) * 2 * n_rows * vb / p.seq_bw
        return t
    if strategy == "partition":
        from .groupby import PARTITION_ROW_BLOCK, choose_groupby_partition_bits

        rb = PARTITION_ROW_BLOCK if row_block is None else row_block
        bits = choose_groupby_partition_bits(n_rows, rb) + 1
        t = p.partition_cost(n_rows, 4, kb + 4, bits)  # (digit, key, iota)
        t += n_aggs * p.gather_cost(n_rows, vb, clustered=False)
        t += (1 + n_aggs) * 2 * n_rows * vb / p.seq_bw  # block-local reduce
        return t
    if strategy == "partition_hash":
        return (2 * n_rows * (kb + vb) / p.seq_bw
                + n_aggs * p.sort_cost(max(n_rows // 4, 1), kb, vb))
    if strategy == "scatter":
        return max(n_aggs, 1) * p.gather_cost(n_rows, vb, clustered=False)
    raise ValueError(f"unknown group-by strategy {strategy!r}")


def predict_groupjoin_time(stats: JoinStats, n_aggs: int,
                           agg_strategy: str = "sort",
                           profile: PrimitiveProfile | None = None,
                           partition_bits: int = 16,
                           group_key_carried: bool = False,
                           build_aggs: int = 0,
                           agg_row_block: int | None = None) -> dict[str, float]:
    """Analytic per-phase time of the fused group-join (core.groupjoin):
    probe cost + scatter-accumulate cost, ZERO materialization/gather terms
    — the fusion's whole point is that the joined row is never written to
    or re-read from HBM.

      transform   co-partition both sides, (key, iota) only — identical to
                  the join's narrow transform
      find        streaming co-partition probe
      accumulate  the per-column lazy transforms (same rate the join model
                  charges them): one unclustered n_s permutation gather
                  for the group key (waived via `group_key_carried` when
                  it IS the join key) and for each probe-side aggregate
                  input; build-side inputs (`build_aggs` of the `n_aggs`)
                  instead cost one n_r permutation gather plus one
                  CLUSTERED probe-length gather through the matched
                  virtual IDs (the GFTR pattern); then the group-by cost
                  shape over ALL n_s probe rows (matched rows are masked
                  in place, never compacted)

    The structural asymmetry vs the unfused plan: fused aggregates the
    whole probe side regardless of match ratio, while join-then-group-by
    materializes n_s * match_ratio rows and only groups those. High match
    ratios therefore favor fusion (the materialization round trip
    dominates); very low ones favor the unfused plan (the tiny join output
    is cheaper to group than the full probe side) — the crossover the
    engine's fusion pass prices."""
    p = profile or PrimitiveProfile()
    kb, vb = stats.key_bytes, stats.payload_bytes
    probe_aggs = max(n_aggs - build_aggs, 0)
    t = {"transform": 0.0, "find": 0.0, "accumulate": 0.0}
    t["transform"] = p.partition_cost(stats.n_r, kb, 4, partition_bits)
    t["transform"] += p.partition_cost(stats.n_s, kb, 4, partition_bits)
    t["find"] = (stats.n_r + stats.n_s) * kb / p.seq_bw
    t["accumulate"] = (0.0 if group_key_carried
                       else p.gather_cost(stats.n_s, kb, clustered=False))
    t["accumulate"] += probe_aggs * p.gather_cost(stats.n_s, vb,
                                                  clustered=False)
    t["accumulate"] += build_aggs * (
        p.gather_cost(stats.n_r, vb, clustered=False)
        + p.gather_cost(stats.n_s, vb, clustered=True))
    t["accumulate"] += predict_groupby_time(stats.n_s, n_aggs, agg_strategy,
                                            p, key_bytes=kb, val_bytes=vb,
                                            row_block=agg_row_block)
    t["total"] = sum(t.values())
    return t

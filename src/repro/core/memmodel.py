"""Analytic peak-memory model for GFUR vs GFTR (paper §4.4, Tables 1-2).

Units: M_c = bytes of one column (n rows x itemsize), M_t = transform
scratch. The model reproduces the paper's phase-by-phase ledger and its
conclusion: GFTR's peak is never higher than GFUR's, so the optimized
pattern does not shrink the solvable problem size.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MemLedger:
    phase: str
    activity: str
    alloc_on_entry: float
    free_on_exit: float
    used_after_exit: float
    peak: float


def gfur_ledger(mt: float = 1.0, mc: float = 1.0) -> list[MemLedger]:
    """Table 1 (in units of M_c, with M_t scratch)."""
    return [
        MemLedger("transform", "init ID_R, transform R'",
                  mt + 3 * mc, mt + mc, 2 * mc, mt + 3 * mc),
        MemLedger("transform", "init ID_S, transform S'",
                  mt + 3 * mc, mt + mc, 4 * mc, mt + 5 * mc),
        MemLedger("find", "write matching IDs", 2 * mc, 4 * mc, 2 * mc, 6 * mc),
        MemLedger("materialize", "materialize payloads", 0.0, 2 * mc, 0.0, 2 * mc),
    ]


def gftr_ledger(mt: float = 1.0, mc: float = 1.0) -> list[MemLedger]:
    """Table 2."""
    return [
        MemLedger("transform", "(R) keys w/ one non-key", mt + 2 * mc, mt, 2 * mc, mt + 2 * mc),
        MemLedger("transform", "(S) keys w/ one non-key", mt + 2 * mc, mt, 4 * mc, mt + 4 * mc),
        MemLedger("find", "write matching IDs", 2 * mc, 2 * mc, 4 * mc, 6 * mc),
        MemLedger("materialize", "two pre-transformed payloads", 0.0, 2 * mc, 2 * mc, 4 * mc),
        MemLedger("materialize", "each remaining payload",
                  mt + 2 * mc, mt + mc, 2 * mc, mt + 4 * mc),
    ]


def peak_memory(pattern: str, mt: float = 1.0, mc: float = 1.0) -> float:
    ledger = gftr_ledger(mt, mc) if pattern == "gftr" else gfur_ledger(mt, mc)
    return max(row.peak for row in ledger)


def peak_memory_bytes(pattern: str, n_rows: int, itemsize: int,
                      mt_bytes: float | None = None) -> float:
    mc = float(n_rows * itemsize)
    mt = mc if mt_bytes is None else mt_bytes  # transform scratch ~ one column
    return peak_memory(pattern, mt=mt, mc=mc)

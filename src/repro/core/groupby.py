"""Grouped aggregations [extension-per-assigned-title].

The assigned paper title ("Efficiently Processing Joins and Grouped
Aggregations on GPUs") and the calibration band cover group-by kernels; the
provided text covers only joins, so this module applies the same design
principles to grouped aggregation:

  * scatter-based aggregation (atomicAdd on GPUs, `segment_sum` scatter here)
    is the unclustered-access baseline — only viable for dense key domains;
  * sort-based aggregation transforms (sorts) the rows first so the reduce is
    over contiguous runs — sequential access, the GFTR insight;
  * two-phase block aggregation ("partition_hash") pre-aggregates each
    VMEM-resident tile with a one-hot matmul reduction (MXU work — the TPU
    analogue of a shared-memory hash table per thread block), then combines
    the per-tile partials with a sorted pass. Correct for *any* key
    distribution (heavy hitters are reduced tile-locally first, the same way
    GPU shared-memory pre-aggregation absorbs skew);
  * partition-based aggregation ("partition", DESIGN.md §8) radix-partitions
    rows on hashed key bits until each partition's group set fits a
    VMEM-resident block, then aggregates every partition independently —
    no global sort, no cross-partition combine, since a group lives in
    exactly one partition. The paper's third group-by algorithm, ideal for
    high group cardinalities;
  * wide payloads follow Algorithm 1 with the one-permutation refinement:
    the sort/partition is planned ONCE (`primitives.plan_sort_permutation` /
    `plan_partition_permutation`) and every payload column is materialized
    with a single `apply_permutation` gather.

All APIs are static-shape: `num_groups` is a capacity; outputs are
(keys[num_groups], aggs[num_groups], valid_count), padded with KEY_SENTINEL.

Supported aggregations: sum, count, min, max, mean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import primitives as prim
from .hash_join import _nonempty, hash32
from .table import KEY_SENTINEL, Table

AGG_OPS = ("sum", "count", "min", "max", "mean")


def _seg_reduce(op, vals, gid, num_segments):
    if op in ("sum", "mean"):
        return jax.ops.segment_sum(vals, gid, num_segments=num_segments)
    if op == "count":
        return jax.ops.segment_sum(jnp.ones_like(vals, jnp.int32), gid, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(vals, gid, num_segments=num_segments)
    if op == "max":
        return jax.ops.segment_max(vals, gid, num_segments=num_segments)
    raise ValueError(op)


def _finalize(op, acc, counts):
    if op == "mean":
        return acc / jnp.maximum(counts, 1).astype(acc.dtype)
    return acc


# Partial-aggregation plumbing: op -> (tile partial op, combine op)
_PARTIAL = {
    "sum": ("sum", "sum"),
    "count": ("count", "sum"),
    "mean": ("sum", "sum"),  # + count partial, finalized at the end
    "min": ("min", "min"),
    "max": ("max", "max"),
}


# ---------------------------------------------------------------------------
# Sort-based (transform-first; GFTR analogue)
# ---------------------------------------------------------------------------
def groupby_sort(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
):
    """Sort rows by key, detect run boundaries, segment-reduce.

    One-permutation materialization (DESIGN.md §8): the key sort is planned
    once and each payload column is transformed with a single
    `apply_permutation` gather — Algorithm 1's lazy transform without the
    per-column re-sort it used to cost.
    Returns (Table(key + agg columns), valid_count)."""
    table = _nonempty(table, key)  # zero rows -> one all-sentinel row
    keys = table[key]
    sk, perm = prim.plan_sort_permutation(keys)
    boundary = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    boundary &= sk != KEY_SENTINEL
    valid_row = sk != KEY_SENTINEL
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1  # dense, sorted group ids
    n_found = gid[-1] + 1
    gid = jnp.where(valid_row, gid, num_groups)
    gid_cap = jnp.where(gid < num_groups, gid, num_groups)  # overflow -> dropped

    out_keys = jnp.full((num_groups + 1,), KEY_SENTINEL, keys.dtype)
    out_keys = out_keys.at[gid_cap].set(jnp.where(valid_row, sk, KEY_SENTINEL), mode="drop")
    counts = jax.ops.segment_sum(
        valid_row.astype(jnp.int32), gid_cap, num_segments=num_groups + 1
    )

    cols = {key: out_keys[:num_groups]}
    for col, op in aggs.items():
        tv = prim.apply_permutation(perm, table[col])  # one gather per column
        acc = _seg_reduce(op, jnp.where(valid_row, tv, 0) if op in ("sum", "mean") else tv,
                          gid_cap, num_groups + 1)
        cols[f"{col}_{op}"] = _finalize(op, acc, counts)[:num_groups]
    count = jnp.minimum(n_found, num_groups)
    return Table(cols), count


# ---------------------------------------------------------------------------
# Two-phase block aggregation (MXU one-hot partials + sorted combine)
# ---------------------------------------------------------------------------
def _block_local_groups(kp):
    """Block-local grouping core shared by the tile and partition paths: for
    (T, B) key blocks (KEY_SENTINEL = invalid slot), sort each block locally
    — the VMEM-resident analogue of a per-thread-block hash table — and
    assign dense local group ids.

    Returns (ks, order, valid, bnd, lgid): locally sorted keys, the per-block
    sort order (to align payload blocks), validity, run boundaries, and local
    group ids (invalid rows -> B, so they drop out of one-hot/segment
    reductions)."""
    block = kp.shape[1]
    order = jnp.argsort(kp, axis=1, stable=True)
    ks = jnp.take_along_axis(kp, order, axis=1)
    valid = ks != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((ks.shape[0], 1), bool), ks[:, 1:] != ks[:, :-1]], axis=1)
    bnd &= valid
    lgid = jnp.cumsum(bnd.astype(jnp.int32), axis=1) - 1
    lgid = jnp.where(valid, lgid, block)
    return ks, order, valid, bnd, lgid


def _tile_partials(keys, cols_ops, block):
    """Phase 1: per tile of `block` rows, aggregate duplicates tile-locally.

    Returns (partial_keys[npad], partial_counts[npad], {name: partial[npad]})
    where slots without a group carry KEY_SENTINEL. Each tile contributes its
    distinct keys once — heavy hitters collapse block-fold per pass."""
    n = keys.shape[0]
    n_pad = -n % block
    kp = jnp.pad(keys, (0, n_pad), constant_values=KEY_SENTINEL).reshape(-1, block)
    ks, order, valid, bnd, lgid = _block_local_groups(kp)
    oh = jax.nn.one_hot(lgid, block, dtype=jnp.float32)  # (T, block, block)

    pcounts = jnp.einsum("tbg->tg", oh)
    # group g's key: scatter run-head keys into slot g (run heads are unique per tile)
    T = ks.shape[0]
    pkeys = (
        jnp.full((T, block + 1), KEY_SENTINEL, keys.dtype)
        .at[jnp.arange(T)[:, None], jnp.where(bnd, lgid, block)]
        .set(ks, mode="drop")[:, :block]
    )

    partials = {}
    for name, (vals, pop) in cols_ops.items():
        vp = jnp.pad(vals, (0, n_pad)).reshape(-1, block)
        vs = jnp.take_along_axis(vp, order, axis=1).astype(jnp.float32)
        if pop == "sum":
            acc = jnp.einsum("tb,tbg->tg", jnp.where(valid, vs, 0.0), oh)
        elif pop == "count":
            acc = pcounts
        elif pop in ("min", "max"):
            fill = jnp.float32(jnp.finfo(jnp.float32).max if pop == "min"
                               else jnp.finfo(jnp.float32).min)
            masked = jnp.where(oh > 0, vs[:, :, None], fill)
            acc = masked.min(axis=1) if pop == "min" else masked.max(axis=1)
        else:
            raise ValueError(pop)
        partials[name] = acc.reshape(-1)
    return pkeys.reshape(-1), pcounts.reshape(-1), partials


def groupby_partition_hash(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    block: int = 256,
):
    """Two-phase aggregation: MXU one-hot tile partials + sorted combine.

    The tile plays the role of the GPU thread block's shared-memory hash
    table; the one-hot matmul is the scatter-free reduction (DESIGN.md §2).
    The combine phase runs over tile partials (<= distinct-per-tile of the
    input rows live), so for low-cardinality or skewed inputs the expensive
    pass shrinks by up to `block`x."""
    table = _nonempty(table, key)  # zero rows -> one all-sentinel row
    keys = table[key]
    # Build partial-op plan: ops needed per output agg (+ count for mean).
    cols_ops = {}
    for col, op in aggs.items():
        pop, _ = _PARTIAL[op]
        cols_ops[f"{col}_{op}"] = (table[col], pop)

    pkeys, pcounts, partials = _tile_partials(keys, cols_ops, block)

    # Phase 2: sorted combine over partials (sum of sums / min of mins / ...).
    sk, scnt, *svals = prim.sort_pairs(pkeys, pcounts, *partials.values())
    valid_row = sk != KEY_SENTINEL
    boundary = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid_row
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    n_found = gid[-1] + 1
    gid = jnp.where(valid_row & (gid < num_groups), gid, num_groups)

    out_keys = jnp.full((num_groups + 1,), KEY_SENTINEL, keys.dtype)
    out_keys = out_keys.at[gid].set(jnp.where(valid_row, sk, KEY_SENTINEL), mode="drop")
    counts = jax.ops.segment_sum(jnp.where(valid_row, scnt, 0.0), gid, num_segments=num_groups + 1)

    out = {key: out_keys[:num_groups]}
    for (name, (_, pop)), sv in zip(cols_ops.items(), svals):
        _, cop = _PARTIAL[{"sum": "sum", "count": "count", "min": "min", "max": "max"}[pop]]
        if cop == "sum":
            acc = jax.ops.segment_sum(jnp.where(valid_row, sv, 0.0), gid,
                                      num_segments=num_groups + 1)
        elif cop == "min":
            acc = jax.ops.segment_min(jnp.where(valid_row, sv, jnp.finfo(jnp.float32).max),
                                      gid, num_segments=num_groups + 1)
        else:
            acc = jax.ops.segment_max(jnp.where(valid_row, sv, jnp.finfo(jnp.float32).min),
                                      gid, num_segments=num_groups + 1)
        out[name] = acc[:num_groups]
    # finalize means / counts dtype
    for col, op in aggs.items():
        name = f"{col}_{op}"
        if op == "mean":
            out[name] = out[name] / jnp.maximum(counts[:num_groups], 1.0)
        if op == "count":
            out[name] = out[name].astype(jnp.int32)
    count = jnp.minimum(n_found, num_groups)
    return Table(out), count


# ---------------------------------------------------------------------------
# Partition-based (high group cardinality; paper's third algorithm)
# ---------------------------------------------------------------------------
# default padded-block capacity per partition (the BUILD_BLOCK analogue);
# a single key's rows co-hash no matter the fan-out, so per-key multiplicity
# beyond this cannot be partitioned away — the engine guard checks against it.
# The layout targets E[partition rows] <= row_block/2 (hashed keys at the
# low multiplicities the chooser routes here put the 2x-mean tail far below
# fp precision), so the padded slot space stays ~2-4x n instead of the 6x a
# quarter-full 256-row block cost — the slot space is what every blocked
# aggregation pass streams over.
PARTITION_ROW_BLOCK = 128


def choose_groupby_partition_bits(n_rows: int,
                                  row_block: int = PARTITION_ROW_BLOCK) -> int:
    """Fan-out so that E[partition rows] <= row_block/2: with hashed keys and
    per-key multiplicity << row_block (the high-cardinality regime this
    algorithm targets), overflow of the padded block becomes negligible.

    Capped at 16 bits (65536 partitions); past the cap the BLOCK must grow
    instead — `_partition_layout` below holds the invariant either way."""
    target = max(1, (2 * n_rows) // row_block)
    return max(1, min(16, (target - 1).bit_length()))


def _partition_layout(n_rows: int, row_block: int,
                      partition_bits: int | None) -> tuple[int, int]:
    """(p_bits, row_block) honoring the VMEM-fit invariant
    E[rows/partition] <= row_block/2. When the requested block would need
    more than the 16-bit fan-out cap, the block grows to cover the expected
    partition size — never silently over-fill partitions (that would drop
    every partition's overhang, not a tail). Explicit partition_bits skips
    the auto-grow: the caller owns the layout (the checked driver relies on
    this to pin its escalated geometry)."""
    if partition_bits is not None:
        return partition_bits, row_block
    p_bits = choose_groupby_partition_bits(n_rows, row_block)
    need = -(-2 * n_rows // (1 << p_bits))  # block for E[size] == block/2
    if need > row_block:
        row_block = 1 << int(need - 1).bit_length()
    return p_bits, row_block


def _partition_digits(keys: jax.Array, p_bits: int) -> jax.Array:
    """Hash-derived partition digit per row, in [0, P]: valid keys spread
    over [0, P) via the avalanching hash (a digit is a pure function of the
    key, so every group lands wholly in one partition); KEY_SENTINEL padding
    floods its own dedicated partition P, so a join output that is half
    padding can never crowd valid keys out of a shared bucket.

    Float keys are bitcast (not value-cast) so every distinct float hashes
    distinctly, with -0.0 normalized to +0.0 first — the two compare equal,
    so they must co-partition the way the sort path co-groups them. NaN keys
    are outside the key contract (valid keys are >= 0, table.py) and are
    routed to the padding partition, i.e. dropped like sentinels."""
    if jnp.issubdtype(keys.dtype, jnp.floating):
        sentinel = jnp.isnan(keys) | (keys == KEY_SENTINEL)
        normed = jnp.where(keys == 0.0, jnp.zeros((), keys.dtype), keys)
        hashable = jax.lax.bitcast_convert_type(
            normed, jnp.dtype(f"int{keys.dtype.itemsize * 8}"))
    else:
        hashable = keys
        sentinel = keys == jnp.asarray(KEY_SENTINEL, keys.dtype)
    d = (hash32(hashable) & ((1 << p_bits) - 1)).astype(jnp.int32)
    return jnp.where(sentinel, 1 << p_bits, d)


def groupby_partition(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    row_block: int = PARTITION_ROW_BLOCK,
    partition_bits: int | None = None,
):
    """Partition-based grouped aggregation (DESIGN.md §8).

    Multi-pass radix partition on the hashed group key's bits until each
    partition fits a VMEM-resident `row_block`-row block, then aggregate
    every partition independently with the block-local sort machinery of
    `partition_hash` — no global sort and no cross-partition combine pass,
    because a group lives in exactly one partition. Dense per-partition
    outputs are concatenated (stable compaction) into the shared
    (Table, valid_count) contract; output rows are ordered by
    (partition, key), not globally key-sorted.

    One-permutation materialization: the partition is planned once
    (`plan_partition_permutation`, sort-free by default — DESIGN.md §10) and
    each column — key and payloads — is gathered exactly once, straight into
    the blocked (P, row_block) layout.

    The per-partition aggregation is scatter-free: one stable block-local
    sort carries the key and every aggregate input together (VMEM-resident
    work — the shared-memory hash-table analogue), group sums fall out of
    masked cumulative sums differenced at run boundaries, and the dense
    output is compacted by a binary search over the monotone run ids — no
    segment scatter, no slot-space scatter, no compaction scatter (min/max
    aggregates alone still need one segmented reduction each).

    Static-shape caveat: a partition holding more than `row_block` rows has
    its overhang dropped. `choose_groupby_partition_bits` sizes the fan-out
    for E[rows/partition] <= row_block/2, which makes overflow negligible for
    the high-cardinality, low-multiplicity inputs the strategy chooser routes
    here; heavy per-key duplication co-hashes regardless of fan-out, so
    skewed/duplicated inputs belong to `partition_hash` instead. Use
    `groupby_partition_checked` for an eager overflow check + escalation."""
    table = _nonempty(table, key)  # zero rows -> one all-sentinel row
    keys = table[key]
    n = keys.shape[0]
    p_bits, row_block = _partition_layout(n, row_block, partition_bits)
    P = 1 << p_bits
    digits = _partition_digits(keys, p_bits)
    # One-permutation plan over P+1 partitions (the extra one swallows
    # sentinel padding and is never materialized). The key column comes back
    # already partitioned (Algorithm 1's key-rides-along idiom).
    perm, (keys_part,), offsets, sizes = prim.plan_partition_permutation(
        digits, P + 1, carry=(keys,))

    # Blocked VMEM layout of the P valid partitions: position (p, i) holds
    # the i-th row of partition p. Composing the block map with the planned
    # permutation gathers every payload column from the ORIGINAL table
    # exactly once; the key is a clustered read of the carried column.
    i = jnp.arange(row_block, dtype=jnp.int32)[None, :]
    pos = offsets[:P, None] + i
    in_part = i < jnp.minimum(sizes[:P, None], row_block)
    pos_c = jnp.clip(pos, 0, n - 1)
    src = jnp.take(perm, pos_c)  # (P, row_block) source rows for payloads
    kblocks = jnp.where(in_part, jnp.take(keys_part, pos_c),
                        jnp.asarray(KEY_SENTINEL, keys.dtype))

    # Per-partition grouping: ONE stable block-local sort moves the key and
    # every aggregate input together (a group lives in exactly one
    # partition, so block runs are final groups). Sentinel slots sort to the
    # front of their block and are masked out of every reduction.
    val_names = [c for c, op in aggs.items() if op != "count"]
    uniq_cols = list(dict.fromkeys(val_names))
    vblocks = [jnp.take(table[c], src) for c in uniq_cols]  # col's ONE gather
    sorted_ = jax.lax.sort((kblocks,) + tuple(vblocks), num_keys=1,
                           is_stable=True)
    ks = sorted_[0]
    vsorted = dict(zip(uniq_cols, sorted_[1:]))
    n_slots = P * row_block
    ksf = ks.reshape(-1)
    valid = (ksf != jnp.asarray(KEY_SENTINEL, keys.dtype))
    head = jnp.concatenate(
        [jnp.ones((P, 1), bool), ks[:, 1:] != ks[:, :-1]], axis=1).reshape(-1)
    bnd = head & valid
    rid = jnp.cumsum(bnd.astype(jnp.int32)) - 1  # monotone run id per slot
    n_found = rid[-1] + 1 if n_slots else jnp.zeros((), jnp.int32)
    count = jnp.minimum(n_found, num_groups)

    # Dense compaction without a scatter: rid is sorted, so the r-th run's
    # first slot is a binary search; run r spans [starts[r], starts[r+1]).
    r_iota = jnp.arange(num_groups + 1, dtype=jnp.int32)
    starts = jnp.searchsorted(rid, r_iota, side="left").astype(jnp.int32)
    starts_c = jnp.clip(starts[:num_groups], 0, max(n_slots - 1, 0))
    present = jnp.arange(num_groups, dtype=jnp.int32) < count
    out_keys = jnp.where(present, jnp.take(ksf, starts_c),
                         jnp.asarray(KEY_SENTINEL, keys.dtype))

    def run_total(per_slot):
        """Count over each run via an exclusive cumsum differenced at run
        boundaries — int32 is exact however long the prefix, never a
        scatter."""
        ecs = jnp.concatenate([jnp.zeros((1,), per_slot.dtype),
                               jnp.cumsum(per_slot)])
        return jnp.take(ecs, starts[1:]) - jnp.take(ecs, starts[:num_groups])

    # Float run sums use BLOCK-LOCAL exclusive cumsums instead: a run never
    # spans blocks (valid rows are a block's sorted suffix), so the prefix a
    # difference cancels is bounded by one block's magnitude — the rounding
    # error of a global n-slot prefix would grow with the whole relation.
    s_flat = starts[:num_groups]
    e_flat = starts[1:]
    row_s = jnp.minimum(s_flat // row_block, P - 1)
    col_s = s_flat - (s_flat // row_block) * row_block
    col_e = jnp.where(e_flat // row_block == s_flat // row_block,
                      e_flat - (e_flat // row_block) * row_block, row_block)

    def run_block_total(masked2d):
        ecs = jnp.concatenate(
            [jnp.zeros((P, 1), masked2d.dtype), jnp.cumsum(masked2d, axis=1)],
            axis=1).reshape(-1)  # (P * (row_block+1),)
        hi = jnp.take(ecs, row_s * (row_block + 1) + col_e)
        lo = jnp.take(ecs, row_s * (row_block + 1) + col_s)
        return jnp.where(present, hi - lo, jnp.zeros((), masked2d.dtype))

    valid2d = valid.reshape(P, row_block)
    counts = run_total(valid.astype(jnp.int32))
    cols = {key: out_keys}
    for col, op in aggs.items():
        if op == "count":
            cols[f"{col}_{op}"] = counts
            continue
        vs = vsorted[col].reshape(-1)
        if op in ("sum", "mean"):
            acc = run_block_total(
                jnp.where(valid2d, vsorted[col], jnp.zeros((), vs.dtype)))
        else:  # min/max: not expressible as a cumsum difference
            seg = jnp.where(valid & (rid < num_groups), rid, num_groups)
            fill = (jnp.finfo if jnp.issubdtype(vs.dtype, jnp.floating)
                    else jnp.iinfo)(vs.dtype)
            masked = jnp.where(valid, vs, fill.max if op == "min" else fill.min)
            acc = _seg_reduce(op, masked, seg, num_groups + 1)[:num_groups]
        cols[f"{col}_{op}"] = _finalize(op, acc, counts)
    return Table(cols), count


def groupby_partition_overflowed(
    keys: jax.Array, *, row_block: int = PARTITION_ROW_BLOCK,
    partition_bits: int | None = None
):
    """Host-side check: would any valid partition exceed the (layout-
    adjusted) block? Returns (overflowed, p_bits, max_partition_rows).
    Sentinel rows are excluded — their dedicated partition is allowed to
    overflow."""
    p_bits, row_block = _partition_layout(keys.shape[0], row_block,
                                          partition_bits)
    digits = _partition_digits(keys, p_bits)
    sizes = jnp.bincount(digits, length=(1 << p_bits) + 1)[:-1]
    mx = int(jnp.max(sizes))
    return mx > row_block, p_bits, mx


def groupby_partition_checked(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    row_block: int = PARTITION_ROW_BLOCK,
    max_extra_bits: int = 4,
    max_attempts: int = 8,
    with_report: bool = False,
    **kw,
):
    """groupby_partition on the resilience ladder (DESIGN.md §13): first
    add fan-out bits — separating co-hashed distinct groups — then, if a
    single key's duplication still overflows (more bits cannot split one
    key), revert the extra bits and grow the block to cover the base
    layout's observed maximum (always the smaller geometry: splitting can
    at best divide the max by the same 2^extra it multiplies the partition
    count by); as a last rung, fall back to the always-exact sort
    strategy. Each check is a cheap host-side histogram; exhaustion raises
    `EscalationExhausted` instead of dropping partition overhang.

    `with_report=True` additionally returns the `EscalationReport`."""
    from repro.resilience import EscalationStep, Ladder

    table = _nonempty(table, key)
    keys = table[key]
    # resolve the auto layout ONCE, then pin it explicitly through the
    # escalation (explicit partition_bits disables the auto-grow)
    base_bits, base_block = _partition_layout(
        keys.shape[0], row_block, kw.pop("partition_bits", None))
    knobs = {"strategy": "partition", "partition_bits": base_bits,
             "row_block": base_block}
    base_mx: dict = {}  # heaviest base-layout partition, cached by check()

    def check(kn):
        if kn["strategy"] != "partition":
            return True, "sort fallback (always exact)", None
        over, _, mx = groupby_partition_overflowed(
            keys, row_block=kn["row_block"],
            partition_bits=kn["partition_bits"])
        if kn["partition_bits"] == base_bits:
            base_mx.setdefault("mx", mx)
        return (not over,
                f"partition rows {mx} > block {kn['row_block']}" if over
                else "", mx)

    def grow_bits(kn, diag):
        if kn["strategy"] != "partition" or kn["partition_bits"] >= 20:
            return None
        return {**kn, "partition_bits": kn["partition_bits"] + 1}

    def grow_block(kn, diag):
        if kn["strategy"] != "partition":
            return None
        mx0 = max(base_mx.get("mx", 0), 1)
        rb = 1 << max(int(mx0 - 1).bit_length(),
                      int(base_block - 1).bit_length())
        if rb <= kn["row_block"] and kn["partition_bits"] == base_bits:
            rb = kn["row_block"] * 2  # forced overflow: grow anyway
        return {**kn, "partition_bits": base_bits, "row_block": rb}

    def to_sort(kn, diag):
        return {**kn, "strategy": "sort"}

    ladder = Ladder("groupby_partition", [
        EscalationStep("partition_bits", grow_bits, max_times=max_extra_bits),
        EscalationStep("row_block", grow_block, max_times=1),
        EscalationStep("strategy:sort", to_sort, max_times=1),
    ], max_attempts=max_attempts)
    report = ladder.resolve(knobs, check)
    kn = report.final_knobs
    if kn["strategy"] == "sort":
        out = groupby_sort(table, key=key, aggs=aggs, num_groups=num_groups)
    else:
        out = groupby_partition(
            table, key=key, aggs=aggs, num_groups=num_groups,
            row_block=kn["row_block"], partition_bits=kn["partition_bits"],
            **kw)
    return (out, report) if with_report else out


# ---------------------------------------------------------------------------
# Scatter baseline (dense key domain)
# ---------------------------------------------------------------------------
def groupby_scatter(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
):
    """Direct scatter aggregation for keys in [0, num_groups) — the
    atomicAdd analogue. Unclustered writes; viable only when the accumulator
    array stays cache/VMEM-resident. Out-of-domain keys (including
    KEY_SENTINEL padding) are dropped, and — like the other strategies —
    the output is compacted to a dense prefix (present groups in ascending
    key order, rows >= valid_count are padding), so all strategies share
    one (Table, valid_count) contract."""
    table = _nonempty(table, key)  # zero rows -> one all-sentinel row
    keys = table[key]
    if not jnp.issubdtype(keys.dtype, jnp.integer):
        raise TypeError(
            f"scatter group-by needs integer keys, got {keys.dtype}; "
            "float keys would be silently floored into merged groups")
    in_domain = (keys >= 0) & (keys < num_groups)
    gid = jnp.where(in_domain, keys, num_groups).astype(jnp.int32)
    counts = jax.ops.segment_sum(
        in_domain.astype(jnp.int32), gid, num_segments=num_groups + 1
    )[:num_groups]
    present = counts > 0
    out = {key: jnp.arange(num_groups, dtype=keys.dtype)}
    for col, op in aggs.items():
        vals = table[col]
        if op in ("sum", "mean"):
            vals = jnp.where(in_domain, vals, 0)
        acc = _seg_reduce(op, vals, gid, num_groups + 1)[:num_groups]
        out[f"{col}_{op}"] = _finalize(op, acc, counts)
    names = list(out)
    compacted, n_present = prim.compact(present, [out[n] for n in names],
                                        num_groups)
    out = dict(zip(names, compacted))
    out[key] = jnp.where(jnp.arange(num_groups) < n_present, out[key],
                         jnp.asarray(KEY_SENTINEL, keys.dtype))
    return Table(out), n_present


def groupby_sort_pallas(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    tile: int = 256,
):
    """Sort-based group-by whose per-tile partial reduction runs in the
    Pallas segsum kernel (scatter-free MXU path; interpret-mode on CPU).
    Sum/count/mean only (kernel computes sums+counts).

    The key sort is planned once (one-permutation layer) and each payload
    column costs one gather + one kernel pass. The count kernel is key-only
    and identical for every column, so it runs at most once — and only when
    a mean/count aggregate actually needs it."""
    from repro.kernels import ops as kops

    table = _nonempty(table, key)  # zero rows -> one all-sentinel row
    keys = table[key]
    for op in aggs.values():
        if op not in ("sum", "mean", "count"):
            raise ValueError(f"sort_pallas supports sum/mean/count, got {op}")
    sk, perm = prim.plan_sort_permutation(keys)
    out = {}
    count = gc = None
    if any(op in ("mean", "count") for op in aggs.values()):
        # hoisted key-only count pass (shared by every mean/count column)
        out[key], gc, count = kops.groupby_sorted_sum(
            sk, jnp.ones(sk.shape, jnp.float32), num_groups, "pallas", tile=tile)
    for col, op in aggs.items():
        if op == "count":
            out[f"{col}_{op}"] = gc.astype(jnp.int32)
            continue
        sv = prim.apply_permutation(perm, table[col])  # one gather per column
        gk, gs, cnt = kops.groupby_sorted_sum(sk, sv.astype(jnp.float32),
                                              num_groups, "pallas", tile=tile)
        if count is None:
            out[key], count = gk, cnt
        out[f"{col}_{op}"] = gs if op == "sum" else gs / jnp.maximum(gc, 1.0)
    return Table(out), count


def choose_groupby_strategy(
    n_rows: int,
    est_groups: float,
    *,
    key_min: float | None = None,
    key_max: float | None = None,
    zipf: float = 0.0,
    dense_domain_limit: int = 1 << 18,
    integer_key: bool = True,
) -> tuple[str, str]:
    """Cardinality-based strategy heuristic, mirroring the paper's
    hash/sort/partition guidance for grouped aggregation (and Fig. 18's
    structure: pick the cheapest access pattern the distribution allows).

    Returns (strategy, rationale):
      * dense, small key domains -> 'scatter' (the accumulator array stays
        cache/VMEM-resident, so the unclustered writes are cheap — the
        atomicAdd-on-shared-memory regime);
      * heavy duplication (rows >> groups) or skew -> 'partition_hash'
        (tile-local pre-aggregation collapses duplicates before the
        expensive pass, the shared-memory-hash-table regime);
      * high cardinality + hashable (integer) keys -> 'partition' (the
        paper's partition-based algorithm: radix-partition on hashed key
        bits until each partition's group set fits a VMEM-resident block,
        aggregate partitions independently — the pass count scales with
        log(groups) instead of the key width, and there is no global
        sort or combine; requires low per-key multiplicity, which high
        cardinality implies);
      * high cardinality, non-integer keys -> 'sort' (one sequential sort
        pass beats hash tables that spill out of fast memory — the GFTR
        insight; float keys cannot be radix-bucketed by value-hash without
        a bitcast normalization, so sort stays the robust fallback).
    """
    domain = None
    # scatter indexes the accumulator by key value, so the keys must be
    # non-negative integers in a small domain
    if (integer_key and key_min is not None and key_max is not None
            and key_min >= 0):
        domain = int(key_max) + 1
    if domain is not None and domain <= dense_domain_limit and domain <= max(
        4 * est_groups, 1024
    ):
        return "scatter", (
            f"dense key domain [0, {domain}) fits a resident accumulator"
        )
    if zipf > 1.0:
        return "partition_hash", (
            f"skewed keys (zipf~{zipf:.2f}): tile pre-aggregation absorbs "
            "heavy hitters"
        )
    if est_groups * 8 <= n_rows:
        return "partition_hash", (
            f"rows/groups ~ {n_rows / max(est_groups, 1.0):.0f}x: tile "
            "pre-aggregation shrinks the combine pass"
        )
    if integer_key:
        return "partition", (
            f"high cardinality (~{est_groups:.0f} groups, low multiplicity): "
            "radix-partition to VMEM-resident accumulators, no global "
            "sort/combine"
        )
    return "sort", (
        "high cardinality, non-integer keys: sequential sort pass beats "
        "spilling hash tables"
    )


def group_aggregate(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    strategy: str = "sort",
    **kw,
):
    """Unified entry point.
    strategy in {'sort', 'partition', 'partition_hash', 'scatter',
    'sort_pallas'}."""
    fn = {
        "sort": groupby_sort,
        "partition": groupby_partition,
        "partition_hash": groupby_partition_hash,
        "scatter": groupby_scatter,
        "sort_pallas": groupby_sort_pallas,
    }[strategy]
    return fn(table, key=key, aggs=aggs, num_groups=num_groups, **kw)

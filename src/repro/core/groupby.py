"""Grouped aggregations [extension-per-assigned-title].

The assigned paper title ("Efficiently Processing Joins and Grouped
Aggregations on GPUs") and the calibration band cover group-by kernels; the
provided text covers only joins, so this module applies the same design
principles to grouped aggregation:

  * scatter-based aggregation (atomicAdd on GPUs, `segment_sum` scatter here)
    is the unclustered-access baseline — only viable for dense key domains;
  * sort-based aggregation transforms (sorts) the rows first so the reduce is
    over contiguous runs — sequential access, the GFTR insight;
  * two-phase block aggregation ("partition_hash") pre-aggregates each
    VMEM-resident tile with a one-hot matmul reduction (MXU work — the TPU
    analogue of a shared-memory hash table per thread block), then combines
    the per-tile partials with a sorted pass. Correct for *any* key
    distribution (heavy hitters are reduced tile-locally first, the same way
    GPU shared-memory pre-aggregation absorbs skew);
  * wide payloads follow Algorithm 1: payload columns are transformed lazily,
    one at a time, against the key column.

All APIs are static-shape: `num_groups` is a capacity; outputs are
(keys[num_groups], aggs[num_groups], valid_count), padded with KEY_SENTINEL.

Supported aggregations: sum, count, min, max, mean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .table import KEY_SENTINEL, Table
from . import primitives as prim

AGG_OPS = ("sum", "count", "min", "max", "mean")


def _seg_reduce(op, vals, gid, num_segments):
    if op in ("sum", "mean"):
        return jax.ops.segment_sum(vals, gid, num_segments=num_segments)
    if op == "count":
        return jax.ops.segment_sum(jnp.ones_like(vals, jnp.int32), gid, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(vals, gid, num_segments=num_segments)
    if op == "max":
        return jax.ops.segment_max(vals, gid, num_segments=num_segments)
    raise ValueError(op)


def _finalize(op, acc, counts):
    if op == "mean":
        return acc / jnp.maximum(counts, 1).astype(acc.dtype)
    return acc


# Partial-aggregation plumbing: op -> (tile partial op, combine op)
_PARTIAL = {
    "sum": ("sum", "sum"),
    "count": ("count", "sum"),
    "mean": ("sum", "sum"),  # + count partial, finalized at the end
    "min": ("min", "min"),
    "max": ("max", "max"),
}


# ---------------------------------------------------------------------------
# Sort-based (transform-first; GFTR analogue)
# ---------------------------------------------------------------------------
def groupby_sort(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
):
    """Sort rows by key, detect run boundaries, segment-reduce.

    Per Algorithm 1's lazy transform, each payload column is sorted alongside
    the key column one at a time (stable order => consistent groups).
    Returns (Table(key + agg columns), valid_count)."""
    keys = table[key]
    sk = prim.sort_pairs(keys)
    boundary = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    boundary &= sk != KEY_SENTINEL
    valid_row = sk != KEY_SENTINEL
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1  # dense, sorted group ids
    n_found = gid[-1] + 1
    gid = jnp.where(valid_row, gid, num_groups)
    gid_cap = jnp.where(gid < num_groups, gid, num_groups)  # overflow -> dropped

    out_keys = jnp.full((num_groups + 1,), KEY_SENTINEL, keys.dtype)
    out_keys = out_keys.at[gid_cap].set(jnp.where(valid_row, sk, KEY_SENTINEL), mode="drop")
    counts = jax.ops.segment_sum(
        valid_row.astype(jnp.int32), gid_cap, num_segments=num_groups + 1
    )

    cols = {key: out_keys[:num_groups]}
    for col, op in aggs.items():
        _, tv = prim.sort_pairs(keys, table[col])  # lazy per-column transform
        acc = _seg_reduce(op, jnp.where(valid_row, tv, 0) if op in ("sum", "mean") else tv,
                          gid_cap, num_groups + 1)
        cols[f"{col}_{op}"] = _finalize(op, acc, counts)[:num_groups]
    count = jnp.minimum(n_found, num_groups)
    return Table(cols), count


# ---------------------------------------------------------------------------
# Two-phase block aggregation (MXU one-hot partials + sorted combine)
# ---------------------------------------------------------------------------
def _tile_partials(keys, cols_ops, block):
    """Phase 1: per tile of `block` rows, aggregate duplicates tile-locally.

    Returns (partial_keys[npad], partial_counts[npad], {name: partial[npad]})
    where slots without a group carry KEY_SENTINEL. Each tile contributes its
    distinct keys once — heavy hitters collapse block-fold per pass."""
    n = keys.shape[0]
    n_pad = -n % block
    kp = jnp.pad(keys, (0, n_pad), constant_values=KEY_SENTINEL).reshape(-1, block)
    order = jnp.argsort(kp, axis=1, stable=True)
    ks = jnp.take_along_axis(kp, order, axis=1)
    valid = ks != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((ks.shape[0], 1), bool), ks[:, 1:] != ks[:, :-1]], axis=1)
    bnd &= valid
    lgid = jnp.cumsum(bnd.astype(jnp.int32), axis=1) - 1
    lgid = jnp.where(valid, lgid, block)  # invalid rows drop out of the one-hot
    oh = jax.nn.one_hot(lgid, block, dtype=jnp.float32)  # (T, block, block)

    pcounts = jnp.einsum("tbg->tg", oh)
    # group g's key: scatter run-head keys into slot g (run heads are unique per tile)
    T = ks.shape[0]
    pkeys = (
        jnp.full((T, block + 1), KEY_SENTINEL, keys.dtype)
        .at[jnp.arange(T)[:, None], jnp.where(bnd, lgid, block)]
        .set(ks, mode="drop")[:, :block]
    )

    partials = {}
    for name, (vals, pop) in cols_ops.items():
        vp = jnp.pad(vals, (0, n_pad)).reshape(-1, block)
        vs = jnp.take_along_axis(vp, order, axis=1).astype(jnp.float32)
        if pop == "sum":
            acc = jnp.einsum("tb,tbg->tg", jnp.where(valid, vs, 0.0), oh)
        elif pop == "count":
            acc = pcounts
        elif pop in ("min", "max"):
            fill = jnp.float32(jnp.finfo(jnp.float32).max if pop == "min" else jnp.finfo(jnp.float32).min)
            masked = jnp.where(oh > 0, vs[:, :, None], fill)
            acc = masked.min(axis=1) if pop == "min" else masked.max(axis=1)
        else:
            raise ValueError(pop)
        partials[name] = acc.reshape(-1)
    return pkeys.reshape(-1), pcounts.reshape(-1), partials


def groupby_partition_hash(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    block: int = 256,
):
    """Two-phase aggregation: MXU one-hot tile partials + sorted combine.

    The tile plays the role of the GPU thread block's shared-memory hash
    table; the one-hot matmul is the scatter-free reduction (DESIGN.md §2).
    The combine phase runs over tile partials (<= distinct-per-tile of the
    input rows live), so for low-cardinality or skewed inputs the expensive
    pass shrinks by up to `block`x."""
    keys = table[key]
    # Build partial-op plan: ops needed per output agg (+ count for mean).
    cols_ops = {}
    for col, op in aggs.items():
        pop, _ = _PARTIAL[op]
        cols_ops[f"{col}_{op}"] = (table[col], pop)

    pkeys, pcounts, partials = _tile_partials(keys, cols_ops, block)

    # Phase 2: sorted combine over partials (sum of sums / min of mins / ...).
    sk, scnt, *svals = prim.sort_pairs(pkeys, pcounts, *partials.values())
    valid_row = sk != KEY_SENTINEL
    boundary = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid_row
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    n_found = gid[-1] + 1
    gid = jnp.where(valid_row & (gid < num_groups), gid, num_groups)

    out_keys = jnp.full((num_groups + 1,), KEY_SENTINEL, keys.dtype)
    out_keys = out_keys.at[gid].set(jnp.where(valid_row, sk, KEY_SENTINEL), mode="drop")
    counts = jax.ops.segment_sum(jnp.where(valid_row, scnt, 0.0), gid, num_segments=num_groups + 1)

    out = {key: out_keys[:num_groups]}
    for (name, (_, pop)), sv in zip(cols_ops.items(), svals):
        _, cop = _PARTIAL[{"sum": "sum", "count": "count", "min": "min", "max": "max"}[pop]]
        if cop == "sum":
            acc = jax.ops.segment_sum(jnp.where(valid_row, sv, 0.0), gid, num_segments=num_groups + 1)
        elif cop == "min":
            acc = jax.ops.segment_min(jnp.where(valid_row, sv, jnp.finfo(jnp.float32).max),
                                      gid, num_segments=num_groups + 1)
        else:
            acc = jax.ops.segment_max(jnp.where(valid_row, sv, jnp.finfo(jnp.float32).min),
                                      gid, num_segments=num_groups + 1)
        out[name] = acc[:num_groups]
    # finalize means / counts dtype
    for col, op in aggs.items():
        name = f"{col}_{op}"
        if op == "mean":
            out[name] = out[name] / jnp.maximum(counts[:num_groups], 1.0)
        if op == "count":
            out[name] = out[name].astype(jnp.int32)
    count = jnp.minimum(n_found, num_groups)
    return Table(out), count


# ---------------------------------------------------------------------------
# Scatter baseline (dense key domain)
# ---------------------------------------------------------------------------
def groupby_scatter(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
):
    """Direct scatter aggregation for keys in [0, num_groups) — the
    atomicAdd analogue. Unclustered writes; viable only when the accumulator
    array stays cache/VMEM-resident. Out-of-domain keys (including
    KEY_SENTINEL padding) are dropped, and — like the other strategies —
    the output is compacted to a dense prefix (present groups in ascending
    key order, rows >= valid_count are padding), so all strategies share
    one (Table, valid_count) contract."""
    keys = table[key]
    if not jnp.issubdtype(keys.dtype, jnp.integer):
        raise TypeError(
            f"scatter group-by needs integer keys, got {keys.dtype}; "
            "float keys would be silently floored into merged groups")
    in_domain = (keys >= 0) & (keys < num_groups)
    gid = jnp.where(in_domain, keys, num_groups).astype(jnp.int32)
    counts = jax.ops.segment_sum(
        in_domain.astype(jnp.int32), gid, num_segments=num_groups + 1
    )[:num_groups]
    present = counts > 0
    out = {key: jnp.arange(num_groups, dtype=keys.dtype)}
    for col, op in aggs.items():
        vals = table[col]
        if op in ("sum", "mean"):
            vals = jnp.where(in_domain, vals, 0)
        acc = _seg_reduce(op, vals, gid, num_groups + 1)[:num_groups]
        out[f"{col}_{op}"] = _finalize(op, acc, counts)
    names = list(out)
    compacted, n_present = prim.compact(present, [out[n] for n in names],
                                        num_groups)
    out = dict(zip(names, compacted))
    out[key] = jnp.where(jnp.arange(num_groups) < n_present, out[key],
                         jnp.asarray(KEY_SENTINEL, keys.dtype))
    return Table(out), n_present


def groupby_sort_pallas(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    tile: int = 256,
):
    """Sort-based group-by whose per-tile partial reduction runs in the
    Pallas segsum kernel (scatter-free MXU path; interpret-mode on CPU).
    Sum/count/mean only (kernel computes sums+counts)."""
    from repro.kernels import ops as kops

    keys = table[key]
    out = {}
    count = None
    first = True
    for col, op in aggs.items():
        if op not in ("sum", "mean", "count"):
            raise ValueError(f"sort_pallas supports sum/mean/count, got {op}")
        sk, sv = prim.sort_pairs(keys, table[col])
        gk, gs, cnt = kops.groupby_sorted_sum(sk, sv.astype(jnp.float32),
                                              num_groups, "pallas", tile=tile)
        _, gc, _ = kops.groupby_sorted_sum(sk, jnp.ones_like(sv, jnp.float32),
                                           num_groups, "pallas", tile=tile)
        if first:
            out[key] = gk
            count = cnt
            first = False
        if op == "sum":
            out[f"{col}_{op}"] = gs
        elif op == "count":
            out[f"{col}_{op}"] = gc.astype(jnp.int32)
        else:
            out[f"{col}_{op}"] = gs / jnp.maximum(gc, 1.0)
    return Table(out), count


def choose_groupby_strategy(
    n_rows: int,
    est_groups: float,
    *,
    key_min: float | None = None,
    key_max: float | None = None,
    zipf: float = 0.0,
    dense_domain_limit: int = 1 << 18,
    integer_key: bool = True,
) -> tuple[str, str]:
    """Cardinality-based strategy heuristic, mirroring the paper's
    hash/sort/partition guidance for grouped aggregation (and Fig. 18's
    structure: pick the cheapest access pattern the distribution allows).

    Returns (strategy, rationale):
      * dense, small key domains -> 'scatter' (the accumulator array stays
        cache/VMEM-resident, so the unclustered writes are cheap — the
        atomicAdd-on-shared-memory regime);
      * heavy duplication (rows >> groups) or skew -> 'partition_hash'
        (tile-local pre-aggregation collapses duplicates before the
        expensive pass, the shared-memory-hash-table regime);
      * high cardinality -> 'sort' (one sequential sort pass beats hash
        tables that spill out of fast memory — the GFTR insight).
    """
    domain = None
    # scatter indexes the accumulator by key value, so the keys must be
    # non-negative integers in a small domain
    if (integer_key and key_min is not None and key_max is not None
            and key_min >= 0):
        domain = int(key_max) + 1
    if domain is not None and domain <= dense_domain_limit and domain <= max(
        4 * est_groups, 1024
    ):
        return "scatter", (
            f"dense key domain [0, {domain}) fits a resident accumulator"
        )
    if zipf > 1.0:
        return "partition_hash", (
            f"skewed keys (zipf~{zipf:.2f}): tile pre-aggregation absorbs "
            "heavy hitters"
        )
    if est_groups * 8 <= n_rows:
        return "partition_hash", (
            f"rows/groups ~ {n_rows / max(est_groups, 1.0):.0f}x: tile "
            "pre-aggregation shrinks the combine pass"
        )
    return "sort", (
        "high cardinality: sequential sort pass beats spilling hash tables"
    )


def group_aggregate(
    table: Table,
    *,
    key: str = "k",
    aggs: dict[str, str],
    num_groups: int,
    strategy: str = "sort",
    **kw,
):
    """Unified entry point.
    strategy in {'sort', 'partition_hash', 'scatter', 'sort_pallas'}."""
    fn = {
        "sort": groupby_sort,
        "partition_hash": groupby_partition_hash,
        "scatter": groupby_scatter,
        "sort_pallas": groupby_sort_pallas,
    }[strategy]
    return fn(table, key=key, aggs=aggs, num_groups=num_groups, **kw)

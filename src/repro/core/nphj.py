"""Non-partitioned hash join (cuDF-analogue baseline, paper Fig. 1/8).

A single global open-addressing table: build inserts R's keys directly, probe
streams S's keys against it — random global-memory accesses on both sides,
which is exactly why the paper's partitioned algorithms beat it. We keep it
as the baseline for the Fig. 8/10 benchmarks.

TPU adaptation of atomic insertion: CUDA uses atomicCAS; XLA has no atomics,
so each linear-probing round inserts via a deterministic max-scatter
(`.at[idx].max(rank)`) and losers retry in the next round. With load factor
<= 1/4 and 16 rounds, failures are (checked to be) absent for the workloads
we run; the returned `failed` count makes the fallback explicit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import primitives as prim
from .hash_join import hash32
from .table import KEY_SENTINEL, Table

_EMPTY = jnp.int32(-1)


def build_table(keys: jax.Array, table_size: int, max_rounds: int = 16):
    """Insert unique keys into an open-addressing table.

    Returns (slot_keys, slot_vids, failed_count)."""
    n = keys.shape[0]
    mask = jnp.uint32(table_size - 1)
    h = (hash32(keys) & mask).astype(jnp.int32)
    rank = jnp.arange(n, dtype=jnp.int32)

    slot_rank = jnp.full((table_size,), _EMPTY, jnp.int32)
    inserted = jnp.zeros((n,), bool)
    slot_of = jnp.full((n,), -1, jnp.int32)

    def round_body(a, state):
        slot_rank, inserted, slot_of = state
        idx = ((h + a) & jnp.int32(table_size - 1)).astype(jnp.int32)
        occupied = jnp.take(slot_rank, idx) != _EMPTY
        want = (~inserted) & (~occupied)
        cand = jnp.where(want, rank, _EMPTY)
        slot_rank = slot_rank.at[jnp.where(want, idx, table_size)].max(cand, mode="drop")
        won = want & (jnp.take(slot_rank, idx) == rank)
        slot_of = jnp.where(won, idx, slot_of)
        inserted = inserted | won
        return slot_rank, inserted, slot_of

    slot_rank, inserted, slot_of = jax.lax.fori_loop(
        0, max_rounds, round_body, (slot_rank, inserted, slot_of)
    )
    slot_keys = jnp.full((table_size,), KEY_SENTINEL, keys.dtype)
    slot_vids = jnp.full((table_size,), -1, jnp.int32)
    safe = jnp.where(inserted, slot_of, table_size)
    slot_keys = slot_keys.at[safe].set(keys, mode="drop")
    slot_vids = slot_vids.at[safe].set(rank, mode="drop")
    failed = jnp.sum(~inserted)
    return slot_keys, slot_vids, failed


def probe_table(slot_keys, slot_vids, probe_keys, max_rounds: int = 16):
    """Probe: returns (vid_r, matched) per probe row (unique build keys)."""
    table_size = slot_keys.shape[0]
    mask = jnp.uint32(table_size - 1)
    h = (hash32(probe_keys) & mask).astype(jnp.int32)
    found_vid = jnp.full(probe_keys.shape, -1, jnp.int32)
    done = probe_keys == KEY_SENTINEL

    def round_body(a, state):
        found_vid, done = state
        idx = ((h + a) & jnp.int32(table_size - 1)).astype(jnp.int32)
        sk = jnp.take(slot_keys, idx)
        hit = (~done) & (sk == probe_keys)
        found_vid = jnp.where(hit, jnp.take(slot_vids, idx), found_vid)
        done = done | hit | (sk == KEY_SENTINEL)  # empty slot terminates chain
        return found_vid, done

    found_vid, _ = jax.lax.fori_loop(0, max_rounds, round_body, (found_vid, done))
    return found_vid, found_vid >= 0


def nphj_join(
    R: Table,
    S: Table,
    *,
    key: str = "k",
    out_size: int | None = None,
    load_factor: float = 0.25,
    max_rounds: int = 16,
):
    """cuDF-style non-partitioned hash join (PK-FK). Returns (Table, count).

    Materialization matches the paper's description: probe side is streamed
    (clustered), build side gathered by hash-permuted vids (unclustered).
    """
    if out_size is None:
        out_size = S.num_rows
    table_size = 1 << max(3, (int(R.num_rows / load_factor) - 1).bit_length())
    slot_keys, slot_vids, _failed = build_table(R[key], table_size, max_rounds)
    vid_r, matched = probe_table(slot_keys, slot_vids, S[key], max_rounds)
    vid_s = jnp.arange(S.num_rows, dtype=jnp.int32)
    (keys_o, vr, vs), count = prim.compact(
        matched, [S[key], vid_r, vid_s], out_size, fill=KEY_SENTINEL
    )
    valid = jnp.arange(out_size) < count
    cols = {key: keys_o}
    for n in R.column_names:
        if n != key:
            cols[n] = prim.gather(R[n], jnp.where(valid, vr, -1), fill=0)
    for n in S.column_names:
        if n != key:
            cols[n] = prim.gather(S[n], jnp.where(valid, vs, -1), fill=0)
    return Table(cols), count

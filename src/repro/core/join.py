"""Unified join API + join sequences (paper §5.2.7).

`join()` dispatches on (algorithm, pattern):
    algorithm: "smj" | "phj" | "nphj"
    pattern:   "gftr" (optimized materialization, *-OM)
             | "gfur" (unoptimized, *-UM)

`join_sequence()` reproduces the paper's N-way star-join driver: a fact table
F(FK_1..FK_N, ID, payloads) joined against dimension tables D_i(K_i, P_i),
fetching FK_{i+1} via the accumulated tuple IDs right before join i+1 to
avoid materializing irrelevant columns (§5.2.7).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import primitives as prim
from .hash_join import phj_join
from .nphj import nphj_join
from .sort_merge import smj_join
from .table import Table

ALGORITHMS = ("smj", "phj", "nphj")
PATTERNS = ("gftr", "gfur")


def join(
    R: Table,
    S: Table,
    *,
    key: str = "k",
    algorithm: str = "phj",
    pattern: str = "gftr",
    out_size: int | None = None,
    mode: str = "pk_fk",
    **kw,
):
    """Inner equi-join of R (build / PK side) and S (probe / FK side).

    Returns (Table, valid_count); see DESIGN.md for the static-shape
    contract. Shorthand names from the paper: SMJ-UM = (smj, gfur),
    SMJ-OM = (smj, gftr), PHJ-UM = (phj, gfur), PHJ-OM = (phj, gftr).
    """
    if algorithm == "smj":
        return smj_join(R, S, key=key, pattern=pattern, out_size=out_size, mode=mode, **kw)
    if algorithm == "phj":
        return phj_join(R, S, key=key, pattern=pattern, out_size=out_size, mode=mode, **kw)
    if algorithm == "nphj":
        if mode != "pk_fk":
            raise ValueError("nphj baseline supports pk_fk only")
        return nphj_join(R, S, key=key, out_size=out_size, **kw)
    raise ValueError(f"unknown algorithm {algorithm!r}")


def by_name(name: str):
    """'PHJ-OM' -> kwargs for join()."""
    alg, mat = name.lower().split("-")
    return dict(algorithm=alg, pattern={"om": "gftr", "um": "gfur"}[mat])


def join_sequence(
    fact: Table,
    dims: list[Table],
    *,
    fk_cols: list[str],
    dim_keys: list[str],
    algorithm: str = "phj",
    pattern: str = "gftr",
    out_size: int | None = None,
    restore_order: bool = False,
    keep_ids: bool = False,
):
    """Sequence of N PK-FK joins (paper Fig. 16).

    fact must contain fk_cols; each dims[i] has key dim_keys[i] plus payload
    columns. Join i materializes dims[i]'s payloads into the running result;
    FK_{i+1} is fetched lazily via the fact-table tuple IDs.

    restore_order=True re-sorts the result by fact row id (canonical sample
    order for ML pipelines — all algorithms then agree exactly);
    keep_ids=True keeps the `_fact_id` column in the output.
    Returns (Table, valid_count).
    """
    n = fact.num_rows
    out_size = out_size or n
    # running state: tuple IDs into the original fact table + materialized payloads
    ids = jnp.arange(n, dtype=jnp.int32)
    acc = Table({"_fact_id": ids})
    count = None
    for i, (dim, fk, dk) in enumerate(zip(dims, fk_cols, dim_keys)):
        # fetch FK_i right before the join (avoids materializing all FKs)
        fk_vals = prim.gather(fact[fk], acc["_fact_id"], fill=-1)
        probe = acc.with_columns(**{dk: fk_vals})
        joined, count = join(
            dim, probe, key=dk, algorithm=algorithm, pattern=pattern, out_size=out_size
        )
        acc = joined.drop([dk]) if dk in joined.column_names else joined
    if restore_order:
        order_key = jnp.where(acc["_fact_id"] >= 0, acc["_fact_id"], n)
        perm = prim.argsort_stable(order_key)
        acc = acc.take(perm)
    # final: materialize fact payload columns (beyond FKs) by tuple ID
    payload = {
        c: prim.gather(fact[c], acc["_fact_id"], fill=0)
        for c in fact.column_names
        if c not in fk_cols
    }
    result = acc.with_columns(**payload)
    if not keep_ids:
        result = result.drop(["_fact_id"])
    return result, count

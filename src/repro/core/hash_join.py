"""Partitioned hash join: PHJ-UM (GFUR, §3.2) and PHJ-OM (GFTR, §4.3).

The paper's PHJ-OM redesign replaces bucket-chaining (non-deterministic,
fragmented) with stable RADIX-PARTITION into contiguous arrays + histogram/
prefix-sum offsets. Our TPU port is deterministic by construction
(prefix-sum ranks, no atomics — DESIGN.md §2), so the GFTR requirement
"partitioning (key, col_1) gives the same layout as (key, col_2)" holds
exactly.

Match finding mirrors the paper's co-partition scheme: the build-side
partition plays the role of the shared-memory hash table (here: a fixed-
capacity VMEM-resident block), and probe keys stream against it. The paper
itself describes the multi-bucket case as "resembling a block nested loop
join"; on TPU the probe is a vectorized equality over the block — the
hash_probe Pallas kernel implements the same loop with explicit VMEM tiling.

Static-shape notes: build partitions are padded to `build_block` capacity
(contiguous + constant-time indexable — the paper's de-fragmentation
requirement); an overflow diagnostic is returned so callers can re-run with
more partition bits. Probe-side partitions are never padded: probe rows are
processed in partitioned order (this is also the paper's probe-side
sub-partitioning load-balance trick, for free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import primitives as prim
from .table import KEY_SENTINEL, Table


def hash32(x: jax.Array) -> jax.Array:
    """Murmur3-style finalizer; avalanches all input bits into 32."""
    if x.dtype.itemsize > 4:
        x = (x ^ (x >> 32)).astype(jnp.uint32)
    else:
        x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


# default padded-block capacity per build partition; rows of ONE key beyond
# this cannot be separated by more partition bits (duplicates co-hash), so
# m:n joins with heavier per-key multiplicity must use sort-merge instead
BUILD_BLOCK = 256


def choose_partition_bits(n_build: int, build_block: int) -> int:
    """Fan-out so that E[partition size] <= build_block/4 (overflow of the
    padded block becomes negligible for hashed keys)."""
    target = max(1, (4 * n_build) // build_block)
    return max(1, min(20, (target - 1).bit_length()))


def _digits(keys, p_bits, hash_keys):
    """Partition digit per row, in [0, p_bits^2]: valid keys spread over
    [0, P) by the hash; KEY_SENTINEL rows (masked padding from an upstream
    operator) flood their own dedicated partition P so they can never crowd
    valid keys out of a shared build block — without this, a join input
    that is half padding concentrates every sentinel in one hash bucket and
    evicts the valid keys that co-hash there (silent dropped matches)."""
    h = hash32(keys) if hash_keys else keys.astype(jnp.uint32)
    d = (h & ((1 << p_bits) - 1)).astype(jnp.int32)
    sentinel = keys == jnp.asarray(KEY_SENTINEL, keys.dtype)
    return jnp.where(sentinel, 1 << p_bits, d)


def _nonempty(table: Table, key: str) -> Table:
    """A zero-row relation breaks the static-shape plumbing (empty
    bincounts, (0,)-vs-(1,) boundary concats). Substitute ONE all-sentinel
    row: the sentinel key is dropped by every probe/build/aggregate by
    construction, so results are identical to the true empty input while
    every intermediate keeps a non-degenerate shape."""
    if table.num_rows:
        return table
    cols = {}
    for n in table.column_names:
        c = table[n]
        fill = KEY_SENTINEL if n == key else 0
        cols[n] = jnp.full((1,), fill, c.dtype)
    return Table(cols)


def _chunked(f, arr_len, chunk, *arrays):
    """Apply f to row-chunks of the arrays sequentially (bounded memory),
    concatenating results. Pads to a chunk multiple."""
    n_pad = -arr_len % chunk
    padded = [jnp.pad(a, [(0, n_pad)] + [(0, 0)] * (a.ndim - 1)) for a in arrays]
    stacked = [a.reshape((-1, chunk) + a.shape[1:]) for a in padded]
    outs = jax.lax.map(lambda xs: f(*xs), tuple(stacked))
    outs = jax.tree_util.tree_map(lambda o: o.reshape((-1,) + o.shape[2:])[:arr_len], outs)
    return outs


# ---------------------------------------------------------------------------
# Build-side padded blocks
# ---------------------------------------------------------------------------
def blocked_partitions(arr_part: jax.Array, off: jax.Array, sz: jax.Array,
                       cap: int, fill):
    """Pad each contiguous partition of a partitioned column to `cap` rows:
    (P, cap) blocks where slot (p, i) holds the i-th row of partition p and
    out-of-partition slots carry `fill`. The single home of the padding
    geometry — key blocks, virtual-ID blocks, and the group-join's value
    blocks must all agree on it."""
    i = jnp.arange(cap, dtype=jnp.int32)[None, :]
    idx = off[:, None].astype(jnp.int32) + i
    valid = i < sz[:, None]
    idx_c = jnp.clip(idx, 0, arr_part.shape[0] - 1)
    return jnp.where(valid, jnp.take(arr_part, idx_c), fill), idx, valid


def build_blocks(keys_part: jax.Array, off: jax.Array, sz: jax.Array, cap: int):
    """Pad each contiguous partition to `cap` rows -> (P, cap) key blocks and
    (P, cap) virtual-ID blocks (positions in the partitioned array).
    Returns (bkeys, bvids, overflow)."""
    bkeys, idx, valid = blocked_partitions(keys_part, off, sz, cap, KEY_SENTINEL)
    bvids = jnp.where(valid, idx, -1)
    overflow = jnp.max(sz) > cap
    return bkeys, bvids, overflow


# ---------------------------------------------------------------------------
# Match finding
# ---------------------------------------------------------------------------
def probe_pk_fk(bkeys, off_r, probe_keys, probe_digits, chunk=8192):
    """For each probe row: find its (unique) match in the build block of its
    co-partition. Returns (vid_r, matched), both clustered in probe order."""

    def body(pk, pd):
        # sentinel rows carry digit P (their dedicated partition, which has
        # no build block); clip to a real block — the pk != KEY_SENTINEL
        # guard already makes every comparison for them False
        pd = jnp.minimum(pd, bkeys.shape[0] - 1)
        cand = jnp.take(bkeys, pd, axis=0)  # (chunk, capR)
        eq = (cand == pk[:, None]) & (pk[:, None] != KEY_SENTINEL)
        hit = jnp.argmax(eq, axis=1).astype(jnp.int32)
        matched = jnp.any(eq, axis=1)
        vid_r = jnp.take(off_r, jnp.minimum(pd, off_r.shape[0] - 1)
                         ).astype(jnp.int32) + hit
        return vid_r, matched

    return _chunked(body, probe_keys.shape[0], chunk, probe_keys, probe_digits)


def probe_counts(bkeys, probe_keys, probe_digits, chunk=8192):
    """m:n: number of build matches per probe row."""

    def body(pk, pd):
        pd = jnp.minimum(pd, bkeys.shape[0] - 1)  # sentinel digit P -> any block
        cand = jnp.take(bkeys, pd, axis=0)
        eq = (cand == pk[:, None]) & (pk[:, None] != KEY_SENTINEL)
        return jnp.sum(eq, axis=1).astype(jnp.int32)

    return _chunked(body, probe_keys.shape[0], chunk, probe_keys, probe_digits)


def probe_kth_match(bkeys, off_r, probe_keys, probe_digits, rows, ranks, chunk=8192):
    """m:n expansion: for output row t assigned to probe row `rows[t]`, find
    its `ranks[t]`-th match in the co-partition block."""

    def body(row, rank):
        pk = jnp.take(probe_keys, row)
        pd = jnp.minimum(jnp.take(probe_digits, row), bkeys.shape[0] - 1)
        cand = jnp.take(bkeys, pd, axis=0)
        eq = (cand == pk[:, None]) & (pk[:, None] != KEY_SENTINEL)
        csum = jnp.cumsum(eq.astype(jnp.int32), axis=1)
        # k-th set bit = first position where csum > k
        pos = jnp.sum((csum <= rank[:, None]).astype(jnp.int32), axis=1)
        pos = jnp.minimum(pos, cand.shape[1] - 1)
        return jnp.take(off_r, pd).astype(jnp.int32) + pos

    return _chunked(body, rows.shape[0], chunk, rows, ranks)


# ---------------------------------------------------------------------------
# Join driver
# ---------------------------------------------------------------------------
def phj_join(
    R: Table,
    S: Table,
    *,
    key: str = "k",
    pattern: str = "gftr",  # "gftr" (PHJ-OM) | "gfur" (PHJ-UM)
    out_size: int | None = None,
    mode: str = "pk_fk",
    build_block: int = BUILD_BLOCK,
    partition_bits: int | None = None,
    hash_keys: bool = True,
    probe_chunk: int = 8192,
    probe_impl: str = "xla",  # "xla" | "pallas" (co-partition probe kernel)
    gather_impl: str = "xla",  # "xla" | "pallas" (windowed clustered gather)
):
    """End-to-end partitioned hash join. Returns (Table, valid_count).

    Build partitions are padded to `build_block`; if any partition would
    overflow (duplicate-heavy build keys), `phj_join_checked` re-runs with
    more partition bits (the paper's multi-pass fan-out escalation).
    """
    if out_size is None:
        out_size = S.num_rows if mode == "pk_fk" else S.num_rows * 2
    out_size = max(out_size, 1)
    R = _nonempty(R, key)
    S = _nonempty(S, key)
    r_pay = [n for n in R.column_names if n != key]
    s_pay = [n for n in S.column_names if n != key]
    p_bits = (
        partition_bits
        if partition_bits is not None
        else choose_partition_bits(R.num_rows, build_block)
    )
    P = 1 << p_bits

    dig_r = _digits(R[key], p_bits, hash_keys)
    dig_s = _digits(S[key], p_bits, hash_keys)
    # One-permutation transform plan (multi-pass radix semantics; determinism
    # by construction — §4.3's requirement): the partition is planned once
    # per side and every column it touches costs exactly one gather. P + 1
    # partitions: the extra one swallows sentinel rows (see _digits) and
    # never gets a build block or a probe pass.
    perm_r, off_r, sz_r = prim.plan_partition_permutation(dig_r, P + 1)
    perm_s, off_s, sz_s = prim.plan_partition_permutation(dig_s, P + 1)

    kr = prim.apply_permutation(perm_r, R[key])
    ks, dig_s_part = prim.apply_permutation(perm_s, S[key], dig_s)

    bkeys, _, overflow = build_blocks(kr, off_r[:P], sz_r[:P], build_block)

    if mode == "pk_fk":
        if probe_impl == "pallas":
            from repro.kernels import ops as _kops

            vid_r, matched = _kops.hash_probe(bkeys, off_r[:P], ks,
                                              off_s[:P], sz_s[:P], "pallas")
        else:
            vid_r, matched = probe_pk_fk(bkeys, off_r, ks, dig_s_part, probe_chunk)
        vid_s = jnp.arange(ks.shape[0], dtype=jnp.int32)
        (keys_o, vr, vs), count = prim.compact(
            matched, [ks, vid_r, vid_s], out_size, fill=KEY_SENTINEL
        )
        valid = jnp.arange(out_size) < count
    else:
        counts = probe_counts(bkeys, ks, dig_s_part, probe_chunk)
        rows, ranks, valid, total = prim.expand_offsets(counts, out_size)
        vr = probe_kth_match(bkeys, off_r, ks, dig_s_part, rows, ranks, probe_chunk)
        vs = rows
        keys_o = jnp.where(valid, jnp.take(ks, vs), KEY_SENTINEL)
        count = jnp.minimum(total, out_size)

    ID_R = jnp.where(valid, vr, -1)
    ID_S = jnp.where(valid, vs, -1)

    cols = {key: keys_o}
    if pattern == "gfur":
        # UM: translate to physical IDs of the untransformed inputs.
        pid_r = jnp.where(valid, jnp.take(perm_r, jnp.clip(vr, 0, R.num_rows - 1)), -1)
        pid_s = jnp.where(valid, jnp.take(perm_s, jnp.clip(vs, 0, S.num_rows - 1)), -1)
        for n in r_pay:
            cols[n] = prim.gather(R[n], pid_r, fill=0)  # unclustered
        for n in s_pay:
            cols[n] = prim.gather(S[n], pid_s, fill=0)  # unclustered
    elif pattern == "gftr":
        # OM: gather from partitioned relations. Probe-side IDs are perfectly
        # clustered; build-side IDs are clustered within partitions (§4.3).
        if gather_impl == "pallas":
            from repro.kernels import ops as _kops

            _g = lambda src, idx: _kops.clustered_gather(src, idx, "auto")
        else:
            _g = lambda src, idx: prim.gather(src, idx, fill=0)
        for n in r_pay:
            tr_n = prim.apply_permutation(perm_r, R[n])  # col n's ONE gather
            cols[n] = _g(tr_n, ID_R)
        for n in s_pay:
            ts_n = prim.apply_permutation(perm_s, S[n])
            cols[n] = _g(ts_n, ID_S)
    else:
        raise ValueError(f"unknown pattern {pattern!r}")

    return Table(cols), count


def phj_overflowed(R: Table, *, key: str = "k", build_block: int = 256,
                   partition_bits: int | None = None, hash_keys: bool = True):
    """Host-side check: would any build partition exceed the padded block?"""
    p_bits = (partition_bits if partition_bits is not None
              else choose_partition_bits(R.num_rows, build_block))
    dig = _digits(R[key], p_bits, hash_keys)
    # the sentinel partition P is allowed to overflow (it never gets a block)
    sizes = jnp.bincount(dig, length=(1 << p_bits) + 1)[:-1]
    return bool(jnp.max(sizes) > build_block), p_bits


def escalate_partition_bits(R: Table, *, key: str = "k",
                            build_block: int = 256,
                            partition_bits: int | None = None,
                            hash_keys: bool = True,
                            max_extra_bits: int = 4) -> int:
    """Resolved fan-out after the checked drivers' escalation policy: add
    partition bits while any build co-partition would overflow its padded
    block (separating co-hashed distinct keys — the paper's multi-pass
    policy). Deterministic: each check is a cheap histogram, each retry
    uses strictly more bits. Shared by `phj_join_checked` and
    `groupjoin_checked`."""
    overflow, p_bits = phj_overflowed(R, key=key, build_block=build_block,
                                      partition_bits=partition_bits,
                                      hash_keys=hash_keys)
    extra = 0
    while overflow and extra < max_extra_bits:
        extra += 1
        overflow, _ = phj_overflowed(R, key=key, build_block=build_block,
                                     partition_bits=p_bits + extra,
                                     hash_keys=hash_keys)
    if extra:
        from repro.obs import metrics  # deferred: core never needs obs otherwise

        metrics.counter("core.overflow_escalations").inc()
    return p_bits + extra


def phj_join_checked(R: Table, S: Table, *, key: str = "k", max_extra_bits: int = 4,
                     build_block: int = 256, max_attempts: int = 8,
                     with_report: bool = False, **kw):
    """phj_join on the resilience ladder (DESIGN.md §13): add partition
    bits while any build co-partition would overflow its padded block (the
    paper's multi-pass fan-out escalation); when more bits cannot help —
    one key's duplicates co-hash no matter the fan-out — fall back to
    sort-merge, which is exact for any multiplicity. The old loop returned
    escalated-but-still-overflowing bits and silently dropped matches;
    the ladder either converges or raises `EscalationExhausted`.

    `with_report=True` additionally returns the `EscalationReport`."""
    from repro.resilience import EscalationStep, Ladder

    hash_keys = kw.get("hash_keys", True)
    base_bits = kw.pop("partition_bits", None)
    if base_bits is None:
        base_bits = choose_partition_bits(R.num_rows, build_block)
    knobs = {"algorithm": "phj", "partition_bits": base_bits,
             "build_block": build_block}

    def check(kn):
        if kn["algorithm"] != "phj":
            return True, "smj fallback (exact for any multiplicity)", None
        over, _ = phj_overflowed(R, key=key, build_block=kn["build_block"],
                                 partition_bits=kn["partition_bits"],
                                 hash_keys=hash_keys)
        return (not over,
                f"build partition > {kn['build_block']} rows" if over else "",
                None)

    def grow_bits(kn, diag):
        if kn["algorithm"] != "phj" or kn["partition_bits"] >= 20:
            return None
        return {**kn, "partition_bits": kn["partition_bits"] + 1}

    def to_smj(kn, diag):
        return {**kn, "algorithm": "smj"}

    ladder = Ladder("phj", [
        EscalationStep("partition_bits", grow_bits, max_times=max_extra_bits),
        EscalationStep("strategy:smj", to_smj, max_times=1),
    ], max_attempts=max_attempts)
    report = ladder.resolve(knobs, check)
    kn = report.final_knobs
    if kn["algorithm"] == "smj":
        from .sort_merge import smj_join  # deferred: no import cycle

        smj_kw = {k: v for k, v in kw.items()
                  if k in ("pattern", "out_size", "mode", "find_impl")}
        out = smj_join(R, S, key=key, **smj_kw)
    else:
        out = phj_join(R, S, key=key, build_block=kn["build_block"],
                       partition_bits=kn["partition_bits"], **kw)
    return (out, report) if with_report else out

"""Columnar Table abstraction.

Relations are stored column-wise as equal-length device arrays, mirroring the
paper's storage model ("relations are stored in the GPU memory as columns, and
all columns are stored as arrays", §3). A Table is a pytree so it can flow
through jit/scan/shard_map unchanged.

Static-shape discipline: XLA requires static shapes, so data-dependent results
(join outputs, group-by outputs) are represented as (Table-with-capacity,
valid_count). Rows at index >= valid_count are padding and carry sentinel
keys. This mirrors fixed-capacity serving buffers and replaces the paper's
"allocate after counting" GPU idiom (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Mapping

import jax
import jax.numpy as jnp

# Sentinel used for padded / invalid key slots. Valid keys must be >= 0.
KEY_SENTINEL = -1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    """An ordered collection of named, equal-length columns."""

    columns: dict[str, jax.Array]

    def __post_init__(self):
        if not self.columns:
            raise ValueError("Table needs at least one column")
        lengths = {k: v.shape[0] for k, v in self.columns.items()}
        if len(set(lengths.values())) > 1:
            raise ValueError(f"ragged columns: {lengths}")

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names), names

    @classmethod
    def tree_unflatten(cls, names, children):
        obj = object.__new__(cls)
        obj.columns = dict(zip(names, children))
        return obj

    # -- basic accessors ---------------------------------------------------
    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> tuple[str, ...]:
        return tuple(self.columns)

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def __iter__(self) -> Iterator[str]:
        return iter(self.columns)

    def nbytes(self) -> int:
        return sum(int(v.size) * v.dtype.itemsize for v in self.columns.values())

    # -- functional updates --------------------------------------------------
    def with_columns(self, **cols: jax.Array) -> "Table":
        new = dict(self.columns)
        new.update(cols)
        return Table(new)

    def select(self, names) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def drop(self, names) -> "Table":
        names = set(names)
        return Table({n: v for n, v in self.columns.items() if n not in names})

    def rename(self, mapping: Mapping[str, str]) -> "Table":
        return Table({mapping.get(n, n): v for n, v in self.columns.items()})

    def take(self, idx: jax.Array) -> "Table":
        """Row gather: out[i] = self[idx[i]]. idx may be unclustered."""
        return Table({n: jnp.take(v, idx, axis=0, mode="clip") for n, v in self.columns.items()})

    def head(self, n: int) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    def pad_to(self, n: int, fill=0) -> "Table":
        cur = self.num_rows
        if cur >= n:
            return self.head(n)
        return Table(
            {
                k: jnp.concatenate([v, jnp.full((n - cur,) + v.shape[1:], fill, v.dtype)])
                for k, v in self.columns.items()
            }
        )

    def __repr__(self):
        cols = ", ".join(f"{n}:{v.dtype}{list(v.shape)}" for n, v in self.columns.items())
        return f"Table({cols})"


def table_from_dict(d: Mapping[str, jax.Array]) -> Table:
    return Table({k: jnp.asarray(v) for k, v in d.items()})


def concat_tables(tables: list[Table]) -> Table:
    names = tables[0].column_names
    return Table({n: jnp.concatenate([t[n] for t in tables]) for n in names})

"""repro.core — the paper's contribution: end-to-end relational joins and
grouped aggregations with GFTR-optimized materialization, as a composable
JAX library (see DESIGN.md)."""

from . import primitives
from .groupby import (choose_groupby_partition_bits, choose_groupby_strategy, group_aggregate,
                      groupby_partition, groupby_partition_checked, groupby_partition_hash,
                      groupby_partition_overflowed, groupby_scatter, groupby_sort,
                      groupby_sort_pallas)
from .groupjoin import (groupjoin_checked, groupjoin_overflowed, groupjoin_required_groups,
                        phj_groupjoin)
from .hash_join import choose_partition_bits, hash32, phj_join, phj_join_checked, phj_overflowed
from .join import ALGORITHMS, PATTERNS, by_name, join, join_sequence
from .memmodel import gftr_ledger, gfur_ledger, peak_memory, peak_memory_bytes
from .nphj import nphj_join
from .planner import (JoinStats, PrimitiveProfile, choose_algorithm, choose_smj_pattern,
                      predict_groupby_time, predict_groupjoin_time, predict_join_time)
from .sort_merge import merge_find_mn, merge_find_pk_fk, smj_join
from .table import KEY_SENTINEL, Table, concat_tables, table_from_dict

__all__ = [
    "Table", "table_from_dict", "concat_tables", "KEY_SENTINEL",
    "join", "join_sequence", "by_name", "ALGORITHMS", "PATTERNS",
    "smj_join", "merge_find_pk_fk", "merge_find_mn",
    "phj_join", "phj_join_checked", "phj_overflowed", "hash32",
    "choose_partition_bits", "nphj_join",
    "group_aggregate", "groupby_sort", "groupby_partition",
    "groupby_partition_checked", "groupby_partition_overflowed",
    "groupby_partition_hash", "groupby_scatter", "groupby_sort_pallas",
    "choose_groupby_strategy", "choose_groupby_partition_bits",
    "phj_groupjoin", "groupjoin_checked", "groupjoin_overflowed",
    "groupjoin_required_groups",
    "JoinStats", "choose_algorithm", "choose_smj_pattern",
    "PrimitiveProfile", "predict_join_time", "predict_groupby_time",
    "predict_groupjoin_time",
    "peak_memory", "peak_memory_bytes", "gfur_ledger", "gftr_ledger",
    "primitives",
]

"""repro.core — the paper's contribution: end-to-end relational joins and
grouped aggregations with GFTR-optimized materialization, as a composable
JAX library (see DESIGN.md)."""

from .table import Table, table_from_dict, concat_tables, KEY_SENTINEL
from .join import join, join_sequence, by_name, ALGORITHMS, PATTERNS
from .sort_merge import smj_join, merge_find_pk_fk, merge_find_mn
from .hash_join import (phj_join, phj_join_checked, phj_overflowed, hash32,
                        choose_partition_bits)
from .nphj import nphj_join
from .groupby import (group_aggregate, groupby_sort, groupby_partition,
                      groupby_partition_checked, groupby_partition_overflowed,
                      groupby_partition_hash, groupby_scatter,
                      groupby_sort_pallas, choose_groupby_strategy,
                      choose_groupby_partition_bits)
from .groupjoin import (phj_groupjoin, groupjoin_checked,
                        groupjoin_overflowed, groupjoin_required_groups)
from .planner import (JoinStats, choose_algorithm, choose_smj_pattern,
                      PrimitiveProfile, predict_join_time,
                      predict_groupby_time, predict_groupjoin_time)
from .memmodel import peak_memory, peak_memory_bytes, gfur_ledger, gftr_ledger
from . import primitives

__all__ = [
    "Table", "table_from_dict", "concat_tables", "KEY_SENTINEL",
    "join", "join_sequence", "by_name", "ALGORITHMS", "PATTERNS",
    "smj_join", "merge_find_pk_fk", "merge_find_mn",
    "phj_join", "phj_join_checked", "phj_overflowed", "hash32",
    "choose_partition_bits", "nphj_join",
    "group_aggregate", "groupby_sort", "groupby_partition",
    "groupby_partition_checked", "groupby_partition_overflowed",
    "groupby_partition_hash", "groupby_scatter", "groupby_sort_pallas",
    "choose_groupby_strategy", "choose_groupby_partition_bits",
    "phj_groupjoin", "groupjoin_checked", "groupjoin_overflowed",
    "groupjoin_required_groups",
    "JoinStats", "choose_algorithm", "choose_smj_pattern",
    "PrimitiveProfile", "predict_join_time", "predict_groupby_time",
    "predict_groupjoin_time",
    "peak_memory", "peak_memory_bytes", "gfur_ledger", "gftr_ledger",
    "primitives",
]

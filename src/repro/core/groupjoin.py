"""Fused group-join: aggregate during the probe, never materialize the join.

The paper observes joins are "widely used in combination with" grouped
aggregation, yet a conventional pipeline materializes the full join result
to HBM — one gather per payload column into a `(capacity, valid_count)`
buffer sized for the worst case — and then immediately re-reads every byte
of it with a group-by. Both passes are bandwidth-bound, so the round trip
is the single largest avoidable data movement in every join+agg query.

`phj_groupjoin` removes it. It runs the same co-partition build/probe as
`phj_join` (PHJ-OM transform + match finding), but instead of compacting
matches and gathering payload columns into a join output, it folds each
matched probe row's aggregate inputs directly into a group-keyed
accumulator:

  * the probe emits (vid_r, matched) in partitioned probe order — exactly
    the `phj_join` pk_fk probe;
  * the group key and every probe-side aggregate input cost one planned
    permutation gather each (the one-permutation layer's lazy transform);
    unmatched rows are masked to KEY_SENTINEL so they can never form or
    join a group;
  * build-side inputs use the GFTR pattern: transform once (one n_build
    permutation gather), then ONE clustered probe-length gather through
    the matched virtual IDs — n_probe rows, not `capacity` rows, and no
    second read;
  * the accumulator is the group-by machinery itself (`group_aggregate`),
    running over the probe-length arrays: scatter-free (one-hot-matmul
    tile partials / segmented reductions — DESIGN.md §2), exact for any
    key distribution with the always-exact 'sort'/'partition_hash'
    strategies.

The joined row is never written: no compaction, no capacity-sized
buffers, no per-payload materialization gathers, no re-read. The cost
model (`planner.predict_groupjoin_time`) prices this as probe cost +
accumulate cost with a zero materialization term.

Scope: inner pk_fk joins (build keys unique). m:n group-joins would need
multiplicity-weighted accumulation and are out of scope; the engine's
fusion pass only fires on provably pk_fk joins.

Static-shape contract: `num_groups` is the accumulator capacity; output is
(Table(group_key + f"{col}_{op}" columns), valid_count), padded with
KEY_SENTINEL — identical to `group_aggregate`. Groups beyond capacity are
dropped; `groupjoin_checked` escalates partition bits (build-block
overflow, the `phj_join_checked` policy) and then accumulator capacity
(exact distinct-group count) so the fused result is always exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import primitives as prim
from .groupby import AGG_OPS, group_aggregate
from .hash_join import (BUILD_BLOCK, _digits, _nonempty, blocked_partitions,
                        build_blocks, choose_partition_bits,
                        escalate_partition_bits, phj_overflowed, probe_pk_fk)
from .table import KEY_SENTINEL, Table


def _value_blocks(vals_part: jax.Array, off: jax.Array, sz: jax.Array,
                  cap: int) -> jax.Array:
    """(P, cap) float32 value blocks aligned with `build_blocks`' key blocks
    (same padding geometry, 0.0 fill)."""
    blocks, _, _ = blocked_partitions(vals_part.astype(jnp.float32), off, sz,
                                      cap, 0.0)
    return blocks


def phj_groupjoin(
    R: Table,
    S: Table,
    *,
    key: str = "k",
    group_key: str,
    aggs: dict[str, str],
    num_groups: int,
    agg_strategy: str = "sort",
    build_block: int = BUILD_BLOCK,
    partition_bits: int | None = None,
    hash_keys: bool = True,
    probe_chunk: int = 8192,
    probe_impl: str = "xla",  # "xla" | "pallas" (fused probe+accumulate kernel)
    agg_kw: dict | None = None,
):
    """Fused pk_fk join + grouped aggregation. Returns (Table, valid_count).

    `group_key` must be a probe-side (S) column — the join key itself is
    allowed. `aggs` maps a column of either relation to an op in
    sum/count/min/max/mean; output columns are named f"{col}_{op}".

    `probe_impl="pallas"` runs the probe+accumulate Pallas kernel (per-tile
    one-hot-matmul partials + segmented combine — the §2 mapping of the
    GPU's shared-memory hash accumulator; sum/count/mean, integer group
    keys). The "xla" path supports the full op set and any `agg_strategy`
    accepted by `group_aggregate`.
    """
    if group_key not in S.column_names:
        raise ValueError(
            f"group_key {group_key!r} must be a probe-side column "
            f"(have {S.column_names}); build-side group keys would need the "
            "matched row materialized — the movement this operator removes")
    for col, op in aggs.items():
        if op not in AGG_OPS:
            raise ValueError(f"unknown agg op {op!r} for {col!r}")
        if col not in S.column_names and col not in R.column_names:
            raise ValueError(f"agg column {col!r} in neither relation")

    R = _nonempty(R, key)
    S = _nonempty(S, key)
    p_bits = (partition_bits if partition_bits is not None
              else choose_partition_bits(R.num_rows, build_block))
    P = 1 << p_bits

    dig_r = _digits(R[key], p_bits, hash_keys)
    dig_s = _digits(S[key], p_bits, hash_keys)
    # P + 1 partitions: sentinel rows flood the extra one (see
    # hash_join._digits) and never reach a build block or probe pass
    perm_r, off_r, sz_r = prim.plan_partition_permutation(dig_r, P + 1)
    perm_s, off_s, sz_s = prim.plan_partition_permutation(dig_s, P + 1)
    off_r, sz_r = off_r[:P], sz_r[:P]
    off_s, sz_s = off_s[:P], sz_s[:P]

    kr = prim.apply_permutation(perm_r, R[key])
    ks, dig_s_part = prim.apply_permutation(perm_s, S[key], dig_s)
    bkeys, _, _ = build_blocks(kr, off_r, sz_r, build_block)

    # Probe-side columns reach partitioned order by the one-permutation
    # layer's lazy transform: exactly one planned-permutation gather per
    # column the aggregation actually reads, computed on demand and shared
    # between the group key and an agg on the same column.
    probe_part: dict[str, jax.Array] = {key: ks}

    def probe_col(col):
        if col not in probe_part:
            probe_part[col] = prim.apply_permutation(perm_s, S[col])
        return probe_part[col]

    gk = probe_col(group_key)

    if probe_impl == "pallas":
        return _groupjoin_pallas(R, S, key, aggs, num_groups, bkeys, off_r,
                                 sz_r, perm_r, probe_col, gk, off_s, sz_s,
                                 group_key)

    vid_r, matched = probe_pk_fk(bkeys, off_r, ks, dig_s_part, probe_chunk)
    gk_masked = jnp.where(matched, gk, jnp.asarray(KEY_SENTINEL, gk.dtype))

    # Per-row aggregate inputs in partitioned probe order — the rows the
    # accumulator consumes directly; the joined row is never assembled.
    cols = {group_key: gk_masked}
    for col, op in aggs.items():
        if col in cols:
            continue  # aggregating the group key: reuse the masked column
        if op == "count":
            # counts ignore values on every strategy; skip any fetch
            cols[col] = jnp.zeros(ks.shape, jnp.int32)
        elif col in S.column_names:
            cols[col] = probe_col(col)  # the column's ONE lazy-transform gather
        else:
            # build-side input, GFTR pattern: transform once (one n_build
            # permutation gather), then ONE clustered probe-length gather
            # through the matched virtual IDs (clustered within
            # co-partitions — the same access shape as phj_join's ID_R)
            tr = prim.apply_permutation(perm_r, R[col])
            cols[col] = prim.gather(tr, jnp.where(matched, vid_r, -1), fill=0)

    return group_aggregate(Table(cols), key=group_key, aggs=aggs,
                           num_groups=num_groups, strategy=agg_strategy,
                           **(agg_kw or {}))


def _groupjoin_pallas(R, S, key, aggs, num_groups, bkeys, off_r, sz_r, perm_r,
                      probe_col, gk, off_s, sz_s, group_key):
    """Probe+accumulate via the Pallas kernel: ONE fused pass — match
    finding, in-VMEM build-value fetch, and tile-local partial aggregation
    for every aggregate column together — then one sorted segmented
    combine. sum/count/mean over integer group keys."""
    from repro.kernels import ops as kops

    for col, op in aggs.items():
        if op not in ("sum", "mean", "count"):
            raise ValueError(
                f"groupjoin probe_impl='pallas' supports sum/mean/count, got "
                f"{op!r} for {col!r} (use the xla path for min/max)")
    if not jnp.issubdtype(gk.dtype, jnp.integer):
        raise ValueError("groupjoin probe_impl='pallas' needs integer group keys")

    # Stack the sum-bearing columns per side; every column rides the single
    # probe kernel pass (col_sides maps output order -> side + within-side
    # index), and probe columns cost one lazy-transform gather each.
    ks = probe_col(key)
    sum_cols = [(col, op) for col, op in aggs.items() if op != "count"]
    col_sides, pv_cols, bv_cols = [], [], []
    for col, _ in sum_cols:
        if col in S.column_names:
            col_sides.append(("probe", len(pv_cols)))
            pv_cols.append(probe_col(col).astype(jnp.float32))
        else:
            vr_part = prim.apply_permutation(perm_r, R[col])
            col_sides.append(("build", len(bv_cols)))
            bv_cols.append(_value_blocks(vr_part, off_r, sz_r, bkeys.shape[1]))
    gkeys, sums, gcounts, count = kops.groupjoin_probe_agg(
        bkeys, jnp.stack(bv_cols, axis=1) if bv_cols else None, off_r,
        ks, gk, jnp.stack(pv_cols) if pv_cols else None, off_s, sz_s,
        num_groups, col_sides=tuple(col_sides), impl="pallas")

    out: dict[str, jax.Array] = {}
    for (col, op), s in zip(sum_cols, sums):
        out[f"{col}_{op}"] = s
    for col, op in aggs.items():
        if op == "count":
            out[f"{col}_{op}"] = gcounts.astype(jnp.int32)
        elif op == "mean":
            out[f"{col}_{op}"] = out[f"{col}_{op}"] / jnp.maximum(
                gcounts.astype(jnp.float32), 1.0)
    return Table({group_key: gkeys, **out}), count


# ---------------------------------------------------------------------------
# Overflow-checked driver (bits-then-capacity escalation)
# ---------------------------------------------------------------------------
def groupjoin_required_groups(S: Table, *, key: str = "k", group_key: str,
                              agg_strategy: str = "sort") -> int:
    """EXACT lower bound on the accumulator capacity the fused aggregation
    needs: the distinct count of probe-side group keys over rows whose join
    key is valid (matching only removes rows) — or, for the 'scatter'
    strategy, the dense key DOMAIN (max valid group key + 1), since scatter
    indexes the accumulator by key value and drops out-of-domain keys.
    Device-side sort/max + scalar transfer; the capacity analogue of
    `phj_overflowed`'s histogram."""
    if S.num_rows == 0:
        return 0
    gk = S[group_key]
    valid = S[key] != jnp.asarray(KEY_SENTINEL, S[key].dtype)
    sentinel = jnp.asarray(KEY_SENTINEL, gk.dtype)
    if agg_strategy == "scatter":
        return int(jnp.max(jnp.where(valid, gk, sentinel))) + 1
    sk = jnp.sort(jnp.where(valid, gk, sentinel))
    present = sk != sentinel
    boundary = jnp.concatenate([present[:1], (sk[1:] != sk[:-1]) & present[1:]])
    return int(jnp.sum(boundary.astype(jnp.int32)))


def groupjoin_overflowed(R: Table, S: Table, *, key: str = "k",
                         group_key: str, num_groups: int,
                         build_block: int = BUILD_BLOCK,
                         partition_bits: int | None = None,
                         hash_keys: bool = True,
                         agg_strategy: str = "sort"):
    """Host-side check of both static capacities the fused path pads to:
    would any build co-partition exceed its block (more partition bits can
    fix it), and does the accumulator cover every possible group (only a
    larger capacity can). Returns (build_overflow, p_bits, group_overflow,
    required_groups)."""
    build_ovf, p_bits = phj_overflowed(R, key=key, build_block=build_block,
                                       partition_bits=partition_bits,
                                       hash_keys=hash_keys)
    required = groupjoin_required_groups(S, key=key, group_key=group_key,
                                         agg_strategy=agg_strategy)
    return build_ovf, p_bits, required > num_groups, required


def groupjoin_checked(R: Table, S: Table, *, key: str = "k", group_key: str,
                      aggs: dict[str, str], num_groups: int,
                      max_extra_bits: int = 4,
                      build_block: int = BUILD_BLOCK, max_attempts: int = 8,
                      with_report: bool = False, **kw):
    """phj_groupjoin on the resilience ladder (DESIGN.md §13), covering
    both static capacities the fused path pads to: FIRST add partition
    bits while a build co-partition overflows its padded block, THEN grow
    the accumulator when `num_groups` would drop groups — to the exact
    distinct-group count (or the dense key domain for the 'scatter'
    strategy, which indexes the accumulator by key value). Both checks are
    cheap host-side reductions; the re-run uses strictly larger static
    shapes, so the result is exact. Bounded: `EscalationExhausted` instead
    of a silent lossy run.

    `with_report=True` additionally returns the `EscalationReport`."""
    from repro.resilience import EscalationStep, Ladder

    hash_keys = kw.get("hash_keys", True)
    agg_strategy = kw.get("agg_strategy", "sort")
    base_bits = kw.pop("partition_bits", None)
    if base_bits is None:
        base_bits = choose_partition_bits(R.num_rows, build_block)
    knobs = {"partition_bits": base_bits, "num_groups": num_groups}

    def check(kn):
        build_ovf, _, group_ovf, required = groupjoin_overflowed(
            R, S, key=key, group_key=group_key, num_groups=kn["num_groups"],
            build_block=build_block, partition_bits=kn["partition_bits"],
            hash_keys=hash_keys, agg_strategy=agg_strategy)
        parts = []
        if build_ovf:
            parts.append(f"build partition > {build_block} rows")
        if group_ovf:
            parts.append(f"{required} groups > capacity {kn['num_groups']}")
        return (not parts, "; ".join(parts),
                {"build_ovf": build_ovf, "required": required})

    def grow_bits(kn, diag):
        # yields to the capacity rung when the diagnosis shows a pure
        # accumulator overflow (more fan-out cannot create capacity)
        if kn["partition_bits"] >= 20:
            return None
        if diag is not None and not diag["build_ovf"] \
                and diag["required"] > kn["num_groups"]:
            return None
        return {**kn, "partition_bits": kn["partition_bits"] + 1}

    def grow_capacity(kn, diag):
        required = diag["required"] if diag else 0
        if diag is not None and diag["build_ovf"] \
                and required <= kn["num_groups"]:
            return None  # capacity cannot fix a build-block overflow
        if required > kn["num_groups"]:
            # lane-friendly growth, mirroring the engine's capacity rounding
            target = -(-required // 64) * 64
        else:  # forced overflow with nothing actually wrong: double
            target = max(64, kn["num_groups"] * 2)
        return {**kn, "num_groups": target}

    ladder = Ladder("groupjoin", [
        EscalationStep("partition_bits", grow_bits, max_times=max_extra_bits),
        EscalationStep("num_groups", grow_capacity, max_times=3),
    ], max_attempts=max_attempts)
    report = ladder.resolve(knobs, check)
    kn = report.final_knobs
    out = phj_groupjoin(R, S, key=key, group_key=group_key, aggs=aggs,
                        num_groups=kn["num_groups"], build_block=build_block,
                        partition_bits=kn["partition_bits"], **kw)
    return (out, report) if with_report else out

"""Serving launcher: --arch <id>, batched requests through the continuous-
batching engine (reduced configs on CPU; --full for TPU scale)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced_config, list_archs
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    stub = {}
    if cfg.family == "vlm":
        stub["vision_emb"] = jnp.asarray(
            rng.normal(size=(args.max_batch, cfg.vision_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "audio":
        stub["enc_emb"] = jnp.asarray(
            rng.normal(size=(args.max_batch, cfg.encoder_len, cfg.d_model)) * 0.02,
            jnp.float32)

    eng = ServeEngine(cfg, params, max_batch=args.max_batch, max_len=128,
                      batch_stub=stub)
    for r in range(args.requests):
        prompt = rng.integers(3, cfg.vocab_size, size=rng.integers(2, 8)).tolist()
        eng.submit(Request(rid=r, prompt=prompt, max_tokens=args.max_tokens))
    ticks = eng.run()
    print(f"[serve] {args.arch}: {args.requests} requests in {ticks} ticks "
          f"(continuous batching over {args.max_batch} slots)")
    return ticks


if __name__ == "__main__":
    main()

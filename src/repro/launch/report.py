"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON records (baseline and optimized directories)."""
from __future__ import annotations

import glob
import json
from pathlib import Path


def load(d):
    out = {}
    for f in glob.glob(f"{d}/*.json"):
        out[Path(f).stem] = json.load(open(f))
    return out


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(recs, mesh_tag: str) -> str:
    rows = ["| arch | shape | status | live GB/dev | fits 16GB | compile s"
            " | collectives (AG/AR/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|"]
    for tag in sorted(recs):
        r = recs[tag]
        if not tag.endswith(mesh_tag):
            continue
        arch, shape, _ = tag.rsplit("__", 2)
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | skipped | — | — | — | {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | **FAILED** | — | — | — | {r['error'][:48]} |")
            continue
        c = r["collectives"]["counts"]
        coll = "/".join(str(c.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        mb = f" (mb={r['microbatches']})" if r.get("microbatches", 1) > 1 else ""
        rows.append(
            f"| {arch} | {shape}{mb} | ok | {fmt_bytes(r['memory']['live_bytes_per_device'])} "
            f"| {'yes' if r['memory']['fits_v5e_16GB'] else '**NO**'} "
            f"| {r['compile_s']} | {coll} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant"
            " | bound frac (compute/bound) | MODEL/HLO flops"
            " | coll bytes/dev GB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for tag in sorted(recs):
        r = recs[tag]
        if not tag.endswith("__single") or r["status"] != "ok":
            continue
        arch, shape, _ = tag.rsplit("__", 2)
        t = r["roofline"]
        a = r["analytic"]
        coll_gb = max(r["collectives"]["bytes_trip_weighted"],
                      a["collective_bytes_per_device"]) / 1e9
        rows.append(
            f"| {arch} | {shape} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant'][:-2]} "
            f"| {t['roofline_fraction_compute']:.2f} "
            f"| {a['useful_ratio']:.2f} | {coll_gb:.2f} |")
    return "\n".join(rows)


def memory_delta_table(base, final) -> str:
    rows = ["| cell | baseline GB/dev | final GB/dev | Δ |", "|---|---|---|---|"]
    for tag in sorted(final):
        b, f = base.get(tag), final[tag]
        if not (b and b.get("status") == "ok" and f.get("status") == "ok"):
            continue
        bg = b["memory"]["live_bytes_per_device"] / 1e9
        fg = f["memory"]["live_bytes_per_device"] / 1e9
        if abs(bg - fg) / max(bg, 1e-9) > 0.15:
            rows.append(f"| {tag} | {bg:.1f} | {fg:.1f} | {100*(fg-bg)/bg:+.0f}% |")
    return "\n".join(rows)


def summarize(final) -> dict:
    s = {"ok": 0, "skipped": 0, "failed": 0, "nofit": 0}
    for r in final.values():
        if r["status"] == "ok":
            s["ok"] += 1
            if not r["memory"]["fits_v5e_16GB"]:
                s["nofit"] += 1
        elif r["status"] == "skipped":
            s["skipped"] += 1
        else:
            s["failed"] += 1
    return s


if __name__ == "__main__":
    import sys

    final = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    base = load(sys.argv[2] if len(sys.argv) > 2 else "experiments/dryrun_baseline")
    print("## summary", summarize(final))
    print("\n### single-pod (16x16 = 256 chips)\n")
    print(dryrun_table(final, "__single"))
    print("\n### multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(final, "__multi"))
    print("\n### roofline (single-pod)\n")
    print(roofline_table(final))
    print("\n### memory deltas vs baseline\n")
    print(memory_delta_table(base, final))

"""Production mesh construction (multi-pod dry-run spec).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. Call only after the process has its device
topology configured (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def _mk(shape: tuple, axes: tuple):
    # jax.sharding.AxisType (explicit-sharding API) only exists on jax
    # >= 0.5; every axis is Auto there by default, so omitting it on older
    # versions is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests/elastic rescale."""
    return _mk(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for v in dict(mesh.shape).values():
        n *= v
    return n

"""Production mesh construction (multi-pod dry-run spec).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. Call only after the process has its device
topology configured (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for tests/elastic rescale."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def mesh_chips(mesh) -> int:
    n = 1
    for v in dict(mesh.shape).values():
        n *= v
    return n

"""Training launcher: --arch <id> --steps N [--mesh none|single|multi].

CPU-scale runs use reduced configs by default (--full for the real ones —
only sensible on a TPU slice). Wires together: config registry, sharded (or
single-device) train step, deterministic data pipeline, fault-tolerant loop
with checkpoint/resume, straggler logging.
"""
from __future__ import annotations

import argparse
import functools

import jax

from repro.configs.base import get_config, get_reduced_config, list_archs
from repro.data.synthetic import make_batch_fn
from repro.models import model as M
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import AdamW, cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true", help="full config (TPU scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced_config(args.arch)
    if cfg.ssm is not None and args.seq % cfg.ssm.chunk:
        args.seq = -(-args.seq // cfg.ssm.chunk) * cfg.ssm.chunk
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=10, total=args.steps),
                master_weights=False)
    opt_state = opt.init(params)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(M.loss_fn, cfg), has_aux=True
        )(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, dict(metrics, loss=loss, grad_norm=gnorm)

    step = jax.jit(step, donate_argnums=(0, 1))
    data_iter = make_batch_fn(cfg.vocab_size, args.batch, args.seq,
                              seed=args.seed, cfg=cfg)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every)
    params, opt_state, report = train_loop(step, params, opt_state, data_iter, loop_cfg)
    print(f"[train] {args.arch}: {report.steps_run} steps, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
    return report


if __name__ == "__main__":
    main()

"""Roofline analysis from the compiled dry-run artifact (EXPERIMENTS.md
§Roofline).

Three terms, per (arch x shape x mesh), in seconds:

    compute    = FLOPs / (chips x 197e12)        [bf16 peak, v5e]
    memory     = HBM bytes / (chips x 819e9)
    collective = wire bytes / (chips x 50e9)     [per-link ICI]

Sources:
  * memory_analysis(): per-device argument/temp bytes (fits-in-HBM proof).
  * HLO text: every all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute operand size. Ops inside while bodies are multiplied
    by the loop trip count, recovered from the largest integer constant
    compared in the loop condition (best-effort; cross-checked against the
    analytic model).
  * HLO dot ops inside the scanned body give a per-layer FLOPs cross-check;
    totals come from the analytic model in models/flops.py because XLA's
    cost_analysis() counts a scanned body once (verified; see §Method).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip, TPU v5e
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s/link
HBM_CAP = 16e9  # v5e HBM per chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[\w\[\]{},\s]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_static: float  # summed once
    bytes_weighted: float  # x while-loop trip counts
    per_op: list


def _computation_spans(text: str):
    """Map computation name -> (start, end) character span."""
    spans = {}
    for m in re.finditer(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{", text, re.M):
        name = m.group(1).lstrip("%")
        start = m.end()
        depth = 1
        i = start
        while i < len(text) and depth:
            if text[i] == "{":
                depth += 1
            elif text[i] == "}":
                depth -= 1
            i += 1
        spans[name] = (start, i)
    return spans


def _while_trip_counts(text: str, spans):
    """body computation name -> estimated trip count."""
    trips = {}
    for m in re.finditer(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", text):
        cond, body = m.group(1), m.group(2)
        span = spans.get(cond)
        trip = 1
        if span:
            consts = [int(c) for c in re.findall(r"constant\((\d+)\)", text[span[0]:span[1]])]
            consts = [c for c in consts if 1 < c <= 1_000_000]
            if consts:
                trip = max(consts)
        trips[body] = trip
    return trips


def parse_collectives(hlo_text: str) -> CollectiveStats:
    spans = _computation_spans(hlo_text)
    trips = _while_trip_counts(hlo_text, spans)

    def multiplier(pos: int) -> int:
        mult = 1
        for name, (s, e) in spans.items():
            if s <= pos < e and name in trips:
                mult *= trips[name]
        return mult

    counts: dict = {}
    b_static = 0.0
    b_weighted = 0.0
    per_op = []
    for m in _COLL_RE.finditer(hlo_text):
        out_type, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(out_type)
        mult = multiplier(m.start())
        counts[kind] = counts.get(kind, 0) + 1
        b_static += nbytes
        b_weighted += nbytes * mult
        per_op.append({"kind": kind, "bytes": nbytes, "trip_mult": mult})
    return CollectiveStats(counts, b_static, b_weighted, per_op)


def parse_dot_flops(hlo_text: str) -> dict:
    """Best-effort FLOPs of dot ops, weighted by while trip counts.

    Works on the pre-optimization (lowered) HLO where contracting dims are
    explicit in the `dot` attributes."""
    spans = _computation_spans(hlo_text)
    trips = _while_trip_counts(hlo_text, spans)

    def multiplier(pos: int) -> int:
        mult = 1
        for name, (s, e) in spans.items():
            if s <= pos < e and name in trips:
                mult *= trips[name]
        return mult

    total = 0.0
    total_weighted = 0.0
    dot_re = re.compile(
        r"=\s*(\w+\[[\d,]*\])[^\n]*?\bdot\((?:[^)]*)\)[^\n]*?"
        r"lhs_contracting_dims=\{([\d,]*)\}", )
    # contraction size needs lhs shape: capture full line
    line_re = re.compile(r"^.*\bdot\(.*$", re.M)
    for lm in line_re.finditer(hlo_text):
        line = lm.group(0)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        out_dt, out_dims = shapes[0]
        out_n = 1
        for d in out_dims.split(","):
            if d:
                out_n *= int(d)
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        contract = 1
        if cm and len(shapes) >= 2:
            lhs_dims = [int(x) for x in shapes[1][1].split(",") if x]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contract *= lhs_dims[int(ci)]
        fl = 2.0 * out_n * contract
        total += fl
        total_weighted += fl * multiplier(lm.start())
    return {"dot_flops_static": total, "dot_flops_weighted": total_weighted}


def roofline_terms(flops_total: float, hbm_bytes_dev: float, coll_bytes_dev: float,
                   chips: int) -> dict:
    compute = flops_total / (chips * PEAK_FLOPS)
    memory = hbm_bytes_dev / HBM_BW
    collective = coll_bytes_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["roofline_fraction_compute"] = compute / bound if bound else 0.0
    terms["step_lower_bound_s"] = bound
    return terms

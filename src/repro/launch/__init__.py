"""Launchers and analysis: mesh construction, train/serve entry points,
multi-pod dry-run, roofline. NOTE: launch.dryrun pins XLA_FLAGS at import
(512 fake devices) — import it only in a dedicated process."""

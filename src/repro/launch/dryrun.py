import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count at first init, and the multi-pod dry-run needs 512
# placeholder host devices to build the production mesh. Do not set this
# anywhere global — smoke tests and benches must see 1 device.

"""Multi-pod dry-run (deliverable e): for every (architecture x input-shape
x mesh) cell, `.lower().compile()` the sharded step on the production mesh
and record memory/cost/collective analyses for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system; skipped cells (long_500k on full-attention archs)
are recorded with their DESIGN.md §5 rationale.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import (SHAPES, cell_is_runnable, get_config,
                                list_archs)
from repro.dist import sharding as SH
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.models import flops as FL
from repro.models import model as M
from repro.models.params import abstract_from_template
from repro.train.optimizer import AdamW, AdamWState
from repro.train import step as STEP


def abstract_opt_state(tmpl, master=True):
    f32 = abstract_from_template(tmpl, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=f32,
        nu=abstract_from_template(tmpl, jnp.float32),
        master=abstract_from_template(tmpl, jnp.float32) if master else None,
    )


# Gradient-accumulation microbatch count per train cell: the memory knob
# for big-activation architectures (chosen so live bytes < 16GB; see
# EXPERIMENTS.md §Perf iteration 4). Default 1.
MICROBATCHES = {
    "mixtral-8x7b": 4,
    "qwen2-moe-a2.7b": 4,
    "llama-3.2-vision-11b": 4,
    "zamba2-2.7b": 4,
    "starcoder2-7b": 2,
    "whisper-large-v3": 2,
    "granite-8b": 2,
    "h2o-danube-3-4b": 2,
}


# Named sharding variants for the §Perf hillclimb. "flat_dp" retires TP
# entirely: both mesh axes do DP/FSDP (for small models whose TP activation
# all-reduces dominate); "ep" maps the expert dim onto the model axis.
VARIANTS = {
    "baseline": {},
    "flat_dp": {
        "param": {"heads": None, "kv_heads": None, "mlp": None, "vocab": None,
                  "inner": None, "embed": ("data", "model"),
                  "expert_mlp": ("data", "model"), "expert_embed": None},
        "act": {"batch": ("pod", "data", "model"), "seq": None, "heads": None,
                "kv_heads": None, "mlp": None, "vocab": None, "inner": None,
                "head_dim": None, "tokens": ("pod", "data", "model")},
    },
    "ep": {
        "param": {"experts": "model", "expert_mlp": ("data",), "expert_embed": None},
        "act": {},
    },
    # serving: weights TP-only resident (no FSDP), so decode steps carry no
    # per-step parameter all-gathers
    "serve_tp": {
        "param": {"embed": None, "expert_embed": None},
        "act": {},
    },
    # training: remat policy saves matmul outputs (backward multiplier 4->3)
    "remat_dots": {"param": {}, "act": {}},
    "flat_dp_dots": {},  # filled below: flat_dp sharding + dots remat
}
VARIANTS["flat_dp_dots"] = VARIANTS["flat_dp"]


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             rule_overrides: dict | None = None, dtype=jnp.bfloat16,
             microbatches: int | None = None, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "pod2x16x16" if multi_pod else "16x16"}
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    rules = SH.default_rules(multi_pod=multi_pod, fsdp=True,
                             seq_shard=(shape.kind in ("train", "prefill")))
    if variant != "baseline":
        rec["variant"] = variant
        vo = VARIANTS[variant]
        rule_overrides = {
            "param": {**vo.get("param", {}), **(rule_overrides or {}).get("param", {})},
            "act": {**vo.get("act", {}), **(rule_overrides or {}).get("act", {})},
        }
    if rule_overrides:
        rules = SH.ShardingRules(param={**rules.param, **rule_overrides.get("param", {})},
                                 act={**rules.act, **rule_overrides.get("act", {})})

    tmpl = M.template(cfg)
    aparams = abstract_from_template(tmpl, dtype)
    t0 = time.time()
    try:
        if shape.kind == "train":
            opt = AdamW(master_weights=True)
            mbs = microbatches if microbatches is not None else MICROBATCHES.get(arch, 1)
            rec["microbatches"] = mbs
            remat = "dots" if variant in ("remat_dots", "flat_dp_dots") else True
            jitted, _psh, _bsh = STEP.build_train_step(cfg, mesh, rules, opt,
                                                       microbatches=mbs,
                                                       remat=remat)
            aopt = abstract_opt_state(tmpl)
            abatch = M.input_specs(cfg, shape, dtype=dtype)
            lowered = jitted.lower(aparams, aopt, abatch)
        elif shape.kind == "prefill":
            jitted, _psh, _bsh = STEP.build_prefill_step(cfg, mesh, rules)
            abatch = M.input_specs(cfg, shape, dtype=dtype)
            lowered = jitted.lower(aparams, abatch)
        else:  # decode
            specs = M.input_specs(cfg, shape, dtype=dtype)
            jitted, _psh, _csh, _tsh = STEP.build_serve_step(
                cfg, mesh, rules, shape.global_batch, shape.seq_len
            )
            lowered = jitted.lower(aparams, specs["cache"], specs["token"], specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failing cell is a bug: record it loudly
        rec.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        }
        live = ma.argument_size_in_bytes + ma.temp_size_in_bytes + \
            ma.output_size_in_bytes - ma.alias_size_in_bytes
        mem["live_bytes_per_device"] = int(live)
        mem["fits_v5e_16GB"] = bool(live < RL.HBM_CAP)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    cost = {"hlo_flops_per_device_body_once": float(ca.get("flops", 0.0)),
            "hlo_bytes_accessed_per_device_body_once": float(ca.get("bytes accessed", 0.0))}

    hlo = compiled.as_text()
    colls = RL.parse_collectives(hlo)
    dots = RL.parse_dot_flops(hlo)

    est = FL.estimate(cfg, shape, dict(mesh.shape),
                      remat_factor=3.0 if variant in ("remat_dots", "flat_dp_dots") else 4.0)
    # Variants change the collective schedule away from the analytic model's
    # assumptions: trust the HLO-parsed bytes there.
    if variant == "baseline":
        coll_bytes_dev = max(colls.bytes_weighted, est.collective_bytes_per_device)
    else:
        coll_bytes_dev = colls.bytes_weighted
    terms = RL.roofline_terms(est.flops_total, est.hbm_bytes_per_device,
                              coll_bytes_dev, chips)
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost_analysis=cost,
        collectives={"counts": colls.counts,
                     "bytes_static": colls.bytes_static,
                     "bytes_trip_weighted": colls.bytes_weighted},
        hlo_dot_flops=dots,
        analytic={
            "flops_total": est.flops_total,
            "flops_layer_fwd": est.flops_layer_fwd,
            "model_flops_6ND": est.model_flops,
            "useful_ratio": est.model_flops / est.flops_total if est.flops_total else 0.0,
            "hbm_bytes_per_device": est.hbm_bytes_per_device,
            "collective_bytes_per_device": est.collective_bytes_per_device,
        },
        roofline=terms,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"[skip-cached] {tag}")
                    continue
                print(f"[run] {tag}", flush=True)
                rec = run_cell(arch, shape, mp, variant=args.variant,
                               microbatches=args.microbatches)
                path.write_text(json.dumps(rec, indent=1, default=str))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s"
                             f" live={rec['memory'].get('live_bytes_per_device', 0)/1e9:.2f}GB"
                             f" dom={rec['roofline']['dominant']}")
                    print(f"  memory_analysis: {rec['memory']}")
                    print(f"  cost_analysis:   {rec['cost_analysis']}")
                elif status == "FAILED":
                    extra = " " + rec["error"][:160]
                print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()

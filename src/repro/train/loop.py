"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
preemption handling, gradient-accumulation microbatching.

Designed for the 1000+-node posture (DESIGN.md §6):
  * crash-resume: every `ckpt_every` steps the full (params, opt, data)
    state is saved atomically; on start the loop resumes from LATEST —
    killing the process at any point loses at most `ckpt_every` steps
    (exercised by tests/test_train_loop.py via two half-runs == one run).
  * preemption: SIGTERM flips a flag; the loop checkpoints and exits 0 so
    the scheduler can reschedule without losing work.
  * straggler mitigation: per-step wall time is tracked against a rolling
    median; steps slower than `straggler_factor`x are logged with their
    step index — on a real cluster this feeds the health controller that
    evicts or re-shards around slow hosts (single-process here, so the
    policy is advisory + tested at the detection level).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable

import jax

from . import checkpoint as CKPT


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_accum: int = 1


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    final_step: int
    losses: list
    straggler_steps: list
    resumed_from: int | None
    preempted: bool = False


class _Preemption:
    def __init__(self):
        self.flag = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            pass  # not in main thread (tests)

    def _handler(self, *_):
        self.flag = True


def train_loop(step_fn: Callable, params, opt_state, data_iter, cfg: LoopConfig,
               *, state_extra: dict | None = None,
               log: Callable = print) -> tuple:
    """Runs step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    data_iter must be resumable: it is constructed from a step index by the
    caller (deterministic synthetic pipeline), so resume replays nothing.
    Returns (params, opt_state, LoopReport).
    """
    start_step = 0
    resumed_from = None
    if cfg.ckpt_dir and CKPT.latest_step(cfg.ckpt_dir) is not None:
        (params, opt_state), start_step, _ = CKPT.restore_checkpoint(
            cfg.ckpt_dir, (params, opt_state)
        )
        resumed_from = start_step
        log(f"[loop] resumed from step {start_step}")

    preempt = _Preemption()
    losses, stragglers, times = [], [], []
    step = start_step
    while step < cfg.total_steps:
        batch = data_iter(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        step += 1
        losses.append(float(metrics["loss"]))
        if len(times) >= 5:
            med = statistics.median(times[-50:])
            if dt > cfg.straggler_factor * med:
                stragglers.append((step, dt, med))
                log(f"[straggler] step {step}: {dt:.3f}s vs median {med:.3f}s")
        if step % cfg.log_every == 0:
            log(f"[loop] step {step} loss={losses[-1]:.4f} ({dt*1e3:.0f} ms)")
        if cfg.ckpt_dir and (step % cfg.ckpt_every == 0 or step == cfg.total_steps
                             or preempt.flag):
            CKPT.save_checkpoint(cfg.ckpt_dir, step, (params, opt_state),
                                 extra=state_extra, keep=cfg.keep)
        if preempt.flag:
            log(f"[loop] preempted at step {step}; checkpointed and exiting")
            break

    report = LoopReport(
        steps_run=step - start_step, final_step=step, losses=losses,
        straggler_steps=stragglers, resumed_from=resumed_from,
        preempted=preempt.flag,
    )
    return params, opt_state, report

"""Elastic scaling: re-shard a training state onto a different mesh.

When nodes join/leave, the controller rebuilds the mesh and calls
`reshard_state`: every leaf is device_put onto its sharding under the new
mesh (jax moves/reshuffles data as needed — on a real cluster this is the
all-gather + re-slice path). The global batch stays fixed; per-device batch
changes with the data-axis size, so training dynamics are unchanged
(verified bit-wise for params in tests/test_elastic.py).
"""
from __future__ import annotations

import jax

from repro.dist import sharding as SH
from repro.models import model as M
from repro.train.optimizer import AdamWState


def reshard_state(cfg, params, opt_state, new_mesh, rules=None):
    """Returns (params, opt_state) resident on new_mesh."""
    rules = rules or SH.default_rules(multi_pod=("pod" in dict(new_mesh.shape)))
    tmpl = M.template(cfg)
    psh = SH.named_shardings(tmpl, new_mesh, rules)
    params2 = jax.tree_util.tree_map(jax.device_put, params, psh)
    rep = jax.sharding.NamedSharding(new_mesh, jax.sharding.PartitionSpec())
    opt2 = AdamWState(
        step=jax.device_put(opt_state.step, rep),
        mu=jax.tree_util.tree_map(jax.device_put, opt_state.mu, psh),
        nu=jax.tree_util.tree_map(jax.device_put, opt_state.nu, psh),
        master=(jax.tree_util.tree_map(jax.device_put, opt_state.master, psh)
                if opt_state.master is not None else None),
    )
    return params2, opt2


def validate_batch_divisibility(global_batch: int, new_mesh) -> bool:
    shape = dict(new_mesh.shape)
    dp = shape.get("data", 1) * shape.get("pod", 1)
    return global_batch % dp == 0

"""Fault-tolerant checkpointing: atomic, manifest-driven, resumable.

Layout:  <dir>/step_000123/
            manifest.json   {step, leaf paths, shapes/dtypes, mesh metadata}
            arr_<i>.npy     one file per pytree leaf (host-gathered)
         <dir>/LATEST       -> atomic pointer file ("step_000123")

Writes go to a temp directory then os.replace() — a crash mid-write can
never corrupt the last good checkpoint (restart-safety is exercised by
tests/test_train_substrate.py). Per-leaf np.save keeps memory bounded; on a real
multi-host cluster each process would save its addressable shards
(process-local leaves) — the manifest already records mesh/sharding metadata
for that extension.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in kp) for kp, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir, step: int, state, *, extra: dict | None = None,
                    keep: int = 3):
    """Atomically persist `state` (any pytree of arrays) at `step`."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    name = f"step_{step:08d}"
    final = ckpt_dir / name
    paths, leaves, _ = _flatten_with_paths(state)

    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            np.save(tmp / f"arr_{i}.npy", arr)
            manifest["leaves"].append(
                {"i": i, "path": p, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = ckpt_dir / ".LATEST.tmp"
    ptr_tmp.write_text(name)
    os.replace(ptr_tmp, ckpt_dir / "LATEST")
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Background-thread checkpoint writer: the train loop hands over a
    host-fetched snapshot and keeps stepping while the previous save is
    written (the standard overlap on real clusters; device_get happens
    synchronously so the arrays are immutable snapshots)."""

    def __init__(self, ckpt_dir, keep: int = 3):
        import threading

        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: "threading.Thread | None" = None
        self._threading = threading

    def save(self, step: int, state, *, extra=None):
        self.wait()  # at most one in-flight save
        snapshot = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), state)
        self._thread = self._threading.Thread(
            target=save_checkpoint, args=(self.ckpt_dir, step, snapshot),
            kwargs={"extra": extra, "keep": self.keep}, daemon=True,
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(d for d in ckpt_dir.iterdir() if d.name.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    ptr = Path(ckpt_dir) / "LATEST"
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (Path(ckpt_dir) / name / "manifest.json").exists():
        # pointer ahead of a crashed write: fall back to newest complete dir
        cands = sorted(
            d for d in Path(ckpt_dir).iterdir()
            if d.name.startswith("step_") and (d / "manifest.json").exists()
        )
        return int(cands[-1].name.split("_")[1]) if cands else None
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir, state_like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of `state_like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (state, step, extra)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    paths, leaves, treedef = _flatten_with_paths(state_like)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    out = []
    sh_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    for p, like, sh in zip(paths, leaves, sh_flat):
        meta = by_path[p]
        arr = np.load(d / f"arr_{meta['i']}.npy")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, manifest["step"], manifest.get("extra", {})

"""Sharded train/serve step builders.

`build_train_step` / `build_serve_step` compose the model with the optimizer
under a mesh + sharding-rule context and return jit'd callables with explicit
in/out shardings and donated buffers. The same builders feed the training
loop (real arrays) and the multi-pod dry-run (ShapeDtypeStructs only).

Distribution strategy (DESIGN.md §6): DP over ('pod','data'), FSDP parameter
sharding over 'data', TP over 'model', optional SP/EP through rule
overrides. Gradient reductions are inserted by XLA SPMD from the sharding
propagation — there is no hand-written pmean; the collective schedule is
inspected by the roofline pass instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig
from repro.dist import sharding as SH
from repro.models import model as M

from .optimizer import AdamW, AdamWState


def batch_shardings(cfg: ArchConfig, mesh, rules: SH.ShardingRules, kind: str):
    """NamedShardings for the input batch of a train/prefill step."""
    bx = rules.act.get("batch")
    if isinstance(bx, tuple):
        bx = tuple(a for a in bx if a in mesh.shape) or None
    sh = {"tokens": NamedSharding(mesh, PartitionSpec(bx, None))}
    if cfg.family == "vlm":
        sh["vision_emb"] = NamedSharding(mesh, PartitionSpec(bx, None, None))
    if cfg.family == "audio":
        sh["enc_emb"] = NamedSharding(mesh, PartitionSpec(bx, None, None))
    return sh


def cache_shardings(cfg: ArchConfig, mesh, rules: SH.ShardingRules, b: int, w: int):
    """Decode-cache NamedShardings. Uses the same spec builder as shard_act
    so the cache layout always matches the decode attention layout (else
    GSPMD reshards the whole KV cache every step — layers.py)."""
    from repro.models.params import axis_spec

    axes = M.cache_axes(cfg, b, w)
    shapes = M.cache_shapes(cfg, b, w)
    mesh_shape = dict(mesh.shape)

    def spec(shape_sds, axleaf):
        return NamedSharding(
            mesh, axis_spec(shape_sds.shape, axleaf.axes, rules.act, mesh_shape))

    return jax.tree_util.tree_map(spec, shapes, axes)


def opt_state_shardings(param_sh, mesh):
    rep = NamedSharding(mesh, PartitionSpec())
    return AdamWState(
        step=rep,
        mu=param_sh,
        nu=jax.tree_util.tree_map(lambda s: s, param_sh),
        master=jax.tree_util.tree_map(lambda s: s, param_sh),
    )


def build_train_step(cfg: ArchConfig, mesh, rules: SH.ShardingRules, opt: AdamW,
                     *, remat: bool = True, donate: bool = True,
                     microbatches: int = 1):
    """Returns (step_fn_jitted, param_shardings, batch_shardings_dict).

    step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 runs gradient accumulation: the global batch is split
    into M sequential microbatches inside the jitted step (activation peak
    divides by ~M at the cost of M x parameter traffic — the memory-vs-
    bandwidth knob used by the big-activations cells, EXPERIMENTS.md §Perf
    iteration 4)."""
    tmpl = M.template(cfg)
    psh = SH.named_shardings(tmpl, mesh, rules)
    osh = opt_state_shardings(psh, mesh)
    bsh = batch_shardings(cfg, mesh, rules, "train")
    rep = NamedSharding(mesh, PartitionSpec())
    loss_fn = functools.partial(M.loss_fn, cfg, remat=remat)

    def step(params, opt_state, batch):
        with SH.sharding_ctx(mesh, rules):
            if microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                        + x.shape[1:]), batch)
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def mb_body(carry, b_i):
                    gacc, lacc, aacc = carry
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b_i)
                    gacc = jax.tree_util.tree_map(
                        lambda a, gg: a + gg.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + l, aacc + m["aux"]), None

                (gsum, lsum, asum), _ = jax.lax.scan(
                    mb_body, (g0, jnp.float32(0), jnp.float32(0)), mb)
                grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
                loss = lsum / microbatches
                metrics = {"ce": loss - asum / microbatches, "aux": asum / microbatches}
            new_params, new_state, gnorm = opt.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return new_params, new_state, metrics

    jitted = jax.jit(
        step,
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, jax.tree_util.tree_map(
            lambda _: rep, {"ce": 0, "aux": 0, "loss": 0, "grad_norm": 0})),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, psh, bsh


def build_serve_step(cfg: ArchConfig, mesh, rules: SH.ShardingRules, b: int, w: int,
                     *, donate: bool = True):
    """serve(params, cache, token, pos) -> (logits, cache), jitted+sharded."""
    tmpl = M.template(cfg)
    psh = SH.named_shardings(tmpl, mesh, rules)
    csh = cache_shardings(cfg, mesh, rules, b, w)
    bx = rules.act.get("batch")
    if isinstance(bx, tuple):
        bx = tuple(a for a in bx if a in mesh.shape) or None
    if b % SH._mesh_axis_size(mesh, bx) != 0:
        bx = None
    tok_sh = NamedSharding(mesh, PartitionSpec(bx))
    rep = NamedSharding(mesh, PartitionSpec())
    logits_sh = NamedSharding(mesh, PartitionSpec(bx, None))

    def serve(params, cache, token, pos):
        with SH.sharding_ctx(mesh, rules):
            return M.decode_step(cfg, params, cache, token, pos)

    jitted = jax.jit(
        serve,
        in_shardings=(psh, csh, tok_sh, rep),
        out_shardings=(logits_sh, csh),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, psh, csh, tok_sh


def build_prefill_step(cfg: ArchConfig, mesh, rules: SH.ShardingRules):
    """prefill(params, batch) -> logits (no optimizer), for inference-prefill
    cells; remat off, forward only."""
    tmpl = M.template(cfg)
    psh = SH.named_shardings(tmpl, mesh, rules)
    bsh = batch_shardings(cfg, mesh, rules, "prefill")

    def prefill(params, batch):
        with SH.sharding_ctx(mesh, rules):
            logits, _aux = M.forward(cfg, params, batch, remat=False)
            return logits

    jitted = jax.jit(prefill, in_shardings=(psh, bsh))
    return jitted, psh, bsh

"""Training substrate: optimizer, sharded step builders, fault-tolerant
loop, checkpointing, elastic remesh."""

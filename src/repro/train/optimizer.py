"""AdamW + gradient clipping + LR schedules, pure-pytree (no optax dep).

Supports bf16 parameters with f32 master weights: when `master_weights` is
on, the optimizer state carries the f32 copy (the bf16 params are just the
compute view), matching the HBM accounting used in the roofline analysis
(12 bytes/param of optimizer state + 2 bytes/param weights).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object
    master: object  # f32 master params or None


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    master_weights: bool = False

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = (
            jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
            if self.master_weights
            else None
        )
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree_util.tree_map(jnp.copy, zeros), master)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, grad_norm)."""
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(g32)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        ref = state.master if self.master_weights else params

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            return (p.astype(jnp.float32) - lr * (u + self.weight_decay * p.astype(jnp.float32)))

        new_master = jax.tree_util.tree_map(upd, ref, mu, nu)
        new_params = jax.tree_util.tree_map(
            lambda nm, p: nm.astype(p.dtype), new_master, params
        )
        return new_params, AdamWState(
            step, mu, nu, new_master if self.master_weights else None
        ), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)

    return f


def linear_warmup(peak_lr: float, warmup: int):
    return lambda step: peak_lr * jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)

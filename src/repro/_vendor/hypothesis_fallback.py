"""Minimal offline stand-in for `hypothesis` (given/settings/strategies).

The CI container cannot pip-install, so the property tests would otherwise
fail at collection. This shim replays each @given test over `max_examples`
pseudo-random draws from a *seeded* numpy generator — deterministic across
runs (seed derives from the test's qualified name and the example index), so
a failure reproduces exactly. It is NOT hypothesis: no shrinking, no
database, no coverage-guided generation — just honest randomized testing of
the same properties.

Installed into sys.modules as `hypothesis` / `hypothesis.strategies` by
`install()`, which tests/conftest.py calls only when the real package is
missing. If hypothesis is ever installable, nothing here runs.

Supported surface (what this repo's tests use, plus the obvious neighbors):
  given (kwargs form), settings(max_examples, deadline), assume,
  strategies.{integers, sampled_from, booleans, floats, lists, tuples,
  just, one_of}, HealthCheck.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when its precondition fails."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    """A strategy is just a draw function over a numpy Generator."""

    def __init__(self, draw, label="strategy"):
        self._draw = draw
        self._label = label

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              f"{self._label}.map")

    def filter(self, pred, max_tries: int = 100):
        def _draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption(f"filter on {self._label} never satisfied")

        return SearchStrategy(_draw, f"{self._label}.filter")

    def __repr__(self):
        return self._label


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})")


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rng: elements[int(rng.integers(len(elements)))],
                          f"sampled_from({elements!r})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(2)), "booleans()")


def floats(min_value: float = -1e9, max_value: float = 1e9, *,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64) -> SearchStrategy:
    def _draw(rng):
        return float(rng.uniform(min_value, max_value))

    return SearchStrategy(_draw, f"floats({min_value}, {max_value})")


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def one_of(*strategies) -> SearchStrategy:
    flat = strategies[0] if len(strategies) == 1 and isinstance(
        strategies[0], (list, tuple)) else strategies
    return SearchStrategy(
        lambda rng: flat[int(rng.integers(len(flat)))].draw(rng), "one_of")


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def _draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return SearchStrategy(_draw, "lists")


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies),
                          "tuples")


class HealthCheck:
    """Accepted and ignored (suppress_health_check=... compatibility)."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"
    all = classmethod(lambda cls: [])


class settings:
    """Decorator recording max_examples; deadline and health checks are
    accepted for signature compatibility and ignored (no wall-clock budget
    enforcement in the shim)."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*args, **strategy_kwargs):
    """@given(name=strategy, ...). Positional strategies are not supported
    (this repo only uses the kwargs form)."""
    if args:
        raise TypeError("hypothesis fallback shim supports only @given(**kwargs)")

    def decorate(fn):
        sig = inspect.signature(fn)
        unknown = set(strategy_kwargs) - set(sig.parameters)
        if unknown:
            raise TypeError(f"@given got undefined arguments {sorted(unknown)}")
        passthrough = [p for name, p in sig.parameters.items()
                       if name not in strategy_kwargs]
        seed_base = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

        @functools.wraps(fn)
        def wrapper(*wargs, **wkwargs):
            cfg = getattr(wrapper, "_fallback_settings", None)
            n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            ran = 0
            for i in range(n):
                rng = np.random.default_rng((seed_base, i))
                drawn = None
                try:
                    # draws sit inside the try: a .filter() that exhausts its
                    # tries skips the example exactly like a failed assume()
                    drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                    fn(*wargs, **wkwargs, **drawn)
                    ran += 1
                except UnsatisfiedAssumption:
                    continue
                except Exception:
                    print(f"Falsifying example ({fn.__qualname__}, "
                          f"example {i}): {drawn!r}", file=sys.stderr)
                    raise
            if n and not ran:
                raise UnsatisfiedAssumption(
                    f"{fn.__qualname__}: every example failed assume()")

        # Hide the drawn parameters from pytest's fixture resolution while
        # keeping any real fixtures (e.g. rng) visible. __signature__ stops
        # inspect from following __wrapped__ back to the original.
        wrapper.__signature__ = sig.replace(parameters=passthrough)
        return wrapper

    return decorate


def install():
    """Register this shim as `hypothesis` + `hypothesis.strategies` in
    sys.modules. Call only after a real `import hypothesis` failed."""
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats", "lists",
                 "tuples", "just", "one_of"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    return hyp

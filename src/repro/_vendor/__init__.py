"""Vendored fallbacks for optional third-party packages that the offline
CI image cannot install. Nothing here is imported unless the real package
is absent (see tests/conftest.py)."""

"""Pallas contract checker: every kernel's compiled shape is statically
auditable from its `pallas_call` eqn, before anything runs on a device.

Three checks per kernel (DESIGN.md §11):

  * VMEM fit — Σ block_shape × itemsize over every block mapping, times a
    double-buffering factor, must fit the per-backend VMEM budget. A block
    spec that exceeds it compiles fine in interpret mode and then OOMs the
    first time it meets real silicon.
  * Grid-output aliasing — two grid steps whose output index_map lands on
    the same block. On GPU-style parallel grids this is the CUDA-atomics
    race the paper works around; on TPU the grid is sequential so a kernel
    may *deliberately* revisit a block to accumulate (histogram does), but
    it must declare that (`allow_output_revisit`) so the hazard is a
    stated contract instead of an accident. Output index_maps that depend
    on scalar-prefetch data are flagged too: their injectivity cannot be
    proven statically.
  * Scatter discipline — kernel bodies must not contain float scatter-add
    primitives (non-deterministic accumulation order on parallel
    backends); the repo's kernels accumulate via one-hot matmuls and
    sorted segmented sums instead (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
from jax import core as jcore
import numpy as np

from .contracts import FloatScatterViolation, GridAliasViolation, VmemBudgetViolation
from .jaxpr_audit import SCATTER_COMBINE_PRIMS, _as_jaxpr, _is_float, walk_eqns

# Per-backend VMEM budgets (bytes). v5e cores carry ~16 MiB of VMEM
# (pallas guide); leave headroom for the compiler's own scratch.
VMEM_BUDGETS = {"tpu_v5e": 16 * 2**20, "tpu_v4": 16 * 2**20}
DEFAULT_BACKEND = "tpu_v5e"
DOUBLE_BUFFER = 2  # pipelined grids keep two copies of each block in flight
MAX_GRID_POINTS = 1 << 14  # cap on exhaustive index_map enumeration


@dataclasses.dataclass
class KernelLintReport:
    """One pallas_call, statically judged."""
    name: str
    grid: tuple
    vmem_bytes: int
    vmem_budget: int
    aliased_output_blocks: int
    data_dependent_output_map: bool
    kernel_scatter_adds: int
    violations: list

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["violations"] = [f"{type(v).__name__}: {v}" for v in self.violations]
        d["grid"] = list(self.grid)
        return d


def _block_bytes(bm) -> int:
    dtype = np.dtype(bm.array_shape_dtype.dtype)
    size = 1
    for d in bm.block_shape:
        size *= int(d) if isinstance(d, (int, np.integer)) else 1
    return size * dtype.itemsize


def _is_output(bm, index: int, num_inputs: int) -> bool:
    origin = str(getattr(bm, "origin", ""))
    if "output" in origin:
        return True
    if "input" in origin or "arg" in origin:
        return False
    return index >= num_inputs


def _depends_on(jaxpr, tainted_vars) -> bool:
    """True if any jaxpr output is data-dependent on `tainted_vars`."""
    tainted = set(map(id, tainted_vars))
    for eqn in jaxpr.eqns:
        if any(not isinstance(v, jcore.Literal) and id(v) in tainted
               for v in eqn.invars):
            tainted.update(id(v) for v in eqn.outvars)
    return any(not isinstance(v, jcore.Literal) and id(v) in tainted
               for v in jaxpr.outvars)


def _eval_index_map(closed, grid_point, extra_avals):
    dummies = [np.zeros(a.shape, a.dtype) for a in extra_avals]
    outs = jcore.eval_jaxpr(closed.jaxpr, closed.consts,
                            *map(np.int32, grid_point), *dummies)
    return tuple(int(np.asarray(o)) for o in outs)


def lint_pallas_eqn(eqn, *, name: str, backend: str = DEFAULT_BACKEND,
                    allow_output_revisit: bool = False) -> KernelLintReport:
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    budget = VMEM_BUDGETS.get(backend, VMEM_BUDGETS[DEFAULT_BACKEND])
    violations: list = []

    vmem = DOUBLE_BUFFER * sum(_block_bytes(bm) for bm in gm.block_mappings)
    if vmem > budget:
        violations.append(VmemBudgetViolation(
            f"{name}: blocks need {vmem} bytes of VMEM "
            f"(x{DOUBLE_BUFFER} double-buffered) vs {budget} on {backend}"))

    # output index_map injectivity over the full grid
    num_inputs = int(getattr(gm, "num_inputs", len(gm.block_mappings)))
    aliased = 0
    data_dependent = False
    n_points = 1
    for g in grid:
        n_points *= max(g, 1)
    for i, bm in enumerate(gm.block_mappings):
        if not _is_output(bm, i, num_inputs):
            continue
        closed = bm.index_map_jaxpr
        invars = closed.jaxpr.invars
        extra = invars[len(grid):]  # scalar-prefetch operands
        if extra and _depends_on(closed.jaxpr, extra):
            data_dependent = True
            if not allow_output_revisit:
                violations.append(GridAliasViolation(
                    f"{name}: output block map depends on runtime data — "
                    f"grid-step injectivity is unprovable statically"))
            continue
        if n_points > MAX_GRID_POINTS:
            continue  # enumeration capped; report stays informational
        seen: dict = {}
        for point in itertools.product(*(range(g) for g in grid)):
            block = _eval_index_map(closed, point,
                                    [v.aval for v in extra])
            if block in seen:
                aliased += 1
                if not allow_output_revisit:
                    violations.append(GridAliasViolation(
                        f"{name}: grid steps {seen[block]} and {point} both "
                        f"write output block {block} — accumulation must be "
                        f"declared (allow_output_revisit) or the map made "
                        f"injective"))
                break
            seen[block] = point

    # scatter discipline inside the kernel body
    body = _as_jaxpr(eqn.params["jaxpr"])
    scatter_adds = 0
    for sub in walk_eqns(body):
        if sub.primitive.name in SCATTER_COMBINE_PRIMS:
            scatter_adds += 1
            if any(_is_float(v.aval) for v in sub.outvars):
                violations.append(FloatScatterViolation(
                    f"{name}: float scatter-add inside the kernel body — "
                    f"accumulate via one-hot matmul or sorted segmented sum "
                    f"(DESIGN.md §2)"))
    return KernelLintReport(
        name=name, grid=grid, vmem_bytes=vmem, vmem_budget=budget,
        aliased_output_blocks=aliased,
        data_dependent_output_map=data_dependent,
        kernel_scatter_adds=scatter_adds, violations=violations)


def lint_fn(fn, *args, name: str | None = None,
            backend: str = DEFAULT_BACKEND,
            allow_output_revisit: bool = False,
            **kwargs) -> list[KernelLintReport]:
    """Trace `fn(*args, **kwargs)` and lint every pallas_call inside."""
    # close over the args: static ints (num_bins, tile sizes) must reach
    # the kernel wrapper as Python values, not tracers
    closed = jax.make_jaxpr(lambda: fn(*args, **kwargs))()
    label = name or getattr(fn, "__name__", "pallas_fn")
    reports = []
    for i, eqn in enumerate(walk_eqns(closed.jaxpr)):
        if eqn.primitive.name != "pallas_call":
            continue
        kname = str(eqn.params.get("name_and_src_info", "")).split(" ")[0]
        reports.append(lint_pallas_eqn(
            eqn, name=f"{label}/{kname or i}", backend=backend,
            allow_output_revisit=allow_output_revisit))
    return reports


def enforce(reports: list[KernelLintReport]) -> None:
    for rep in reports:
        if rep.violations:
            raise rep.violations[0]


# ---------------------------------------------------------------------------
# production registry: every kernel in src/repro/kernels, representative
# shapes, with intentional hazards declared
# ---------------------------------------------------------------------------
def production_kernel_specs():
    """(name, thunk, allow_output_revisit) for every production kernel.
    Thunks build (fn, args, kwargs) at call time so jax only initializes
    when the sweep runs. histogram declares output revisiting: its single
    output block is accumulated across the (sequential) TPU grid by
    design."""
    import jax.numpy as jnp

    from repro.kernels.gather import gather_windowed_pallas
    from repro.kernels.hash_probe import hash_probe_pallas, probe_agg_pallas
    from repro.kernels.histogram import histogram_pallas
    from repro.kernels.merge_join import lower_bound_windowed_pallas
    from repro.kernels.radix_partition import (block_histograms_pallas,
                                               partition_ranks_pallas)
    from repro.kernels.segsum import segsum_partials_pallas

    def i32(x):
        return jnp.asarray(x, jnp.int32)

    def digits():
        # 4096 rows -> a 4-step grid, so histogram's intentional output
        # revisiting (sequential accumulation) is actually exercised
        return i32(np.arange(4096) % 16)

    def probe_layout():
        bkeys = i32(np.arange(4 * 128).reshape(4, 128))
        off_r = i32([0, 128, 256, 384])
        pk = i32(np.arange(6 * 128).reshape(6, 128) % 512)
        part = i32([0, 0, 1, 2, 3, 3])
        return bkeys, off_r, pk, part

    def probe_agg_args():
        bkeys, off_r, pk, part = probe_layout()
        bvals = jnp.ones((4, 1, 128), jnp.float32)
        gkb = pk % 64
        pvb = jnp.ones((6, 1, 128), jnp.float32)
        return (bkeys, bvals, pk, gkb, pvb, part)

    return [
        ("histogram", lambda: (histogram_pallas, (digits(), 16), {}), True),
        ("block_histograms",
         lambda: (block_histograms_pallas, (digits(), 16), {}), False),
        ("partition_ranks",
         lambda: (partition_ranks_pallas, (digits(), 16), {}), False),
        ("segsum_partials",
         lambda: (segsum_partials_pallas,
                  (i32(np.sort(np.arange(1024) % 64)),
                   jnp.ones((1024,), jnp.float32)), {}), False),
        ("gather_windowed",
         lambda: (gather_windowed_pallas,
                  (jnp.ones((4096,), jnp.float32), i32(np.arange(2048)),
                   i32([0, 1])), {}), False),
        ("lower_bound_windowed",
         lambda: (lower_bound_windowed_pallas,
                  (i32(np.arange(2048)), i32(np.arange(2048)),
                   i32([0, 1])), {}), False),
        ("hash_probe",
         lambda: (hash_probe_pallas, probe_layout(), {}), False),
        ("probe_agg",
         lambda: (probe_agg_pallas, probe_agg_args(),
                  {"col_sides": (("build", 0), ("probe", 0))}), False),
    ]


def lint_production_kernels(backend: str = DEFAULT_BACKEND):
    """Lint every registered production kernel; returns all reports."""
    reports = []
    for kname, thunk, allow in production_kernel_specs():
        fn, args, kwargs = thunk()
        reports.extend(lint_fn(fn, *args, name=kname, backend=backend,
                               allow_output_revisit=allow, **kwargs))
    return reports

"""Static auditor over jaxprs: primitive budgets, liveness watermarks,
dtype-contract checks.

The paper's cost models price plans in *primitive* terms — number of sort
passes, partition passes, gathers/scatters — so the only way to know that
the plan XLA compiled is the plan the model priced is to count those
primitives in the traced jaxpr (DESIGN.md §11). This module is the
counting layer: a recursive walker that descends into every sub-jaxpr a
higher-order primitive carries (`pjit`, `cond` branches, `scan`/`while`
bodies, `pallas_call` kernel bodies, custom_vjp/jvp call jaxprs) and
produces:

  * a `PrimitiveBudget` — counts of the plan-shaping primitives (sorts,
    gathers, scatters, scatter-adds, all_to_alls, pallas_calls);
  * a liveness-based peak-live-bytes watermark — walking eqns in order,
    tracking each value's last use, the high-water mark of live bytes is
    an upper bound on the compiled program's residency and the witness
    for "this fusion never materializes the join output";
  * a dtype-contract report — eqns whose outputs silently widen to a
    64-bit dtype none of their inputs carried (the classic f64/i64
    promotion that doubles every downstream pass).

Counting convention: a primitive inside `scan`/`while` counts ONCE (the
static shape of the program, mirroring how the cost model prices it), not
once per iteration — trip counts are a runtime property, budgets are a
compile-time property.
"""
from __future__ import annotations

import dataclasses

import jax
from jax import core as jcore
import numpy as np

SORT_PRIMS = frozenset({"sort"})
GATHER_PRIMS = frozenset({"gather"})
SCATTER_SET_PRIMS = frozenset({"scatter"})
SCATTER_COMBINE_PRIMS = frozenset(
    {"scatter-add", "scatter-mul", "scatter-min", "scatter-max"})
ALL_TO_ALL_PRIMS = frozenset({"all_to_all"})
PALLAS_PRIMS = frozenset({"pallas_call"})
WIDE_BYTES = 8  # itemsize threshold for the 64-bit promotion check


@dataclasses.dataclass(frozen=True)
class PrimitiveBudget:
    """Counts of the plan-shaping primitives in a (recursively walked)
    jaxpr. Addition/subtraction compose budgets across plan subtrees."""
    sorts: int = 0
    gathers: int = 0
    scatters: int = 0
    scatter_adds: int = 0
    float_scatter_adds: int = 0
    all_to_alls: int = 0
    pallas_calls: int = 0

    def __add__(self, other: "PrimitiveBudget") -> "PrimitiveBudget":
        return PrimitiveBudget(*(a + b for a, b in
                                 zip(self.astuple(), other.astuple())))

    def __sub__(self, other: "PrimitiveBudget") -> "PrimitiveBudget":
        return PrimitiveBudget(*(a - b for a, b in
                                 zip(self.astuple(), other.astuple())))

    def astuple(self) -> tuple:
        return dataclasses.astuple(self)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def describe(self) -> str:
        return " ".join(f"{k}={v}" for k, v in self.as_dict().items() if v)


@dataclasses.dataclass(frozen=True)
class AuditReport:
    """Everything the contract layer needs to judge one traced program."""
    budget: PrimitiveBudget
    peak_live_bytes: int
    peak_live_at: str  # primitive name at the watermark ('<args>' if inputs)
    arg_bytes: int  # bytes of the jaxpr's invars + constvars
    out_bytes: int  # bytes of the jaxpr's outvars
    promotions: tuple  # eqn descriptions that widened to 64-bit silently

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["budget"] = self.budget.as_dict()
        d["promotions"] = list(self.promotions)
        return d


# ---------------------------------------------------------------------------
# recursive walk
# ---------------------------------------------------------------------------
def _as_jaxpr(obj):
    """Normalize Jaxpr/ClosedJaxpr to the raw Jaxpr, else None."""
    if hasattr(obj, "eqns") and hasattr(obj, "invars"):
        return obj
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    return None


def subjaxprs(eqn) -> list:
    """Every sub-jaxpr an eqn's params carry (pjit/cond/scan/while bodies,
    pallas_call kernels, custom_*_call jaxprs), as raw Jaxprs."""
    out = []
    for val in eqn.params.values():
        items = val if isinstance(val, (tuple, list)) else (val,)
        for item in items:
            j = _as_jaxpr(item)
            if j is not None:
                out.append(j)
    return out


def walk_eqns(jaxpr):
    """Yield every eqn of `jaxpr` and (recursively) of its sub-jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxprs(eqn):
            yield from walk_eqns(sub)


def _is_float(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and np.issubdtype(dt, np.floating)


def budget_of_jaxpr(jaxpr) -> PrimitiveBudget:
    counts = dict.fromkeys(
        ("sorts", "gathers", "scatters", "scatter_adds",
         "float_scatter_adds", "all_to_alls", "pallas_calls"), 0)
    for eqn in walk_eqns(jaxpr):
        name = eqn.primitive.name
        if name in SORT_PRIMS:
            counts["sorts"] += 1
        elif name in GATHER_PRIMS:
            counts["gathers"] += 1
        elif name in SCATTER_SET_PRIMS:
            counts["scatters"] += 1
        elif name in SCATTER_COMBINE_PRIMS:
            counts["scatter_adds"] += 1
            if any(_is_float(v.aval) for v in eqn.outvars):
                counts["float_scatter_adds"] += 1
        elif name in ALL_TO_ALL_PRIMS:
            counts["all_to_alls"] += 1
        elif name in PALLAS_PRIMS:
            counts["pallas_calls"] += 1
    return PrimitiveBudget(**counts)


# ---------------------------------------------------------------------------
# dtype contract: no silent 64-bit promotion
# ---------------------------------------------------------------------------
def _itemsize(aval) -> int:
    dt = getattr(aval, "dtype", None)
    return 0 if dt is None else np.dtype(dt).itemsize


def find_promotions(jaxpr) -> tuple:
    """Eqns whose outputs are 64-bit-wide while no input was: the silent
    f64/i64 promotions that double the byte volume of every later pass.
    Deliberate widenings (a 64-bit input somewhere in the eqn) are fine —
    the 8-byte-key experiments stay legal."""
    bad = []
    for eqn in walk_eqns(jaxpr):
        wide_out = [v for v in eqn.outvars if _itemsize(v.aval) >= WIDE_BYTES]
        if not wide_out:
            continue
        if any(_itemsize(v.aval) >= WIDE_BYTES for v in eqn.invars):
            continue
        # iota/full-style creation from static params is a choice, not a
        # promotion, but it still widens the pipeline: report it too.
        avals = ", ".join(str(v.aval) for v in wide_out)
        bad.append(f"{eqn.primitive.name} -> {avals}")
    return tuple(bad)


# ---------------------------------------------------------------------------
# liveness watermark
# ---------------------------------------------------------------------------
def _var_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    size = 1
    for d in shape:
        if not isinstance(d, int):  # symbolic dim: can't price statically
            return 0
        size *= d
    return size * _itemsize(aval)


def _roots_bytes(jaxpr) -> int:
    seen, total = set(), 0
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        if id(v) not in seen:
            seen.add(id(v))
            total += _var_bytes(v)
    return total


def liveness_peak(jaxpr, _cache=None) -> tuple[int, str]:
    """(peak_live_bytes, primitive_at_peak) for a jaxpr, by last-use
    liveness over its eqns. Sub-jaxpr eqns contribute their own internal
    peak (beyond their inputs, which are live at this level already) at
    the point of the call — scan/while bodies are priced once, like the
    budget. An upper bound on residency: XLA may fuse intermediates away,
    but it cannot make a materialization the jaxpr never wrote."""
    jaxpr = _as_jaxpr(jaxpr)
    cache = {} if _cache is None else _cache
    key = id(jaxpr)
    if key in cache:
        return cache[key]

    last_use: dict = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if not isinstance(v, jcore.Literal):
                last_use[v] = i
    for v in jaxpr.outvars:
        if not isinstance(v, jcore.Literal):
            last_use[v] = len(jaxpr.eqns)

    live: dict = {}
    for v in list(jaxpr.invars) + list(jaxpr.constvars):
        live[v] = _var_bytes(v)
    live_bytes = sum(live.values())
    peak, peak_at = live_bytes, "<args>"

    for i, eqn in enumerate(jaxpr.eqns):
        inner_extra = 0
        for sub in subjaxprs(eqn):
            sub_peak, _ = liveness_peak(sub, cache)
            inner_extra += max(0, sub_peak - _roots_bytes(sub))
        out_bytes = sum(_var_bytes(v) for v in eqn.outvars)
        here = live_bytes + out_bytes + inner_extra
        if here > peak:
            peak, peak_at = here, eqn.primitive.name
        for v in eqn.outvars:
            if last_use.get(v, -1) > i and v not in live:
                live[v] = _var_bytes(v)
                live_bytes += live[v]
        for v in eqn.invars:
            if (not isinstance(v, jcore.Literal) and last_use.get(v) == i
                    and v in live):
                live_bytes -= live.pop(v)

    cache[key] = (peak, peak_at)
    return peak, peak_at


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def audit_jaxpr(jaxpr) -> AuditReport:
    """Full audit of a (Closed)Jaxpr: budget + watermark + promotions."""
    raw = _as_jaxpr(jaxpr)
    peak, peak_at = liveness_peak(raw)
    return AuditReport(
        budget=budget_of_jaxpr(raw),
        peak_live_bytes=peak,
        peak_live_at=peak_at,
        arg_bytes=_roots_bytes(raw),
        out_bytes=sum(_var_bytes(v) for v in raw.outvars
                      if not isinstance(v, jcore.Literal)),
        promotions=find_promotions(raw),
    )


def audit_fn(fn, *args, **kwargs) -> AuditReport:
    """Trace `fn(*args, **kwargs)` and audit the resulting jaxpr."""
    return audit_jaxpr(jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args))


def budget_of(fn, *args, **kwargs) -> PrimitiveBudget:
    return audit_fn(fn, *args, **kwargs).budget


def count_sorts(fn_or_jaxpr, *args, **kwargs) -> int:
    """Shared test API (replaces the per-test-file `_count_sorts` copies):
    sort-primitive count of a jaxpr, or of `fn(*args)` traced."""
    if _as_jaxpr(fn_or_jaxpr) is not None:
        return budget_of_jaxpr(fn_or_jaxpr).sorts
    return budget_of(fn_or_jaxpr, *args, **kwargs).sorts

"""repro.analysis — static analysis over jaxprs and physical plans.

Proves that every compiled plan honors its priced contract (DESIGN.md
§11): `jaxpr_audit` counts plan-shaping primitives and tracks a liveness
watermark, `kernel_lint` statically checks Pallas kernels (VMEM fit,
grid-output aliasing, scatter discipline), `contracts` holds each
operator's promised budget and the typed `ContractViolation` hierarchy.
`python -m repro.analysis` sweeps every production entry point and writes
ANALYSIS.json (a hard CI gate)."""
from .contracts import (ContractViolation, DtypePromotionViolation, FloatScatterViolation,
                        GridAliasViolation, MaterializationViolation, OperatorContract,
                        SortBudgetViolation, VmemBudgetViolation, check, contract_for_node,
                        enforce, groupby_contract, groupjoin_contract, join_contract,
                        orderby_contract, partition_plan_contract, passthrough_contract)
from .jaxpr_audit import (AuditReport, PrimitiveBudget, audit_fn, audit_jaxpr, budget_of,
                          budget_of_jaxpr, count_sorts, liveness_peak, walk_eqns)
from .kernel_lint import KernelLintReport, lint_fn, lint_pallas_eqn, lint_production_kernels

__all__ = [
    "AuditReport", "PrimitiveBudget", "audit_fn", "audit_jaxpr",
    "budget_of", "budget_of_jaxpr", "count_sorts", "liveness_peak",
    "walk_eqns",
    "ContractViolation", "SortBudgetViolation", "MaterializationViolation",
    "DtypePromotionViolation", "FloatScatterViolation",
    "VmemBudgetViolation", "GridAliasViolation",
    "OperatorContract", "check", "enforce", "contract_for_node",
    "join_contract", "groupby_contract", "groupjoin_contract",
    "orderby_contract", "passthrough_contract", "partition_plan_contract",
    "KernelLintReport", "lint_fn", "lint_pallas_eqn",
    "lint_production_kernels",
]

"""Operator contracts: what each physical operator promises the compiler
will (not) do, in the same primitive vocabulary the cost model prices.

A contract is the *priced* side of priced-vs-compiled (DESIGN.md §11):
the planner charged PHJ zero sort passes, so a compiled PHJ plan
containing a `sort` primitive is a plan the model mis-priced — the
chooser's Figure-18 decisions stop being trustworthy the moment that
drifts. `check()` compares an `AuditReport` (the compiled side, from
`jaxpr_audit`) against a contract and returns typed violations;
`enforce()` raises the first one.

The materialization contract is expressed through the liveness watermark:
a fused group-join's peak-live-bytes must stay a small multiple of its
input+output bytes, *independent of the join-output capacity* — that is
the checkable form of "the joined row never exists" (PR 4's claim).
"""
from __future__ import annotations

import dataclasses

from .jaxpr_audit import AuditReport, PrimitiveBudget


class ContractViolation(Exception):
    """A compiled plan diverged from the contract the cost model priced."""


class SortBudgetViolation(ContractViolation):
    """More sort primitives than the priced plan allows (e.g. a 'sort-free'
    partition pipeline silently compiled through the sort-based arm)."""


class MaterializationViolation(ContractViolation):
    """Peak live bytes exceed the contract bound — something the fusion
    promised never to materialize got materialized."""


class DtypePromotionViolation(ContractViolation):
    """An eqn silently widened to a 64-bit dtype none of its inputs had."""


class FloatScatterViolation(ContractViolation):
    """Float scatter-add outside the approved segmented-sum accumulators
    (non-deterministic on parallel backends; the CUDA-atomics hazard)."""


class VmemBudgetViolation(ContractViolation):
    """A Pallas kernel's blocks don't fit the per-backend VMEM budget."""


class GridAliasViolation(ContractViolation):
    """Two grid steps map to the same output block without the kernel
    declaring sequential-accumulation semantics."""


@dataclasses.dataclass(frozen=True)
class OperatorContract:
    """Budget bounds one operator promises. `None` means unconstrained."""
    name: str
    max_sorts: int | None = None
    max_float_scatter_adds: int | None = None
    forbid_64bit_promotion: bool = True
    # peak_live_bytes <= live_multiplier * (arg_bytes + out_bytes) + slack
    live_multiplier: float | None = None
    live_slack_bytes: int = 1 << 20

    def describe(self) -> str:
        parts = []
        if self.max_sorts is not None:
            parts.append(f"sorts<={self.max_sorts}")
        if self.max_float_scatter_adds is not None:
            parts.append(f"f32-scatter-adds<={self.max_float_scatter_adds}")
        if self.live_multiplier is not None:
            parts.append(f"peak-live<={self.live_multiplier:g}x(in+out)")
        if self.forbid_64bit_promotion:
            parts.append("no-64bit-promotion")
        return " ".join(parts) if parts else "unconstrained"


def check(contract: OperatorContract, report: AuditReport,
          budget: PrimitiveBudget | None = None) -> list[ContractViolation]:
    """Judge a compiled program against its contract. `budget` overrides
    the report's (the executor passes per-node incremental budgets so a
    parent isn't charged for its children's primitives)."""
    budget = report.budget if budget is None else budget
    out: list[ContractViolation] = []
    if contract.max_sorts is not None and budget.sorts > contract.max_sorts:
        out.append(SortBudgetViolation(
            f"{contract.name}: compiled plan contains {budget.sorts} sort "
            f"primitive(s); the priced contract allows "
            f"{contract.max_sorts}"))
    if (contract.max_float_scatter_adds is not None
            and budget.float_scatter_adds > contract.max_float_scatter_adds):
        out.append(FloatScatterViolation(
            f"{contract.name}: {budget.float_scatter_adds} float "
            f"scatter-add(s) vs allowed {contract.max_float_scatter_adds} "
            f"(approved segmented-sum accumulators only)"))
    if contract.forbid_64bit_promotion and report.promotions:
        out.append(DtypePromotionViolation(
            f"{contract.name}: silent 64-bit promotion at "
            f"{'; '.join(report.promotions[:3])}"))
    if contract.live_multiplier is not None:
        bound = (contract.live_multiplier
                 * (report.arg_bytes + report.out_bytes)
                 + contract.live_slack_bytes)
        if report.peak_live_bytes > bound:
            out.append(MaterializationViolation(
                f"{contract.name}: peak live bytes "
                f"{report.peak_live_bytes} (at {report.peak_live_at}) "
                f"exceed {bound:.0f} = {contract.live_multiplier:g}x"
                f"(in={report.arg_bytes} + out={report.out_bytes}) + "
                f"{contract.live_slack_bytes} slack — a promised-away "
                f"materialization happened"))
    return out


def enforce(contract: OperatorContract, report: AuditReport,
            budget: PrimitiveBudget | None = None) -> None:
    violations = check(contract, report, budget)
    if violations:
        raise violations[0]


# ---------------------------------------------------------------------------
# per-operator contract registry (the priced budgets)
# ---------------------------------------------------------------------------
# Sort budget per group-by strategy. 'sort' pays exactly one sort;
# 'partition' pays one block-local sort after the sort-free radix planner;
# 'partition_hash' re-sorts once per side (plan + combine); 'scatter' is
# sort-free; 'sort_pallas' pays one plan sort plus one combine sort per
# segmented-sum call (hoisted count + one per aggregate column).
GROUPBY_SORTS = {"sort": 1, "partition": 1, "partition_hash": 2, "scatter": 0}


def groupby_contract(strategy: str, n_aggs: int) -> OperatorContract:
    if strategy == "sort_pallas":
        max_sorts = 2 + n_aggs
    else:
        max_sorts = GROUPBY_SORTS.get(strategy, 2)
    # one float accumulator pass per aggregate (+1: mean's count/sum pair)
    return OperatorContract(name=f"groupby[{strategy}]", max_sorts=max_sorts,
                            max_float_scatter_adds=2 * n_aggs + 1)


JOIN_SORTS = {"phj": 0, "nphj": 0, "smj": 2}


def join_contract(algorithm: str, pattern: str = "gftr") -> OperatorContract:
    # joins move payloads with gathers/plain scatters; a float scatter-add
    # in a join is always a drifted accumulator
    return OperatorContract(name=f"join[{algorithm}/{pattern}]",
                            max_sorts=JOIN_SORTS.get(algorithm, 0),
                            max_float_scatter_adds=0)


GROUPJOIN_LIVE_MULTIPLIER = 512.0
GROUPJOIN_LIVE_SLACK = 8 << 20


def groupjoin_contract(agg_strategy: str, n_aggs: int,
                       live_multiplier: float | None = GROUPJOIN_LIVE_MULTIPLIER,
                       ) -> OperatorContract:
    """Fused probe+accumulate: PHJ partitioning is sort-free, so the only
    sorts are the accumulator's own; and the join output must never
    materialize — peak live bytes stay bounded by the inputs, independent
    of the join cardinality. The bound is deliberately loose (512x + 8MiB
    slack): the CPU reference probe's candidate matrix (n_pad x capR int32,
    priced by the model and join-capacity-independent) dominates residency
    at audit scale, so a tight multiple of in+out would flag the probe
    itself. What the bound still pins is the *asymptotic* claim — any plan
    that materializes a join output at fanout beyond ~512x its input blows
    through it, while the fused path stays constant no matter the join
    cardinality."""
    base = groupby_contract(agg_strategy, n_aggs)
    return OperatorContract(name=f"groupjoin[phj+{agg_strategy}]",
                            max_sorts=base.max_sorts,
                            max_float_scatter_adds=base.max_float_scatter_adds,
                            live_multiplier=live_multiplier,
                            live_slack_bytes=GROUPJOIN_LIVE_SLACK)


def orderby_contract() -> OperatorContract:
    return OperatorContract(name="order_by_limit", max_sorts=1,
                            max_float_scatter_adds=0)


def passthrough_contract(name: str) -> OperatorContract:
    """Scan/filter/project: no sorts, no float accumulation."""
    return OperatorContract(name=name, max_sorts=0, max_float_scatter_adds=0)


def partition_plan_contract(impl: str = "pallas") -> OperatorContract:
    """The radix partition planner itself: the 'pallas' rank pipeline is
    sort-free (PR 5's claim); the 'xla' reference arm pays one stable sort
    per pass and is priced accordingly."""
    return OperatorContract(name=f"partition_plan[{impl}]",
                            max_sorts=0 if impl == "pallas" else None,
                            max_float_scatter_adds=0)


def contract_for_node(node) -> OperatorContract:
    """Map an engine physical node to its priced contract."""
    from repro.engine import physical as P
    if isinstance(node, P.PJoin):
        return join_contract(node.algorithm, node.pattern)
    if isinstance(node, P.PGroupBy):
        return groupby_contract(node.strategy, len(node.aggs))
    if isinstance(node, P.PGroupJoin):
        return groupjoin_contract(node.agg_strategy, len(node.aggs))
    if isinstance(node, P.POrderByLimit):
        return orderby_contract()
    if isinstance(node, P.PScan):
        return passthrough_contract("scan")
    if isinstance(node, P.PFilter):
        return passthrough_contract("filter")
    if isinstance(node, P.PProject):
        return passthrough_contract("project")
    return OperatorContract(name=type(node).__name__)

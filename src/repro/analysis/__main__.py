"""`python -m repro.analysis` — sweep every production entry point, audit
each compiled plan against its priced contract, lint every Pallas kernel,
and write ANALYSIS.json. Non-zero exit on any contract violation: this is
a hard CI gate (scripts/ci.sh), the machine check that the plan XLA
compiled is the plan the cost model priced (DESIGN.md §11).

Sections:
  operators — phj/smj/nphj joins (both materialization patterns), all five
              group-by strategies, the fused group-join, and the
              permutation planners (sort-free radix vs XLA reference);
  kernels   — static VMEM fit / grid-aliasing / scatter-discipline lint
              over every kernel in src/repro/kernels;
  engine    — optimizer-chosen physical plans (star, filtered top-k,
              fusible group-join), audited node by node via
              executor.audit, across the chooser's branches.

Usage: python -m repro.analysis [--out ANALYSIS.json]
"""
from __future__ import annotations

import functools
import json
import sys

import numpy as np

from . import contracts as C
from .jaxpr_audit import audit_fn
from .kernel_lint import lint_production_kernels


def _operator_entries():
    """(name, fn, args, contract) for every core operator entry point,
    at trace-friendly shapes (tracing is shape-polymorphic in cost: these
    budgets are the budgets at any scale; pass counts are pinned by the
    same static bit-widths the planner uses)."""
    import jax.numpy as jnp

    from repro.core import (Table, group_aggregate, join, phj_groupjoin,
                            primitives as prim)

    rng = np.random.default_rng(0)
    n_r, n_s, n_groups = 512, 2048, 64
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(
                   rng.integers(0, n_groups, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    G = Table({"k": S["g"], "v": jnp.asarray(
        rng.normal(size=n_s).astype(np.float32))})
    keys = S["k"]
    digits = jnp.asarray(rng.integers(0, 16, n_s).astype(np.int32))
    aggs = {"v": "sum"}

    entries = []
    for alg in ("phj", "smj", "nphj"):
        for pattern in ("gftr", "gfur"):
            if alg == "nphj" and pattern == "gfur":
                continue  # nphj has a single materialization pattern
            fn = functools.partial(join, key="k", algorithm=alg,
                                   pattern=pattern, out_size=n_s,
                                   mode="pk_fk")
            entries.append((f"join/{alg}/{pattern}/pk_fk", fn, (R, S),
                            C.join_contract(alg, pattern)))
    entries.append((
        "join/phj/gftr/mn",
        functools.partial(join, key="k", algorithm="phj", pattern="gftr",
                          out_size=2 * n_s, mode="mn"),
        (R, S), C.join_contract("phj", "gftr")))

    for strategy in ("sort", "partition", "partition_hash", "scatter",
                     "sort_pallas"):
        fn = functools.partial(group_aggregate, key="k", aggs=aggs,
                               num_groups=2 * n_groups, strategy=strategy)
        entries.append((f"groupby/{strategy}", fn, (G,),
                        C.groupby_contract(strategy, len(aggs))))

    for strategy in ("sort", "scatter"):
        fn = functools.partial(phj_groupjoin, key="k", group_key="g",
                               aggs={"rv": "sum", "sv": "mean"},
                               num_groups=2 * n_groups,
                               agg_strategy=strategy)
        entries.append((f"groupjoin/phj+{strategy}", fn, (R, S),
                        C.groupjoin_contract(strategy, 2)))

    entries.append((
        "primitives/partition_plan/pallas",
        functools.partial(prim.plan_partition_permutation, num_partitions=16,
                          impl="pallas"),
        (digits,), C.partition_plan_contract("pallas")))
    entries.append((
        "primitives/sort_plan/radix",
        functools.partial(prim.plan_sort_permutation, impl="radix"),
        (keys,),
        C.OperatorContract(name="sort_plan[radix]", max_sorts=0,
                           max_float_scatter_adds=0)))
    entries.append((
        "primitives/sort_plan/xla",
        functools.partial(prim.plan_sort_permutation, impl="xla"),
        (keys,),
        C.OperatorContract(name="sort_plan[xla]", max_sorts=1,
                           max_float_scatter_adds=0)))
    return entries


def _engine_plans():
    """Optimizer-chosen plans across the chooser's branches: a star query
    (join choice), a filtered top-k (filter + order-by), and a fusible
    join + group-by both as chosen and with fusion forced off."""
    import jax.numpy as jnp

    from repro.core import Table
    from repro.engine import Catalog, optimize, scan

    rng = np.random.default_rng(1)
    n_r, n_s = 512, 4096
    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(rng.integers(0, 64, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    cat = Catalog({"R": R, "S": S})

    plans = []
    q = scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="mean")
    plans.append(("engine/join_groupby", optimize(q, cat,
                                                  measure_profile=False)))
    plans.append(("engine/forced_unfused",
                  optimize(q, cat, measure_profile=False,
                           force_join=("phj", "gftr"))))
    q2 = (scan("S").filter("sv", ">", 50).join(scan("R"), key="k")
          .group_by("g", sv="sum")
          .order_by("sv_sum", limit=8, descending=True))
    plans.append(("engine/filtered_topk", optimize(q2, cat,
                                                   measure_profile=False)))
    return plans


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    out_path = "ANALYSIS.json"
    if "--out" in argv:
        out_path = argv[argv.index("--out") + 1]

    report = {"operators": {}, "kernels": {}, "engine": {}}
    n_violations = 0

    print("== operators ==")
    for name, fn, args, contract in _operator_entries():
        rep = audit_fn(fn, *args)
        violations = C.check(contract, rep)
        n_violations += len(violations)
        status = "VIOLATION" if violations else "ok"
        print(f"{name}: compiled[{rep.budget.describe() or 'none'}] "
              f"priced[{contract.describe()}] "
              f"peak-live={rep.peak_live_bytes/1024:.0f}KiB {status}")
        entry = rep.as_dict()
        entry["contract"] = contract.describe()
        entry["violations"] = [f"{type(v).__name__}: {v}"
                               for v in violations]
        report["operators"][name] = entry

    print("== kernels ==")
    for krep in lint_production_kernels():
        n_violations += len(krep.violations)
        status = "VIOLATION" if krep.violations else "ok"
        print(f"{krep.name}: grid={krep.grid} "
              f"vmem={krep.vmem_bytes/1024:.0f}KiB/"
              f"{krep.vmem_budget/1024:.0f}KiB "
              f"revisits={krep.aliased_output_blocks} {status}")
        report["kernels"][krep.name] = krep.as_dict()

    print("== engine ==")
    from repro.engine import executor

    for name, plan in _engine_plans():
        plan_audit = executor.audit(plan)
        n_violations += len(plan_audit.violations)
        status = "VIOLATION" if plan_audit.violations else "ok"
        root = plan_audit.root_report
        print(f"{name}: compiled[{root.budget.describe() or 'none'}] "
              f"peak-live={root.peak_live_bytes/1024:.0f}KiB "
              f"nodes={len(plan_audit.entries)} {status}")
        report["engine"][name] = plan_audit.as_dict()

    report["summary"] = {"violations": n_violations}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {n_violations} violation(s)")
    return 1 if n_violations else 0


if __name__ == "__main__":
    sys.exit(main())

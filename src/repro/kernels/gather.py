"""Clustered GATHER kernel (materialization phase, §2.3 / Table 4).

The paper's unclustered GATHER loads ~4.5 cache lines per warp instruction;
clustered maps load ~1.5. On TPU the analogue is the HBM->VMEM window: for a
clustered gather map, the indices of an output tile span a small input
window, so the kernel streams one aligned 2W window into VMEM per tile and
resolves the gather *inside* VMEM as a one-hot matmul (MXU work, exact for
f32 payloads; int32 payloads go through a 16-bit hi/lo split — see
common.py). Unclustered maps have unbounded spans and fall back to XLA's
random-access take (ops.py makes that dispatch — it is the measurable
difference the paper's Figure 7 is about).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

from .common import ceil_div, combine_u32_hi_lo, resolve_interpret, split_u32_hi_lo


def _gather_kernel(window_rows: int, is_int: bool, w_ref, idx_ref, lo_ref, hi_ref, out_ref):
    i = pl.program_id(0)
    win_start = w_ref[i] * window_rows
    window = jnp.concatenate([lo_ref[0], hi_ref[0]])  # (2W,)
    rel = idx_ref[0] - win_start  # (T,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (rel.shape[0], 2 * window_rows), 1)
    oh = (rel[:, None] == iota).astype(jnp.float32)  # (T, 2W), <=1 one per row
    if is_int:
        hi16, lo16 = split_u32_hi_lo(window)
        out = combine_u32_hi_lo(oh @ hi16, oh @ lo16, out_ref.dtype)
    else:
        out = (oh @ window.astype(jnp.float32)).astype(out_ref.dtype)
    out_ref[0, :] = out


def gather_windowed_pallas(
    src: jax.Array,
    idx: jax.Array,
    win_idx: jax.Array,
    *,
    window_rows: int = 1024,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """out[i] = src[idx[i]] for clustered idx. win_idx gives each tile's
    aligned window (units of window_rows); indices outside a tile's 2W
    window produce 0 (callers pre-check spans; ops.py dispatches)."""
    n_src, n_out = src.shape[0], idx.shape[0]
    is_int = jnp.issubdtype(src.dtype, jnp.integer)
    n_wb = ceil_div(n_src, window_rows)
    spad = jnp.zeros((n_wb * window_rows - n_src + window_rows,), src.dtype)
    src2 = jnp.concatenate([src, spad]).reshape(n_wb + 1, window_rows)

    n_tiles = ceil_div(n_out, tile)
    ipad = jnp.full((n_tiles * tile - n_out,), -1, jnp.int32)
    idx2 = jnp.concatenate([idx.astype(jnp.int32), ipad]).reshape(n_tiles, tile)
    win_idx = jnp.clip(win_idx.astype(jnp.int32), 0, n_wb - 1)

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, w: (i, 0)),
            pl.BlockSpec((1, window_rows), lambda i, w: (w[i], 0)),
            pl.BlockSpec((1, window_rows), lambda i, w: (w[i] + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, w: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_gather_kernel, window_rows, bool(is_int)),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), src.dtype),
        interpret=resolve_interpret(interpret),
    )(win_idx, idx2, src2, src2)
    return out.reshape(-1)[:n_out]

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-ref)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

KEY_SENTINEL = -1


def histogram(digits: jax.Array, num_bins: int) -> jax.Array:
    """Counts per digit value. digits int32 in [0, num_bins)."""
    return jnp.bincount(digits, length=num_bins).astype(jnp.int32)


def partition_ranks(digits: jax.Array, num_bins: int) -> jax.Array:
    """Stable-partition destination index per element:
    dest[i] = offset[digit[i]] + |{j < i : digit[j] == digit[i]}|."""
    n = digits.shape[0]
    oh = (digits[:, None] == jnp.arange(num_bins)[None, :]).astype(jnp.int32)
    within = jnp.cumsum(oh, axis=0) - oh  # exclusive rank within digit
    sizes = oh.sum(axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1]])
    return (offsets[digits] + within[jnp.arange(n), digits]).astype(jnp.int32)


def lower_bound(build_sorted: jax.Array, probe: jax.Array) -> jax.Array:
    """searchsorted(build, probe, 'left')."""
    return jnp.searchsorted(build_sorted, probe, side="left").astype(jnp.int32)


def upper_bound(build_sorted: jax.Array, probe: jax.Array) -> jax.Array:
    return jnp.searchsorted(build_sorted, probe, side="right").astype(jnp.int32)


def hash_probe_blocks(bkeys: jax.Array, off_r: jax.Array, probe_keys: jax.Array,
                      probe_part: jax.Array):
    """Co-partition PK probe. bkeys (P, capR) padded build blocks (sentinel
    fill); probe row j belongs to partition probe_part[j]. Returns
    (vid_r, matched): position of the unique match in the partitioned build
    array, else (-1ish, False)."""
    cand = jnp.take(bkeys, probe_part, axis=0)  # (n, capR)
    eq = (cand == probe_keys[:, None]) & (probe_keys[:, None] != KEY_SENTINEL)
    hit = jnp.argmax(eq, axis=1).astype(jnp.int32)
    matched = jnp.any(eq, axis=1)
    vid = jnp.take(off_r, probe_part).astype(jnp.int32) + hit
    return vid, matched


def windowed_gather(src: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i] = src[idx[i]] (idx assumed in-range)."""
    return jnp.take(src, idx, axis=0)


def segsum_partials(sorted_keys: jax.Array, values: jax.Array, tile: int):
    """Per-tile partial aggregation over key-sorted rows.

    Returns (pkeys, psums, pcounts), each (num_tiles*tile,): slot t*tile+g is
    tile t's local group g (KEY_SENTINEL where no group). Summing partials by
    key reproduces the global group sums."""
    n = sorted_keys.shape[0]
    pad = -n % tile
    k = jnp.concatenate([sorted_keys, jnp.full((pad,), KEY_SENTINEL, sorted_keys.dtype)])
    v = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
    k = k.reshape(-1, tile)
    v = v.reshape(-1, tile)
    valid = k != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((k.shape[0], 1), bool), k[:, 1:] != k[:, :-1]], 1) & valid
    lgid = jnp.cumsum(bnd.astype(jnp.int32), axis=1) - 1
    lgid = jnp.where(valid, lgid, tile)
    oh = jax.nn.one_hot(lgid, tile, dtype=jnp.float32)  # (T, tile, tile)
    psums = jnp.einsum("tb,tbg->tg", v.astype(jnp.float32), oh)
    pcounts = jnp.einsum("tbg->tg", oh)
    T = k.shape[0]
    pkeys = (
        jnp.full((T, tile + 1), KEY_SENTINEL, sorted_keys.dtype)
        .at[jnp.arange(T)[:, None], jnp.where(bnd, lgid, tile)]
        .set(k, mode="drop")[:, :tile]
    )
    return pkeys.reshape(-1), psums.reshape(-1), pcounts.reshape(-1).astype(jnp.int32)

"""Pallas TPU kernels for the paper's compute hot-spots (validated with
interpret=True on CPU; see DESIGN.md §2 for the CUDA->TPU mapping):

  histogram        - radix histogram (shared-memory atomics -> one-hot sums)
  radix_partition  - stable partition ranks (two-pass, prefix sums)
  merge_join       - windowed lower-bound (Merge Path -> VMEM rank count)
  hash_probe       - co-partition probe (shared-memory bucket -> VMEM block)
  gather           - clustered GATHER (coalescing -> VMEM window + one-hot matmul)
  segsum           - grouped-aggregation tile partials (scatter-free MXU)
"""
from . import ops, ref
from .histogram import histogram_pallas
from .radix_partition import partition_ranks_pallas, block_histograms_pallas
from .merge_join import lower_bound_windowed_pallas
from .hash_probe import hash_probe_pallas, layout_probe_blocks
from .gather import gather_windowed_pallas
from .segsum import segsum_partials_pallas

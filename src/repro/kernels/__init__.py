"""Pallas TPU kernels for the paper's compute hot-spots (compiled on TPU,
interpret mode elsewhere — `common.default_interpret`, override with
REPRO_PALLAS_INTERPRET; see DESIGN.md §2/§10 for the CUDA->TPU mapping):

  histogram        - radix histogram (shared-memory atomics -> one-hot sums)
  radix_partition  - stable partition ranks + the sort-free multi-pass
                     partition/sort planners (prefix sums, zero sort ops)
  merge_join       - windowed lower-bound (Merge Path -> VMEM rank count)
  hash_probe       - co-partition probe (shared-memory bucket -> VMEM block)
  gather           - clustered GATHER (coalescing -> VMEM window + one-hot matmul)
  segsum           - grouped-aggregation tile partials (scatter-free MXU)
"""
from . import ops, ref
from .gather import gather_windowed_pallas
from .hash_probe import hash_probe_pallas, layout_probe_blocks
from .histogram import histogram_pallas
from .merge_join import lower_bound_windowed_pallas
from .radix_partition import (block_histograms_pallas, partition_plan_pallas,
                              partition_ranks_pallas, sort_plan_radix)
from .segsum import segsum_partials_pallas

__all__ = [
    "ops", "ref",
    "histogram_pallas",
    "block_histograms_pallas", "partition_plan_pallas",
    "partition_ranks_pallas", "sort_plan_radix",
    "lower_bound_windowed_pallas",
    "hash_probe_pallas", "layout_probe_blocks",
    "gather_windowed_pallas",
    "segsum_partials_pallas",
]

"""Public jit'd wrappers + kernel/XLA dispatch for the Pallas kernels.

Dispatch policy mirrors the paper's planner logic: the windowed (clustered)
kernels are only profitable/correct when the gather map / merge frontier is
clustered, so each wrapper measures the per-tile span (cheap, O(n/tile)) and
falls back to XLA's random-access path otherwise.

Execution mode is resolved per call (`common.resolve_interpret`): compiled
kernels on TPU, interpret mode elsewhere; REPRO_PALLAS_INTERPRET=0/1
overrides either way, and takes effect immediately — nothing is frozen at
import time.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.resilience import faults

from . import ref
from .common import ceil_div
from .gather import gather_windowed_pallas
from .hash_probe import hash_probe_pallas, layout_probe_blocks, probe_agg_pallas
from .histogram import histogram_pallas
from .merge_join import lower_bound_windowed_pallas
from .radix_partition import partition_plan_pallas, partition_ranks_pallas, sort_plan_radix
from .segsum import segsum_partials_pallas

# Production arm of the partition planner (core.primitives resolves its
# impl=None through this): 'pallas' = the sort-free histogram/rank pipeline,
# 'xla' = the stable-sort reference. Env knob for A/B and bisection; read
# and validated per call (never frozen at import), so an unknown value
# raises instead of silently running an arm the cost model never priced.
PARTITION_PLAN_IMPLS = ("pallas", "xla")


def partition_plan_impl() -> str:
    env = os.environ.get("REPRO_PARTITION_PLAN_IMPL", "pallas")
    if env not in PARTITION_PLAN_IMPLS:
        raise ValueError(
            f"REPRO_PARTITION_PLAN_IMPL={env!r} is not a recognized value; "
            f"allowed: {'/'.join(PARTITION_PLAN_IMPLS)}")
    return env


def __getattr__(name):  # keep the old constant's spelling working
    if name == "PARTITION_PLAN_IMPL":
        return partition_plan_impl()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

KEY_SENTINEL = -1


def _pallas_arm(site: str, pallas_fn, xla_fn):
    """Run a dispatch's pallas arm with graceful degradation: if the arm
    raises (real kernel failure, unsupported backend, or an armed
    `REPRO_FAULTS=pallas:<site>` injection), fall back to the bit-identical
    XLA arm and record the event (DESIGN.md §13's pallas -> xla chain; the
    dense-jnp reference IS the xla arm here, so the chain terminates).

    Zero-overhead contract: with no faults active and a healthy kernel this
    is one host-side call through `pallas_fn` — the try/except and the
    fault check contribute nothing to the traced jaxpr."""
    try:
        faults.check_pallas(site)
        return pallas_fn()
    except Exception as e:  # noqa: BLE001 — any arm failure degrades
        from repro.obs import metrics  # deferred: kernels stay obs-free
        from repro.resilience import escalation

        metrics.counter("resilience.kernel_fallbacks").inc()
        metrics.counter(f"resilience.kernel_fallbacks.{site}").inc()
        escalation.record_degradation(
            f"kernels.{site}", f"pallas arm failed: {type(e).__name__}: {e}")
        return xla_fn()


# ---------------------------------------------------------------------------
# histogram / partition ranks
# ---------------------------------------------------------------------------
def histogram(digits: jax.Array, num_bins: int, impl: str = "pallas") -> jax.Array:
    if impl == "pallas":
        return _pallas_arm(
            "histogram",
            lambda: histogram_pallas(digits, num_bins, interpret=None),
            lambda: ref.histogram(digits, num_bins))
    return ref.histogram(digits, num_bins)


def _partition_ranks_xla(digits, num_bins):
    dest = ref.partition_ranks(digits, num_bins)
    sz = ref.histogram(digits, num_bins)
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sz)[:-1].astype(jnp.int32)])
    return dest, off, sz


def partition_ranks(digits: jax.Array, num_bins: int, impl: str = "pallas"):
    """dest position per element (stable partition)."""
    if impl == "pallas":
        return _pallas_arm(
            "partition_ranks",
            lambda: partition_ranks_pallas(digits, num_bins, interpret=None),
            lambda: _partition_ranks_xla(digits, num_bins))
    return _partition_ranks_xla(digits, num_bins)


# ---------------------------------------------------------------------------
# partition / sort planning (one-permutation layer backends)
# ---------------------------------------------------------------------------
def partition_plan(digits: jax.Array, num_partitions: int, *, carry=(),
                   max_pass_bits: int | None = None, impl: str = "pallas",
                   pass_impl: str = "auto"):
    """Stable-partition plan: (perm, carried, offsets, sizes), all layout
    arrays int32. The production entry behind
    `core.primitives.plan_partition_permutation`.

    impl='pallas': the sort-free rank pipeline (per-pass histogram ->
    block/digit exclusive prefix -> stable ranks, LSD-composed past one
    pass's bin budget) — O(n) per pass, zero sort primitives in the jaxpr.
    impl='xla': the stable-sort reference arm (the previous production
    path), kept for parity testing and as the conservative fallback;
    `max_pass_bits` there runs the paper's multi-pass composition with
    sorts standing in for the rank passes.

    Both arms return bit-identical results — the stable partition
    permutation is unique (tests/test_permutation.py pins the parity)."""
    if impl == "pallas":
        return _pallas_arm(
            "partition_plan",
            lambda: partition_plan_pallas(
                digits, num_partitions, carry=carry,
                max_pass_bits=max_pass_bits, pass_impl=pass_impl,
                interpret=None),
            lambda: _partition_plan_xla(digits, num_partitions, carry,
                                        max_pass_bits))
    if impl != "xla":
        raise ValueError(f"unknown partition plan impl {impl!r}")
    return _partition_plan_xla(digits, num_partitions, carry, max_pass_bits)


def _partition_plan_xla(digits, num_partitions, carry, max_pass_bits):
    n = digits.shape[0]
    digits = digits.astype(jnp.int32)
    iota = jnp.arange(n, dtype=jnp.int32)
    if max_pass_bits is None:
        res = jax.lax.sort((digits,) + tuple(carry) + (iota,), num_keys=1,
                           is_stable=True)
        carried, perm = res[1:-1], res[-1]
    else:
        total_bits = max(1, int(num_partitions - 1).bit_length())
        perm = iota
        cur = digits
        carried = tuple(carry)
        bit = 0
        while bit < total_bits:
            bits = min(max_pass_bits, total_bits - bit)
            sub = (cur >> bit) & ((1 << bits) - 1)
            res = jax.lax.sort((sub, cur) + carried + (perm,), num_keys=1,
                               is_stable=True)
            cur, carried, perm = res[1], res[2:-1], res[-1]
            bit += bits
    sizes = jnp.bincount(digits, length=num_partitions).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1].astype(jnp.int32)]
    )
    return perm, carried, offsets, sizes


def sort_plan(keys: jax.Array, impl: str = "xla"):
    """Stable sort plan: (sorted_keys, perm). impl='xla' is the production
    arm (XLA's tuned sort — the paper's vendor-primitive choice, §2.3);
    impl='radix' composes the same sort-free rank passes over the full
    sign-biased key pattern (int32 keys), for radix-hardware parity and
    fully sort-free pipelines."""
    if impl == "radix":
        return _pallas_arm(
            "sort_plan",
            lambda: sort_plan_radix(keys, interpret=None),
            lambda: _sort_plan_xla(keys))
    if impl != "xla":
        raise ValueError(f"unknown sort plan impl {impl!r}")
    return _sort_plan_xla(keys)


def _sort_plan_xla(keys):
    iota = jnp.arange(keys.shape[0], dtype=jnp.int32)
    sk, perm = jax.lax.sort((keys, iota), num_keys=1, is_stable=True)
    return sk, perm


def apply_partition(dest: jax.Array, *arrays: jax.Array):
    """Materialize the partition: invert dest (scatter of iota) and gather.
    The kernel computes ranks; XLA moves the bytes (DESIGN.md §2)."""
    n = dest.shape[0]
    inv = jnp.zeros((n,), jnp.int32).at[jnp.clip(dest, 0, n - 1)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop"
    )
    return tuple(jnp.take(a, inv, axis=0) for a in arrays)


# ---------------------------------------------------------------------------
# merge lower bound
# ---------------------------------------------------------------------------
def merge_lower_bound(
    build_sorted: jax.Array,
    probe_sorted: jax.Array,
    impl: str = "auto",
    *,
    window_rows: int = 1024,
    tile: int = 1024,
):
    """lower bound of each (sorted) probe key in the sorted build keys.

    impl='auto' checks tile spans eagerly (concrete values required);
    'pallas' forces the windowed kernel; 'xla' forces searchsorted."""
    if impl == "xla":
        return ref.lower_bound(build_sorted, probe_sorted)
    n_p = probe_sorted.shape[0]
    n_tiles = ceil_div(n_p, tile)
    firsts = probe_sorted[:: tile]
    coarse = jnp.searchsorted(build_sorted, firsts, side="left").astype(jnp.int32)
    win_idx = coarse // window_rows
    if impl == "auto":
        # span check: lb range covered by each tile's 2W window?
        lasts = probe_sorted[jnp.minimum(jnp.arange(n_tiles) * tile + tile - 1, n_p - 1)]
        coarse_hi = jnp.searchsorted(build_sorted, lasts, side="left").astype(jnp.int32)
        fits = bool(jnp.all(coarse_hi < (win_idx + 2) * window_rows))
        if not fits:
            return ref.lower_bound(build_sorted, probe_sorted)
    return _pallas_arm(
        "merge_lower_bound",
        lambda: lower_bound_windowed_pallas(
            build_sorted, probe_sorted, win_idx,
            window_rows=window_rows, tile=tile, interpret=None),
        lambda: ref.lower_bound(build_sorted, probe_sorted))


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------
def hash_probe(
    bkeys: jax.Array,
    off_r: jax.Array,
    probe_keys_part: jax.Array,
    probe_off: jax.Array,
    probe_sz: jax.Array,
    impl: str = "pallas",
):
    """Co-partition PK-FK probe over a partitioned probe side.

    Returns (vid_r, matched) aligned with probe_keys_part order."""
    P, cap_r = bkeys.shape
    n = probe_keys_part.shape[0]

    def xla_arm():
        # reconstruct per-row partition ids from the layout. Rows past the
        # last real partition (a sentinel partition's overhang) still map to
        # P - 1; their keys are KEY_SENTINEL so they can never match.
        row = jnp.arange(n, dtype=jnp.int32)
        part = jnp.clip(
            jnp.searchsorted(probe_off, row, side="right").astype(jnp.int32) - 1, 0, P - 1
        )
        return ref.hash_probe_blocks(bkeys, off_r, probe_keys_part, part)

    if impl == "xla":
        return xla_arm()

    def pallas_arm():
        cap_s = cap_r
        max_blocks = ceil_div(n, cap_s) + P
        pk, part, src_idx = layout_probe_blocks(probe_keys_part, probe_off, probe_sz, cap_s, max_blocks)
        vid, hit = hash_probe_pallas(bkeys, off_r, pk, part, interpret=None)
        # scatter sub-block results back to partitioned probe order
        flat_src = src_idx.reshape(-1)
        ok = flat_src >= 0
        vid_out = jnp.full((n,), -1, jnp.int32).at[jnp.where(ok, flat_src, n)].set(
            vid.reshape(-1), mode="drop"
        )
        hit_out = jnp.zeros((n,), jnp.int32).at[jnp.where(ok, flat_src, n)].set(
            hit.reshape(-1), mode="drop"
        )
        return vid_out, hit_out.astype(bool)

    return _pallas_arm("hash_probe", pallas_arm, xla_arm)


# ---------------------------------------------------------------------------
# fused probe + accumulate (group-join)
# ---------------------------------------------------------------------------
def _combine_group_partials(pk, ps_cols, pc, num_groups, key_dtype):
    """Sorted segmented combine of per-tile (key, sums..., count) partials
    into the dense (keys, sums (C, G), counts, n_found) accumulator contract
    — the same combine shape as groupby_sorted_sum, carrying counts and any
    number of sum columns through ONE sort."""
    sk, sc, *ss = jax.lax.sort((pk, pc) + tuple(ps_cols), num_keys=1,
                               is_stable=True)
    valid = sk != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid
    gid = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    n_found = gid[-1] + 1
    gid = jnp.where(valid & (gid < num_groups), gid, num_groups)
    keys_o = jnp.full((num_groups + 1,), KEY_SENTINEL, key_dtype).at[gid].set(
        jnp.where(valid, sk, KEY_SENTINEL), mode="drop"
    )
    sums_o = jnp.stack([
        jax.ops.segment_sum(jnp.where(valid, s, 0.0), gid,
                            num_segments=num_groups + 1)[:num_groups]
        for s in ss
    ]) if ss else jnp.zeros((0, num_groups), jnp.float32)
    counts_o = jax.ops.segment_sum(jnp.where(valid, sc, 0), gid,
                                   num_segments=num_groups + 1)
    return (keys_o[:num_groups], sums_o, counts_o[:num_groups],
            jnp.minimum(n_found, num_groups))


def groupjoin_probe_agg(
    bkeys: jax.Array,  # (P, capR) padded build key blocks
    bvals: jax.Array | None,  # (P, Cb, capR) build value blocks, None if none
    off_r: jax.Array,  # (P,) build partition offsets
    probe_keys_part: jax.Array,  # partitioned probe join keys
    gk_part: jax.Array,  # partitioned probe group keys
    pv_part: jax.Array | None,  # (Cp, n) partitioned probe value columns
    probe_off: jax.Array,
    probe_sz: jax.Array,
    num_groups: int,
    *,
    col_sides: tuple,  # ("probe"|"build", within-side index) per sum column
    impl: str = "pallas",
):
    """Co-partition pk_fk probe fused with grouped accumulation: each probe
    sub-block is matched against its build block ONCE and reduced to
    per-tile (group key, sums..., count) partials in VMEM — the joined rows
    are never written, and every aggregate column rides the same probe pass
    — then one sorted segmented combine produces the accumulator.

    Returns (group_keys[num_groups], sums[C, num_groups],
    counts[num_groups], valid_count)."""
    P, cap_r = bkeys.shape
    n = probe_keys_part.shape[0]
    count_only = not col_sides
    if count_only:  # keys+counts still flow through one (dummy) sum column
        col_sides = (("probe", 0),)
    if bvals is None:
        bvals = jnp.zeros((P, 1, cap_r), jnp.float32)
    if pv_part is None:
        pv_part = jnp.zeros((1, n), jnp.float32)
    def xla_arm():
        # reference arm: plain probe, then per-row values + segmented combine
        row = jnp.arange(n, dtype=jnp.int32)
        part = jnp.clip(
            jnp.searchsorted(probe_off, row, side="right").astype(jnp.int32) - 1,
            0, P - 1)
        vid, matched = ref.hash_probe_blocks(bkeys, off_r, probe_keys_part, part)
        bp = jnp.clip(
            jnp.searchsorted(off_r, vid, side="right").astype(jnp.int32) - 1,
            0, P - 1)
        slot = jnp.clip(vid - jnp.take(off_r, bp), 0, cap_r - 1)
        cols = []
        for side, j in col_sides:
            if side == "build":
                val = jnp.take(bvals[:, j, :].reshape(-1), bp * cap_r + slot)
            else:
                val = pv_part[j].astype(jnp.float32)
            cols.append(jnp.where(matched, val, 0.0))
        gke = jnp.where(matched, gk_part, KEY_SENTINEL)
        keys_o, sums_o, counts_o, found = _combine_group_partials(
            gke, cols, matched.astype(jnp.int32), num_groups, gk_part.dtype)
        return keys_o, sums_o[:0] if count_only else sums_o, counts_o, found

    if impl == "xla":
        return xla_arm()

    def pallas_arm():
        cap_s = cap_r
        max_blocks = ceil_div(n, cap_s) + P
        pk, part, src_idx = layout_probe_blocks(
            probe_keys_part, probe_off, probe_sz, cap_s, max_blocks)
        safe = jnp.clip(src_idx, 0, n - 1)
        pad = src_idx >= 0
        gkb = jnp.where(pad, jnp.take(gk_part, safe), KEY_SENTINEL)
        # (B, Cp, capS): every probe value column laid out with the same block map
        pvb = jnp.where(pad[:, None, :],
                        jnp.take(pv_part.astype(jnp.float32), safe, axis=1
                                 ).transpose(1, 0, 2), 0.0)
        pkeys, psums, pcounts = probe_agg_pallas(
            bkeys, bvals, pk, gkb, pvb, part,
            col_sides=tuple(col_sides), interpret=None)
        C = len(col_sides)
        keys_o, sums_o, counts_o, found = _combine_group_partials(
            pkeys.reshape(-1),
            [psums[:, c, :].reshape(-1) for c in range(C)],
            pcounts.reshape(-1), num_groups, gk_part.dtype)
        return keys_o, sums_o[:0] if count_only else sums_o, counts_o, found

    return _pallas_arm("groupjoin_probe_agg", pallas_arm, xla_arm)


# ---------------------------------------------------------------------------
# clustered gather
# ---------------------------------------------------------------------------
def clustered_gather(
    src: jax.Array,
    idx: jax.Array,
    impl: str = "auto",
    *,
    window_rows: int = 1024,
    tile: int = 1024,
):
    """GATHER with windowed-kernel dispatch. Invalid idx (<0) -> 0."""
    safe_idx = jnp.clip(idx, 0, src.shape[0] - 1)
    if impl == "xla":
        out = jnp.take(src, safe_idx, axis=0)
        return jnp.where(idx >= 0, out, 0)
    n = idx.shape[0]
    n_tiles = ceil_div(n, tile)
    t0 = safe_idx[::tile]
    win_idx = t0 // window_rows
    if impl == "auto":
        tile_pad = jnp.pad(safe_idx, (0, n_tiles * tile - n)).reshape(n_tiles, tile)
        spans_ok = bool(jnp.all(tile_pad.max(1) < (win_idx + 2) * window_rows)
                        & jnp.all(tile_pad.min(1) >= win_idx * window_rows))
        if not spans_ok:
            out = jnp.take(src, safe_idx, axis=0)
            return jnp.where(idx >= 0, out, 0)

    def pallas_arm():
        out = gather_windowed_pallas(
            src, safe_idx, win_idx, window_rows=window_rows, tile=tile,
            interpret=None)
        return jnp.where(idx >= 0, out, 0)

    return _pallas_arm(
        "clustered_gather", pallas_arm,
        lambda: jnp.where(idx >= 0, jnp.take(src, safe_idx, axis=0), 0))


# ---------------------------------------------------------------------------
# grouped aggregation over sorted keys
# ---------------------------------------------------------------------------
def groupby_sorted_sum(
    sorted_keys: jax.Array,
    values: jax.Array,
    num_groups: int,
    impl: str = "pallas",
    *,
    tile: int = 256,
):
    """Group sums over key-sorted rows: Pallas tile partials + host combine.
    Returns (group_keys, group_sums, count)."""
    if impl == "pallas":
        pk, ps, pc = _pallas_arm(
            "groupby_sorted_sum",
            lambda: segsum_partials_pallas(sorted_keys, values, tile=tile,
                                           interpret=None),
            lambda: ref.segsum_partials(sorted_keys, values, tile))
    else:
        pk, ps, pc = ref.segsum_partials(sorted_keys, values, tile)
    # combine partials: they are key-sorted except sentinel slots; re-sort.
    sk, ss = jax.lax.sort((pk, ps), num_keys=1, is_stable=True)
    valid = sk != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid
    gid = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    n_found = gid[-1] + 1
    gid = jnp.where(valid & (gid < num_groups), gid, num_groups)
    keys_o = jnp.full((num_groups + 1,), KEY_SENTINEL, sorted_keys.dtype).at[gid].set(
        jnp.where(valid, sk, KEY_SENTINEL), mode="drop"
    )
    sums_o = jax.ops.segment_sum(jnp.where(valid, ss, 0.0), gid, num_segments=num_groups + 1)
    return keys_o[:num_groups], sums_o[:num_groups], jnp.minimum(n_found, num_groups)

"""Public jit'd wrappers + kernel/XLA dispatch for the Pallas kernels.

Dispatch policy mirrors the paper's planner logic: the windowed (clustered)
kernels are only profitable/correct when the gather map / merge frontier is
clustered, so each wrapper measures the per-tile span (cheap, O(n/tile)) and
falls back to XLA's random-access path otherwise. On this CPU container all
kernels execute with interpret=True; on a real TPU set
`repro.kernels.ops.INTERPRET = False`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .common import ceil_div
from .histogram import histogram_pallas
from .radix_partition import partition_ranks_pallas, block_histograms_pallas
from .merge_join import lower_bound_windowed_pallas
from .hash_probe import hash_probe_pallas, layout_probe_blocks
from .gather import gather_windowed_pallas
from .segsum import segsum_partials_pallas

INTERPRET = True  # CPU container: interpret-mode execution of kernel bodies

KEY_SENTINEL = -1


# ---------------------------------------------------------------------------
# histogram / partition ranks
# ---------------------------------------------------------------------------
def histogram(digits: jax.Array, num_bins: int, impl: str = "pallas") -> jax.Array:
    if impl == "pallas":
        return histogram_pallas(digits, num_bins, interpret=INTERPRET)
    return ref.histogram(digits, num_bins)


def partition_ranks(digits: jax.Array, num_bins: int, impl: str = "pallas"):
    """dest position per element (stable partition)."""
    if impl == "pallas":
        dest, off, sz = partition_ranks_pallas(digits, num_bins, interpret=INTERPRET)
        return dest, off, sz
    dest = ref.partition_ranks(digits, num_bins)
    sz = ref.histogram(digits, num_bins)
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sz)[:-1].astype(jnp.int32)])
    return dest, off, sz


def apply_partition(dest: jax.Array, *arrays: jax.Array):
    """Materialize the partition: invert dest (scatter of iota) and gather.
    The kernel computes ranks; XLA moves the bytes (DESIGN.md §2)."""
    n = dest.shape[0]
    inv = jnp.zeros((n,), jnp.int32).at[jnp.clip(dest, 0, n - 1)].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop"
    )
    return tuple(jnp.take(a, inv, axis=0) for a in arrays)


# ---------------------------------------------------------------------------
# merge lower bound
# ---------------------------------------------------------------------------
def merge_lower_bound(
    build_sorted: jax.Array,
    probe_sorted: jax.Array,
    impl: str = "auto",
    *,
    window_rows: int = 1024,
    tile: int = 1024,
):
    """lower bound of each (sorted) probe key in the sorted build keys.

    impl='auto' checks tile spans eagerly (concrete values required);
    'pallas' forces the windowed kernel; 'xla' forces searchsorted."""
    if impl == "xla":
        return ref.lower_bound(build_sorted, probe_sorted)
    n_p = probe_sorted.shape[0]
    n_tiles = ceil_div(n_p, tile)
    firsts = probe_sorted[:: tile]
    coarse = jnp.searchsorted(build_sorted, firsts, side="left").astype(jnp.int32)
    win_idx = coarse // window_rows
    if impl == "auto":
        # span check: lb range covered by each tile's 2W window?
        lasts = probe_sorted[jnp.minimum(jnp.arange(n_tiles) * tile + tile - 1, n_p - 1)]
        coarse_hi = jnp.searchsorted(build_sorted, lasts, side="left").astype(jnp.int32)
        fits = bool(jnp.all(coarse_hi < (win_idx + 2) * window_rows))
        if not fits:
            return ref.lower_bound(build_sorted, probe_sorted)
    return lower_bound_windowed_pallas(
        build_sorted, probe_sorted, win_idx,
        window_rows=window_rows, tile=tile, interpret=INTERPRET,
    )


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------
def hash_probe(
    bkeys: jax.Array,
    off_r: jax.Array,
    probe_keys_part: jax.Array,
    probe_off: jax.Array,
    probe_sz: jax.Array,
    impl: str = "pallas",
):
    """Co-partition PK-FK probe over a partitioned probe side.

    Returns (vid_r, matched) aligned with probe_keys_part order."""
    P, cap_r = bkeys.shape
    n = probe_keys_part.shape[0]
    if impl == "xla":
        # reconstruct per-row partition ids from the layout
        row = jnp.arange(n, dtype=jnp.int32)
        part = jnp.clip(
            jnp.searchsorted(probe_off, row, side="right").astype(jnp.int32) - 1, 0, P - 1
        )
        return ref.hash_probe_blocks(bkeys, off_r, probe_keys_part, part)
    cap_s = cap_r
    max_blocks = ceil_div(n, cap_s) + P
    pk, part, src_idx = layout_probe_blocks(probe_keys_part, probe_off, probe_sz, cap_s, max_blocks)
    vid, hit = hash_probe_pallas(bkeys, off_r, pk, part, interpret=INTERPRET)
    # scatter sub-block results back to partitioned probe order
    flat_src = src_idx.reshape(-1)
    ok = flat_src >= 0
    vid_out = jnp.full((n,), -1, jnp.int32).at[jnp.where(ok, flat_src, n)].set(
        vid.reshape(-1), mode="drop"
    )
    hit_out = jnp.zeros((n,), jnp.int32).at[jnp.where(ok, flat_src, n)].set(
        hit.reshape(-1), mode="drop"
    )
    return vid_out, hit_out.astype(bool)


# ---------------------------------------------------------------------------
# clustered gather
# ---------------------------------------------------------------------------
def clustered_gather(
    src: jax.Array,
    idx: jax.Array,
    impl: str = "auto",
    *,
    window_rows: int = 1024,
    tile: int = 1024,
):
    """GATHER with windowed-kernel dispatch. Invalid idx (<0) -> 0."""
    safe_idx = jnp.clip(idx, 0, src.shape[0] - 1)
    if impl == "xla":
        out = jnp.take(src, safe_idx, axis=0)
        return jnp.where(idx >= 0, out, 0)
    n = idx.shape[0]
    n_tiles = ceil_div(n, tile)
    t0 = safe_idx[::tile]
    win_idx = t0 // window_rows
    if impl == "auto":
        tile_pad = jnp.pad(safe_idx, (0, n_tiles * tile - n)).reshape(n_tiles, tile)
        spans_ok = bool(jnp.all(tile_pad.max(1) < (win_idx + 2) * window_rows)
                        & jnp.all(tile_pad.min(1) >= win_idx * window_rows))
        if not spans_ok:
            out = jnp.take(src, safe_idx, axis=0)
            return jnp.where(idx >= 0, out, 0)
    out = gather_windowed_pallas(
        src, safe_idx, win_idx, window_rows=window_rows, tile=tile, interpret=INTERPRET
    )
    return jnp.where(idx >= 0, out, 0)


# ---------------------------------------------------------------------------
# grouped aggregation over sorted keys
# ---------------------------------------------------------------------------
def groupby_sorted_sum(
    sorted_keys: jax.Array,
    values: jax.Array,
    num_groups: int,
    impl: str = "pallas",
    *,
    tile: int = 256,
):
    """Group sums over key-sorted rows: Pallas tile partials + host combine.
    Returns (group_keys, group_sums, count)."""
    if impl == "pallas":
        pk, ps, pc = segsum_partials_pallas(sorted_keys, values, tile=tile, interpret=INTERPRET)
    else:
        pk, ps, pc = ref.segsum_partials(sorted_keys, values, tile)
    # combine partials: they are key-sorted except sentinel slots; re-sort.
    sk, ss = jax.lax.sort((pk, ps), num_keys=1, is_stable=True)
    valid = sk != KEY_SENTINEL
    bnd = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid
    gid = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    n_found = gid[-1] + 1
    gid = jnp.where(valid & (gid < num_groups), gid, num_groups)
    keys_o = jnp.full((num_groups + 1,), KEY_SENTINEL, sorted_keys.dtype).at[gid].set(
        jnp.where(valid, sk, KEY_SENTINEL), mode="drop"
    )
    sums_o = jax.ops.segment_sum(jnp.where(valid, ss, 0.0), gid, num_segments=num_groups + 1)
    return keys_o[:num_groups], sums_o[:num_groups], jnp.minimum(n_found, num_groups)

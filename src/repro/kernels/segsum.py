"""Segmented partial-aggregation kernel (grouped aggregations,
extension-per-assigned-title; see groupby.py).

Input rows are key-sorted; each grid step processes one VMEM-resident tile,
detects run boundaries, and reduces each local run with one-hot matmuls
(sum/count) — scatter-free MXU work, the TPU analogue of a thread block's
shared-memory hash aggregation. Per-tile partials (at most one per distinct
key per tile) are combined by a cheap host-side pass; heavy-hitter keys
collapse tile-locally first, which is how skew is absorbed.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from .common import ceil_div, combine_u32_hi_lo, resolve_interpret, split_u32_hi_lo

KEY_SENTINEL = -1


def _segsum_kernel(k_ref, v_ref, pk_ref, ps_ref, pc_ref):
    k = k_ref[0]  # (T,) sorted within tile
    v = v_ref[0].astype(jnp.float32)
    T = k.shape[0]
    valid = k != KEY_SENTINEL
    prev = jnp.concatenate([jnp.full((1,), KEY_SENTINEL, k.dtype), k[:-1]])
    bnd = (k != prev) & valid
    lgid = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    lgid = jnp.where(valid, lgid, T)
    iota = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    oh = (lgid[:, None] == iota).astype(jnp.float32)  # (rows T, groups T)
    ps_ref[0, :] = v @ oh
    counts = jnp.ones((T,), jnp.float32) @ oh
    pc_ref[0, :] = counts.astype(jnp.int32)
    # group keys via run-head selection (single 1 per column -> exact matmul)
    head = oh * bnd[:, None].astype(jnp.float32)
    hi16, lo16 = split_u32_hi_lo(k)
    pk = combine_u32_hi_lo(head.T @ hi16, head.T @ lo16, k.dtype)
    pk_ref[0, :] = jnp.where(counts > 0, pk, KEY_SENTINEL)


def segsum_partials_pallas(
    sorted_keys: jax.Array,
    values: jax.Array,
    *,
    tile: int = 256,
    interpret: bool | None = None,
):
    """Per-tile (keys, sums, counts) partials over key-sorted input.
    Matches ref.segsum_partials."""
    n = sorted_keys.shape[0]
    n_tiles = ceil_div(n, tile)
    kp = jnp.concatenate(
        [sorted_keys, jnp.full((n_tiles * tile - n,), KEY_SENTINEL, sorted_keys.dtype)]
    ).reshape(n_tiles, tile)
    vp = jnp.concatenate(
        [values, jnp.zeros((n_tiles * tile - n,), values.dtype)]
    ).reshape(n_tiles, tile)
    pk, ps, pc = pl.pallas_call(
        _segsum_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile), sorted_keys.dtype),
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(kp, vp)
    return pk.reshape(-1), ps.reshape(-1), pc.reshape(-1)

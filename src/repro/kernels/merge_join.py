"""Merge-join match-finding kernel (SMJ, §3.1) — windowed lower-bound.

GPU Merge Path exists to load-balance threads over the merge frontier; on
TPU the grid is balanced by construction (equal probe tiles), and the
per-tile work becomes a dense rank count against a VMEM-resident window of
the sorted build keys (DESIGN.md §2):

    lb(p) = win_start + |{ w in window : w < p }|

which is exact when the window covers [lb(first), lb(last)] of the tile —
guaranteed by the two-level scheme in ops.py (a cheap coarse searchsorted of
tile boundaries chooses each tile's window; tiles whose span exceeds the
window fall back to XLA searchsorted). Probe tiles are sorted, so windows
are monotone — sequential HBM traffic, the same clustering argument as GFTR.

Layout: build keys padded to (n_wb + 1, W) with an INT_MAX tail block; each
grid step sees two consecutive W-blocks (an aligned 2W window) selected by a
scalar-prefetched window index.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

from .common import ceil_div, resolve_interpret

INT_MAX = jnp.iinfo(jnp.int32).max


def _lb_kernel(window_rows: int, w_ref, probe_ref, lo_ref, hi_ref, out_ref):
    i = pl.program_id(0)
    win_start = w_ref[i] * window_rows
    window = jnp.concatenate([lo_ref[0], hi_ref[0]])  # (2W,) sorted
    p = probe_ref[0]  # (T,)
    lt = (window[None, :] < p[:, None]).astype(jnp.int32)  # (T, 2W)
    out_ref[0, :] = win_start + lt.sum(axis=1)


def lower_bound_windowed_pallas(
    build_sorted: jax.Array,
    probe_sorted: jax.Array,
    win_idx: jax.Array,
    *,
    window_rows: int = 1024,
    tile: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """lb per probe element, given per-tile window indices (in units of
    window_rows). Caller guarantees the 2W window covers each tile's range
    (ops.py checks and falls back otherwise). Returns int32 (n_probe,)."""
    n_b, n_p = build_sorted.shape[0], probe_sorted.shape[0]
    n_wb = ceil_div(n_b, window_rows)
    bpad = jnp.full((n_wb * window_rows - n_b + window_rows,), INT_MAX, build_sorted.dtype)
    build2 = jnp.concatenate([build_sorted, bpad]).reshape(n_wb + 1, window_rows)

    n_tiles = ceil_div(n_p, tile)
    ppad = jnp.full((n_tiles * tile - n_p,), INT_MAX, probe_sorted.dtype)
    probe2 = jnp.concatenate([probe_sorted, ppad]).reshape(n_tiles, tile)

    win_idx = jnp.clip(win_idx.astype(jnp.int32), 0, n_wb - 1)

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, w: (i, 0)),
            pl.BlockSpec((1, window_rows), lambda i, w: (w[i], 0)),
            pl.BlockSpec((1, window_rows), lambda i, w: (w[i] + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, w: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_lb_kernel, window_rows),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(win_idx, probe2, build2, build2)
    return out.reshape(-1)[:n_p]

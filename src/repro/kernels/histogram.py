"""Radix-histogram Pallas kernel.

Grid: sequential row-blocks of the digit array (viewed as (rows, 128) lanes).
Each step builds a block-local histogram by summing a one-hot expansion
(dense VPU/MXU work — the TPU replacement for shared-memory atomics,
DESIGN.md §2) and accumulates into the single (1, num_bins) output block,
which stays VMEM-resident across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import LANES, as_lanes, ceil_div


def _hist_kernel(num_bins: int, x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].reshape(-1)  # (rows*128,)
    bins = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], num_bins), 1)
    oh = (x[:, None] == bins).astype(jnp.int32)
    o_ref[...] += oh.sum(axis=0, keepdims=True)


def histogram_pallas(
    digits: jax.Array,
    num_bins: int,
    *,
    block_rows: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Counts per digit. digits int32; out-of-range digits are ignored
    (padding uses -1). Returns (num_bins,) int32."""
    d2 = as_lanes(digits, fill=-1)  # (R, 128)
    rows = d2.shape[0]
    grid = ceil_div(rows, block_rows)
    d2 = jnp.pad(d2, ((0, grid * block_rows - rows), (0, 0)), constant_values=-1)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, num_bins),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_bins), jnp.int32),
        interpret=interpret,
    )(d2)
    return out[0]

"""Radix-histogram Pallas kernel.

Grid: sequential row-blocks of the digit array (viewed as (rows, 128) lanes).
Each step builds a block-local histogram by summing a one-hot expansion
(dense VPU/MXU work — the TPU replacement for shared-memory atomics,
DESIGN.md §2) and accumulates into the single (1, num_bins) output block,
which stays VMEM-resident across the whole grid.

The one-hot core (`common.digit_onehot`) is shared with the per-block
histogram and rank kernels in radix_partition.py; padding rows carry
PAD_DIGIT and are excluded from the counts by construction.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from .common import LANES, digit_lane_blocks, digit_onehot, resolve_interpret


def _hist_kernel(num_bins: int, x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].reshape(-1)  # (rows*128,)
    oh = digit_onehot(x, num_bins)
    o_ref[...] += oh.sum(axis=0, keepdims=True)


def histogram_pallas(
    digits: jax.Array,
    num_bins: int,
    *,
    block_rows: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """Counts per digit. digits int32; padding/pad rows (PAD_DIGIT or any
    negative digit) are excluded by construction. Returns (num_bins,)
    int32."""
    d2 = digit_lane_blocks(digits, block_rows)
    grid = d2.shape[0] // block_rows
    out = pl.pallas_call(
        functools.partial(_hist_kernel, num_bins),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_bins), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(d2)
    return out[0]

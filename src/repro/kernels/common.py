"""Shared helpers for the Pallas TPU kernels.

Conventions (see DESIGN.md §2):
  * 1-D relational arrays are padded and viewed as (rows, 128) so blocks are
    lane-aligned; row-block sizes are multiples of 8 (f32 sublane).
  * Integer payloads that flow through one-hot matmuls are split into 16-bit
    halves so the f32 MXU accumulates them exactly (values < 2^16 are exact
    in f32; the one-hot has a single 1 per row, so no rounding ever occurs).
  * Padding rows in digit arrays always carry PAD_DIGIT (< 0) and are
    excluded from histograms/ranks by construction (`digit_onehot` masks
    them), never by relying on a fill value happening to miss a bin.
  * Kernels default to interpret mode off-TPU (`default_interpret`;
    override with REPRO_PALLAS_INTERPRET=0/1) and are written with TPU
    BlockSpecs for the v5e target.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

LANES = 128
SUBLANES = 8

# The single fill value for padded digit slots. Kernels exclude pad rows by
# construction: `digit_onehot` masks x < 0 out of every histogram/rank
# one-hot, so a pad row can never be counted or ranked into a bin.
PAD_DIGIT = -1


_INTERPRET_TRUE = ("1", "true", "yes", "on")
_INTERPRET_FALSE = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Pallas execution mode: compiled kernels on TPU, interpret elsewhere.

    REPRO_PALLAS_INTERPRET=1/0 (also true/false/yes/on/off...) overrides the
    backend detection — e.g. force interpret on a TPU host while debugging,
    or force compilation off-TPU to surface lowering errors. Unknown values
    raise instead of silently picking a mode: a typo'd override must not
    flip which compiler ran the kernels."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        val = env.strip().lower()
        if val in _INTERPRET_TRUE:
            return True
        if val in _INTERPRET_FALSE:
            return False
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET={env!r} is not a recognized value; "
            f"allowed: {'/'.join(_INTERPRET_TRUE)} (interpret) or "
            f"{'/'.join(_INTERPRET_FALSE)} (compiled)")
    try:
        return jax.default_backend() != "tpu"
    except Exception:  # pragma: no cover - no backend at all
        return True


def resolve_interpret(interpret: bool | None) -> bool:
    """Kernel-entry helper: an explicit interpret flag wins, None defers to
    the backend detection (+ env override) above."""
    return default_interpret() if interpret is None else bool(interpret)


def digit_onehot(x: jax.Array, num_bins: int) -> jax.Array:
    """(T,) int digits -> (T, num_bins) 0/1 int32 one-hot.

    The shared core of every histogram/rank kernel (and of their dense
    interpret-mode twins): bin membership is an equality against a bin iota,
    and pad rows (PAD_DIGIT, or any negative digit) are masked out
    explicitly — excluded by construction, not by -1 never matching."""
    bins = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], num_bins), 1)
    return ((x[:, None] == bins) & (x[:, None] >= 0)).astype(jnp.int32)


def pad_to(x: jax.Array, multiple: int, fill=0) -> jax.Array:
    n = x.shape[0]
    pad = -n % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def as_lanes(x: jax.Array, fill=0) -> jax.Array:
    """(n,) -> (ceil(n/128), 128)."""
    xp = pad_to(x, LANES, fill)
    return xp.reshape(-1, LANES)


def digit_lane_blocks(digits: jax.Array, block_rows: int) -> jax.Array:
    """The one pad-and-tile path for digit arrays entering histogram/rank
    kernels: (n,) -> (grid*block_rows, 128) with every padding slot —
    lane padding and grid padding alike — filled with PAD_DIGIT. Pairs with
    `digit_onehot`, which drops those rows by construction."""
    d2 = as_lanes(digits, fill=PAD_DIGIT)
    rows = d2.shape[0]
    grid = ceil_div(rows, block_rows)
    return jnp.pad(d2, ((0, grid * block_rows - rows), (0, 0)),
                   constant_values=PAD_DIGIT)


def split_u32_hi_lo(x: jax.Array):
    """int32/uint32 -> (hi16, lo16) as f32, exactly representable."""
    u = x.astype(jnp.uint32)
    hi = (u >> 16).astype(jnp.float32)
    lo = (u & jnp.uint32(0xFFFF)).astype(jnp.float32)
    return hi, lo


def combine_u32_hi_lo(hi: jax.Array, lo: jax.Array, dtype=jnp.int32):
    u = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
    return u.astype(dtype)


def exact_onehot_matmul_i32(onehot_f32: jax.Array, values_i32: jax.Array) -> jax.Array:
    """(T, W) one-hot @ (W,) int32 -> (T,) int32, exact via hi/lo split.

    Turns a gather into MXU work — the TPU replacement for per-thread
    random loads (DESIGN.md §2)."""
    hi, lo = split_u32_hi_lo(values_i32)
    out_hi = onehot_f32 @ hi
    out_lo = onehot_f32 @ lo
    return combine_u32_hi_lo(out_hi, out_lo, values_i32.dtype)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)

"""Shared helpers for the Pallas TPU kernels.

Conventions (see DESIGN.md §2):
  * 1-D relational arrays are padded and viewed as (rows, 128) so blocks are
    lane-aligned; row-block sizes are multiples of 8 (f32 sublane).
  * Integer payloads that flow through one-hot matmuls are split into 16-bit
    halves so the f32 MXU accumulates them exactly (values < 2^16 are exact
    in f32; the one-hot has a single 1 per row, so no rounding ever occurs).
  * All kernels run under interpret=True on CPU (this container) and are
    written with TPU BlockSpecs for the v5e target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANES = 128
SUBLANES = 8


def pad_to(x: jax.Array, multiple: int, fill=0) -> jax.Array:
    n = x.shape[0]
    pad = -n % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])


def as_lanes(x: jax.Array, fill=0) -> jax.Array:
    """(n,) -> (ceil(n/128), 128)."""
    xp = pad_to(x, LANES, fill)
    return xp.reshape(-1, LANES)


def split_u32_hi_lo(x: jax.Array):
    """int32/uint32 -> (hi16, lo16) as f32, exactly representable."""
    u = x.astype(jnp.uint32)
    hi = (u >> 16).astype(jnp.float32)
    lo = (u & jnp.uint32(0xFFFF)).astype(jnp.float32)
    return hi, lo


def combine_u32_hi_lo(hi: jax.Array, lo: jax.Array, dtype=jnp.int32):
    u = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
    return u.astype(dtype)


def exact_onehot_matmul_i32(onehot_f32: jax.Array, values_i32: jax.Array) -> jax.Array:
    """(T, W) one-hot @ (W,) int32 -> (T,) int32, exact via hi/lo split.

    Turns a gather into MXU work — the TPU replacement for per-thread
    random loads (DESIGN.md §2)."""
    hi, lo = split_u32_hi_lo(values_i32)
    out_hi = onehot_f32 @ hi
    out_lo = onehot_f32 @ lo
    return combine_u32_hi_lo(out_hi, out_lo, values_i32.dtype)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)

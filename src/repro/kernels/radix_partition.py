"""Stable radix-partition rank kernels and the sort-free partition planner
(RADIX-PARTITION primitive, §2.3/§4.3).

Single pass — the classic GPU partitioning pipeline (He et al. SIGMOD'08;
Sioulas et al. ICDE'19), with prefix sums instead of atomics (deterministic
by construction — the property PHJ-OM needs):

  pass A (histogram): per-block digit histograms -> (num_blocks, G)
  host:   exclusive prefix over blocks & digits -> per-block base offsets
  pass B (rank):      per-element destination index
            dest[i] = base[block, digit] + rank_within_block(i)

The within-block stable rank is a cumsum over the one-hot digit expansion —
dense VPU work; no scatter ever happens inside a kernel. The actual data
movement is then a single XLA gather with the inverted permutation.

Multi-pass (`partition_plan_pallas`): fan-outs past one pass's bin budget
compose LSD passes of <= `pass_bits` bits each — pass k ranks bits
[k*b, (k+1)*b) of the digit over the order left by pass k-1, and stability
makes the composition equal the single stable partition on all bits (the
§4.3 argument, property-tested against the sort-based XLA arm). Each pass
is O(n * 2^pass_bits) dense work plus one n-sized scatter to fold the
pass's destinations into the running permutation; no comparison sort
anywhere, so the whole plan is linear in n.

Interpret-mode note: off-TPU the per-pass ranks run as the kernel's own
arithmetic in straight-line jnp (`pass_impl="dense"` — `digit_onehot` +
cumsum, exactly the kernel body without the pallas_call emulation overhead);
on TPU the compiled two-kernel pipeline runs (`pass_impl="kernel"`). Both
arms are parity-tested against each other and the sort-based reference.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

from .common import LANES, ceil_div, digit_lane_blocks, digit_onehot, resolve_interpret


def _block_hist_kernel(num_bins: int, x_ref, o_ref):
    x = x_ref[...].reshape(-1)
    oh = digit_onehot(x, num_bins)
    o_ref[...] = oh.sum(axis=0, keepdims=True)


def block_histograms_pallas(
    digits: jax.Array, num_bins: int, *, block_rows: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    """(num_blocks, num_bins) per-block histograms. Padding rows (PAD_DIGIT)
    are excluded by construction — `digit_onehot` masks negative digits out
    of the one-hot, so no fill value can ever be counted into a bin."""
    d2 = digit_lane_blocks(digits, block_rows)
    grid = d2.shape[0] // block_rows
    return pl.pallas_call(
        functools.partial(_block_hist_kernel, num_bins),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, num_bins), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(d2)


def _rank_kernel(num_bins: int, x_ref, base_ref, o_ref):
    x = x_ref[...].reshape(-1)  # (T,)
    oh = digit_onehot(x, num_bins)  # (T, G); pad rows all-zero
    excl = jnp.cumsum(oh, axis=0) - oh  # exclusive within-block rank per digit
    # own-column selection without gather: elementwise mask + row-sum
    rank = (excl * oh).sum(axis=1)
    base = (base_ref[...][0][None, :] * oh).sum(axis=1)  # base[digit_i]
    dest = jnp.where(x >= 0, base + rank, -1)
    o_ref[...] = dest.reshape(o_ref.shape)


def partition_ranks_pallas(
    digits: jax.Array,
    num_bins: int,
    *,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Destination index per element for the stable partition (one pass).

    Returns (dest, offsets, sizes): dest[i] = output position of element i;
    offsets/sizes describe the contiguous partition layout. Negative digits
    (PAD_DIGIT padding) get dest -1 and never occupy a position."""
    n = digits.shape[0]
    interpret = resolve_interpret(interpret)
    bh = block_histograms_pallas(digits, num_bins, block_rows=block_rows,
                                 interpret=interpret)
    sizes = bh.sum(axis=0)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1].astype(jnp.int32)])
    # base[b, g] = offsets[g] + sum_{b' < b} bh[b', g]
    prev = jnp.cumsum(bh, axis=0) - bh
    base = (offsets[None, :] + prev).astype(jnp.int32)

    d2 = digit_lane_blocks(digits, block_rows)
    grid = d2.shape[0] // block_rows
    dest = pl.pallas_call(
        functools.partial(_rank_kernel, num_bins),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d2.shape[0], LANES), jnp.int32),
        interpret=interpret,
    )(d2, base)
    return dest.reshape(-1)[:n], offsets, sizes


# ---------------------------------------------------------------------------
# Sort-free multi-pass planner
# ---------------------------------------------------------------------------
def _dense_pass_dest(digits: jax.Array, num_bins: int) -> jax.Array:
    """The rank kernels' arithmetic as straight-line jnp — histogram, digit
    prefix, and stable within-digit rank from one masked one-hot cumsum.
    This is the interpret-mode arm of a plan pass: identical math to
    `partition_ranks_pallas` (same `digit_onehot` core) without the
    pallas_call emulation overhead."""
    oh = digit_onehot(digits, num_bins)  # (n, G)
    excl = jnp.cumsum(oh, axis=0) - oh  # exclusive within-digit rank
    sizes = excl[-1] + oh[-1] if digits.shape[0] else jnp.zeros(
        (num_bins,), jnp.int32)
    offsets = (jnp.cumsum(sizes) - sizes).astype(jnp.int32)
    rank = (excl * oh).sum(axis=1)
    base = jnp.take(offsets, jnp.clip(digits, 0, num_bins - 1))
    return jnp.where(digits >= 0, base + rank, -1)


def pass_dest(digits: jax.Array, num_bins: int, *,
              pass_impl: str = "auto", block_rows: int = 8,
              interpret: bool | None = None) -> jax.Array:
    """One stable partition pass: destination per element for `num_bins`
    digits. pass_impl: 'kernel' forces the two-kernel pallas pipeline,
    'dense' the straight-line jnp twin, 'auto' picks dense under interpret
    mode (same math, no emulation overhead) and the kernels on TPU."""
    interpret = resolve_interpret(interpret)
    if pass_impl == "auto":
        pass_impl = "dense" if interpret else "kernel"
    if pass_impl == "dense":
        return _dense_pass_dest(digits.astype(jnp.int32), num_bins)
    dest, _, _ = partition_ranks_pallas(
        digits.astype(jnp.int32), num_bins, block_rows=block_rows,
        interpret=interpret)
    return dest


def _compose_lsd(extract_digit, n: int, total_bits: int, pass_bits: int,
                 tail_mask=None, *, pass_impl: str = "auto",
                 interpret: bool | None = None) -> jax.Array:
    """Compose stable LSD passes into one gather-form permutation.

    extract_digit(perm, bit, bits) must return the pass digits IN CURRENT
    ORDER (i.e. of source rows perm[0..n)). `tail_mask`, when given, marks
    rows of a dedicated trailing class (the planner's sentinel partition):
    each pass ranks them into one extra bin past the bit bins, which keeps
    them stably behind every real digit without widening the bit passes.

    Each pass costs one rank computation plus one n-sized scatter — the
    inversion that folds the pass's scatter-form destinations into the
    running gather-form permutation. No sort primitive anywhere."""
    iota = jnp.arange(n, dtype=jnp.int32)
    perm = iota
    bit = 0
    first = True
    while first or bit < total_bits:
        bits = min(pass_bits, max(total_bits - bit, 0))
        nb = (1 << bits) + (1 if tail_mask is not None else 0)
        pd = extract_digit(perm, bit, bits)
        if tail_mask is not None:
            tm = tail_mask if first else jnp.take(tail_mask, perm)
            pd = jnp.where(tm, nb - 1, pd)
        dest = pass_dest(pd, nb, pass_impl=pass_impl, interpret=interpret)
        perm = jnp.zeros((n,), jnp.int32).at[dest].set(perm, mode="drop")
        bit += bits
        first = False
    return perm


def partition_plan_pallas(
    digits: jax.Array,
    num_partitions: int,
    *,
    carry=(),
    max_pass_bits: int | None = None,
    pass_impl: str = "auto",
    interpret: bool | None = None,
):
    """Sort-free stable partition plan: histogram -> prefix -> rank passes,
    LSD-composed for any fan-out. Drop-in producer of the planner contract:

    Returns (perm, carried, offsets, sizes), all layout arrays int32:
      perm[j]    = source row landing at output position j (gather form)
      offsets[p] = first output position of partition p
      sizes[p]   = rows in partition p

    digits must lie in [0, num_partitions). Carried columns are materialized
    with one gather through the composed permutation each (they cannot ride
    the rank passes, which move no payload bytes at all — that is the point);
    the contract and values match the XLA reference arm exactly.

    When num_partitions-1 crosses a pass boundary that num_partitions-2 does
    not (the group-by planner's 2^k+1 layout, whose last partition swallows
    sentinel padding), the top partition is ranked as a dedicated tail class
    inside each pass instead of paying an extra whole pass for one bin.

    offsets come from a binary search over the partitioned digits (they are
    sorted by construction after the final pass) — no bincount scatter, no
    sort."""
    n = digits.shape[0]
    digits = digits.astype(jnp.int32)
    interpret = resolve_interpret(interpret)
    # 8-bit passes on TPU (the paper's Ampere bound); 4-bit in interpret
    # mode, where a pass is O(n * bins) dense work and smaller bins win.
    pb = 4 if (interpret and pass_impl != "kernel") else 8
    if max_pass_bits is not None:
        pb = max(1, min(pb, max_pass_bits))
    B = num_partitions
    full_bits = max(1, (B - 1).bit_length())
    tail_bits = max((B - 2).bit_length(), 0) if B >= 2 else 0
    use_tail = B >= 2 and ceil_div(tail_bits, pb) < ceil_div(full_bits, pb)
    tail_mask = (digits == B - 1) if use_tail else None
    total_bits = tail_bits if use_tail else full_bits

    def extract(perm, bit, bits):
        cur = digits if bit == 0 else jnp.take(digits, perm)
        return (cur >> bit) & ((1 << bits) - 1)

    perm = _compose_lsd(extract, n, total_bits, pb, tail_mask,
                        pass_impl=pass_impl, interpret=interpret)
    dsort = jnp.take(digits, perm)  # sorted by construction
    offsets = jnp.searchsorted(
        dsort, jnp.arange(B, dtype=jnp.int32), side="left").astype(jnp.int32)
    sizes = jnp.diff(jnp.concatenate(
        [offsets, jnp.full((1,), n, jnp.int32)])).astype(jnp.int32)
    carried = tuple(jnp.take(c, perm, axis=0) for c in carry)
    return perm, carried, offsets, sizes


def sort_plan_radix(keys: jax.Array, *, pass_impl: str = "auto",
                    interpret: bool | None = None):
    """Sort-free stable sort plan over full integer keys: LSD rank passes
    over the sign-biased 32-bit pattern. Returns (sorted_keys, perm) with
    the `plan_sort_permutation` contract; equals the XLA stable sort
    exactly (parity-tested). int32/uint32 keys only — the radix arm exists
    for radix-hardware parity and fully sort-free pipelines; XLA's tuned
    sort remains the default production arm (§2.3)."""
    if keys.dtype not in (jnp.int32, jnp.uint32):
        raise TypeError(f"radix sort plan needs (u)int32 keys, got {keys.dtype}")
    n = keys.shape[0]
    # signed keys: xor the sign bit so unsigned digit order equals signed
    # key order; unsigned keys are already in digit order
    bias = jnp.uint32(0x80000000 if keys.dtype == jnp.int32 else 0)
    u = keys.astype(jnp.uint32) ^ bias
    interpret = resolve_interpret(interpret)
    pb = 4 if (interpret and pass_impl != "kernel") else 8

    def extract(perm, bit, bits):
        cur = u if bit == 0 else jnp.take(u, perm)
        return ((cur >> bit) & ((1 << bits) - 1)).astype(jnp.int32)

    perm = _compose_lsd(extract, n, 32, pb, None, pass_impl=pass_impl,
                        interpret=interpret)
    return jnp.take(keys, perm), perm

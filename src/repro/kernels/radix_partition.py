"""Stable radix-partition rank kernels (RADIX-PARTITION primitive, §2.3/§4.3).

Two-pass structure, mirroring the paper's multi-pass partitioner but with
prefix sums instead of atomics (deterministic by construction — the property
PHJ-OM needs):

  pass A (histogram.py): per-block digit histograms -> (num_blocks, G)
  host:   exclusive prefix over blocks & digits -> per-block base offsets
  pass B (this file):    per-element destination index
            dest[i] = base[block, digit] + rank_within_block(i)

The within-block stable rank is a cumsum over the one-hot digit expansion —
dense VPU work; no scatter ever happens inside the kernel. The actual data
movement is then a single XLA gather with the inverted permutation (ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import LANES, as_lanes, ceil_div
from .histogram import histogram_pallas


def _block_hist_kernel(num_bins: int, x_ref, o_ref):
    x = x_ref[...].reshape(-1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], num_bins), 1)
    oh = (x[:, None] == bins).astype(jnp.int32)
    o_ref[...] = oh.sum(axis=0, keepdims=True)


def block_histograms_pallas(
    digits: jax.Array, num_bins: int, *, block_rows: int = 8, interpret: bool = True
) -> jax.Array:
    """(num_blocks, num_bins) per-block histograms."""
    d2 = as_lanes(digits, fill=-1)
    rows = d2.shape[0]
    grid = ceil_div(rows, block_rows)
    d2 = jnp.pad(d2, ((0, grid * block_rows - rows), (0, 0)), constant_values=-1)
    return pl.pallas_call(
        functools.partial(_block_hist_kernel, num_bins),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, num_bins), jnp.int32),
        interpret=interpret,
    )(d2)


def _rank_kernel(num_bins: int, x_ref, base_ref, o_ref):
    x = x_ref[...].reshape(-1)  # (T,)
    bins = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], num_bins), 1)
    oh = (x[:, None] == bins).astype(jnp.int32)  # (T, G)
    excl = jnp.cumsum(oh, axis=0) - oh  # exclusive within-block rank per digit
    # own-column selection without gather: elementwise mask + row-sum
    rank = (excl * oh).sum(axis=1)
    base = (base_ref[...][0][None, :] * oh).sum(axis=1)  # base[digit_i]
    dest = jnp.where(x >= 0, base + rank, -1)
    o_ref[...] = dest.reshape(o_ref.shape)


def partition_ranks_pallas(
    digits: jax.Array,
    num_bins: int,
    *,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Destination index per element for the stable partition.

    Returns (dest, offsets, sizes): dest[i] = output position of element i;
    offsets/sizes describe the contiguous partition layout."""
    n = digits.shape[0]
    bh = block_histograms_pallas(digits, num_bins, block_rows=block_rows, interpret=interpret)
    sizes = bh.sum(axis=0)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes)[:-1].astype(jnp.int32)])
    # base[b, g] = offsets[g] + sum_{b' < b} bh[b', g]
    prev = jnp.cumsum(bh, axis=0) - bh
    base = (offsets[None, :] + prev).astype(jnp.int32)

    d2 = as_lanes(digits, fill=-1)
    rows = d2.shape[0]
    grid = ceil_div(rows, block_rows)
    d2 = jnp.pad(d2, ((0, grid * block_rows - rows), (0, 0)), constant_values=-1)
    dest = pl.pallas_call(
        functools.partial(_rank_kernel, num_bins),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, num_bins), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid * block_rows, LANES), jnp.int32),
        interpret=interpret,
    )(d2, base)
    return dest.reshape(-1)[:n], offsets, sizes

"""Co-partition hash-probe kernel (PHJ match finding, §3.2/§4.3).

The paper's thread block loads one build-side bucket into shared memory and
streams probe keys against it. TPU mapping (DESIGN.md §2):

  shared-memory bucket  ->  (1, capR) build block held in VMEM
  probe stream          ->  (1, capS) probe sub-block (the paper's probe-side
                            sub-partition decomposition, which is also its
                            load-balancing step)
  SIMT probe loop       ->  one (capS x capR) vectorized equality

Probe rows are laid out partition-major and padded so every sub-block is
capS-aligned and belongs to exactly one partition; a scalar-prefetched array
maps sub-block -> partition id, which drives the build BlockSpec. The build
partition offset (for virtual-ID construction) rides along in SMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KEY_SENTINEL = -1


def _probe_kernel(part_ref, off_ref, probe_ref, bkeys_ref, vid_ref, hit_ref):
    i = pl.program_id(0)
    pk = probe_ref[0]  # (capS,)
    bk = bkeys_ref[0]  # (capR,)
    cap_r = bk.shape[0]
    eq = (pk[:, None] == bk[None, :]) & (pk[:, None] != KEY_SENTINEL)
    iota = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    hitpos = jnp.where(eq, iota, cap_r).min(axis=1)
    matched = hitpos < cap_r
    base = off_ref[part_ref[i]]
    vid_ref[0, :] = jnp.where(matched, base + hitpos, -1)
    hit_ref[0, :] = matched.astype(jnp.int32)


def hash_probe_pallas(
    bkeys: jax.Array,  # (P, capR) padded build blocks, KEY_SENTINEL fill
    off_r: jax.Array,  # (P,) partition offsets in the partitioned build array
    probe_blocks: jax.Array,  # (B, capS) partition-major padded probe keys
    block_part: jax.Array,  # (B,) partition id per probe sub-block
    *,
    interpret: bool = True,
):
    """Returns (vid, matched): (B, capS) int32 match position in the
    partitioned build array (or -1) and 0/1 hit flags."""
    B, capS = probe_blocks.shape
    P, capR = bkeys.shape
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
            pl.BlockSpec((1, capR), lambda i, part, off: (part[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
        ],
    )
    vid, hit = pl.pallas_call(
        _probe_kernel,
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, capS), jnp.int32),
            jax.ShapeDtypeStruct((B, capS), jnp.int32),
        ],
        interpret=interpret,
    )(block_part.astype(jnp.int32), off_r.astype(jnp.int32), probe_blocks, bkeys)
    return vid, hit


def layout_probe_blocks(
    keys_part: jax.Array,  # partitioned probe keys (contiguous partitions)
    off: jax.Array,
    sz: jax.Array,
    cap_s: int,
    max_blocks: int,
):
    """Decompose partitions into capS-aligned sub-blocks (paper's probe-side
    sub-partitioning). Static worst case: n/capS + P blocks.

    Returns (probe_blocks (B, capS), block_part (B,), src_idx (B, capS)) where
    src_idx maps each slot back to its position in keys_part (-1 = padding).
    """
    P = off.shape[0]
    n = keys_part.shape[0]
    blocks_per = -(-sz // cap_s)  # ceil
    boff = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(blocks_per).astype(jnp.int32)])
    b = jnp.arange(max_blocks, dtype=jnp.int32)
    part = jnp.clip(jnp.searchsorted(boff, b, side="right").astype(jnp.int32) - 1, 0, P - 1)
    sub = b - boff[part]
    valid_block = b < boff[-1]
    j = jnp.arange(cap_s, dtype=jnp.int32)[None, :]
    src = off[part][:, None].astype(jnp.int32) + sub[:, None] * cap_s + j
    in_part = (sub[:, None] * cap_s + j) < sz[part][:, None]
    src_idx = jnp.where(valid_block[:, None] & in_part, src, -1)
    pk = jnp.where(
        src_idx >= 0,
        jnp.take(keys_part, jnp.clip(src_idx, 0, n - 1)),
        KEY_SENTINEL,
    )
    return pk, part, src_idx

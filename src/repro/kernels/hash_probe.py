"""Co-partition hash-probe kernel (PHJ match finding, §3.2/§4.3).

The paper's thread block loads one build-side bucket into shared memory and
streams probe keys against it. TPU mapping (DESIGN.md §2):

  shared-memory bucket  ->  (1, capR) build block held in VMEM
  probe stream          ->  (1, capS) probe sub-block (the paper's probe-side
                            sub-partition decomposition, which is also its
                            load-balancing step)
  SIMT probe loop       ->  one (capS x capR) vectorized equality

Probe rows are laid out partition-major and padded so every sub-block is
capS-aligned and belongs to exactly one partition; a scalar-prefetched array
maps sub-block -> partition id, which drives the build BlockSpec. The build
partition offset (for virtual-ID construction) rides along in SMEM.
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

from .common import resolve_interpret

KEY_SENTINEL = -1


def _probe_kernel(part_ref, off_ref, probe_ref, bkeys_ref, vid_ref, hit_ref):
    i = pl.program_id(0)
    pk = probe_ref[0]  # (capS,)
    bk = bkeys_ref[0]  # (capR,)
    cap_r = bk.shape[0]
    eq = (pk[:, None] == bk[None, :]) & (pk[:, None] != KEY_SENTINEL)
    iota = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    hitpos = jnp.where(eq, iota, cap_r).min(axis=1)
    matched = hitpos < cap_r
    base = off_ref[part_ref[i]]
    vid_ref[0, :] = jnp.where(matched, base + hitpos, -1)
    hit_ref[0, :] = matched.astype(jnp.int32)


def hash_probe_pallas(
    bkeys: jax.Array,  # (P, capR) padded build blocks, KEY_SENTINEL fill
    off_r: jax.Array,  # (P,) partition offsets in the partitioned build array
    probe_blocks: jax.Array,  # (B, capS) partition-major padded probe keys
    block_part: jax.Array,  # (B,) partition id per probe sub-block
    *,
    interpret: bool | None = None,
):
    """Returns (vid, matched): (B, capS) int32 match position in the
    partitioned build array (or -1) and 0/1 hit flags."""
    B, capS = probe_blocks.shape
    P, capR = bkeys.shape
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
            pl.BlockSpec((1, capR), lambda i, part, off: (part[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
            pl.BlockSpec((1, capS), lambda i, part, off: (i, 0)),
        ],
    )
    vid, hit = pl.pallas_call(
        _probe_kernel,
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, capS), jnp.int32),
            jax.ShapeDtypeStruct((B, capS), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_part.astype(jnp.int32), off_r.astype(jnp.int32), probe_blocks, bkeys)
    return vid, hit


# ---------------------------------------------------------------------------
# Fused probe + accumulate (group-join): the joined row never leaves VMEM
# ---------------------------------------------------------------------------
def _probe_agg_kernel(part_ref, probe_ref, gk_ref, pv_ref, bkeys_ref,
                      bvals_ref, pk_ref, ps_ref, pc_ref, *, col_sides):
    """One probe sub-block: match finding (vectorized equality against the
    co-partition's build block) immediately followed by tile-local grouped
    aggregation — both as matmuls, the §2 scatter-free mapping.

    Instead of writing (vid, hit) per row for a later materialization pass,
    the kernel reduces the tile to at most one (group key, partial sums,
    partial count) tuple per distinct group: the fused analogue of the
    GPU's shared-memory hash-table accumulator. Group assignment needs no
    sort — each row's slot is the first row in the tile carrying the same
    group key (a (capS x capS) equality + iota-min), and the one-hot of
    those slots drives the reduction matmuls.

    `col_sides` (static) maps each output column to its value source:
    ("probe", j) reads pv_ref[0, j]; ("build", j) fetches the matched build
    value from bvals_ref[0, j] via a one-hot matmul over the hit positions.
    Match finding and group assignment run ONCE per tile no matter how many
    aggregate columns ride the pass."""
    del part_ref  # consumed by the BlockSpec index maps only
    pk = probe_ref[0]  # (capS,) probe join keys
    gk = gk_ref[0]  # (capS,) probe group keys
    bk = bkeys_ref[0]  # (capR,) build block keys
    cap_r = bk.shape[0]
    cap_s = pk.shape[0]
    eq = (pk[:, None] == bk[None, :]) & (pk[:, None] != KEY_SENTINEL)
    iota_r = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1)
    hitpos = jnp.where(eq, iota_r, cap_r).min(axis=1)
    matched = hitpos < cap_r
    # one-hot of the (unique, deterministic) first hit position: fetches any
    # number of build value columns without leaving VMEM
    oh_b = (iota_r == hitpos[:, None]).astype(jnp.float32)
    gke = jnp.where(matched, gk, KEY_SENTINEL)
    # slot of row i = first row in the tile with the same group key
    eqg = (gke[:, None] == gke[None, :]) & (gke[:, None] != KEY_SENTINEL)
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (cap_s, cap_s), 1)
    rep = jnp.where(eqg, iota_s, cap_s).min(axis=1)
    oh = (rep[:, None] == iota_s).astype(jnp.float32)  # (rows, slots)
    for c, (side, j) in enumerate(col_sides):
        if side == "build":
            val = (oh_b * bvals_ref[0, j][None, :]).sum(axis=1)
        else:
            val = pv_ref[0, j]
        ps_ref[0, c, :] = jnp.where(matched, val, 0.0) @ oh
    counts = matched.astype(jnp.float32) @ oh
    pc_ref[0, :] = counts.astype(jnp.int32)
    # slot j only ever receives rows whose group key equals gke[j]
    pk_ref[0, :] = jnp.where(counts > 0, gke, KEY_SENTINEL)


def probe_agg_pallas(
    bkeys: jax.Array,  # (P, capR) padded build key blocks
    bvals: jax.Array,  # (P, Cb, capR) float32 build value blocks
    probe_blocks: jax.Array,  # (B, capS) partition-major padded probe keys
    gk_blocks: jax.Array,  # (B, capS) probe group keys (KEY_SENTINEL padding)
    pv_blocks: jax.Array,  # (B, Cp, capS) float32 probe value columns
    block_part: jax.Array,  # (B,) partition id per probe sub-block
    *,
    col_sides: tuple,  # static ("probe"|"build", within-side index) per output
    interpret: bool | None = None,
):
    """Fused probe+accumulate partials over any number of aggregate value
    columns in ONE probe pass. Returns (pkeys (B, capS), psums (B, C, capS),
    pcounts (B, capS)): at most one live slot per distinct group per tile
    (KEY_SENTINEL elsewhere); combine with a sorted segmented reduction."""
    import functools

    B, capS = probe_blocks.shape
    P, capR = bkeys.shape
    Cp = pv_blocks.shape[1]
    Cb = bvals.shape[1]
    C = len(col_sides)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, capS), lambda i, part: (i, 0)),
            pl.BlockSpec((1, capS), lambda i, part: (i, 0)),
            pl.BlockSpec((1, Cp, capS), lambda i, part: (i, 0, 0)),
            pl.BlockSpec((1, capR), lambda i, part: (part[i], 0)),
            pl.BlockSpec((1, Cb, capR), lambda i, part: (part[i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capS), lambda i, part: (i, 0)),
            pl.BlockSpec((1, C, capS), lambda i, part: (i, 0, 0)),
            pl.BlockSpec((1, capS), lambda i, part: (i, 0)),
        ],
    )
    pk, ps, pc = pl.pallas_call(
        functools.partial(_probe_agg_kernel, col_sides=tuple(col_sides)),
        grid_spec=spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, capS), gk_blocks.dtype),
            jax.ShapeDtypeStruct((B, C, capS), jnp.float32),
            jax.ShapeDtypeStruct((B, capS), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(block_part.astype(jnp.int32), probe_blocks, gk_blocks,
      pv_blocks.astype(jnp.float32), bkeys, bvals.astype(jnp.float32))
    return pk, ps, pc


def layout_probe_blocks(
    keys_part: jax.Array,  # partitioned probe keys (contiguous partitions)
    off: jax.Array,
    sz: jax.Array,
    cap_s: int,
    max_blocks: int,
):
    """Decompose partitions into capS-aligned sub-blocks (paper's probe-side
    sub-partitioning). Static worst case: n/capS + P blocks.

    Returns (probe_blocks (B, capS), block_part (B,), src_idx (B, capS)) where
    src_idx maps each slot back to its position in keys_part (-1 = padding).
    """
    P = off.shape[0]
    n = keys_part.shape[0]
    blocks_per = -(-sz // cap_s)  # ceil
    boff = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(blocks_per).astype(jnp.int32)])
    b = jnp.arange(max_blocks, dtype=jnp.int32)
    part = jnp.clip(jnp.searchsorted(boff, b, side="right").astype(jnp.int32) - 1, 0, P - 1)
    sub = b - boff[part]
    valid_block = b < boff[-1]
    j = jnp.arange(cap_s, dtype=jnp.int32)[None, :]
    src = off[part][:, None].astype(jnp.int32) + sub[:, None] * cap_s + j
    in_part = (sub[:, None] * cap_s + j) < sz[part][:, None]
    src_idx = jnp.where(valid_block[:, None] & in_part, src, -1)
    pk = jnp.where(
        src_idx >= 0,
        jnp.take(keys_part, jnp.clip(src_idx, 0, n - 1)),
        KEY_SENTINEL,
    )
    return pk, part, src_idx

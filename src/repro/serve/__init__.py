"""Decode-serving engine (continuous batching over the decode step)."""

"""Serving layer: decode serving (engine.py) and relational query serving
(query.py — compiled-plan cache with capacity bucketing, cost-priced
admission, per-signature circuit breakers; chaos.py is its soak harness,
DESIGN.md §14)."""

from repro.serve.engine import Request, ServeEngine  # noqa: F401
from repro.serve.query import (  # noqa: F401
    CircuitBreaker,
    CompiledEntry,
    QueryRequest,
    QueryServer,
    bucket_rows,
    pad_table,
    plan_signature,
)

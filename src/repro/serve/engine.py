"""Batched serving engine: continuous batching over fixed-capacity slots.

vLLM-style slot management adapted to XLA static shapes (the same
capacity+count discipline as the relational layer): the engine owns a
(max_batch,) slot array; requests are admitted into free slots, every
decode_step advances all live slots one token at their OWN position
(vector `pos` — per-slot ring-buffer offsets), finished slots are freed and
immediately refillable. Admission resets the freed slot's cache rows to
their pristine values so no state leaks between requests (verified by
tests/test_data_and_serve.py::test_slot_reuse_no_leak). The KV/SSM cache is
allocated once at capacity; cross-KV (vision/audio stubs) is per-slot
static.

Single-host reference implementation with the same step function the
sharded serve path uses (launch/serve.py builds it with a mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M


def _reset_slot(cache, pristine, axes, slot: int):
    """Copy slot `slot`'s rows from the pristine cache (per-leaf batch axis
    located via the cache's logical-axes tree)."""

    def one(c, p, ax):
        try:
            b_axis = ax.axes.index("batch")
        except ValueError:
            return c
        idx = (slice(None),) * b_axis + (slot,)
        return c.at[idx].set(p[idx])

    return jax.tree_util.tree_map(one, cache, pristine, axes)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 2, batch_stub=None,
                 dtype=jnp.float32, step_fn: Callable | None = None):
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_len, self.eos_id = max_batch, max_len, eos_id
        stub = batch_stub or {}
        self.cache = M.init_cache(cfg, params, max_batch, max_len, stub, dtype)
        self._pristine = jax.tree_util.tree_map(jnp.copy, self.cache)
        self._cache_axes = M.cache_axes(cfg, max_batch, max_len, dtype)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)  # per-slot position
        self.tokens = np.zeros(max_batch, np.int32)
        self.queue: list[Request] = []
        self._step = step_fn or jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
        )

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                # fresh slot: position 0, pristine cache rows (no leakage
                # from the previous occupant)
                self.slot_pos[i] = 0
                self.cache = _reset_slot(self.cache, self._pristine,
                                         self._cache_axes, i)
                # prefill-by-decode: feed prompt tokens one per engine step
                req._prompt_cursor = 1
                self.tokens[i] = req.prompt[0]

    # -- one engine tick ------------------------------------------------------
    def step(self):
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return False
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.slot_pos),
        )
        logits = np.asarray(logits)
        for i in live:
            self.slot_pos[i] += 1
            req = self.slot_req[i]
            if req._prompt_cursor < len(req.prompt):  # still prefilling
                self.tokens[i] = req.prompt[req._prompt_cursor]
                req._prompt_cursor += 1
                continue
            nxt = int(np.argmax(logits[i]))
            req.out.append(nxt)
            self.tokens[i] = nxt
            if nxt == self.eos_id or len(req.out) >= req.max_tokens \
               or int(self.slot_pos[i]) >= self.max_len - 1:
                req.done = True
                self.slot_req[i] = None  # free slot for continuous batching
        return True

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            if not self.step():
                break
            ticks += 1
        return ticks

"""Batched serving engine: continuous batching over fixed-capacity slots.

vLLM-style slot management adapted to XLA static shapes (the same
capacity+count discipline as the relational layer): the engine owns a
(max_batch,) slot array; requests are admitted into free slots, every
decode_step advances all live slots one token at their OWN position
(vector `pos` — per-slot ring-buffer offsets), finished slots are freed and
immediately refillable. Admission resets the freed slot's cache rows to
their pristine values so no state leaks between requests (verified by
tests/test_data_and_serve.py::test_slot_reuse_no_leak). The KV/SSM cache is
allocated once at capacity; cross-KV (vision/audio stubs) is per-slot
static.

Single-host reference implementation with the same step function the
sharded serve path uses (launch/serve.py builds it with a mesh).

Resilience (DESIGN.md §13): admission sheds when the queue is full
(`max_queue`), per-request deadlines evict overdue work, and a failing
decode step is retried with backoff; if it keeps failing, the
most-recently-admitted slot is evicted (requeued while it has retry
budget, failed alone once it doesn't) so one poisoned query cannot take
down the batch. The cache is only ever reassigned on a successful step,
so a failed step leaves every surviving slot's state untouched.

Memory governance (DESIGN.md §15): an optional byte budget
(`mem_budget_bytes`) gates slot admission — a request declaring
`mem_bytes` buys a reservation ticket before it takes a slot. A queue
head whose ticket does not fit is DEFERRED, not admitted and not shed:
it holds its queue position, ages in `ticks_deferred` (never in
`ticks_queued` or `ticks_running`), and retries every tick until enough
in-flight work releases its tickets. Every slot-exit path — completion,
deadline eviction, poisoned eviction, requeue — releases the ticket.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.engine import membudget as MB
from repro.models import model as M
from repro.obs import metrics
from repro.resilience import escalation, faults


def _reset_slot(cache, pristine, axes, slot: int):
    """Copy slot `slot`'s rows from the pristine cache (per-leaf batch axis
    located via the cache's logical-axes tree)."""

    def one(c, p, ax):
        try:
            b_axis = ax.axes.index("batch")
        except ValueError:
            return c
        idx = (slice(None),) * b_axis + (slot,)
        return c.at[idx].set(p[idx])

    return jax.tree_util.tree_map(one, cache, pristine, axes)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # absolute engine tick by which the request must finish (None = no
    # deadline); overdue requests are evicted from slot or queue with
    # error="deadline"
    deadline_ticks: int | None = None
    # why the request finished without completing: "", "shed", "deadline",
    # "poisoned"
    error: str = ""
    # re-admissions allowed after this request's slot is evicted for a
    # persistent step failure before it is failed alone
    retries_left: int = 1
    # bytes this request's slot state needs while live; admission reserves
    # them against the engine's budget (0 = exempt from the governor)
    mem_bytes: int = 0
    # -- latency breakdown (engine ticks; accumulated across requeues and
    # observed into the serve.ticks_* histograms when the request ends) --
    submit_tick: int = -1
    done_tick: int = -1
    ticks_queued: int = 0   # ticks spent waiting in the queue
    ticks_running: int = 0  # ticks spent live in a slot
    ticks_retrying: int = 0  # failed step attempts charged while live
    ticks_deferred: int = 0  # ticks blocked at the queue head on memory
    _enqueued_at: int = dataclasses.field(default=0, repr=False)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 2, batch_stub=None,
                 dtype=jnp.float32, step_fn: Callable | None = None,
                 max_queue: int | None = None, step_retries: int = 2,
                 retry_backoff_s: float = 0.005,
                 mem_budget_bytes: int | None = None):
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_len, self.eos_id = max_batch, max_len, eos_id
        self.max_queue = max_queue
        self.budget = MB.MemoryBudget(mem_budget_bytes)
        self.step_retries = step_retries
        self.retry_backoff_s = retry_backoff_s
        stub = batch_stub or {}
        self.cache = M.init_cache(cfg, params, max_batch, max_len, stub, dtype)
        self._pristine = jax.tree_util.tree_map(jnp.copy, self.cache)
        self._cache_axes = M.cache_axes(cfg, max_batch, max_len, dtype)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)  # per-slot position
        self.tokens = np.zeros(max_batch, np.int32)
        self.queue: list[Request] = []
        self.tick = 0  # absolute engine tick (deadline clock)
        # admission order, newest = the eviction candidate on a poisoned step
        self._admit_seq = itertools.count()
        self._slot_seq = [-1] * max_batch
        self._hold_admission = False  # one-tick pause after an eviction
        self._step = step_fn or jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos)
        )

    # -- latency accounting --------------------------------------------------
    def _finish(self, req: Request):
        """Stamp the end of a request's life and publish its tick
        breakdown (queued vs running vs retrying) to the serve.ticks_*
        histograms — `latency_summary()` reports their percentiles."""
        req.done_tick = self.tick
        metrics.histogram("serve.ticks_queued").observe(req.ticks_queued)
        metrics.histogram("serve.ticks_running").observe(req.ticks_running)
        metrics.histogram("serve.ticks_retrying").observe(req.ticks_retrying)
        metrics.histogram("serve.ticks_deferred").observe(req.ticks_deferred)

    @staticmethod
    def latency_summary(pcts=(50, 95, 99)) -> dict:
        """Per-stage tick percentiles over every finished request."""
        return {name: metrics.histogram(f"serve.{name}").summary(pcts)
                for name in ("ticks_queued", "ticks_running",
                             "ticks_retrying", "ticks_deferred")}

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        req.submit_tick = self.tick
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # load shedding: fail fast at admission instead of letting the
            # backlog grow past what the engine can drain
            req.error, req.done = "shed", True
            self._finish(req)
            metrics.counter("resilience.serve_shed").inc()
            escalation.record_degradation(
                "serve", f"shed rid={req.rid}: queue full ({self.max_queue})")
            return
        req._enqueued_at = self.tick
        self.queue.append(req)

    def _admit(self):
        # after an eviction, let the surviving batch run one tick before
        # refilling: readmitting into a still-failing batch would burn the
        # requeued request's retry budget on someone else's poison (an
        # empty batch can't be poisoned, so admission always resumes there)
        if self._hold_admission:
            self._hold_admission = False
            if any(r is not None for r in self.slot_req):
                return
        for i in range(self.max_batch):
            if self.slot_req[i] is None and self.queue:
                head = self.queue[0]
                if head.mem_bytes and not self.budget.try_reserve(
                        f"r{head.rid}", head.mem_bytes):
                    # memory-deferred: the head keeps its queue position
                    # and ages as DEFERRED — not queued, and certainly not
                    # running. No one jumps past it (FIFO under pressure,
                    # so a big request cannot starve behind small ones).
                    head.ticks_queued += self.tick - head._enqueued_at
                    head._enqueued_at = self.tick
                    head.ticks_deferred += 1
                    metrics.counter("serve.mem_deferrals").inc()
                    break
                req = self.queue.pop(0)
                req.ticks_queued += self.tick - req._enqueued_at
                self.slot_req[i] = req
                self._slot_seq[i] = next(self._admit_seq)
                # fresh slot: position 0, pristine cache rows (no leakage
                # from the previous occupant)
                self.slot_pos[i] = 0
                self.cache = _reset_slot(self.cache, self._pristine,
                                         self._cache_axes, i)
                # prefill-by-decode: feed prompt tokens one per engine step
                req._prompt_cursor = 1
                self.tokens[i] = req.prompt[0]

    # -- resilience sweeps ----------------------------------------------------
    def _overdue(self, req: Request | None) -> bool:
        return (req is not None and req.deadline_ticks is not None
                and self.tick >= req.deadline_ticks)

    def _sweep_deadlines(self):
        for i, req in enumerate(self.slot_req):
            if self._overdue(req):
                req.error, req.done = "deadline", True
                self._finish(req)
                self.slot_req[i] = None
                self.budget.release(f"r{req.rid}")
                metrics.counter("resilience.serve_deadline_evictions").inc()
        overdue = [r for r in self.queue if self._overdue(r)]
        if overdue:
            self.queue = [r for r in self.queue if not self._overdue(r)]
            for req in overdue:
                req.error, req.done = "deadline", True
                req.ticks_queued += self.tick - req._enqueued_at
                self._finish(req)
                metrics.counter("resilience.serve_deadline_evictions").inc()

    def _evict_poisoned(self, err: Exception):
        """A step failed past its retry budget: evict the most recently
        admitted slot — the request whose arrival changed the batch — and
        requeue it if it has retry budget left, else fail it alone."""
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        i = max(live, key=lambda j: self._slot_seq[j])
        req = self.slot_req[i]
        self.slot_req[i] = None
        self.budget.release(f"r{req.rid}")
        self._hold_admission = True
        metrics.counter("resilience.serve_evictions").inc()
        escalation.record_degradation(
            "serve", f"evicted rid={req.rid}: {type(err).__name__}: {err}")
        if req.retries_left > 0:
            req.retries_left -= 1
            req.out.clear()  # partial output from the failed run is void
            req._enqueued_at = self.tick
            self.queue.append(req)
        else:
            req.error, req.done = "poisoned", True
            self._finish(req)

    # -- one engine tick ------------------------------------------------------
    def step(self):
        self.tick += 1
        self._sweep_deadlines()
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return False
        # bounded retry with backoff; `self.cache` is reassigned only from a
        # successful call, so a failed step leaves all slot state untouched
        for retry in range(self.step_retries + 1):
            try:
                faults.check_site("serve.step")
                logits, cache = self._step(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.slot_pos),
                )
                break
            except Exception as e:  # noqa: BLE001 — isolate, don't crash
                for i in live:  # the whole batch burns the failed attempt
                    self.slot_req[i].ticks_retrying += 1
                if retry < self.step_retries:
                    metrics.counter("resilience.serve_retries").inc()
                    time.sleep(self.retry_backoff_s * (1 << retry))
                    continue
                self._evict_poisoned(e)
                return True  # the surviving slots run again next tick
        self.cache = cache
        logits = np.asarray(logits)
        for i in live:
            self.slot_pos[i] += 1
            req = self.slot_req[i]
            req.ticks_running += 1
            if req._prompt_cursor < len(req.prompt):  # still prefilling
                self.tokens[i] = req.prompt[req._prompt_cursor]
                req._prompt_cursor += 1
                continue
            nxt = int(np.argmax(logits[i]))
            req.out.append(nxt)
            self.tokens[i] = nxt
            if nxt == self.eos_id or len(req.out) >= req.max_tokens \
               or int(self.slot_pos[i]) >= self.max_len - 1:
                req.done = True
                self._finish(req)
                self.slot_req[i] = None  # free slot for continuous batching
                self.budget.release(f"r{req.rid}")
        return True

    def run(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            if not self.step():
                break
            ticks += 1
        return ticks

"""Chaos/soak harness for the query-serving runtime (DESIGN.md §14).

`python -m repro.serve --chaos` drives hundreds of mixed queries — PK-FK
joins, grouped aggregations, fused group-joins, and filter+top-k over
`data/relgen.py` workloads — through a `QueryServer` five times:

  baseline    no faults. Every request must complete on the fast path;
              its canonicalized result becomes the query's oracle (spot
              cross-checked against independent one-shot engine runs),
              and its warm latencies become the p50/p95/p99 + throughput
              baseline written to BENCH_serve.json.
  overflow    `overflow:phj@0` on every join-shaped query (the first two
              also fail their fast attempt via `raise:qserve.execute@0`,
              tripping the breaker): quarantined joins must climb the phj
              escalation ladder on the safe path and still match their
              oracles; the half-open probe must close the breaker.
  pallas      `pallas:*` on every group-join-shaped query: the signature
              compiles with every pallas arm down (xla fallbacks), zero
              failures, zero breaker activity, oracle-identical results.
  raise       `raise:qserve.execute` (every occurrence) on the first four
              group-by-shaped queries: they must fail ALONE (fast and
              safe), open the breaker, and the clean remainder must
              recover through the half-open probe back to the fast path.
  estimates   `estimates:/32` on every group-by-shaped query: the first
              one plans the signature with 32x-too-small cardinalities,
              poisoning the cached plan. Saturation detection must catch
              the silent truncation, the safe path must escalate
              `degrade_plan` levels until results fit, and every result
              must still match its oracle.

After each fault pass the harness asserts the blast radius: failures
confined to the faulted signature, every untargeted request fast-path and
oracle-identical (zero contamination), untargeted warm p99 within 2x of
the fault-free baseline, and the `qserve.*` / `resilience.*` counter
deltas consistent with the injected faults (a fault family that fires
nothing is a broken family). A final pressure pass pins the admission
machinery: exact shed counts at a full queue, exact deadline evictions,
and cost-based rejection under a tiny `max_price_s`.

A memory pass then pins the byte-budget governor: big splittable queries
(a wide-filter shape whose audited peak scales with the morsel axis)
served under a budget below their whole-plan peak must complete via the
morsel-driven out-of-core path bit-identical to their fault-free
oracles, an injected `oom:executor.run@0` must recover through the
chunked fallback, reserved bytes must never exceed the budget, standard
queries must stay untouched on the fast path, and a never-fitting
unsplittable query must be rejected with a typed error — not a crash.

All chaos payloads are integers, so canonicalized results (sorted valid
rows over sorted columns) are bit-identical across every execution
strategy a breaker or ladder can pick.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.table import Table
from repro.data import relgen
from repro.engine import executor
from repro.engine import stats as S
from repro.engine.logical import scan
from repro.engine.physical import optimize
from repro.obs import metrics
from repro.serve.query import QueryRequest, QueryServer, pad_table, plan_signature

SHAPES = ("join", "groupby", "groupjoin", "topk")
FAMILY_TARGETS = {"overflow": "join", "pallas": "groupjoin",
                  "raise": "groupby", "estimates": "groupby"}
FAMILY_SPECS = {
    # (spec for the first `breaker_threshold` targeted queries,
    #  spec for the rest). `raise:qserve.execute@0` fails only the fast
    # attempt, so the combined spec exercises the ladder via the safe
    # fallback AND trips the breaker.
    "overflow": ("raise:qserve.execute@0,overflow:phj@0", "overflow:phj@0"),
    "pallas": ("pallas:*", "pallas:*"),
    "raise": ("raise:qserve.execute", ""),
    "estimates": ("estimates:/32", "estimates:/32"),
}
RAISE_FAULTED = 4  # hard-faulted queries in the raise family

# plan constants (fixed per shape — a shape is ONE signature; only the
# dataset sizes vary, inside one capacity bucket)
PLANS = {
    "join": scan("S").join(scan("R"), key="k"),
    "groupby": scan("S").group_by("k", s1="sum"),
    "groupjoin": scan("fact").join(scan("dim0"), left_key="fk0",
                                   right_key="k0").group_by("fk0",
                                                            payload="sum"),
    "topk": scan("S").filter("s1", "<", 1 << 30).order_by("s1", limit=32),
}


def canon(table, count):
    """Valid rows, order- and shape-insensitive (integer payloads)."""
    n = int(count)
    cols = sorted(table.column_names)
    mats = [np.asarray(table[c])[:n] for c in cols]
    return tuple(cols), sorted(zip(*[m.tolist() for m in mats]))


@dataclasses.dataclass
class ChaosQuery:
    qid: int
    shape: str
    plan: object
    tables: dict
    oracle: object = None  # canonicalized fault-free result


def _make_tables(shape: str, rng: np.random.Generator) -> dict:
    """One dataset for `shape`, sized inside the shape's capacity bucket
    (so every query of a shape lands on ONE plan signature, and valid
    counts never equal a bucket — saturation stays a truncation signal)."""
    seed = int(rng.integers(0, 2**31 - 1))
    if shape == "join":
        n_r, n_s = int(rng.integers(300, 480)), int(rng.integers(1100, 1900))
        R, Stab = relgen.generate(relgen.JoinWorkload(
            "cj", n_r, n_s, 1, 1, seed=seed))
        return {"R": R, "S": Stab}
    if shape in ("groupby", "topk"):
        # sparse group keys (domain 5000 >> distinct): the shape whose
        # capacities hinge on the distinct-count estimate
        n_s = int(rng.integers(1100, 1900))
        _, Stab = relgen.generate(relgen.JoinWorkload(
            "cg", 5000, n_s, 1, 1, seed=seed))
        return {"S": Stab}
    n_fact, n_dim = int(rng.integers(600, 1000)), int(rng.integers(70, 120))
    fact, dims, _, _ = relgen.generate_star(n_fact, n_dim, 1, seed=seed)
    return {"fact": fact, "dim0": dims[0]}


def build_mix(n_queries: int, seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    return [ChaosQuery(qid=i, shape=SHAPES[i % len(SHAPES)],
                       plan=PLANS[SHAPES[i % len(SHAPES)]],
                       tables=_make_tables(SHAPES[i % len(SHAPES)], rng))
            for i in range(n_queries)]


def _counter_window():
    names = [n for n, m in metrics.REGISTRY._metrics.items()
             if isinstance(m, metrics.Counter)]
    return {n: metrics.counter(n).value for n in names}


def _counter_delta(before: dict) -> dict:
    after = _counter_window()
    keys = set(before) | set(after)
    return {k: after.get(k, 0) - before.get(k, 0)
            for k in sorted(keys)
            if after.get(k, 0) != before.get(k, 0)}


def _drive(queries, fault_for=None, submit_per_tick: int = 4,
           server_kw: dict | None = None):
    """One soak pass: fresh server, `submit_per_tick` arrivals per tick,
    step until drained. Returns (server, requests, counter_deltas,
    wall_s)."""
    before = _counter_window()
    kw = dict(measure_profile=True, breaker_cooldown=5)
    kw.update(server_kw or {})
    server = QueryServer(**kw)
    reqs = []
    t0 = time.perf_counter()
    i = 0
    while i < len(queries) or server.queue or server.deferred:
        for _ in range(submit_per_tick):
            if i < len(queries):
                q = queries[i]
                spec = fault_for(q) if fault_for else ""
                req = QueryRequest(qid=q.qid, plan=q.plan, tables=q.tables,
                                   fault_spec=spec)
                server.submit(req)
                reqs.append(req)
                i += 1
        server.step()
    return server, reqs, _counter_delta(before), time.perf_counter() - t0


def _warm_walls(reqs) -> dict:
    """Per-shape-signature exec wall times EXCLUDING each signature's
    first completed run (which pays the jit compile)."""
    seen: set = set()
    walls: dict[str, list] = {}
    for req in reqs:
        if not req.done or req.error or req.result is None:
            continue
        if req.signature not in seen:
            seen.add(req.signature)
            continue
        walls.setdefault(req.signature, []).append(req.exec_wall_s)
    return walls


def run_chaos(queries_per_family: int = 200, seed: int = 0,
              smoke: bool = False,
              families=("overflow", "pallas", "raise", "estimates")) -> dict:
    if smoke:
        queries_per_family = min(queries_per_family, 48)
    failures: list[str] = []

    def check(cond, msg):
        if not cond:
            failures.append(msg)

    queries = build_mix(queries_per_family, seed=seed)
    by_shape = {s: [q for q in queries if q.shape == s] for s in SHAPES}

    # ---- baseline: fault-free oracles + latency/throughput floor --------
    server, reqs, delta, wall = _drive(queries)
    req_by_qid = {r.qid: r for r in reqs}
    sig_of_shape: dict[str, str] = {}
    for q in queries:
        req = req_by_qid[q.qid]
        check(req.done and not req.error,
              f"baseline.q{q.qid}: {req.error or 'not done'}")
        check(req.path == "fast", f"baseline.q{q.qid}: path={req.path}")
        if req.result is not None:
            q.oracle = canon(*req.result)
        sig_of_shape[q.shape] = req.signature
    check(delta.get("qserve.failed", 0) == 0, "baseline.failed_nonzero")
    check(delta.get("qserve.saturations", 0) == 0,
          "baseline.saturations_nonzero")
    # spot-check oracles against independent one-shot engine runs
    for s in SHAPES:
        q = by_shape[s][0]
        one_shot = optimize(q.plan, S.Catalog(q.tables),
                            measure_profile=True).run()
        check(q.oracle == canon(*one_shot), f"baseline.oracle_mismatch.{s}")

    walls = _warm_walls(reqs)
    all_walls = [w for ws in walls.values() for w in ws]
    base_p = metrics.percentiles(all_walls, (50, 95, 99))
    base_shape_p99 = {s: metrics.percentiles(walls.get(sig_of_shape[s], []),
                                             (99,))["p99"] for s in SHAPES}
    baseline = {
        "queries": len(queries), "wall_s": wall,
        "throughput_qps": len(queries) / wall if wall else 0.0,
        "p50_s": base_p["p50"], "p95_s": base_p["p95"],
        "p99_s": base_p["p99"],
        "per_shape_p99_s": base_shape_p99,
        "plans_compiled": delta.get("qserve.plans_compiled", 0),
        "plan_cache_hits": delta.get("qserve.plan_cache_hits", 0),
        "counters": delta,
    }
    check(baseline["plans_compiled"] == len(SHAPES),
          f"baseline.compiles={baseline['plans_compiled']} != {len(SHAPES)}")
    # whole-plan audited peaks per standard signature (sized under the
    # default — effectively unbounded — budget), for the memory pass
    standard_peaks = {sig: e.peak_bytes for sig, e in server.cache.items()}

    # ---- fault families -------------------------------------------------
    family_reports = {}
    for family in families:
        target = FAMILY_TARGETS[family]
        first_spec, rest_spec = FAMILY_SPECS[family]
        n_first = RAISE_FAULTED if family == "raise" else 2
        seen_targets = {"n": 0}

        def fault_for(q, _target=target, _first=first_spec, _rest=rest_spec,
                      _n_first=n_first, _seen=seen_targets):
            if q.shape != _target:
                return ""
            _seen["n"] += 1
            return _first if _seen["n"] <= _n_first else _rest

        server, reqs, delta, wall = _drive(queries, fault_for=fault_for)
        req_by_qid = {r.qid: r for r in reqs}
        target_qids = [q.qid for q in by_shape[target]]
        expect_failed = ([q.qid for q in by_shape[target][:RAISE_FAULTED]]
                         if family == "raise" else [])

        wrong = contaminated = 0
        for q in queries:
            req = req_by_qid[q.qid]
            if q.qid in expect_failed:
                check(req.error == "failed",
                      f"{family}.q{q.qid}: expected failed, got "
                      f"{req.error or req.path}")
                continue
            if not (req.done and not req.error and req.result is not None):
                check(False, f"{family}.q{q.qid}: {req.error or 'not done'} "
                             f"{req.detail}")
                continue
            if canon(*req.result) != q.oracle:
                wrong += 1
            if q.shape != target and (req.path != "fast" or req.escalations):
                contaminated += 1
        check(wrong == 0, f"{family}.wrong_results={wrong}")
        check(contaminated == 0, f"{family}.contaminated={contaminated}")
        check(delta.get("qserve.failed", 0) == len(expect_failed),
              f"{family}.failed={delta.get('qserve.failed', 0)} != "
              f"{len(expect_failed)}")
        check(delta.get("qserve.shed", 0) == 0, f"{family}.shed_nonzero")
        check(delta.get("resilience.faults_fired", 0) > 0,
              f"{family}.no_faults_fired")

        # family-specific counter consistency
        if family == "overflow":
            check(delta.get("resilience.ladder_escalations", 0) > 0,
                  "overflow.no_ladder_escalations")
            check(delta.get("qserve.breaker_opens", 0) >= 1,
                  "overflow.breaker_never_opened")
            check(delta.get("qserve.breaker_closes", 0) >= 1,
                  "overflow.breaker_never_closed")
        elif family == "pallas":
            check(delta.get("resilience.kernel_fallbacks", 0) > 0,
                  "pallas.no_kernel_fallbacks")
            check(delta.get("qserve.breaker_opens", 0) == 0,
                  "pallas.breaker_opened")
        elif family == "raise":
            check(delta.get("qserve.breaker_opens", 0) >= 1,
                  "raise.breaker_never_opened")
            check(delta.get("qserve.breaker_closes", 0) >= 1,
                  "raise.breaker_never_closed")
            br = server.breakers.get(sig_of_shape[target])
            check(br is not None and br.state == "closed",
                  "raise.breaker_not_recovered")
        elif family == "estimates":
            check(delta.get("qserve.saturations", 0) > 0,
                  "estimates.no_saturations")
            check(delta.get("qserve.safe_escalations", 0) > 0,
                  "estimates.no_safe_escalations")
            check(delta.get("qserve.breaker_opens", 0) >= 1,
                  "estimates.breaker_never_opened")

        # blast radius: untargeted signatures' warm p99 within 2x baseline
        walls = _warm_walls(reqs)
        confinement = {}
        for s in SHAPES:
            if s == target:
                continue
            p99 = metrics.percentiles(walls.get(sig_of_shape[s], []),
                                      (99,))["p99"]
            base = base_shape_p99[s]
            confinement[s] = {"p99_s": p99, "baseline_p99_s": base}
            check(p99 <= max(2 * base, base + 0.010),
                  f"{family}.p99_blowup.{s}: {p99:.4f}s vs base {base:.4f}s")

        family_reports[family] = {
            "queries": len(queries), "target_shape": target,
            "targeted": len(target_qids), "wall_s": wall,
            "expected_failed": len(expect_failed),
            "wrong_results": wrong, "contaminated": contaminated,
            "confinement": confinement, "counters": delta,
        }

    # ---- pressure: shedding / deadlines / admission pricing -------------
    pq = by_shape["join"][0]  # one signature, 14 simultaneous arrivals
    before = _counter_window()
    server = QueryServer(measure_profile=True, max_queue=8,
                         slots_per_tick=2)
    press_reqs = [QueryRequest(qid=1000 + j, plan=pq.plan, tables=pq.tables,
                               # the first two expire on the very tick they
                               # would be admitted: sweep-before-admit
                               # must evict, not run, them
                               deadline_ticks=1 if j < 2 else None)
                  for j in range(14)]
    for req in press_reqs:
        server.submit(req)
    server.run()
    shed = sum(r.error == "shed" for r in press_reqs)
    dead = sum(r.error == "deadline" for r in press_reqs)
    done = sum(bool(r.result is not None and not r.error)
               for r in press_reqs)
    check(shed == 6, f"pressure.shed={shed} != 6")  # 14 arrivals, queue of 8
    check(dead == 2, f"pressure.deadline={dead} != 2")
    check(done == 6, f"pressure.completed={done} != 6")
    priced = QueryServer(measure_profile=True, max_price_s=1e-12)
    rej = [QueryRequest(qid=2000 + j, plan=pq.plan, tables=pq.tables)
           for j in range(2)]
    for req in rej:
        priced.submit(req)
    priced.run()
    check(all(r.error == "rejected" for r in rej), "pressure.not_rejected")
    pressure = {"shed": shed, "deadline": dead, "completed": done,
                "rejected": sum(r.error == "rejected" for r in rej),
                "counters": _counter_delta(before)}

    # ---- memory: byte budget, morsel out-of-core fallback, oom faults ---
    # The big splittable shape is a wide multi-column filter: its audited
    # peak scales linearly with the morsel axis. (Join-shaped plans carry
    # a probe-size-independent hash-build structure, so at chaos scale
    # they cannot shrink their peak much by chunking the probe side.)
    before = _counter_window()
    rngm = np.random.default_rng(seed + 7)
    big_plan = scan("B").filter("c0", "<", 60)
    big_qs = []
    # sized so budget = 0.6 * whole-peak clears every standard shape's
    # whole-plan peak (~17 MiB, dominated by the fixed PHJ build side)
    for j in range(3):
        cols = {f"c{c}": jnp.asarray(
                    rngm.integers(0, 100, 250_000).astype(np.int32))
                for c in range(48)}
        big_qs.append(ChaosQuery(qid=3000 + j, shape="bigfilter",
                                 plan=big_plan, tables={"B": Table(cols)}))
    # size the big shape with the same machinery admission uses
    _, bucketsB = plan_signature(big_plan, big_qs[0].tables)
    paddedB = {n: pad_table(t, bucketsB[n])
               for n, t in big_qs[0].tables.items()}
    physB = optimize(big_plan, S.Catalog(paddedB), measure_profile=True)
    big_whole = executor.plan_peak_bytes(
        physB, paddedB,
        counts={n: t.num_rows for n, t in big_qs[0].tables.items()})
    budget = int(big_whole * 0.6)  # big must chunk; standard must fit
    max_standard = max(standard_peaks.values())
    check(budget > int(1.05 * max_standard),
          f"memory.budget_too_small: budget={budget} vs "
          f"standard peak {max_standard}")
    for q in big_qs:
        q.oracle = canon(*optimize(q.plan, S.Catalog(q.tables),
                                   measure_profile=True).run())
    # one join query in its OWN capacity bucket (S outside the standard
    # 2048 bucket) gets an injected oom on its fast attempt: it must
    # recover through the chunked fallback without perturbing the cached
    # morsel factor of the standard join signature
    seedo = int(np.random.default_rng(seed + 13).integers(0, 2**31 - 1))
    R2, S2 = relgen.generate(relgen.JoinWorkload("cm", 350, 2500, 1, 1,
                                                 seed=seedo))
    oomq = ChaosQuery(qid=3100, shape="join", plan=PLANS["join"],
                      tables={"R": R2, "S": S2})
    oomq.oracle = canon(*optimize(oomq.plan, S.Catalog(oomq.tables),
                                  measure_profile=True).run())

    mem_queries = list(queries)
    for pos, bq in zip((5, 17, 29), big_qs):
        mem_queries.insert(min(pos, len(mem_queries)), bq)
    mem_queries.append(oomq)

    def mem_fault(q):
        return "oom:executor.run@0" if q.qid == oomq.qid else ""

    server, reqs, _, wall = _drive(
        mem_queries, fault_for=mem_fault,
        server_kw=dict(mem_budget_bytes=budget))
    req_by_qid = {r.qid: r for r in reqs}
    wrong = contaminated = 0
    for q in mem_queries:
        req = req_by_qid[q.qid]
        if not (req.done and not req.error and req.result is not None):
            check(False, f"memory.q{q.qid}: {req.error or 'not done'} "
                         f"{req.detail}")
            continue
        if canon(*req.result) != q.oracle:
            wrong += 1
        if q.qid < 3000 and (req.path != "fast" or req.morsels != 1
                             or req.escalations):
            contaminated += 1
    check(wrong == 0, f"memory.wrong_results={wrong}")
    check(contaminated == 0, f"memory.contaminated={contaminated}")
    for bq in big_qs:
        check(req_by_qid[bq.qid].morsels >= 2,
              f"memory.q{bq.qid}.not_chunked "
              f"(morsels={req_by_qid[bq.qid].morsels})")
    # the injected oom is caught INSIDE executor.run, which degrades the
    # plan onto its morsel rung before the server ever sees a failure:
    # the request stays fast-path, the engine counters record the rescue
    check(req_by_qid[oomq.qid].path == "fast",
          f"memory.oom_query_path={req_by_qid[oomq.qid].path}")
    check(server.budget.peak_reserved <= server.budget.total,
          f"memory.reserved_over_budget: {server.budget.peak_reserved} > "
          f"{server.budget.total}")
    check(server.budget.reserved == 0, "memory.reservations_leaked")

    # blast radius: standard signatures' warm p99 within 2x baseline
    walls = _warm_walls(reqs)
    mem_confinement = {}
    for s in SHAPES:
        p99 = metrics.percentiles(walls.get(sig_of_shape[s], []),
                                  (99,))["p99"]
        base = base_shape_p99[s]
        mem_confinement[s] = {"p99_s": p99, "baseline_p99_s": base}
        check(p99 <= max(2 * base, base + 0.010),
              f"memory.p99_blowup.{s}: {p99:.4f}s vs base {base:.4f}s")

    # a never-fitting unsplittable shape (top-k root has no morsel axis)
    # must be REJECTED with the typed error, not crash the server
    tq = by_shape["topk"][0]
    rej_server = QueryServer(measure_profile=True, mem_budget_bytes=4096)
    rej_req = QueryRequest(qid=3200, plan=tq.plan, tables=tq.tables)
    rej_server.submit(rej_req)
    rej_server.run()
    check(rej_req.error == "rejected",
          f"memory.unsplittable_not_rejected: {rej_req.error}")
    check("MemoryBudgetExceeded" in (rej_req.detail or ""),
          f"memory.reject_detail: {rej_req.detail}")

    mem_delta = _counter_delta(before)
    check(mem_delta.get("qserve.chunked_runs", 0) >= 3,
          f"memory.chunked_runs={mem_delta.get('qserve.chunked_runs', 0)}")
    check(mem_delta.get("qserve.mem_rejections", 0) >= 1,
          "memory.no_mem_rejections")
    check(mem_delta.get("resilience.oom_injected", 0) >= 1,
          "memory.oom_never_fired")
    check(mem_delta.get("resilience.plan_degradations", 0) >= 1,
          "memory.oom_not_rescued_by_morsel_rung")
    memory_report = {
        "budget_bytes": budget, "big_whole_peak_bytes": big_whole,
        "max_standard_peak_bytes": max_standard,
        "big_morsels": [req_by_qid[bq.qid].morsels for bq in big_qs],
        "chunked_runs": mem_delta.get("qserve.chunked_runs", 0),
        "mem_deferrals": mem_delta.get("qserve.mem_deferrals", 0),
        "mem_rejections": mem_delta.get("qserve.mem_rejections", 0),
        "oom_injected": mem_delta.get("resilience.oom_injected", 0),
        "reserved_le_budget": bool(server.budget.peak_reserved
                                   <= server.budget.total),
        "peak_reserved_bytes": server.budget.peak_reserved,
        "wrong_results": wrong, "contaminated": contaminated,
        "confinement": mem_confinement, "wall_s": wall,
        "counters": mem_delta,
    }

    return {
        "ok": not failures, "failures": failures,
        "config": {"queries_per_family": queries_per_family, "seed": seed,
                   "smoke": smoke, "shapes": list(SHAPES),
                   "families": list(families)},
        "baseline": baseline, "families": family_reports,
        "pressure": pressure, "memory": memory_report,
    }

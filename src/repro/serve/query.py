"""Concurrent relational query-serving runtime (DESIGN.md §14).

The engine under traffic: many logical plans in flight at once, driven by
the same deterministic tick-loop discipline as the decode server
(serve/engine.py) — bounded queue with load shedding, per-query
deadlines, a fixed number of execution slots per tick — plus the three
mechanisms that make a *relational* server more than a loop around
`optimize().run()`:

  * **capacity bucketing** — input relations are padded up to
    power-of-two capacity buckets (`bucket_rows` / `pad_table`) and their
    TRUE valid counts ride into the executor as traced scalars
    (`executor.run(..., counts=...)`), so differently-sized datasets with
    the same plan shape and schema hit the SAME compiled executable. The
    compiled-plan cache is keyed by `plan_signature` = hash(logical plan,
    per-table capacity bucket + dtype schema).
  * **cost-priced admission** — the optimizer's `predict_*` total cost is
    the admission ticket: each tick admits FIFO work until a per-tick
    predicted-seconds budget is spent, and a query priced above
    `max_price_s` is rejected outright. Planning happens once per
    signature, at first admission, and the price is cached with the plan.
  * **per-signature circuit breakers** — a signature whose fast
    (compiled) executions keep failing is quarantined: while its breaker
    is OPEN, its queries run the SAFE path — eager `checked_mode`
    execution (escalation ladders live) over a `physical.degrade_plan`
    escalation chain — while every other signature stays on the fast
    path. Half-open probes re-try the fast path after a cooldown and
    close the breaker on success. One hostile query shape degrades alone.

Failure detection on the fast path is two-pronged: exceptions (ladder
exhaustion, kernel faults, injected `raise:*`) and *saturation* — a
data-dependent root whose valid count fills its static capacity is
treated as suspect truncation (the silent-failure mode of adversarially
wrong estimates, e.g. `estimates:/32`), because every capacity-clamped
operator reports `count = min(found, capacity)`. Saturated fast runs are
re-run on the safe path, which escalates `degrade_plan` levels (capacity
x2 per level) until the result fits, then remembers the converged level
on the cache entry.

Memory governor (DESIGN.md §15): admission also buys a *bytes ticket* —
each signature's audited `peak_live_bytes` (computed once, cached on the
entry) must fit ``budget - reserved`` (`engine.membudget.MemoryBudget`).
Over-budget-but-splittable signatures run out-of-core through the morsel
driver (`executor.run_morsels`) at the smallest fitting power-of-two
factor; a request whose ticket doesn't fit *right now* is DEFERRED
(off-queue, so it never starves fresh submissions of max_queue slots);
a signature that can never fit is rejected with the typed
`MemoryBudgetExceeded`. Tickets release when the run leaves the server,
on every path.

Chaos hooks: each request's `fault_spec` (the `repro.resilience.faults`
grammar) is activated around ITS planning/execution only, and the
host-side sites `qserve.plan` / `qserve.execute` can be targeted by
`raise:` specs (`oom:qserve.admit` / `oom:executor.run` inject
allocation failures). See serve/chaos.py for the soak harness.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import time
from typing import Mapping

import jax.numpy as jnp

from repro.core.table import Table
from repro.engine import executor
from repro.engine import membudget as MB
from repro.engine import physical as P
from repro.engine import stats as S
from repro.obs import metrics
from repro.resilience import escalation, faults

MIN_BUCKET = 64  # smallest capacity bucket (one lane-rounded tile)


class CapacitySaturated(RuntimeError):
    """A root operator's valid count reached its static capacity: the
    result is *suspected* truncated (capacity clamping makes real
    truncation indistinguishable from an exact fit), so the run is
    treated as failed and retried with more headroom."""


def bucket_rows(n: int) -> int:
    """Power-of-two capacity bucket for an ``n``-row relation (>= MIN_BUCKET).
    Padding to the bucket means at most 2x wasted rows, in exchange for a
    compiled-plan cache that differently-sized relations can share."""
    return max(MIN_BUCKET, 1 << max(int(n - 1).bit_length(), 0))


def pad_table(t: Table, capacity: int) -> Table:
    """Pad every column of `t` to `capacity` rows.

    Integer columns are padded with a synthetic continuation
    (max+1, max+2, ...): this preserves exact column uniqueness — the
    optimizer's PK-FK proof runs on the padded table — and never inflates
    any existing key's multiplicity, so padded statistics stay faithful to
    the real data's join geometry. Float columns wrap-repeat. Padded rows
    are dead weight at run time: the executor's (Table, valid_count)
    discipline masks them to KEY_SENTINEL before any key-consuming
    operator, so their values only ever influence compile-time statistics.
    """
    n = t.num_rows
    if n == capacity:
        return t
    if n > capacity:
        raise ValueError(f"table has {n} rows > bucket capacity {capacity}")
    pad = capacity - n
    cols = {}
    for name in t.column_names:
        col = t[name]
        if jnp.issubdtype(col.dtype, jnp.integer):
            fill = col.max() + 1 + jnp.arange(pad, dtype=col.dtype)
            cols[name] = jnp.concatenate([col, fill.astype(col.dtype)])
        else:
            cols[name] = jnp.resize(col, (capacity,))
    return Table(cols)


def plan_signature(plan, tables: Mapping[str, Table]):
    """Normalize-and-hash a submission into its cache identity.

    The signature covers the logical plan tree (frozen dataclass repr —
    operator order, keys, aggregates, filter constants) and each input
    relation's (capacity bucket, column dtypes). Two submissions whose
    plans match and whose relations share schemas and buckets collapse to
    one signature — one optimizer call, one compiled executable, one
    circuit breaker. Returns ``(signature, {table: bucket})``."""
    buckets = {name: bucket_rows(t.num_rows) for name, t in tables.items()}
    schema = tuple(
        (name, buckets[name],
         tuple((c, str(tables[name][c].dtype))
               for c in tables[name].column_names))
        for name in sorted(tables))
    digest = hashlib.sha256(repr((plan, schema)).encode()).hexdigest()
    return digest[:16], buckets


def _saturated(root, count) -> bool:
    """True when a data-dependent root filled its static capacity — the
    truncation-suspicion signal. Order-by-limit roots saturate by design
    (top-k fills its limit); scans/projects are full-width by contract."""
    if not isinstance(root, (P.PFilter, P.PJoin, P.PGroupBy, P.PGroupJoin)):
        return False
    return int(count) >= root.capacity


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass
class CircuitBreaker:
    """Per-plan-signature failure isolation (DESIGN.md §14).

    State machine::

        CLOSED ──(threshold consecutive fast failures)──> OPEN
        OPEN ──(cooldown ticks elapsed)──> HALF_OPEN: one fast probe
        HALF_OPEN ──probe success──> CLOSED   (cooldown resets)
        HALF_OPEN ──probe failure──> OPEN     (cooldown doubles, capped)

    While OPEN, `route()` sends every request of the signature to the
    safe path (degraded plans + eager checked_mode). Safe-path successes
    do NOT close the breaker — they prove the quarantine works, not that
    the fast path recovered; only a half-open probe can close it. A
    safe-path failure pushes the next probe out (the signature is failing
    even degraded; probing the fast path sooner is pointless)."""

    signature: str
    threshold: int = 2
    cooldown: int = 8
    max_cooldown: int = 64
    state: str = CLOSED
    failures: int = 0  # consecutive fast-path failures
    opened_at: int = -1
    _cooldown0: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self):
        self._cooldown0 = self.cooldown

    def route(self, tick: int) -> str:
        """'fast' or 'safe' for a request arriving at `tick`."""
        if self.state == OPEN and tick - self.opened_at >= self.cooldown:
            self.state = HALF_OPEN
            metrics.counter("qserve.breaker_probes").inc()
            return "fast"  # the half-open probe
        return "fast" if self.state == CLOSED else "safe"

    def record_fast_success(self, tick: int) -> None:
        if self.state == HALF_OPEN:
            metrics.counter("qserve.breaker_closes").inc()
            self.cooldown = self._cooldown0
        self.state, self.failures = CLOSED, 0

    def record_fast_failure(self, tick: int) -> None:
        self.failures += 1
        if self.state == HALF_OPEN:
            self.cooldown = min(self.cooldown * 2, self.max_cooldown)
            self._open(tick)
        elif self.state == CLOSED and self.failures >= self.threshold:
            self._open(tick)

    def record_safe_failure(self, tick: int) -> None:
        if self.state == OPEN:
            self.opened_at = tick  # still toxic: push the probe out

    def _open(self, tick: int) -> None:
        self.state, self.opened_at = OPEN, tick
        metrics.counter("qserve.breaker_opens").inc()
        escalation.record_degradation(
            "qserve", f"breaker OPEN sig={self.signature[:8]} "
                      f"cooldown={self.cooldown}")


# ---------------------------------------------------------------------------
# requests and cache entries
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class QueryRequest:
    """One query in flight. `fault_spec` (the REPRO_FAULTS grammar; "" =
    none) is activated via `faults.inject()` around THIS request's
    planning and execution stages only — the chaos harness's per-request
    hostile-conditions hook."""

    qid: int
    plan: object  # logical.Plan
    tables: dict  # {name: Table} — the request's actual (unpadded) inputs
    # absolute server tick by which the request must START running
    # (None = no deadline); overdue queued requests are evicted with
    # error="deadline"
    deadline_ticks: int | None = None
    fault_spec: str = ""
    # -- outcome -----------------------------------------------------------
    result: tuple | None = None  # (Table, valid_count) on success
    done: bool = False
    # why the request finished without a result: "" | "shed" | "rejected"
    # | "deadline" | "failed"
    error: str = ""
    detail: str = ""
    # which execution path delivered the result: "fast" | "safe" |
    # "fast+safe" (fast attempt failed, same-tick safe fallback delivered)
    path: str = ""
    signature: str = ""
    price_s: float = 0.0  # the optimizer's predicted cost = admission ticket
    # -- latency breakdown -------------------------------------------------
    submit_tick: int = -1
    admit_tick: int = -1
    done_tick: int = -1
    ticks_queued: int = 0
    # ticks spent memory-deferred: the bytes ticket didn't fit
    # `budget - reserved`, so the request waited WITHOUT occupying a
    # max_queue slot (DESIGN.md §15)
    ticks_deferred: int = 0
    plan_wall_s: float = 0.0
    exec_wall_s: float = 0.0
    escalations: int = 0  # safe-path degrade-level escalations
    morsels: int = 1  # morsel factor the result was produced at (1 = whole)


@dataclasses.dataclass
class CompiledEntry:
    """One signature's cached artifacts: the optimized plan (whose
    `compiled_bucketed` executable all same-signature requests share), its
    predicted price, and the lazily-built `degrade_plan` escalation chain
    the safe path climbs. `safe_level` remembers where the safe path last
    converged, so a quarantined signature pays its escalation walk once."""

    signature: str
    buckets: dict
    plan: P.PhysicalPlan
    price_s: float
    hits: int = 0
    safe_level: int = 0
    degraded_chain: list = dataclasses.field(default_factory=list, repr=False)
    # -- memory governor (DESIGN.md §15) -------------------------------------
    # the bytes ticket admission buys: the audited peak-live watermark of
    # the form this signature actually runs (whole plan, or the smallest
    # fitting morsel clone when the whole plan exceeds the budget)
    peak_bytes: int = 0
    # 1 = whole-plan execution fits; >= 2 = run through the morsel driver
    # at this factor; 0 = NEVER fits (no morsel axis, or no factor small
    # enough) — admission rejects with MemoryBudgetExceeded
    morsel_factor: int = 1

    def degraded(self, level: int) -> P.PhysicalPlan:
        """The plan with `degrade_plan` applied `level` times (level 0 =
        the original plan run under checked_mode; each level doubles every
        data-bearing capacity and forces exact strategies)."""
        if level == 0:
            return self.plan
        while len(self.degraded_chain) < level:
            base = (self.degraded_chain[-1] if self.degraded_chain
                    else self.plan)
            self.degraded_chain.append(P.degrade_plan(
                base, f"qserve safe level {len(self.degraded_chain) + 1}"))
        return self.degraded_chain[level - 1]


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------
class QueryServer:
    """Deterministic tick-loop relational query server.

    Usage::

        server = QueryServer(tick_budget_s=0.05)
        for q in queries:
            server.submit(QueryRequest(qid=..., plan=..., tables=...))
        server.run()                # drains the queue
        server.completed            # every request, with outcomes

    Per tick (`step()`): sweep queued deadlines -> admit FIFO work
    (bounded by `slots_per_tick` and the predicted-cost tick budget;
    overpriced queries rejected) -> execute admitted requests through
    their signatures' breaker-chosen path."""

    def __init__(self, *, max_queue: int | None = 256,
                 slots_per_tick: int = 4,
                 tick_budget_s: float = float("inf"),
                 max_price_s: float = float("inf"),
                 mem_budget_bytes: int | None = None,
                 safety: float = 1.5, measure_profile: bool = False,
                 breaker_threshold: int = 2, breaker_cooldown: int = 8,
                 breaker_max_cooldown: int = 64, max_safe_level: int = 6):
        self.max_queue = max_queue
        self.slots_per_tick = slots_per_tick
        self.tick_budget_s = tick_budget_s
        self.max_price_s = max_price_s
        # bytes ticket (DESIGN.md §15): each admitted request reserves its
        # signature's peak-live bytes until its run finishes; default
        # budget is backend-detected / REPRO_MEM_BUDGET_BYTES
        self.budget = MB.MemoryBudget(mem_budget_bytes)
        self.safety = safety
        self.measure_profile = measure_profile
        self.breaker_kw = dict(threshold=breaker_threshold,
                               cooldown=breaker_cooldown,
                               max_cooldown=breaker_max_cooldown)
        self.max_safe_level = max_safe_level
        self.cache: dict[str, CompiledEntry] = {}
        self.breakers: dict[str, CircuitBreaker] = {}
        self.queue: list[QueryRequest] = []
        # memory-deferred requests: planned and priced, waiting for budget
        # headroom. NOT part of `queue` — a stuck large query must not
        # occupy a max_queue slot and starve fresh submissions
        self.deferred: list[QueryRequest] = []
        self.completed: list[QueryRequest] = []
        self.tick = 0

    # -- admission -----------------------------------------------------------
    def submit(self, req: QueryRequest) -> None:
        req.submit_tick = self.tick
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.error, req.done, req.done_tick = "shed", True, self.tick
            metrics.counter("qserve.shed").inc()
            escalation.record_degradation(
                "qserve", f"shed qid={req.qid}: queue full ({self.max_queue})")
            self.completed.append(req)
            return
        metrics.counter("qserve.submitted").inc()
        self.queue.append(req)

    def _fault_ctx(self, req: QueryRequest):
        return (faults.inject(req.fault_spec) if req.fault_spec
                else contextlib.nullcontext())

    def _finish(self, req: QueryRequest, error: str, detail: str = "") -> None:
        req.error, req.done, req.done_tick = error, True, self.tick
        req.detail = detail[:200]
        self.completed.append(req)

    def _sweep_deadlines(self) -> None:
        overdue = [r for r in self.queue + self.deferred
                   if r.deadline_ticks is not None
                   and self.tick >= r.deadline_ticks]
        if not overdue:
            return
        self.queue = [r for r in self.queue if r not in overdue]
        self.deferred = [r for r in self.deferred if r not in overdue]
        for req in overdue:
            metrics.counter("qserve.deadline_evictions").inc()
            self._finish(req, "deadline")

    def _ensure_entry(self, req: QueryRequest) -> CompiledEntry:
        t0 = time.perf_counter()
        sig, buckets = plan_signature(req.plan, req.tables)
        req.signature = sig
        if sig not in self.breakers:
            self.breakers[sig] = CircuitBreaker(sig, **self.breaker_kw)
        entry = self.cache.get(sig)
        if entry is None:
            faults.check_site("qserve.plan")
            # plan against the PADDED relations: the optimizer's capacity
            # and strategy choices must hold for every dataset in the
            # bucket, and padded statistics are faithful (see pad_table)
            padded = {n: pad_table(t, buckets[n])
                      for n, t in req.tables.items()}
            phys = P.optimize(req.plan, S.Catalog(padded),
                              safety=self.safety,
                              measure_profile=self.measure_profile)
            entry = CompiledEntry(signature=sig, buckets=buckets, plan=phys,
                                  price_s=float(phys.total_cost))
            self._size_entry(entry, padded)
            self.cache[sig] = entry
            metrics.counter("qserve.plans_compiled").inc()
        else:
            entry.hits += 1
            metrics.counter("qserve.plan_cache_hits").inc()
        req.price_s = entry.price_s
        req.plan_wall_s = time.perf_counter() - t0
        return entry

    def _size_entry(self, entry: CompiledEntry, padded: Mapping) -> None:
        """Size a fresh entry's bytes ticket (DESIGN.md §15): the audited
        peak-live watermark of the bucketed form the signature runs. When
        the whole plan exceeds the TOTAL budget, probe power-of-two morsel
        factors (smallest first) for the first clone whose peak fits and
        cache it — the ticket is then the MORSEL peak, and every run of
        the signature goes through the morsel driver. No fitting factor
        (or no morsel axis) leaves ``morsel_factor = 0``: the signature
        can never fit, and admission rejects it with the typed error."""
        counts = {n: t.num_rows for n, t in padded.items()}
        entry.peak_bytes = executor.plan_peak_bytes(
            entry.plan, padded, counts=counts)
        if entry.peak_bytes <= self.budget.total:
            return
        axis = P.morsel_axis(entry.plan.root)
        if axis is None:
            entry.morsel_factor = 0
            return
        rows = entry.buckets[axis]
        factor = 2
        while True:
            try:
                mp = P.morsel_plan(entry.plan, factor, rows=rows)
            except ValueError:  # no recombinable partial rewrite
                break
            m = P.morsel_rows(rows, factor)
            mtables = dict(padded)
            mtables[axis] = padded[axis].head(m)
            mcounts = dict(counts)
            mcounts[axis] = m
            peak = executor.plan_peak_bytes(mp, mtables, counts=mcounts)
            if peak <= self.budget.total:
                entry.peak_bytes = peak
                entry.morsel_factor = factor
                return
            if m <= MIN_BUCKET:
                break  # morsels can't shrink further
            factor *= 2
        entry.morsel_factor = 0  # never fits

    def _try_reserve(self, entry: CompiledEntry, req: QueryRequest) -> bool:
        """Buy the request's bytes ticket: reserve the entry's peak against
        `budget - reserved`. The `oom:qserve.admit` fault site models an
        allocation race lost at admission — an injected hit counts as a
        failed reservation (the request defers), never as an error."""
        try:
            with self._fault_ctx(req):
                faults.check_oom("qserve.admit")
        except faults.OOMInjected:
            return False
        return self.budget.try_reserve(f"q{req.qid}", entry.peak_bytes)

    def _admit(self) -> list[QueryRequest]:
        batch: list[QueryRequest] = []
        spent = 0.0
        # memory-deferred requests retry FIRST (FIFO seniority: they were
        # submitted before anything still in the queue), sharing the tick's
        # slot and seconds budgets with fresh admissions
        still_deferred: list[QueryRequest] = []
        for i, req in enumerate(self.deferred):
            entry = self.cache[req.signature]
            if len(batch) >= self.slots_per_tick or (
                    batch and spent + req.price_s > self.tick_budget_s):
                still_deferred.extend(self.deferred[i:])
                break
            if not self._try_reserve(entry, req):
                still_deferred.append(req)
                continue
            spent += req.price_s
            req.admit_tick = self.tick
            batch.append(req)
        self.deferred = still_deferred
        while self.queue and len(batch) < self.slots_per_tick:
            req = self.queue[0]
            try:
                with self._fault_ctx(req):
                    self._ensure_entry(req)
            except Exception as e:  # noqa: BLE001 — planning failed alone
                self.queue.pop(0)
                metrics.counter("qserve.failed").inc()
                escalation.record_degradation(
                    "qserve", f"plan failed qid={req.qid}: "
                              f"{type(e).__name__}: {e}"[:160])
                self._finish(req, "failed", f"plan: {type(e).__name__}: {e}")
                continue
            if req.price_s > self.max_price_s:
                # admission control: the cost model prices the query out
                self.queue.pop(0)
                metrics.counter("qserve.rejected").inc()
                escalation.record_degradation(
                    "qserve", f"rejected qid={req.qid}: price "
                              f"{req.price_s:.6f}s > {self.max_price_s}s")
                self._finish(req, "rejected",
                             f"price {req.price_s:.6f}s > cap")
                continue
            entry = self.cache[req.signature]
            if entry.morsel_factor == 0:
                # can NEVER fit the device budget, at any morsel factor:
                # typed rejection, not a crash or an eternal deferral
                self.queue.pop(0)
                exc = MB.MemoryBudgetExceeded(
                    entry.peak_bytes, self.budget.total,
                    "unsplittable at any morsel factor")
                metrics.counter("qserve.mem_rejections").inc()
                escalation.record_degradation(
                    "qserve", f"mem-rejected qid={req.qid}: {exc}"[:160])
                self._finish(req, "rejected", f"{type(exc).__name__}: {exc}")
                continue
            if batch and spent + req.price_s > self.tick_budget_s:
                break  # FIFO head waits for a tick with budget headroom
            if not self._try_reserve(entry, req):
                # splittable and budget-sized, just not NOW: defer without
                # holding a max_queue slot; retried next tick. Queue time
                # freezes here — deferred ticks accrue separately
                self.queue.pop(0)
                req.ticks_queued = self.tick - req.submit_tick
                metrics.counter("qserve.mem_deferrals").inc()
                self.deferred.append(req)
                continue
            self.queue.pop(0)
            spent += req.price_s
            req.admit_tick = self.tick
            req.ticks_queued = self.tick - req.submit_tick
            batch.append(req)
        return batch

    # -- execution -----------------------------------------------------------
    def _pad_inputs(self, entry: CompiledEntry, req: QueryRequest):
        padded = {n: pad_table(t, entry.buckets[n])
                  for n, t in req.tables.items()}
        counts = {n: t.num_rows for n, t in req.tables.items()}
        return padded, counts

    def _run_fast(self, entry: CompiledEntry, req: QueryRequest):
        faults.check_site("qserve.execute")
        padded, counts = self._pad_inputs(entry, req)
        if entry.morsel_factor > 1:
            # budget-sized signature: out-of-core morsel path, one chunk
            # at a time through the cached morsel clone's executable
            out, count = executor.run_morsels(
                entry.plan, padded, counts=counts,
                factor=entry.morsel_factor)
            metrics.counter("qserve.chunked_runs").inc()
            req.morsels = entry.morsel_factor
        else:
            out, count = executor.run(entry.plan, padded, counts=counts)
        metrics.counter("qserve.fast_runs").inc()
        if _saturated(entry.plan.root, count):
            metrics.counter("qserve.saturations").inc()
            raise CapacitySaturated(
                f"root count {int(count)} filled capacity "
                f"{entry.plan.root.capacity}")
        return out, count

    def _run_safe(self, entry: CompiledEntry, req: QueryRequest):
        """Quarantine execution: eager checked_mode (ladders live) over the
        degrade_plan escalation chain, climbing levels until the result
        fits its capacities. Converged level is cached on the entry."""
        faults.check_site("qserve.execute")
        padded, counts = self._pad_inputs(entry, req)
        last_exc: Exception | None = None
        for level in range(entry.safe_level, self.max_safe_level + 1):
            plan = entry.degraded(level)
            try:
                out, count = executor.run(plan, padded, counts=counts,
                                          jit=False)
            except executor._NON_DEGRADABLE:
                raise
            except Exception as e:  # noqa: BLE001 — escalate a level
                last_exc = e
                metrics.counter("qserve.safe_escalations").inc()
                req.escalations += 1
                continue
            if _saturated(plan.root, count):
                metrics.counter("qserve.safe_escalations").inc()
                req.escalations += 1
                continue
            entry.safe_level = level
            metrics.counter("qserve.safe_runs").inc()
            return out, count
        raise CapacitySaturated(
            f"safe path exhausted at level {self.max_safe_level}"
        ) from last_exc

    def _run_chunked_safe(self, entry: CompiledEntry, req: QueryRequest):
        """Memory fallback: a run that hit an allocation failure retries
        out-of-core, climbing power-of-two morsel factors until one fits
        the device. The converged factor is cached on the entry so later
        runs of the signature go straight to the morsel path."""
        axis = P.morsel_axis(entry.plan.root)
        if axis is None:
            raise MB.MemoryBudgetExceeded(
                entry.peak_bytes, self.budget.total, "no morsel axis")
        padded, counts = self._pad_inputs(entry, req)
        rows = entry.buckets[axis]
        factor = max(entry.morsel_factor, 1) * 2
        last_exc: Exception | None = None
        while factor <= max(rows // MIN_BUCKET, 2):
            try:
                out, count = executor.run_morsels(
                    entry.plan, padded, counts=counts, factor=factor)
            except executor._NON_DEGRADABLE:
                raise
            except Exception as e:  # noqa: BLE001 — shrink and retry
                if not MB.is_memory_error(e):
                    raise
                last_exc = e
                factor *= 2
                continue
            entry.morsel_factor = factor
            metrics.counter("qserve.chunked_runs").inc()
            req.morsels = factor
            return out, count
        raise MB.MemoryBudgetExceeded(
            entry.peak_bytes, self.budget.total,
            f"morsel factors exhausted at {factor // 2}") from last_exc

    def _fallback(self, entry: CompiledEntry, req: QueryRequest,
                  fast_exc: Exception):
        """The same-tick fallback after a fast failure: allocation
        failures of a splittable plan go out-of-core (`_run_chunked_safe`
        — a SMALLER working set); everything else climbs the
        capacity-doubling safe chain."""
        if (MB.is_memory_error(fast_exc)
                and P.morsel_axis(entry.plan.root) is not None):
            return self._run_chunked_safe(entry, req)
        return self._run_safe(entry, req)

    def _run_one(self, req: QueryRequest) -> None:
        entry = self.cache[req.signature]
        breaker = self.breakers[req.signature]
        t0 = time.perf_counter()
        try:
            with self._fault_ctx(req):
                route = breaker.route(self.tick)
                try:
                    if route == "fast":
                        out = self._run_fast(entry, req)
                    else:
                        out = self._run_safe(entry, req)
                except executor._NON_DEGRADABLE:
                    raise  # programming errors surface; never quarantine
                except Exception as e:  # noqa: BLE001 — contain to request
                    if route == "fast":
                        breaker.record_fast_failure(self.tick)
                        metrics.counter("qserve.fast_failures").inc()
                        try:
                            out = self._fallback(entry, req, e)
                            route = "fast+safe"
                        except executor._NON_DEGRADABLE:
                            raise
                        except Exception as e2:  # noqa: BLE001
                            breaker.record_safe_failure(self.tick)
                            metrics.counter("qserve.failed").inc()
                            req.exec_wall_s = time.perf_counter() - t0
                            self._finish(req, "failed",
                                         f"{type(e2).__name__}: {e2}")
                            return
                    else:
                        breaker.record_safe_failure(self.tick)
                        metrics.counter("qserve.failed").inc()
                        req.exec_wall_s = time.perf_counter() - t0
                        self._finish(req, "failed",
                                     f"{type(e).__name__}: {e}")
                        return
                else:
                    if route == "fast":
                        breaker.record_fast_success(self.tick)
        finally:
            # the bytes ticket is held from admission to HERE — every exit
            # path (success, failure, even a surfacing programming error)
            # releases it, so reservations can never leak
            self.budget.release(f"q{req.qid}")
        req.exec_wall_s = time.perf_counter() - t0
        req.result = out
        req.path = route
        req.done, req.done_tick = True, self.tick
        metrics.counter("qserve.completed").inc()
        metrics.histogram("qserve.exec_wall_s").observe(req.exec_wall_s)
        metrics.histogram("qserve.latency_ticks").observe(
            self.tick - req.submit_tick + 1)
        self.completed.append(req)

    # -- the loop ------------------------------------------------------------
    def step(self) -> bool:
        """One server tick. Returns True if any work happened or remains."""
        self.tick += 1
        self._sweep_deadlines()
        batch = self._admit()
        # the post-admission ledger is the tick's high-water mark: every
        # ticket bought this tick is reserved, nothing has released yet
        metrics.histogram("qserve.bytes_reserved").observe(
            float(self.budget.reserved))
        for req in self.deferred:
            req.ticks_deferred += 1
        for req in batch:
            self._run_one(req)
        return bool(batch) or bool(self.queue) or bool(self.deferred)

    def run(self, max_ticks: int = 100_000) -> int:
        """Step until the queue and deferred list drain (or `max_ticks`).
        Returns ticks."""
        ticks = 0
        while (self.queue or self.deferred) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks

"""`python -m repro.serve --chaos` — chaos/soak gate for the query server.

Drives the mixed-query soak (serve/chaos.py) under every fault-grammar
family, writes the scoreboard to BENCH_serve.json (p50/p99 latency +
throughput baseline, per-family blast-radius reports, degradation
counters), and exits non-zero if any delivered result diverged from its
fault-free oracle or any blast-radius / counter-consistency assertion
failed.

Usage: python -m repro.serve --chaos [--smoke] [--out PATH]
  --smoke   CI scale (<= 48 queries per family instead of 200)
  --out     output path (default BENCH_serve.json)
"""
from __future__ import annotations

import json
import sys


def main(argv: list[str]) -> int:
    if "--chaos" not in argv:
        print(__doc__)
        return 0 if argv in ([], ["--help"]) else 2
    out = "BENCH_serve.json"
    if "--out" in argv:
        out = argv[argv.index("--out") + 1]
    from repro.serve.chaos import run_chaos

    report = run_chaos(smoke="--smoke" in argv)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps({"ok": report["ok"], "failures": report["failures"],
                      "baseline": {k: report["baseline"][k] for k in
                                   ("p50_s", "p99_s", "throughput_qps")},
                      "wrote": out}, indent=2, sort_keys=True))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

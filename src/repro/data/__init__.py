"""Deterministic synthetic data: relational generators + LM batch fns."""

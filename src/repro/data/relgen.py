"""Relational workload generator — the paper's §5 experimental matrix.

Generates (R, S) pairs with the paper's knobs:
  * sizes (|R|, |S|) with payload column counts per side
  * match ratio (fraction of S rows with a partner; §5.2.3: implemented by
    replacing a fraction of R's primary keys with out-of-domain values)
  * foreign-key skew via Zipf factor (§5.2.4)
  * 4-byte / 8-byte keys and payloads (§5.2.5)
  * star schemas for join sequences (§5.2.7)
  * TPC-H/DS-shaped extracts (Table 6: row counts, K/NK column mixes,
    dictionary-encoded strings -> ints; scaled down by `scale` to fit CPU)

Keys are 0..|R|-1 shuffled (paper §5.1), payload values are derived from the
key so correctness checks can recompute expected outputs cheaply.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import Table


@dataclasses.dataclass(frozen=True)
class JoinWorkload:
    name: str
    n_r: int
    n_s: int
    r_payloads: int = 2
    s_payloads: int = 2
    match_ratio: float = 1.0
    zipf: float = 0.0
    key_dtype: str = "int32"
    payload_dtype: str = "int32"
    seed: int = 0


def _payload(keys: np.ndarray, j: int, dtype) -> np.ndarray:
    return ((keys.astype(np.int64) * (j + 3) * 2654435761) % (1 << 31)).astype(dtype)


def generate(w: JoinWorkload) -> tuple[Table, Table]:
    rng = np.random.default_rng(w.seed)
    kdt = np.dtype(w.key_dtype)
    pdt = np.dtype(w.payload_dtype)

    rkeys = rng.permutation(w.n_r).astype(kdt)
    if w.match_ratio < 1.0:
        # replace a fraction of primary keys with non-matching values (§5.2.3)
        n_drop = int(round((1.0 - w.match_ratio) * w.n_r))
        drop_idx = rng.choice(w.n_r, n_drop, replace=False)
        rkeys[drop_idx] = (np.arange(n_drop) + 2 * w.n_r + 1).astype(kdt)

    if w.zipf > 0:
        ranks = rng.zipf(max(w.zipf, 1.01), size=w.n_s).astype(np.int64)
        skeys = ((ranks - 1) % w.n_r).astype(kdt)
    else:
        skeys = rng.integers(0, w.n_r, w.n_s).astype(kdt)

    R = {"k": jnp.asarray(rkeys)}
    for j in range(w.r_payloads):
        R[f"r{j+1}"] = jnp.asarray(_payload(rkeys, j, pdt))
    S = {"k": jnp.asarray(skeys)}
    for j in range(w.s_payloads):
        S[f"s{j+1}"] = jnp.asarray(_payload(skeys, 100 + j, pdt))
    return Table(R), Table(S)


def generate_star(n_fact: int, n_dim: int, n_joins: int, *, payloads_per_dim=1,
                  seed=0):
    """Fact table with N foreign keys + N dimension tables (Fig. 16)."""
    rng = np.random.default_rng(seed)
    fact = {"payload": jnp.arange(n_fact, dtype=jnp.int32)}
    dims, fks, dks = [], [], []
    for i in range(n_joins):
        fk = rng.integers(0, n_dim, n_fact).astype(np.int32)
        fact[f"fk{i}"] = jnp.asarray(fk)
        dkeys = rng.permutation(n_dim).astype(np.int32)
        cols = {f"k{i}": jnp.asarray(dkeys)}
        for j in range(payloads_per_dim):
            cols[f"p{i}_{j}"] = jnp.asarray(_payload(dkeys, i * 7 + j, np.int32))
        dims.append(Table(cols))
        fks.append(f"fk{i}")
        dks.append(f"k{i}")
    return Table(fact), dims, fks, dks


# TPC-H/DS extracts (Table 6), scaled: (|R|, |S|, K/NK mix per side)
TPC_JOINS = {
    # id: (query, n_r, n_s, r_key_cols, r_nonkey, s_key_cols, s_nonkey, note)
    "J1": ("TPC-H Q7", 15_000_000, 18_200_000, 1, 3, 0, 1, "PK-FK wide join"),
    "J2": ("TPC-H Q18", 15_000_000, 60_000_000, 1, 2, 0, 1, ""),
    "J3": ("TPC-H Q19", 2_000_000, 2_100_000, 0, 3, 0, 3, ""),
    "J4": ("TPC-DS Q64", 1_900_000, 58_000_000, 0, 1, 3, 7, "many S payloads"),
    "J5": ("TPC-DS Q95", 72_000_000, 72_000_000, 0, 1, 0, 1, "self narrow join, m:n"),
}


def generate_tpc(jid: str, *, scale: float = 1 / 64, payload_bytes: int = 8,
                 key_bytes: int = 4, seed: int = 0):
    """Scaled TPC-H/DS join extract. Key attrs are 4B ints; non-key attrs are
    `payload_bytes` ints (dictionary-encoded strings per §5.3)."""
    q, n_r, n_s, rk, rnk, sk, snk, note = TPC_JOINS[jid]
    n_r, n_s = max(int(n_r * scale), 1024), max(int(n_s * scale), 1024)
    kdt = "int32" if key_bytes == 4 else "int64"
    pdt = "int32" if payload_bytes == 4 else "int64"
    w = JoinWorkload(
        name=jid, n_r=n_r, n_s=n_s, r_payloads=rk + rnk, s_payloads=sk + snk,
        match_ratio=1.0, key_dtype=kdt, payload_dtype=pdt, seed=seed,
    )
    if jid == "J5":  # FK-FK self join: duplicate keys on the build side too
        rng = np.random.default_rng(seed)
        keys_r = rng.integers(0, n_r // 4, n_r).astype(kdt)
        keys_s = rng.integers(0, n_r // 4, n_s).astype(kdt)
        R = {"k": jnp.asarray(keys_r), "r1": jnp.asarray(_payload(keys_r, 0, np.dtype(pdt)))}
        S = {"k": jnp.asarray(keys_s), "s1": jnp.asarray(_payload(keys_s, 9, np.dtype(pdt)))}
        return Table(R), Table(S), "mn"
    R, S = generate(w)
    return R, S, "pk_fk"

"""On-device feature-join input pipeline (the paper's ML motivation, §1:
100%-match joins feeding model training on the accelerator).

A training example is assembled relationally, entirely on device:

  fact table   F(sample_id, fk_user, fk_item, label)
  dim tables   U(user_id, user feature cols), I(item_id, item feature cols)

  batch = (F ⋈ U ⋈ I) with GFTR materialization      [repro.core.join]
  aggregate features = GROUP BY over recent history  [repro.core.groupby]

The joined feature columns are binned into token ids so the same LM train
step consumes them (examples/ml_pipeline.py runs this end to end). The
join pattern/algorithm knobs are exposed so the benchmark harness can show
GFUR-vs-GFTR end-to-end pipeline deltas.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import Table, group_aggregate, join_sequence


@dataclasses.dataclass(frozen=True)
class FeatureJoinConfig:
    n_users: int = 4096
    n_items: int = 8192
    user_features: int = 3
    item_features: int = 3
    algorithm: str = "phj"
    pattern: str = "gftr"
    vocab: int = 512  # token bins
    seed: int = 0


def make_dim_tables(cfg: FeatureJoinConfig):
    rng = np.random.default_rng(cfg.seed)
    U = {"uid": jnp.asarray(rng.permutation(cfg.n_users).astype(np.int32))}
    for j in range(cfg.user_features):
        U[f"uf{j}"] = jnp.asarray(rng.normal(size=cfg.n_users).astype(np.float32))
    I = {"iid": jnp.asarray(rng.permutation(cfg.n_items).astype(np.int32))}
    for j in range(cfg.item_features):
        I[f"if{j}"] = jnp.asarray(rng.normal(size=cfg.n_items).astype(np.float32))
    return Table(U), Table(I)


def make_fact_batch(cfg: FeatureJoinConfig, batch: int, seq: int, step: int):
    rng = np.random.default_rng((cfg.seed, step))
    n = batch * seq
    return Table({
        "fk_user": jnp.asarray(rng.integers(0, cfg.n_users, n).astype(np.int32)),
        "fk_item": jnp.asarray(rng.integers(0, cfg.n_items, n).astype(np.int32)),
        "label": jnp.asarray(rng.integers(0, 2, n).astype(np.int32)),
    })


def assemble_batch(cfg: FeatureJoinConfig, U: Table, I: Table, fact: Table,
                   batch: int, seq: int):
    """Join features on device and tokenize into an LM batch."""
    joined, count = join_sequence(
        fact, [U.rename({"uid": "k0"}), I.rename({"iid": "k1"})],
        fk_cols=["fk_user", "fk_item"], dim_keys=["k0", "k1"],
        algorithm=cfg.algorithm, pattern=cfg.pattern,
        restore_order=True, keep_ids=True,  # canonical sample order
    )
    # bin the first user/item feature into token ids (toy featurization)
    uf = joined["uf0"]
    itf = joined["if0"]
    tok = (
        (jnp.clip(uf + itf, -3.0, 3.0) + 3.0) / 6.0 * (cfg.vocab - 2)
    ).astype(jnp.int32) + 1
    tokens = tok.reshape(batch, seq)
    tokens = jnp.concatenate([tokens, tokens[:, :1]], axis=1)  # (b, s+1)
    return {"tokens": tokens}, joined, count


def history_aggregates(cfg: FeatureJoinConfig, fact: Table, num_groups: int = 1024,
                       strategy: str = "partition_hash"):
    """GROUP BY fk_user: per-user engagement stats (count + label mean) —
    the grouped-aggregation half of the assigned title, used as pipeline
    features."""
    t = Table({"k": fact["fk_user"], "label": fact["label"].astype(jnp.float32)})
    return group_aggregate(
        t, key="k", aggs={"label": "mean"}, num_groups=num_groups,
        strategy=strategy,
    )

"""Deterministic synthetic LM data: resumable by construction.

Batches are a pure function of (seed, step), so crash-resume replays the
exact stream with no iterator state to checkpoint. The token process is a
mixture of Zipf-ish unigrams and short copy motifs so small models have
learnable structure (loss drops measurably within tens of steps — used by
the e2e tests)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def make_batch_fn(vocab: int, batch: int, seq: int, *, seed: int = 0,
                  cfg=None):
    """Returns data_iter(step) -> batch dict for the given arch config."""

    def data_iter(step: int):
        rng = np.random.default_rng((seed, step))
        # zipf-ish unigram base
        ranks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
        toks = (ranks - 1) % max(vocab - 2, 1) + 1
        # copy motif: repeat a short window to create learnable bigrams
        motif = rng.integers(1, vocab, size=(batch, 8))
        pos = rng.integers(0, max(seq - 16, 1))
        toks[:, pos : pos + 8] = motif
        toks[:, pos + 8 : pos + 16] = motif
        out = {"tokens": jnp.asarray(toks[:, : seq + 1], jnp.int32)}
        if cfg is not None and cfg.family == "vlm":
            out["vision_emb"] = jnp.asarray(
                rng.normal(size=(batch, cfg.vision_tokens, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        if cfg is not None and cfg.family == "audio":
            out["enc_emb"] = jnp.asarray(
                rng.normal(size=(batch, cfg.encoder_len, cfg.d_model)) * 0.02,
                jnp.float32,
            )
        return out

    return data_iter

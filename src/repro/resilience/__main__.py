"""`python -m repro.resilience --smoke` — fault-injection smoke gate.

Exercises the three resilience layers under deterministic faults
(DESIGN.md §13) and exits non-zero if any degraded run diverges from its
fault-free oracle:

  ladders  — one forced overflow at attempt 0 per escalation ladder
             (phj, groupjoin, groupby_partition): the ladder must
             escalate, converge, and reproduce the oracle's valid rows;
  kernels  — `pallas:*` forces every pallas arm in kernels/ops.py to
             raise: each dispatch must fall back to its XLA arm and
             reproduce the oracle bit-for-bit;
  engine   — `raise:executor.run@0` forces one executor failure: the
             degrade-once re-plan must reproduce the oracle;
  memory   — `oom:executor.run@0` forces one allocation failure: the
             executor must degrade onto the MORSEL rung (out-of-core
             chunked execution, DESIGN.md §15) and reproduce the oracle.

Escalated knobs change row order (partition bits) and padded shape
(accumulator capacity), never the multiset of valid rows — so runs are
compared as canonicalized valid rows: sorted tuples over sorted columns.

The smoke also asserts the `resilience.*` counters moved: a smoke that
passes without firing any fault is a broken smoke (scripts/ci.sh greps
the JSON for this).

Usage: python -m repro.resilience --smoke [--json]
"""
from __future__ import annotations

import json
import sys

import numpy as np


def _canon(table, count):
    """Valid rows, order- and shape-insensitive: sorted row tuples over
    sorted column names (all smoke payloads are integer-valued)."""
    n = int(count)
    cols = sorted(table.column_names)
    mats = [np.asarray(table[c])[:n] for c in cols]
    return tuple(cols), sorted(zip(*[m.tolist() for m in mats]))


def _check(name, oracle, got, failures):
    if oracle == got:
        return {"case": name, "ok": True}
    failures.append(name)
    return {"case": name, "ok": False}


def smoke() -> int:
    import jax.numpy as jnp

    from repro.core import Table
    from repro.core.groupby import groupby_partition_checked
    from repro.core.groupjoin import groupjoin_checked
    from repro.core.hash_join import phj_join_checked
    from repro.data import relgen
    from repro.engine import Catalog, optimize, scan
    from repro.obs import metrics
    from repro.resilience import faults

    rng = np.random.default_rng(7)
    R = Table({"k": jnp.asarray(np.arange(512, dtype=np.int32)),
               "v": jnp.asarray(rng.integers(0, 100, 512).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, 512, 2048).astype(np.int32)),
               "w": jnp.asarray(rng.integers(0, 9, 2048).astype(np.int32))})

    failures: list[str] = []
    cases = []

    # -- ladders: forced overflow at attempt 0, one per ladder --------------
    oracle = _canon(*phj_join_checked(R, S, key="k"))
    with faults.inject("overflow:phj@0"):
        out, rep = phj_join_checked(R, S, key="k", with_report=True)
    entry = _check("ladder.phj", oracle, _canon(*out), failures)
    entry.update(escalated=rep.escalated, attempts=len(rep.attempts))
    cases.append(entry)

    gj_kw = dict(key="k", group_key="k", aggs={"w": "sum"}, num_groups=512)
    oracle = _canon(*groupjoin_checked(R, S, **gj_kw))
    with faults.inject("overflow:groupjoin@0"):
        out, rep = groupjoin_checked(R, S, with_report=True, **gj_kw)
    entry = _check("ladder.groupjoin", oracle, _canon(*out), failures)
    entry.update(escalated=rep.escalated, attempts=len(rep.attempts))
    cases.append(entry)

    gb_kw = dict(key="k", aggs={"w": "sum"}, num_groups=512)
    oracle = _canon(*groupby_partition_checked(S, **gb_kw))
    with faults.inject("overflow:groupby_partition@0"):
        out, rep = groupby_partition_checked(S, with_report=True, **gb_kw)
    entry = _check("ladder.groupby_partition", oracle, _canon(*out), failures)
    entry.update(escalated=rep.escalated, attempts=len(rep.attempts))
    cases.append(entry)

    # -- kernels: every pallas arm raises, xla fallback must be exact -------
    before = metrics.counter("resilience.kernel_fallbacks").value
    oracle = _canon(*phj_join_checked(R, S, key="k"))
    with faults.inject("pallas:*"):
        got = _canon(*phj_join_checked(R, S, key="k"))
    cases.append(_check("kernels.phj_all_pallas_down", oracle, got, failures))
    oracle = _canon(*groupjoin_checked(R, S, **gj_kw))
    with faults.inject("pallas:*"):
        got = _canon(*groupjoin_checked(R, S, **gj_kw))
    cases.append(_check("kernels.groupjoin_all_pallas_down", oracle, got,
                        failures))
    if metrics.counter("resilience.kernel_fallbacks").value <= before:
        failures.append("kernels.no_fallback_fired")

    # -- engine: one forced executor failure, degrade-once re-plan ----------
    w = relgen.JoinWorkload("t", 1000, 4000, 2, 1, match_ratio=1.0)
    er, es = relgen.generate(w)
    cat = Catalog({"R": er, "S": es})
    q = scan("R").join(scan("S"), key="k").group_by("k", s1="sum")
    oracle = _canon(*optimize(q, cat, measure_profile=False).run())
    plan = optimize(q, cat, measure_profile=False)
    with faults.inject("raise:executor.run@0"):
        got = _canon(*plan.run())
    entry = _check("engine.degrade_once", oracle, got, failures)
    entry["degraded"] = bool(plan.degraded_plan is not None
                             and plan.degraded_plan.degraded)
    if not entry["degraded"]:
        failures.append("engine.no_degradation")
    cases.append(entry)

    # -- memory: one forced oom, degrade onto the morsel rung ---------------
    plan2 = optimize(q, cat, measure_profile=False)
    with faults.inject("oom:executor.run@0"):
        got = _canon(*plan2.run())
    entry = _check("engine.oom_morsel_rung", oracle, got, failures)
    entry["morsel_factor"] = (plan2.degraded_plan.morsel_factor
                              if plan2.degraded_plan is not None else 0)
    if entry["morsel_factor"] < 2:
        failures.append("engine.oom_no_morsel_degradation")
    cases.append(entry)

    snap = {k: v for k, v in sorted(metrics.snapshot().items())
            if k.startswith("resilience.")}
    for name in ("resilience.ladder_escalations",
                 "resilience.kernel_fallbacks",
                 "resilience.plan_degradations",
                 "resilience.oom_injected",
                 "resilience.faults_fired"):
        if not snap.get(name):
            failures.append(f"counter_zero.{name}")

    result = {"ok": not failures, "failures": failures, "cases": cases,
              "metrics": snap}
    print(json.dumps(result, indent=2, sort_keys=True))
    return 0 if not failures else 1


def main(argv: list[str]) -> int:
    if "--smoke" in argv:
        return smoke()
    print(__doc__)
    return 0 if argv in ([], ["--help"]) else 2


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

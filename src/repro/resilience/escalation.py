"""Declarative bounded-attempt escalation engine (DESIGN.md §13).

The repo's operators run with static capacities (build blocks, partition
fan-out, accumulator sizes) chosen from estimates. When an estimate is
wrong, the checked drivers re-run with bigger knobs. Before this module,
each driver hand-rolled its own retry loop with its own exhaustion
behavior — including the silent-corruption case where `phj_join_checked`
ran out of extra bits and proceeded anyway, dropping matches.

A `Ladder` makes the policy declarative and uniformly bounded:

  * the operator states its knobs (a plain dict) and an ordered list of
    `EscalationStep`s — each a growth rule `grow(knobs, diag) -> new
    knobs or None` with a per-step application cap;
  * a `check(knobs) -> (ok, detail, diag)` callback performs the cheap
    host-side overflow check (histogram max, distinct count, ...);
  * `Ladder.resolve` alternates check and grow: on overflow it asks the
    FIRST step that still has budget and can grow; a step that returns
    None (cannot help) yields to the next rung — bits give way to
    capacity, capacity to a strategy fallback;
  * every run returns an `EscalationReport` (attempt log, final knobs,
    wasted work) and feeds `obs.metrics`; exhaustion raises a typed
    `EscalationExhausted` carrying the report — never a silent wrong
    answer.

Fault hook: `faults.overflow_forced(operator, attempt)` can force any
check to report overflow, driving the ladder deterministically through
its rungs (the convergence tests and the `--smoke` CLI rely on this).
All of this is host-side Python — nothing here is traced, so ladders add
zero jaxpr overhead.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

from . import faults

# module-level ring of recent reports so explain(actuals=...) and the
# smoke CLI can surface what the last run escalated, without threading a
# report through every return path. Monotone seq so consumers can window.
_RING_CAP = 64
_reports: list = []
_degradations: list = []
_seq = itertools.count()


@dataclasses.dataclass
class EscalationStep:
    """One rung: a named growth rule. `grow(knobs, diag)` returns the new
    knob dict, or None when this rung cannot help (exhausted semantics
    distinct from budget: a bits rung at its cap returns None so the
    ladder moves on to capacity/strategy rungs)."""

    name: str
    grow: Callable[[dict, object], dict | None]
    max_times: int = 4


@dataclasses.dataclass
class Attempt:
    """One check under one knob assignment."""

    index: int
    knobs: dict
    ok: bool
    forced: bool = False  # overflow forced by fault injection
    step: str = ""  # rung applied to ESCAPE this attempt ("" on success)
    detail: str = ""


@dataclasses.dataclass
class EscalationReport:
    """Structured outcome of a ladder run; feeds metrics, explain(), and
    EscalationExhausted."""

    operator: str
    attempts: list = dataclasses.field(default_factory=list)
    final_knobs: dict = dataclasses.field(default_factory=dict)
    converged: bool = False
    steps_applied: dict = dataclasses.field(default_factory=dict)
    # wasted device work: each failed check re-ran a cheap device reduction
    # (histogram / distinct count); the count is the honest proxy since the
    # checks are O(n) scans the final run repeats.
    wasted_checks: int = 0
    seq: int = -1

    @property
    def escalated(self) -> bool:
        return len(self.attempts) > 1

    def as_dict(self) -> dict:
        return {
            "operator": self.operator,
            "converged": self.converged,
            "attempts": [
                {"index": a.index, "ok": a.ok, "forced": a.forced,
                 "step": a.step, "detail": a.detail,
                 "knobs": dict(a.knobs)}
                for a in self.attempts
            ],
            "final_knobs": dict(self.final_knobs),
            "steps_applied": dict(self.steps_applied),
            "wasted_checks": self.wasted_checks,
        }

    def summary(self) -> str:
        if not self.escalated:
            return f"{self.operator}: clean (1 attempt)"
        path = " -> ".join(a.step for a in self.attempts if a.step)
        state = "converged" if self.converged else "EXHAUSTED"
        return (f"{self.operator}: {state} after {len(self.attempts)} "
                f"attempts via [{path}]")


class EscalationExhausted(RuntimeError):
    """Every rung's budget is spent and the check still reports overflow.
    Carries the full report — the caller (or executor.run's degradation
    path) decides what to do; the ladder never silently proceeds."""

    def __init__(self, report: EscalationReport):
        self.report = report
        super().__init__(report.summary())


@dataclasses.dataclass
class Ladder:
    """An operator's declared escalation policy."""

    operator: str
    steps: list  # [EscalationStep]
    max_attempts: int = 8

    def resolve(self, knobs: dict,
                check: Callable[[dict], tuple]) -> EscalationReport:
        """Alternate check/grow until the check passes. `check(knobs)`
        returns (ok, detail, diag); diag is passed to the growth rules
        (e.g. the observed max partition size or required group count).
        Returns the report on convergence; raises EscalationExhausted
        otherwise. Host-side only — never traced."""
        from repro.obs import metrics  # deferred: core paths import us

        report = EscalationReport(operator=self.operator, final_knobs=knobs)
        used = {s.name: 0 for s in self.steps}
        knobs = dict(knobs)
        for attempt in range(self.max_attempts):
            metrics.counter("resilience.ladder_attempts").inc()
            ok, detail, diag = check(knobs)
            forced = False
            if ok and faults.overflow_forced(self.operator, attempt):
                ok, forced = False, True
                detail = (detail + "; " if detail else "") + "forced by fault"
            rec = Attempt(index=attempt, knobs=dict(knobs), ok=ok,
                          forced=forced, detail=detail)
            report.attempts.append(rec)
            if ok:
                report.converged = True
                report.final_knobs = dict(knobs)
                report.steps_applied = {k: v for k, v in used.items() if v}
                if report.escalated:
                    metrics.counter("resilience.ladder_escalations").inc()
                    metrics.counter("core.overflow_escalations").inc()
                record_report(report)
                return report
            report.wasted_checks += 1
            grown = None
            for step in self.steps:
                if used[step.name] >= step.max_times:
                    continue
                grown = step.grow(knobs, diag)
                if grown is not None:
                    used[step.name] += 1
                    rec.step = step.name
                    knobs = dict(grown)
                    break
            if grown is None:
                break  # no rung can help: exhausted
        report.final_knobs = dict(knobs)
        report.steps_applied = {k: v for k, v in used.items() if v}
        metrics.counter("resilience.ladder_exhausted").inc()
        record_report(report)
        raise EscalationExhausted(report)


# ---------------------------------------------------------------------------
# report / degradation rings
# ---------------------------------------------------------------------------
def record_report(report: EscalationReport) -> int:
    report.seq = next(_seq)
    _reports.append(report)
    del _reports[:-_RING_CAP]
    return report.seq


def recent_reports(since: int = -1) -> list:
    """Reports with seq > since, oldest first."""
    return [r for r in _reports if r.seq > since]


def current_seq() -> int:
    """High-water mark; pass to recent_reports(since=...) to window."""
    return max((r.seq for r in _reports), default=-1)


def record_degradation(component: str, reason: str) -> None:
    """Note a degradation event (pallas arm fell back, plan re-planned,
    serve slot evicted) for the smoke CLI / explain footer."""
    from repro.obs import metrics  # deferred

    _degradations.append({"component": component, "reason": reason,
                          "seq": next(_seq)})
    del _degradations[:-_RING_CAP]
    metrics.counter("resilience.degradations").inc()


def recent_degradations(since: int = -1) -> list:
    return [d for d in _degradations if d["seq"] > since]

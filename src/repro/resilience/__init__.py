"""repro.resilience — escalation runtime, fault injection, degradation.

Three cooperating layers (DESIGN.md §13):

  * `escalation` — the declarative bounded-attempt `Ladder` engine behind
    every `*_checked` driver; structured `EscalationReport`s, typed
    `EscalationExhausted`, `resilience.*` metrics;
  * `faults` — deterministic, seedable fault injection (`REPRO_FAULTS` /
    `inject()`): forced overflows, corrupted estimates, pallas-arm
    failures — zero overhead (identical jaxpr) when inactive;
  * graceful degradation lives at its consumers: `kernels/ops.py`
    (pallas -> xla arm fallback), `engine/executor.run` (one re-plan with
    escalated capacities, `DEGRADED[reason]`), `serve/engine.py`
    (timeout, bounded retry, load shedding).

`python -m repro.resilience --smoke` forces one overflow per ladder and
one pallas failure per dispatch and asserts results match the fault-free
run (wired into scripts/ci.sh).
"""
from .escalation import (Attempt, EscalationExhausted, EscalationReport,
                         EscalationStep, Ladder, current_seq,
                         recent_degradations, recent_reports,
                         record_degradation, record_report)
from .faults import ENV_VAR, FaultInjected, FaultPlan, FaultSpec, inject, parse

__all__ = [
    "Attempt", "EscalationExhausted", "EscalationReport", "EscalationStep",
    "Ladder", "current_seq", "recent_degradations", "recent_reports",
    "record_degradation", "record_report",
    "ENV_VAR", "FaultInjected", "FaultPlan", "FaultSpec", "inject", "parse",
]

"""Deterministic fault injection for the escalation/degradation machinery.

Recovery paths are only trustworthy if they run; this module makes every
recovery path in the repo *forceable* — deterministically, from a test, a
CLI smoke, or an env var — without perturbing production execution when
disabled. Three fault families:

  * ``overflow:<ladder>@<when>`` — force an escalation ladder's overflow
    check to report "overflowed" at chosen attempt indices, driving the
    ladder up its rungs regardless of the data (escalation.py consults
    `overflow_forced` before trusting a check result);
  * ``pallas:<site|*>[@<when>]`` / ``raise:<site>[@<when>]`` — make the
    pallas arm of a `kernels/ops.py` dispatch (or any named host-side
    site) raise `FaultInjected`, exercising the pallas -> xla degradation
    chain and the executor's re-plan path;
  * ``estimates:<x|/><factor>`` — multiply (x) or divide (/) the
    statistics layer's cardinality/distinct estimates by a factor,
    producing adversarially wrong capacities that the ladders must
    recover from;
  * ``oom:<site>[@<when>]`` — make a named host-side allocation site
    raise `OOMInjected` (a `MemoryError`), exercising the memory
    governor: the executor's morsel-driven out-of-core rung
    (`physical.degrade_plan(memory=True)`) and the query server's
    byte-budget deferral path. Sites: ``executor.run`` (consulted once
    per execution attempt, next to the `raise:` site) and
    ``qserve.admit`` (the bytes-ticket reservation in
    QueryServer._admit — an armed site defers the request instead of
    admitting it).

An optional ``seed:<int>`` spec makes the estimate corruption vary
deterministically per site (hash of seed+site jitters the factor), so a
property test can sweep many wrong-estimate shapes from one spec.

Grammar (validated at READ time, per call — the
`REPRO_PALLAS_INTERPRET` / `REPRO_PARTITION_PLAN_IMPL` convention, never
frozen at import)::

    REPRO_FAULTS := spec[,spec...]
    spec         := overflow:<ladder>@<when>
                  | pallas:<site|*>[@<when>]
                  | raise:<site>[@<when>]
                  | oom:<site>[@<when>]
                  | estimates:<x|/><factor>
                  | seed:<int>
    when         := all | <int>[+<int>...]      (attempt/occurrence indices)

Examples::

    REPRO_FAULTS=overflow:phj@0                # phj ladder overflows at attempt 0
    REPRO_FAULTS=pallas:*                      # every pallas arm raises, always
    REPRO_FAULTS=pallas:hash_probe@0+1         # first two hash_probe calls raise
    REPRO_FAULTS=estimates:/16,seed:7          # distinct estimates ~16x too low

Programmatic use (preferred in tests; the innermost context wins over the
env var)::

    with faults.inject("overflow:groupjoin@0"):
        ...

Named `raise:` sites are open-ended — any host-side `check_site(name)`
call is targetable. The query-serving runtime (DESIGN.md §14) exposes
``qserve.plan`` (first-admission planning of a signature, inside
QueryServer._ensure_entry) and ``qserve.execute`` (consulted once per
execution attempt: occurrence 0 is the fast attempt, occurrence 1 the
same-request safe fallback, so ``raise:qserve.execute@0`` fails only the
fast path while ``raise:qserve.execute`` fails the request outright).
The serve/chaos.py soak drives whole fault families through these plus
per-request ``overflow:*`` / ``pallas:*`` / ``estimates:*`` specs.

Zero-overhead contract: every injection site is host-side Python executed
at TRACE time; when no faults are active each hook returns immediately
(one module-level attribute check + an env lookup) and contributes
NOTHING to the traced jaxpr — pinned by tests/test_resilience.py.

Occurrence counting is deterministic: each (fault-kind, site) pair keeps a
per-activation counter, reset whenever the active spec changes (context
enter/exit or a new env string), so ``@0`` always means "the first call
under this activation".
"""
from __future__ import annotations

import contextlib
import dataclasses
import os

ENV_VAR = "REPRO_FAULTS"

_GRAMMAR = (
    "spec[,spec...] with spec := overflow:<ladder>@<when> | "
    "pallas:<site|*>[@<when>] | raise:<site>[@<when>] | "
    "oom:<site>[@<when>] | "
    "estimates:<x|/><factor> | seed:<int>; when := all | <int>[+<int>...]"
)


class FaultInjected(RuntimeError):
    """Raised by an armed injection site. Carries the site name so the
    degradation layers can report WHAT failed, not just that something
    did."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        super().__init__(f"injected fault at {site!r}"
                         + (f": {detail}" if detail else ""))


class OOMInjected(FaultInjected, MemoryError):
    """Injected allocation failure. Subclasses MemoryError so the memory
    classifier (`engine.membudget.is_memory_error`) routes it exactly like
    a real backend RESOURCE_EXHAUSTED — onto the morsel rung, never the
    capacity-doubling rung."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One parsed spec. `when` is None for 'all' (every occurrence),
    else a frozenset of occurrence indices."""

    kind: str  # overflow | pallas | raise | oom | estimates | seed
    target: str  # ladder/site name, "*" wildcard, or "" for estimates/seed
    when: frozenset | None = None
    factor: float = 1.0  # estimates only (already inverted for '/')
    seed: int = 0  # seed only

    def fires_at(self, occurrence: int) -> bool:
        return self.when is None or occurrence in self.when


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The full parsed REPRO_FAULTS / inject() value."""

    raw: str
    specs: tuple = ()

    def matching(self, kind: str, target: str):
        for s in self.specs:
            if s.kind == kind and (s.target == target or s.target == "*"):
                yield s

    @property
    def seed(self) -> int:
        for s in self.specs:
            if s.kind == "seed":
                return s.seed
        return 0


_EMPTY = FaultPlan(raw="")


def _bad(spec: str, why: str) -> ValueError:
    return ValueError(
        f"{ENV_VAR} spec {spec!r} is not a recognized value ({why}); "
        f"allowed grammar: {_GRAMMAR}")


def _parse_when(spec: str, text: str) -> frozenset | None:
    if text == "all":
        return None
    try:
        idx = frozenset(int(p) for p in text.split("+"))
    except ValueError:
        raise _bad(spec, f"bad occurrence list {text!r}") from None
    if any(i < 0 for i in idx):
        raise _bad(spec, "occurrence indices must be >= 0")
    return idx


def parse(value: str) -> FaultPlan:
    """Parse a REPRO_FAULTS string, raising ValueError (naming the
    grammar) on anything unrecognized. An empty/whitespace value is the
    empty plan."""
    value = value.strip()
    if not value:
        return _EMPTY
    specs = []
    for spec in value.split(","):
        spec = spec.strip()
        if not spec:
            continue
        kind, sep, rest = spec.partition(":")
        if not sep:
            raise _bad(spec, "missing ':'")
        if kind == "overflow":
            target, sep, when = rest.partition("@")
            if not sep or not target:
                raise _bad(spec, "overflow needs <ladder>@<when>")
            specs.append(FaultSpec("overflow", target,
                                   _parse_when(spec, when)))
        elif kind in ("pallas", "raise", "oom"):
            target, sep, when = rest.partition("@")
            if not target:
                raise _bad(spec, f"{kind} needs a site name"
                                 + ("" if kind == "oom" else " or '*'"))
            if kind in ("raise", "oom") and target == "*":
                raise _bad(spec, f"{kind}:* would break host-side control "
                                 "flow everywhere; name a site")
            specs.append(FaultSpec(
                kind, target, _parse_when(spec, when) if sep else None))
        elif kind == "estimates":
            if not rest or rest[0] not in "x/":
                raise _bad(spec, "estimates needs x<factor> or /<factor>")
            try:
                f = float(rest[1:])
            except ValueError:
                raise _bad(spec, f"bad factor {rest[1:]!r}") from None
            if f <= 0:
                raise _bad(spec, "factor must be > 0")
            specs.append(FaultSpec(
                "estimates", "", factor=(f if rest[0] == "x" else 1.0 / f)))
        elif kind == "seed":
            try:
                specs.append(FaultSpec("seed", "", seed=int(rest)))
            except ValueError:
                raise _bad(spec, f"bad seed {rest!r}") from None
        else:
            raise _bad(spec, f"unknown fault kind {kind!r}")
    return FaultPlan(raw=value, specs=tuple(specs))


# ---------------------------------------------------------------------------
# activation: innermost inject() context wins over the env var
# ---------------------------------------------------------------------------
_stack: list[FaultPlan] = []

# occurrence counters for the CURRENT activation; keyed by (kind, site).
# _counts_key tracks which raw spec the counters belong to so a changed
# env string (or context enter/exit) restarts counting at 0.
_counts: dict = {}
_counts_key: str | None = None


def _active() -> FaultPlan:
    """The governing plan: innermost inject() context, else REPRO_FAULTS
    (parsed and validated on every call — never frozen at import)."""
    global _counts_key
    if _stack:
        plan = _stack[-1]
    else:
        env = os.environ.get(ENV_VAR, "")
        plan = parse(env) if env.strip() else _EMPTY
    if plan.raw != _counts_key:
        _counts.clear()
        _counts_key = plan.raw
    return plan


def active() -> bool:
    """True when any fault spec is in force (cheap enough for hot paths:
    no parsing unless the env var is set or a context is entered)."""
    if _stack:
        return bool(_stack[-1].specs)
    return bool(os.environ.get(ENV_VAR, "").strip())


@contextlib.contextmanager
def inject(spec: str):
    """Activate a fault spec for the dynamic extent of the with-block.
    Occurrence counters start at zero on entry and are discarded on exit,
    so `@0` semantics are reproducible per activation."""
    plan = parse(spec)
    _stack.append(plan)
    _counts.clear()
    global _counts_key
    _counts_key = plan.raw
    try:
        yield plan
    finally:
        _stack.pop()
        _counts.clear()
        _counts_key = None


def _occurrence(kind: str, site: str) -> int:
    key = (kind, site)
    n = _counts.get(key, 0)
    _counts[key] = n + 1
    return n


def _record(name: str) -> None:
    from repro.obs import metrics  # deferred: keep faults import-light

    metrics.counter(name).inc()


# ---------------------------------------------------------------------------
# injection sites (each a no-op returning immediately when inactive)
# ---------------------------------------------------------------------------
def overflow_forced(ladder: str, attempt: int) -> bool:
    """Should ladder `ladder`'s check at `attempt` be forced to report
    overflow? Consulted by escalation.Ladder AFTER the real check, so a
    forced overflow always exercises a real escalation."""
    if not active():
        return False
    for s in _active().matching("overflow", ladder):
        if s.fires_at(attempt):
            _record("resilience.faults_fired")
            return True
    return False


def check_pallas(site: str) -> None:
    """Raise FaultInjected if the pallas arm at `site` is armed. Called by
    kernels/ops.py dispatches before running their pallas path."""
    if not active():
        return
    for s in _active().matching("pallas", site):
        if s.fires_at(_occurrence("pallas", site)):
            _record("resilience.faults_fired")
            raise FaultInjected(site, "pallas arm forced to fail")
    return


def check_site(site: str) -> None:
    """Raise FaultInjected if a `raise:` spec targets this host-side
    site (e.g. 'executor.run')."""
    if not active():
        return
    for s in _active().matching("raise", site):
        if s.fires_at(_occurrence("raise", site)):
            _record("resilience.faults_fired")
            raise FaultInjected(site)
    return


def check_oom(site: str) -> None:
    """Raise OOMInjected (a MemoryError) if an `oom:` spec targets this
    host-side allocation site (e.g. 'executor.run', 'qserve.admit')."""
    if not active():
        return
    for s in _active().matching("oom", site):
        if s.fires_at(_occurrence("oom", site)):
            _record("resilience.faults_fired")
            _record("resilience.oom_injected")
            raise OOMInjected(site, "allocation failure forced")
    return


def estimate_factor(site: str = "") -> float:
    """Multiplier the statistics layer applies to its estimates. 1.0 when
    no estimates fault is active. With a `seed:` spec the factor is
    deterministically jittered per site (within [factor/2, factor*2] in
    log space), so one spec yields many distinct-but-reproducible wrong
    estimates."""
    if not active():
        return 1.0
    plan = _active()
    factor = 1.0
    for s in plan.specs:
        if s.kind == "estimates":
            factor *= s.factor
    if factor != 1.0 and plan.seed:
        h = hash((plan.seed, site)) & 0xFFFF
        factor *= 2.0 ** ((h / 0xFFFF) * 2.0 - 1.0)
        _record("resilience.faults_fired")
    elif factor != 1.0:
        _record("resilience.faults_fired")
    return factor

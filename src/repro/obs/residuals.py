"""Measured-vs-modeled residuals and the regret signal (DESIGN.md §12).

A *residual* is the ratio measured/modeled for one plan node — 1.0 means
the cost model priced the operator exactly; the BENCH_groupby.json
partition-vs-sort gap (modeled 1.11x faster, measured ~1.7x slower) is a
~2x residual asymmetry between two strategies of the same operator.
`residuals_of` extracts them from a `QueryTrace`; `ResidualStore` keeps a
per-(operator, strategy) EWMA so repeated runs sharpen the picture
instead of the last run overwriting it; `regret_check` replays a cost
comparison with each candidate's predicted time multiplied by its stored
residual and reports when the model's winner *loses* the corrected
comparison by more than `REGRET_FACTOR` — the flag the optimizer attaches
to plans whose predicted winner lost last run (ROADMAP).

Residuals are per-backend: the store lives inside CALIBRATION.json under
the backend fingerprint (obs.calibration), never pooled across devices.
"""
from __future__ import annotations

import dataclasses

EWMA_ALPHA = 0.3  # weight of the newest observation
REGRET_FACTOR = 2.0  # "lost by >2x" threshold (ROADMAP)


@dataclasses.dataclass
class NodeResidual:
    """One node's measured-vs-modeled outcome."""

    op: str  # operator kind (join/groupby/groupjoin/...)
    strategy: str  # chosen algorithm/pattern or strategy
    predicted_s: float
    measured_s: float

    @property
    def key(self) -> str:
        return f"{self.op}/{self.strategy}" if self.strategy else self.op

    @property
    def ratio(self) -> float:
        return self.measured_s / self.predicted_s

    def as_dict(self) -> dict:
        return {"op": self.op, "strategy": self.strategy,
                "predicted_s": self.predicted_s,
                "measured_s": self.measured_s, "ratio": self.ratio}


def residuals_of(trace) -> list:
    """NodeResiduals for every span the cost model actually priced
    (scan/project carry zero predicted cost — no ratio to learn from)."""
    return [NodeResidual(op=s.op, strategy=s.strategy,
                         predicted_s=s.predicted_s, measured_s=s.wall_s)
            for s in trace.spans() if s.predicted_s > 0.0]


class ResidualStore:
    """Per-(operator, strategy) EWMA of measured/modeled ratios.

    `data` maps "op/strategy" -> {"ewma", "count", "last"} and is the
    JSON-serializable half; `correction()` is the consumer-facing read:
    the multiplicative factor that maps a modeled time onto this backend's
    measured reality (1.0 when nothing was ever observed)."""

    def __init__(self, data: dict | None = None):
        self.data: dict = dict(data or {})

    @classmethod
    def from_dict(cls, data: dict) -> "ResidualStore":
        return cls({k: dict(v) for k, v in data.items()
                    if isinstance(v, dict) and "ewma" in v})

    def as_dict(self) -> dict:
        return {k: dict(v) for k, v in sorted(self.data.items())}

    def update(self, residuals, alpha: float = EWMA_ALPHA) -> None:
        for r in residuals:
            ratio = float(r.ratio)
            ent = self.data.get(r.key)
            if ent is None:
                self.data[r.key] = {"ewma": ratio, "count": 1,
                                    "last": ratio}
            else:
                ent["ewma"] = (1 - alpha) * float(ent["ewma"]) + alpha * ratio
                ent["count"] = int(ent.get("count", 0)) + 1
                ent["last"] = ratio

    def correction(self, op: str, strategy: str = "",
                   default: float = 1.0) -> float:
        key = f"{op}/{strategy}" if strategy else op
        ent = self.data.get(key)
        return float(ent["ewma"]) if ent else default

    def observed(self, op: str, strategy: str = "") -> bool:
        key = f"{op}/{strategy}" if strategy else op
        return key in self.data


def regret_check(store: ResidualStore, op: str, choices: dict,
                 chosen: str, factor: float = REGRET_FACTOR) -> str:
    """Replay a strategy choice with residual-corrected costs.

    `choices` maps strategy -> predicted seconds (the model's comparison);
    each is multiplied by the store's EWMA for (op, strategy). Returns a
    regret message when the chosen strategy's corrected time exceeds the
    best corrected alternative by >= `factor` — i.e. last run's residuals
    say the predicted winner actually loses by that much — and "" when the
    choice survives correction (or nothing relevant was ever observed).
    Advisory only: the flag annotates the plan, it never flips the choice
    (the residuals may come from different shapes than this query's)."""
    if chosen not in choices or not store.observed(op, chosen):
        return ""
    corrected = {s: t * store.correction(op, s) for s, t in choices.items()}
    alts = {s: c for s, c in corrected.items() if s != chosen}
    if not alts:
        return ""
    best = min(alts, key=alts.get)
    if corrected[chosen] >= factor * alts[best] > 0.0:
        return (f"REGRET: predicted winner '{chosen}' loses by "
                f"{corrected[chosen] / alts[best]:.1f}x after residual "
                f"correction (measured/modeled EWMA "
                f"{store.correction(op, chosen):.2f}x vs '{best}' "
                f"{store.correction(op, best):.2f}x)")
    return ""

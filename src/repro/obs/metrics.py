"""Lightweight counter/histogram registry (DESIGN.md §12).

The runtime scoreboard the serving layer inherits: plans compiled,
plan-cache hits, overflow escalations, contract audits — anything a
long-lived process wants to report without attaching a profiler. The
resilience layer (DESIGN.md §13) reports here under `resilience.*`:
`ladder_attempts` / `ladder_escalations` / `ladder_exhausted` (checked
operator ladders), `kernel_fallbacks` (+ `.{site}`) for pallas→XLA arm
fallbacks, `plan_degradations` (executor degrade-once),
`serve_shed` / `serve_retries` / `serve_evictions` /
`serve_deadline_evictions` (serving), `degradations` and `faults_fired`
(fault injection). The relational query server (DESIGN.md §14) reports
under `qserve.*`: `submitted` / `completed` / `shed` / `rejected` /
`deadline_evictions` / `failed` (request outcomes), `plans_compiled` /
`plan_cache_hits` (signature cache), `fast_runs` / `fast_failures` /
`safe_runs` / `safe_escalations` / `saturations` (execution paths), and
`breaker_opens` / `breaker_probes` / `breaker_closes` (circuit
breakers). The memory governor (DESIGN.md §15) adds the `qserve.bytes_*`
and oom families: `qserve.bytes_reserved` (histogram — in-flight bytes
ticket ledger observed every tick; its max must never exceed the
budget), `qserve.mem_rejections` (never-fits typed rejections),
`qserve.mem_deferrals` (fits-later deferrals — also `serve.mem_deferrals`
for the batched engine's slot governor), `qserve.chunked_runs`
(server-dispatched morsel runs), `engine.morsel_runs` (individual
morsels executed by the out-of-core driver), and
`resilience.oom_injected` (deterministic `oom:<site>` faults fired).
Metrics
are plain Python (no jax import, no locks beyond the GIL's atomicity for
`+=` on ints): incrementing a counter costs one dict lookup + an add, so
instrumented hot paths stay hot.

Usage::

    from repro.obs import metrics

    metrics.counter("engine.plans_compiled").inc()
    metrics.histogram("engine.run_wall_s").observe(dt)
    metrics.snapshot()   # {name: value | summary-dict}, for reporting
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Counter:
    """Monotone event count."""

    name: str
    value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def as_value(self):
        return self.value


# Percentiles need retained observations; cap the buffer so a long-lived
# server's histograms stay O(1) memory. At the cap, every other retained
# sample is dropped and the keep-stride doubles — a deterministic (no RNG)
# systematic sample that stays uniformly spread over the whole stream.
SAMPLE_CAP = 4096


def percentiles(values, pcts=(50, 95, 99)) -> dict:
    """Nearest-rank percentiles over raw values: ``{"p50": ..., ...}``.
    Shared by Histogram.summary() and anything holding its own latency
    list (BENCH writers); benches should stop hand-rolling medians."""
    out = {}
    s = sorted(float(v) for v in values)
    for p in pcts:
        key = f"p{p:g}"
        if not s:
            out[key] = 0.0
            continue
        rank = max(int(-(-len(s) * p // 100)), 1)  # ceil, 1-based
        out[key] = s[min(rank, len(s)) - 1]
    return out


@dataclasses.dataclass
class Histogram:
    """Streaming summary of an observed quantity (count/sum/min/max/last)
    plus a bounded sample buffer for percentile export.

    No buckets: the consumers here (CLI tables, BENCH_*.json rows) want
    moments and a few percentiles, and a full histogram would force a
    bucket-boundary choice on every metric. `mean` is derived; percentiles
    are nearest-rank over the retained samples (exact until SAMPLE_CAP
    observations, a deterministic stride-thinned approximation after)."""

    name: str
    count: int = 0
    total: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")
    last: float = 0.0
    samples: list = dataclasses.field(default_factory=list, repr=False)
    stride: int = 1  # keep every stride-th observation (doubles at the cap)

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        self.min = x if x < self.min else self.min
        self.max = x if x > self.max else self.max
        self.last = x
        if (self.count - 1) % self.stride == 0:
            self.samples.append(x)
            if len(self.samples) >= SAMPLE_CAP:
                self.samples = self.samples[::2]
                self.stride *= 2

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        return percentiles(self.samples, (p,))[f"p{p:g}"]

    def summary(self, pcts=(50, 95, 99)) -> dict:
        """Moments + percentiles, JSON-ready — the BENCH_serve.json /
        ServeEngine latency-report shape."""
        out = {"count": self.count, "mean": self.mean,
               "min": self.min if self.count else 0.0,
               "max": self.max if self.count else 0.0}
        out.update(percentiles(self.samples, pcts))
        return out

    def as_value(self):
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.min, "max": self.max, "last": self.last}


class MetricsRegistry:
    """Name -> metric map. `counter()`/`histogram()` get-or-create, so call
    sites never coordinate registration; asking for an existing name with
    the other kind raises (one name, one type)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name)
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        return {name: m.as_value() for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        self._metrics.clear()


REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()

"""Per-node query tracing: what the device actually did (DESIGN.md §12).

`trace_execute(plan)` runs a physical plan node by node, bottom-up, with a
device sync around every operator: each node's children are executed
first, their results fed back in as *traced arguments* (never baked
constants — XLA would fold a constant subtree away and the "measurement"
would time nothing), and the node's own jitted computation is timed with
`timed_call` (explicit `block_until_ready` on all outputs, median-of-k).
The result is a `QueryTrace` tree of `Span`s carrying, per node:

    wall_s        device-synced median wall time of the node alone
    predicted_s   the optimizer's cost-model prediction for the node
    rows_in/out   valid-row counts through the operator
    bytes_in/out  device bytes entering/leaving (capacity x itemsize)
    strategy      the chosen algorithm/pattern or group-by strategy

exportable as JSON (`as_dict`/`to_json`) and as Chrome trace-event format
(`chrome_trace`/`to_chrome_trace` — loadable in Perfetto / about:tracing).

Tracing is strictly opt-in: `executor.run(plan)` without `trace=True`
never imports this module's machinery, allocates no `Span`, and compiles
the exact same whole-plan jaxpr as before (pinned by
tests/test_obs.py::test_untraced_run_is_zero_overhead). Per-node
attribution necessarily forfeits whole-plan XLA fusion, so the sum of
span times can exceed the untraced end-to-end time; `overhead_bound_s`
quantifies the slack the trace itself claims (per-node dispatch/sync
floor + a relative fusion term), and the traced run times the untraced
compiled plan too (`e2e_wall_s`) so every trace carries its own
measured-vs-attributed comparison.
"""
from __future__ import annotations

import dataclasses
import json
import time


def timed_call(fn, *args, iters: int = 1, warmup: int = 1):
    """(result, median wall seconds) of `fn(*args)`, blocking on every
    output leaf before and after each timed call. The shared timing
    primitive: the tracer, `PrimitiveProfile` consumers, and
    benchmarks/common.time_fn all measure through here, so benchmark
    numbers and trace numbers are commensurable."""
    import jax

    out = None
    for _ in range(max(warmup, 0)):
        out = jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return out, max(ts[len(ts) // 2], 0.0)


def median_wall(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of jit-ready `fn(*args)` (see `timed_call`)."""
    return timed_call(fn, *args, iters=iters, warmup=warmup)[1]


def sync_floor(iters: int = 5) -> float:
    """Median wall of a trivial jitted dispatch+sync — the per-node floor
    a traced run pays that the untraced fused plan does not."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x + 1)
    return timed_call(f, jnp.zeros((8,), jnp.int32), iters=iters, warmup=1)[1]


@dataclasses.dataclass
class Span:
    """One physical plan node's measured execution."""

    op: str  # operator kind: scan/filter/project/join/groupby/...
    name: str  # the node's describe() line (choice + estimates)
    strategy: str  # algorithm/pattern or group-by strategy, "" if n/a
    path: tuple  # child-index path from the root (root = ())
    predicted_s: float  # optimizer cost-model prediction (node alone)
    wall_s: float  # device-synced median wall of the node alone
    rows_in: int
    rows_out: int
    bytes_in: int
    bytes_out: int
    t0_s: float  # offset of the timed window from the trace start
    children: list = dataclasses.field(default_factory=list)

    # allocation counter pinning the zero-overhead contract: an untraced
    # run must never construct a Span (tests/test_obs.py)
    allocated = 0

    def __post_init__(self):
        Span.allocated += 1

    @property
    def residual(self):
        """measured/modeled ratio; None where the model prices the node
        at zero (scan/project carry no predicted cost to divide by)."""
        if self.predicted_s > 0.0:
            return self.wall_s / self.predicted_s
        return None

    def as_dict(self) -> dict:
        return {
            "op": self.op, "name": self.name, "strategy": self.strategy,
            "path": list(self.path), "predicted_s": self.predicted_s,
            "measured_s": self.wall_s, "residual": self.residual,
            "rows_in": self.rows_in, "rows_out": self.rows_out,
            "bytes_in": self.bytes_in, "bytes_out": self.bytes_out,
        }


@dataclasses.dataclass
class QueryTrace:
    """Measured execution tree of one physical plan."""

    root: Span
    backend: str  # backend fingerprint (obs.calibration)
    total_wall_s: float  # whole traced traversal, compiles included
    e2e_wall_s: float  # untraced compiled whole-plan median wall
    sync_floor_s: float  # per-dispatch sync floor at trace time
    iters: int = 1
    warmup: int = 1
    # EscalationReports recorded while this trace ran (repro.resilience's
    # report ring, windowed by sequence number) — explain(actuals=trace)
    # renders these as its escalation footer
    escalations: tuple = ()

    def spans(self) -> list:
        out = []

        def walk(s):
            out.append(s)
            for c in s.children:
                walk(c)

        walk(self.root)
        return out

    def by_path(self) -> dict:
        return {s.path: s for s in self.spans()}

    @property
    def sum_wall_s(self) -> float:
        return sum(s.wall_s for s in self.spans())

    @property
    def overhead_bound_s(self) -> float:
        """The slack the trace claims for its own attribution: per-node
        dispatch/sync floor, plus a relative term for the whole-plan XLA
        fusion that per-node execution forfeits (a fused filter+join
        never materializes the filter output; its parts, timed alone,
        do). Within this bound, the per-node walls must account for the
        untraced end-to-end time — the acceptance check of DESIGN.md §12."""
        n = len(self.spans())
        return n * self.sync_floor_s + 0.75 * max(self.sum_wall_s,
                                                  self.e2e_wall_s)

    def as_dict(self) -> dict:
        return {
            "backend": self.backend,
            "total_wall_s": self.total_wall_s,
            "e2e_wall_s": self.e2e_wall_s,
            "sum_wall_s": self.sum_wall_s,
            "sync_floor_s": self.sync_floor_s,
            "overhead_bound_s": self.overhead_bound_s,
            "iters": self.iters, "warmup": self.warmup,
            "nodes": [s.as_dict() for s in self.spans()],
            "escalations": [r.as_dict() for r in self.escalations],
        }

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_dict(), f, indent=2, sort_keys=True)

    def chrome_trace(self) -> list:
        """Chrome trace-event list (Perfetto / about:tracing loadable):
        one complete ('X') event per span on a single track, timestamps
        in microseconds from the trace start."""
        events = []
        for s in self.spans():
            events.append({
                "name": f"{s.op}[{s.strategy}]" if s.strategy else s.op,
                "cat": "plan-node", "ph": "X",
                "ts": s.t0_s * 1e6, "dur": max(s.wall_s, 1e-9) * 1e6,
                "pid": 0, "tid": 0,
                "args": s.as_dict(),
            })
        return events

    def to_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_trace(),
                       "displayTimeUnit": "ms"}, f, indent=2)

    def table(self) -> str:
        """Human-readable predicted-vs-measured table, one row per node."""
        head = (f"{'node':<28} {'strategy':<16} {'rows_out':>9} "
                f"{'predicted':>11} {'measured':>11} {'residual':>9}")
        lines = [head, "-" * len(head)]
        for s in self.spans():
            label = ("  " * len(s.path)) + s.op
            res = f"{s.residual:.2f}x" if s.residual is not None else "-"
            flag = " <-- >2x" if s.residual is not None and (
                s.residual >= 2.0 or s.residual <= 0.5) else ""
            lines.append(
                f"{label:<28} {s.strategy:<16} {s.rows_out:>9} "
                f"{s.predicted_s*1e6:>9.0f}us {s.wall_s*1e6:>9.0f}us "
                f"{res:>9}{flag}")
        lines.append(
            f"{'sum(nodes)':<28} {'':<16} {'':>9} "
            f"{'':>11} {self.sum_wall_s*1e6:>9.0f}us "
            f"(e2e {self.e2e_wall_s*1e6:.0f}us, "
            f"bound {self.overhead_bound_s*1e6:.0f}us)")
        return "\n".join(lines)


def _table_bytes(t) -> int:
    return int(sum(t[c].nbytes for c in t.column_names))


_OP_NAMES = {
    "PScan": "scan", "PFilter": "filter", "PProject": "project",
    "PJoin": "join", "PGroupBy": "groupby", "PGroupJoin": "groupjoin",
    "POrderByLimit": "orderby",
}


def op_of(node) -> str:
    return _OP_NAMES.get(type(node).__name__, type(node).__name__.lower())


def strategy_of(node) -> str:
    from repro.engine import physical as P

    if isinstance(node, P.PJoin):
        return f"{node.algorithm}/{node.pattern}"
    if isinstance(node, P.PGroupBy):
        return node.strategy
    if isinstance(node, P.PGroupJoin):
        return f"phj+{node.agg_strategy}"
    return ""


def _with_children(node, mats):
    """Shallow copy of a physical node with its children replaced by
    `executor.Materialized` wrappers, so `execute` consumes precomputed
    child results instead of recursing."""
    kids = node.children()
    if not kids:
        return node
    if len(kids) == 1:
        return dataclasses.replace(node, child=mats[0])
    return dataclasses.replace(node, build=mats[0], probe=mats[1])


def trace_execute(plan, tables=None, *, iters: int = 1, warmup: int = 1,
                  measure_e2e: bool = True, validate_capacity: bool = True):
    """Execute `plan` with per-node timing. Returns
    ``(table, valid_count, QueryTrace)`` — the table/count pair is
    numerically identical to the untraced `run()` result (same operator
    code, same static shapes; only the execution granularity differs).

    Children run first and their results become traced jit arguments of
    the parent's computation, which keeps per-node timings honest (no
    constant folding) at the price of whole-plan fusion — see
    `QueryTrace.overhead_bound_s` for the accounting.

    With ``validate_capacity=True`` (the default) the trace finishes with
    one untimed eager pass under `executor.checked_mode()`: every
    capacity-sensitive node re-runs through its resilience ladder, so a
    plan whose capacities were misestimated records `EscalationReport`s —
    surfaced on `QueryTrace.escalations` and rendered by
    `explain(actuals=trace)` (DESIGN.md §13)."""
    import jax

    from repro.engine import executor
    from repro.engine import physical as P

    from repro.resilience import escalation

    from .calibration import backend_fingerprint

    tables = dict(tables if tables is not None else plan.catalog.tables)
    t_begin = time.perf_counter()
    floor = sync_floor()
    esc_since = escalation.current_seq()

    def visit(node, path):
        child_out = []
        child_spans = []
        for i, kid in enumerate(node.children()):
            r, s = visit(kid, path + (i,))
            child_out.append(r)
            child_spans.append(s)
        if isinstance(node, P.PScan):
            fn = jax.jit(lambda tb: executor.execute(node, tb))
            args = (tables,)
            rows_in = int(tables[node.table].num_rows)
            bytes_in = _table_bytes(tables[node.table])
        else:
            def fn(child_vals):
                mats = [executor.Materialized(v) for v in child_vals]
                return executor.execute(_with_children(node, mats), {})

            fn = jax.jit(fn)
            args = (child_out,)
            rows_in = sum(int(c) for _, c in child_out)
            bytes_in = sum(_table_bytes(t) + 4 for t, _ in child_out)
        t0 = time.perf_counter() - t_begin
        (out_t, out_c), wall = timed_call(fn, *args, iters=iters,
                                          warmup=warmup)
        span = Span(
            op=op_of(node), name=node.describe(),
            strategy=strategy_of(node), path=path,
            predicted_s=float(node.cost), wall_s=wall,
            rows_in=rows_in, rows_out=int(out_c),
            bytes_in=bytes_in, bytes_out=_table_bytes(out_t) + 4,
            t0_s=t0, children=child_spans,
        )
        return (out_t, out_c), span

    (out_t, out_c), root = visit(plan.root, ())
    if validate_capacity:
        # untimed: ladder checks are host-side histograms plus (only on
        # escalation) a larger-shape re-run; results are discarded — the
        # pass exists for its EscalationReports
        with executor.checked_mode():
            executor.execute(plan.root, tables)
    e2e = 0.0
    if measure_e2e:
        # the untraced compiled plan, measured the same way — reuses (and
        # warms) the plan's own compiled-executable cache
        _, e2e = timed_call(lambda: executor.run(plan, tables),
                            iters=max(iters, 1), warmup=max(warmup, 1))
    trace = QueryTrace(
        root=root, backend=backend_fingerprint(),
        total_wall_s=time.perf_counter() - t_begin, e2e_wall_s=e2e,
        sync_floor_s=floor, iters=iters, warmup=warmup,
        escalations=tuple(escalation.recent_reports(esc_since)),
    )
    return out_t, out_c, trace

"""Persistent calibration store: measured profiles + residual feedback.

`CALIBRATION.json` (override: `REPRO_CALIBRATION_PATH`, validated at read
time like `REPRO_PALLAS_INTERPRET` — a bad value raises instead of
silently writing somewhere else) caches `PrimitiveProfile.measure()`
results **across processes**, keyed by a backend fingerprint (platform +
device kind + jax version): the second process on the same backend loads
the stored constants instead of re-running the microbenchmarks, and a
different backend never reads another's numbers. The same entry holds the
per-(operator, strategy) measured/modeled residual EWMAs
(`obs.residuals.ResidualStore`) that each traced run feeds back, so the
engine's cost model sharpens run over run instead of being calibrated
once and trusted forever (ROADMAP: "stop treating calibration as
one-shot").

Schema (one entry per backend fingerprint)::

    {
      "<fingerprint>": {
        "profiles": {"<n>": {"seq_bw": ..., "sort_pass_bw": ...,
                              "partition_pass_bw": ...,
                              "unclustered_penalty": ...,
                              "clustered_penalty": ...}},
        "residuals": {"<op>/<strategy>": {"ewma": r, "count": k,
                                           "last": r}}
      }
    }

`engine.physical.calibrated_profile()` consults this store before
re-measuring; `python -m repro.obs` updates both halves.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re

from repro.core.planner import PrimitiveProfile

from .residuals import ResidualStore

DEFAULT_PATH = "CALIBRATION.json"

_PROFILE_FIELDS = tuple(f.name for f in dataclasses.fields(PrimitiveProfile))


def calibration_path() -> str:
    """Resolved store path. `REPRO_CALIBRATION_PATH` overrides the default
    `CALIBRATION.json` (cwd); the override is validated per call, never
    frozen at import: an empty value, an existing directory, or a parent
    directory that does not exist raises ValueError naming the variable —
    a typo'd path must not silently split the calibration history."""
    env = os.environ.get("REPRO_CALIBRATION_PATH")
    if env is None:
        return DEFAULT_PATH
    path = env.strip()
    if not path:
        raise ValueError(
            "REPRO_CALIBRATION_PATH is set but empty; unset it to use "
            f"./{DEFAULT_PATH} or point it at a writable JSON file path")
    if os.path.isdir(path):
        raise ValueError(
            f"REPRO_CALIBRATION_PATH={env!r} is a directory; it must name "
            "the JSON file itself (e.g. /path/to/CALIBRATION.json)")
    parent = os.path.dirname(os.path.abspath(path))
    if not os.path.isdir(parent):
        raise ValueError(
            f"REPRO_CALIBRATION_PATH={env!r} points into a directory that "
            f"does not exist ({parent}); create it first")
    return path


def backend_fingerprint() -> str:
    """Stable id of the measuring backend: platform, device kind, and jax
    version. Profiles measured under one fingerprint are never served to
    another — a CPU container's bandwidths must not price a TPU plan."""
    import platform

    import jax

    try:
        backend = jax.default_backend()
        kind = getattr(jax.devices()[0], "device_kind", backend)
    except Exception:  # pragma: no cover - no backend at all
        backend, kind = "none", "none"
    kind = re.sub(r"[^A-Za-z0-9_.-]+", "_", str(kind)).strip("_") or backend
    return (f"{platform.system().lower()}-{backend}-{kind}"
            f"-jax{jax.__version__}")


class CalibrationStore:
    """Read-modify-write view of the calibration JSON file. Load/save are
    whole-file (the store is a few KiB of constants); every read path
    tolerates a missing or corrupt file by starting empty — calibration is
    an accelerant, never a correctness dependency."""

    def __init__(self, path: str | None = None):
        self.path = path if path is not None else calibration_path()
        self.data: dict = {}
        self.load()

    def load(self) -> None:
        try:
            with open(self.path) as f:
                data = json.load(f)
            self.data = data if isinstance(data, dict) else {}
        except (OSError, ValueError):
            self.data = {}

    def save(self) -> None:
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.data, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)  # atomic: readers never see a torn file

    def _entry(self, fingerprint: str) -> dict:
        return self.data.setdefault(fingerprint,
                                    {"profiles": {}, "residuals": {}})

    # -- measured profiles --------------------------------------------------
    def get_profile(self, fingerprint: str,
                    n: int) -> PrimitiveProfile | None:
        """The stored profile measured at calibration size `n`, or None.
        Entries missing any model constant are ignored (schema drift must
        fall back to re-measuring, not to half a profile)."""
        raw = self.data.get(fingerprint, {}).get("profiles", {}).get(str(n))
        if not isinstance(raw, dict):
            return None
        try:
            kw = {k: float(raw[k]) for k in _PROFILE_FIELDS}
        except (KeyError, TypeError, ValueError):
            return None
        return PrimitiveProfile(**kw)

    def put_profile(self, fingerprint: str, n: int,
                    profile: PrimitiveProfile) -> None:
        self._entry(fingerprint)["profiles"][str(n)] = {
            k: float(getattr(profile, k)) for k in _PROFILE_FIELDS}

    # -- residual feedback --------------------------------------------------
    def residual_store(self, fingerprint: str) -> ResidualStore:
        raw = self.data.get(fingerprint, {}).get("residuals", {})
        return ResidualStore.from_dict(raw if isinstance(raw, dict) else {})

    def put_residuals(self, fingerprint: str, store: ResidualStore) -> None:
        self._entry(fingerprint)["residuals"] = store.as_dict()


def load_residuals(path: str | None = None,
                   fingerprint: str | None = None) -> ResidualStore:
    """The current backend's residual store (empty when nothing was ever
    recorded, or the store path is invalid — advisory data only)."""
    try:
        store = CalibrationStore(path)
        return store.residual_store(fingerprint or backend_fingerprint())
    except ValueError:
        return ResidualStore()

"""`python -m repro.obs` — run the standard traced workload, write
TRACE.json (+ TRACE.perfetto.json), update CALIBRATION.json, and print
the predicted-vs-measured table per plan node (DESIGN.md §12).

Two optimizer-chosen queries cover the residual surfaces that matter:

  star     join + grouped aggregation (the fusion pass decides fused vs
           unfused — joins and accumulators both get residuals)
  highcard high-cardinality integer-key group-by, the partition-vs-sort
           crossover the cost model is known to misprice off-TPU
           (BENCH_groupby.json): its >2x residual is the divergence this
           loop exists to surface

Each run feeds the measured/modeled residuals back into the calibration
store's per-(operator, strategy) EWMAs, so the next `optimize()` on this
backend sees the regret flag wherever the model's winner lost by >2x.

Usage:
    python -m repro.obs [--smoke] [--trace-out TRACE.json]
                        [--iters K] [--warmup W]

Exit code 0; CI (scripts/ci.sh) asserts the emitted files against their
schemas: every trace node carries predicted + measured + residual, and
the calibration entry holds both a profile and non-empty residuals.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _workloads(smoke: bool):
    """(name, PhysicalPlan) pairs over freshly generated tables."""
    import jax.numpy as jnp

    from repro.core import Table
    from repro.engine import Catalog, Optimizer, scan

    rng = np.random.default_rng(7)
    n_r, n_s = (512, 4096) if smoke else (4096, 65536)
    n_hc = 4096 if smoke else 65536

    R = Table({"k": jnp.asarray(rng.permutation(n_r).astype(np.int32)),
               "rv": jnp.asarray(rng.integers(0, 100, n_r).astype(np.int32))})
    S = Table({"k": jnp.asarray(rng.integers(0, n_r, n_s).astype(np.int32)),
               "g": jnp.asarray(rng.integers(0, 64, n_s).astype(np.int32)),
               "sv": jnp.asarray(rng.integers(0, 100, n_s).astype(np.int32))})
    # high-cardinality sparse integer keys: unique (multiplicity 1, so the
    # partition guard's exact proof holds) but spread over a domain too
    # wide for the scatter accumulator -> the chooser routes to the
    # paper's partition strategy, the known-misoriced arm off-TPU
    hk = (rng.permutation(n_hc) * 97).astype(np.int32)
    T = Table({"k": jnp.asarray(hk),
               "v": jnp.asarray(rng.normal(size=n_hc).astype(np.float32))})
    cat = Catalog({"R": R, "S": S, "T": T})

    opt = Optimizer(cat)  # calibrated profile via the persistent store
    star = opt.optimize(
        scan("S").join(scan("R"), key="k").group_by("g", rv="sum", sv="mean"))
    highcard = opt.optimize(scan("T").group_by("k", v="sum"))
    return [("star", star), ("highcard_groupby", highcard)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--trace-out", default="TRACE.json")
    ap.add_argument("--perfetto-out", default="TRACE.perfetto.json")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    args = ap.parse_args(argv)

    from repro.obs import (CalibrationStore, backend_fingerprint,
                           residuals_of)

    fp = backend_fingerprint()
    print(f"backend: {fp}")

    traces = {}
    all_residuals = []
    for name, plan in _workloads(args.smoke):
        _, _, trace = plan.run(trace=True, trace_iters=args.iters,
                               trace_warmup=args.warmup)
        traces[name] = trace
        all_residuals.extend(residuals_of(trace))
        print(f"\n== {name} ==")
        print(plan.explain(actuals=trace))
        print(trace.table())

    with open(args.trace_out, "w") as f:
        json.dump({"backend": fp,
                   "queries": {n: t.as_dict() for n, t in traces.items()}},
                  f, indent=2, sort_keys=True)
    print(f"\nwrote {args.trace_out} "
          f"({sum(len(t.spans()) for t in traces.values())} spans)")
    events = [dict(e, pid=i) for i, t in enumerate(traces.values())
              for e in t.chrome_trace()]
    with open(args.perfetto_out, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    print(f"wrote {args.perfetto_out} (Perfetto-loadable)")

    # feed the residuals back: profile stays (calibrated_profile already
    # persisted it), EWMAs sharpen with this run's measured/modeled ratios
    store = CalibrationStore()
    rs = store.residual_store(fp)
    rs.update(all_residuals)
    store.put_residuals(fp, rs)
    if not store.data.get(fp, {}).get("profiles"):
        # measurement failed earlier (fallback profile): record the v5e
        # constants explicitly so the store entry is complete either way
        from repro.engine import calibrated_profile

        store.put_profile(fp, 1 << 16, calibrated_profile())
    store.save()
    print(f"updated {store.path}: "
          f"{len(rs.data)} residual key(s) for this backend")
    print("\nresidual EWMAs (measured/modeled; 1.0 = model exact):")
    for key, ent in sorted(rs.data.items()):
        flag = "  <-- >2x" if ent["ewma"] >= 2.0 or ent["ewma"] <= 0.5 else ""
        print(f"  {key:<28} ewma={ent['ewma']:.2f}x "
              f"count={ent['count']}{flag}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

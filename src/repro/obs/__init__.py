"""repro.obs — runtime observability: tracing, residuals, calibration,
metrics (DESIGN.md §12).

Closes the loop between the planner's predictions and what the device
actually does:

  trace        per-node span tracer (`executor.run(..., trace=True)`),
               QueryTrace exportable as JSON + Chrome trace-event format,
               and the shared `timed_call`/`median_wall` timing primitive
  residuals    measured/modeled ratios per (operator, strategy), EWMA'd
               across runs; `regret_check` flags plans whose predicted
               winner lost the corrected comparison by >2x
  calibration  persistent CALIBRATION.json keyed by backend fingerprint:
               caches `PrimitiveProfile.measure()` across processes and
               carries the residual feedback the optimizer consults
  metrics      counter/histogram registry (plans compiled, cache hits,
               overflow escalations, contract audits)

`python -m repro.obs` runs a standard traced workload, writes TRACE.json,
updates CALIBRATION.json, and prints the predicted-vs-measured table.
"""
from . import metrics
from .calibration import (DEFAULT_PATH, CalibrationStore, backend_fingerprint,
                          calibration_path, load_residuals)
from .residuals import (EWMA_ALPHA, REGRET_FACTOR, NodeResidual, ResidualStore,
                        regret_check, residuals_of)
from .trace import (QueryTrace, Span, median_wall, sync_floor, timed_call,
                    trace_execute)

__all__ = [
    "QueryTrace", "Span", "trace_execute", "timed_call", "median_wall",
    "sync_floor",
    "NodeResidual", "ResidualStore", "residuals_of", "regret_check",
    "EWMA_ALPHA", "REGRET_FACTOR",
    "CalibrationStore", "backend_fingerprint", "calibration_path",
    "load_residuals", "DEFAULT_PATH",
    "metrics",
]

"""Logical plan IR + fluent builder for the cost-based query engine.

A logical plan is a tree of frozen dataclass nodes describing *what* to
compute, with no algorithm, pattern, or capacity choices — those are the
optimizer's job (engine.physical). Plans are hashable values, so they can
key plan caches and be compared in tests.

Operators (relational core, enough for the paper's workloads — multi-way
PK-FK / m:n joins, filters, grouped aggregation, top-k):

    Scan(table)                    named base relation in a Catalog
    Filter(child, column, op, v)   elementwise predicate
    Project(child, columns)        column pruning
    Join(left, right, lk, rk)      equi-join; optimizer picks build side
    GroupBy(child, key, aggs)      grouped aggregation
    OrderByLimit(child, key, n)    top-k by one column

Build plans with the fluent API::

    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1")
         .group_by("fk0", payload="sum")
         .order_by("payload_sum", limit=10, descending=True))
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Mapping

# single home of the predicate table: validation (here), selectivity
# sampling (stats), and execution (executor) all consume the same ops
FILTER_OP_FNS = {
    "==": operator.eq,
    "!=": operator.ne,
    "<": operator.lt,
    "<=": operator.le,
    ">": operator.gt,
    ">=": operator.ge,
}
FILTER_OPS = tuple(FILTER_OP_FNS)
JOIN_MODES = ("auto", "pk_fk", "mn")


@dataclasses.dataclass(frozen=True)
class Plan:
    """Base node; carries the fluent builder methods."""

    def filter(self, column: str, op: str, value) -> "Filter":
        if op not in FILTER_OPS:
            raise ValueError(f"filter op must be one of {FILTER_OPS}, got {op!r}")
        return Filter(self, column, op, value)

    def project(self, *columns: str) -> "Project":
        return Project(self, tuple(columns))

    def join(self, other: "Plan", *, key: str | None = None,
             left_key: str | None = None, right_key: str | None = None,
             mode: str = "auto") -> "Join":
        if key is not None:
            left_key = right_key = key
        if left_key is None or right_key is None:
            raise ValueError("join needs key= or both left_key=/right_key=")
        if mode not in JOIN_MODES:
            raise ValueError(f"join mode must be one of {JOIN_MODES}")
        return Join(self, other, left_key, right_key, mode)

    def group_by(self, key: str, aggs: Mapping[str, str] | None = None,
                 **agg_kw: str) -> "GroupBy":
        merged = dict(aggs or {})
        merged.update(agg_kw)
        if not merged:
            raise ValueError("group_by needs at least one aggregation")
        return GroupBy(self, key, tuple(sorted(merged.items())))

    def order_by(self, key: str, *, limit: int,
                 descending: bool = False) -> "OrderByLimit":
        return OrderByLimit(self, key, int(limit), descending)

    # -- traversal helpers --------------------------------------------------
    def children(self) -> tuple["Plan", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class Scan(Plan):
    table: str


@dataclasses.dataclass(frozen=True)
class Filter(Plan):
    child: Plan
    column: str
    op: str
    value: float

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Project(Plan):
    child: Plan
    columns: tuple[str, ...]

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class Join(Plan):
    left: Plan
    right: Plan
    left_key: str
    right_key: str
    mode: str = "auto"

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class GroupBy(Plan):
    child: Plan
    key: str
    aggs: tuple[tuple[str, str], ...]  # ((column, op), ...) sorted

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class OrderByLimit(Plan):
    child: Plan
    key: str
    limit: int
    descending: bool = False

    def children(self):
        return (self.child,)


def scan(table: str) -> Scan:
    """Entry point of the fluent API."""
    return Scan(table)


# ---------------------------------------------------------------------------
# Schema propagation (column names per node) — used by validation + optimizer
# ---------------------------------------------------------------------------
def output_columns(node: Plan, schemas: Mapping[str, tuple[str, ...]]) -> tuple[str, ...]:
    """Column names produced by `node`, given base-table schemas.

    Raises on references to missing columns and on join payload-name
    collisions, so malformed plans fail at build/optimize time rather than
    mid-execution.
    """
    if isinstance(node, Scan):
        if node.table not in schemas:
            raise KeyError(f"unknown table {node.table!r}")
        return tuple(schemas[node.table])
    if isinstance(node, Filter):
        cols = output_columns(node.child, schemas)
        if node.column not in cols:
            raise KeyError(f"filter column {node.column!r} not in {cols}")
        return cols
    if isinstance(node, Project):
        cols = output_columns(node.child, schemas)
        missing = [c for c in node.columns if c not in cols]
        if missing:
            raise KeyError(f"project columns {missing} not in {cols}")
        return node.columns
    if isinstance(node, Join):
        # Equi-join output keeps BOTH key columns (equal values) so chained
        # joins can reference either name regardless of how the optimizer
        # re-orders the tree; when the names coincide they collapse to one.
        lcols = output_columns(node.left, schemas)
        rcols = output_columns(node.right, schemas)
        if node.left_key not in lcols:
            raise KeyError(f"join key {node.left_key!r} not in left {lcols}")
        if node.right_key not in rcols:
            raise KeyError(f"join key {node.right_key!r} not in right {rcols}")
        shared = set(lcols) & set(rcols)
        allowed = {node.left_key} if node.left_key == node.right_key else set()
        clash = shared - allowed
        if clash:
            raise ValueError(f"join column name collision: {sorted(clash)}")
        return lcols + tuple(c for c in rcols if c not in shared)
    if isinstance(node, GroupBy):
        cols = output_columns(node.child, schemas)
        if node.key not in cols:
            raise KeyError(f"group key {node.key!r} not in {cols}")
        for col, op in node.aggs:
            if col not in cols:
                raise KeyError(f"agg column {col!r} not in {cols}")
        return (node.key,) + tuple(f"{c}_{op}" for c, op in node.aggs)
    if isinstance(node, OrderByLimit):
        cols = output_columns(node.child, schemas)
        if node.key not in cols:
            raise KeyError(f"order key {node.key!r} not in {cols}")
        return cols
    raise TypeError(f"unknown plan node {type(node).__name__}")

"""Table statistics + cardinality estimation for the query optimizer.

The paper's decision procedure (Fig. 18) and cost model (§5.4) consume a
`JoinStats` descriptor — sizes, payload widths, match ratio, skew, byte
widths. Callers used to hand-build those; this module estimates them from
the data itself, with device-side sketches and small host transfers:

  * row counts / min / max          — exact, one reduction each
  * distinct count                  — linear-counting sketch over hashed
                                      keys (B >= 2n buckets, so the
                                      occupancy inversion stays accurate)
  * zipf-skew exponent              — log-log slope of the top run-length
                                      counts of a hashed-stride sample
  * match ratio (join selectivity)  — sampled probe keys membership-tested
                                      against the sorted build key column
  * filter selectivity              — predicate evaluated on a sample

Everything is deterministic (hashed-stride sampling, no RNG state) so
plans are reproducible run to run.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hash_join import hash32
from repro.resilience import faults
from repro.core.planner import JoinStats
from repro.core.table import Table

from .logical import FILTER_OP_FNS

DEFAULT_SAMPLE = 4096


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Distinct/min/max/zipf for one column. `distinct` is propagated
    UNCHANGED through row-reducing ops: it is then an upper bound (filters
    can only remove key values), and every capacity consumer combines it
    with `min(distinct, surviving_rows)` — shrinking it by selectivity
    would under-size capacities for duplicated keys (a filter that keeps
    10% of rows usually keeps ~all keys when each key has many rows).

    `integer` records the sketched column's dtype kind. It survives
    propagation through joins/projections (they never change a carried
    column's dtype), which lets the group-by chooser route *derived* key
    columns — where no base-table origin is traceable — to the hash-bucketed
    'partition' strategy only when the keys are radix-hashable integers."""

    distinct: float
    min: float
    max: float
    zipf: float  # estimated skew exponent; 0 = uniform
    integer: bool = True  # dtype kind of the sketched column


@dataclasses.dataclass(frozen=True)
class TableStats:
    num_rows: int
    columns: Mapping[str, ColumnStats]

    def __getitem__(self, name: str) -> ColumnStats:
        return self.columns[name]


# ---------------------------------------------------------------------------
# Sampling + sketches
# ---------------------------------------------------------------------------
def sample_column(col: jax.Array, m: int = DEFAULT_SAMPLE, seed: int = 0) -> jax.Array:
    """Deterministic hashed-stride sample of up to m values (Fibonacci
    multiplicative stride — covers the array pseudo-randomly with no RNG)."""
    n = col.shape[0]
    if n <= m:
        return col
    idx = (np.arange(m, dtype=np.uint64) * np.uint64(2654435761) + np.uint64(seed)) % n
    return jnp.take(col, jnp.asarray(idx.astype(np.int32)))


def _hashable(col: jax.Array) -> jax.Array:
    """hash32 value-casts its input, which collapses sub-integer float
    distinctions; bitcast floats to same-width ints so every distinct
    float hashes distinctly."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        width = col.dtype.itemsize * 8
        return jax.lax.bitcast_convert_type(col, jnp.dtype(f"int{width}"))
    return col


def estimate_distinct(col: jax.Array) -> float:
    """Linear-counting sketch: hash into B >= max(2n, 64k) buckets, invert
    occupancy. Accurate to a few percent in that regime."""
    n = col.shape[0]
    if n == 0:
        return 0.0
    B = 1 << max(16, int(2 * n - 1).bit_length())
    h = hash32(_hashable(col)) % jnp.uint32(B)
    occupied = jnp.zeros((B,), jnp.bool_).at[h].set(True)
    v = int(jnp.sum(occupied))
    if v >= B:  # saturated (cannot happen with B >= 2n, but stay safe)
        return float(n)
    est = -B * np.log1p(-v / B)
    # deterministic corruption hook (REPRO_FAULTS=estimates:...): 1.0 when
    # no fault is active, so production estimates are untouched
    est *= faults.estimate_factor("distinct")
    return float(min(max(est, 1.0), n))


def estimate_zipf(col: jax.Array, m: int = 2 * DEFAULT_SAMPLE, seed: int = 0) -> float:
    """Skew exponent: least-squares slope of log(frequency) vs log(rank)
    over the top run-length counts of a sorted sample. ~0 for uniform keys,
    ~a for Zipf(a)-distributed keys. Clamped to [0, 4]."""
    s = jnp.sort(sample_column(col, m, seed))
    boundary = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1
    counts = jax.ops.segment_sum(
        jnp.ones_like(gid), gid, num_segments=s.shape[0]
    )
    top = np.asarray(jax.lax.top_k(counts, min(64, s.shape[0]))[0], dtype=np.float64)
    top = top[top >= 2]  # singleton tail carries no skew signal
    if top.size < 4:
        return 0.0
    ranks = np.arange(1, top.size + 1, dtype=np.float64)
    slope = np.polyfit(np.log(ranks), np.log(top), 1)[0]
    return float(min(max(-slope, 0.0), 4.0))


def _membership_ratio(sorted_build: jax.Array, probe_sample: jax.Array,
                      mask: jax.Array | None = None) -> float:
    """Fraction of (mask-selected) probe sample keys present in the sorted
    build keys — the one membership-test implementation every match-ratio
    path shares."""
    lb = jnp.searchsorted(sorted_build, probe_sample, side="left")
    lb_c = jnp.minimum(lb, sorted_build.shape[0] - 1)
    hit = (jnp.take(sorted_build, lb_c) == probe_sample) & (
        lb < sorted_build.shape[0])
    if mask is None:
        return float(jnp.mean(hit.astype(jnp.float32)))
    denom = jnp.maximum(jnp.sum(mask), 1)
    return float(jnp.sum(hit & mask) / denom)


def estimate_match_ratio(build_keys: jax.Array, probe_keys: jax.Array,
                         m: int = DEFAULT_SAMPLE, seed: int = 0) -> float:
    """Join selectivity: fraction of (sampled) probe keys with a partner in
    the build key column — one sort of the build keys + a searchsorted."""
    return _membership_ratio(jnp.sort(build_keys),
                             sample_column(probe_keys, m, seed))


def estimate_selectivity(col: jax.Array, op: str, value,
                         m: int = DEFAULT_SAMPLE, seed: int = 0) -> float:
    """Filter selectivity from a sampled predicate evaluation."""
    s = sample_column(col, m, seed)
    mask = FILTER_OP_FNS[op](s, value)
    return float(jnp.mean(mask.astype(jnp.float32)))


def collect_column_stats(col: jax.Array, *, sample: int = DEFAULT_SAMPLE,
                         seed: int = 0) -> ColumnStats:
    """Sketch one column (shared by TableStats and the Catalog cache)."""
    return ColumnStats(
        distinct=estimate_distinct(col),
        min=float(jnp.min(col)),
        max=float(jnp.max(col)),
        zipf=estimate_zipf(col, 2 * sample, seed),
        integer=bool(jnp.issubdtype(col.dtype, jnp.integer)),
    )


def collect_table_stats(table: Table, *, sample: int = DEFAULT_SAMPLE,
                        seed: int = 0) -> TableStats:
    """Statistics for every column of a base table (eager; the Catalog's
    per-column path is the lazy production route)."""
    cols = {name: collect_column_stats(table[name], sample=sample, seed=seed)
            for name in table.column_names}
    return TableStats(num_rows=table.num_rows, columns=cols)


# ---------------------------------------------------------------------------
# Catalog: named base tables + lazily cached statistics
# ---------------------------------------------------------------------------
class Catalog:
    """The engine's view of the database: named `Table`s plus per-table
    statistics, collected on first use and cached (re-`register` a table to
    invalidate)."""

    def __init__(self, tables: Mapping[str, Table] | None = None):
        self.tables: dict[str, Table] = dict(tables or {})
        self._stats: dict[str, TableStats] = {}
        self._col_stats: dict[tuple[str, str], ColumnStats] = {}
        self._unique: dict[tuple[str, str], bool] = {}
        self._sel: dict[tuple, float] = {}
        self._mr: dict[tuple, float] = {}
        self._mn_rows: dict[tuple, float] = {}
        self._mult: dict[tuple, float] = {}

    def register(self, name: str, table: Table) -> "Catalog":
        self.tables[name] = table
        self._stats.pop(name, None)
        for cache in (self._col_stats, self._unique, self._sel):
            for k in [k for k in cache if k[0] == name]:
                del cache[k]
        self._mult = {k: v for k, v in self._mult.items() if k[0][0] != name}
        # _mr keys: (build_origin, probe_origin, preds) with origin=(table,col)
        self._mr = {k: v for k, v in self._mr.items()
                    if name not in (k[0][0], k[1][0])}
        # _mn_rows keys: ((origin, preds), (origin, preds))
        self._mn_rows = {k: v for k, v in self._mn_rows.items()
                         if name not in (k[0][0][0], k[1][0][0])}
        return self

    def schemas(self) -> dict[str, tuple[str, ...]]:
        return {name: t.column_names for name, t in self.tables.items()}

    def stats(self, name: str) -> TableStats:
        if name not in self._stats:
            self._stats[name] = collect_table_stats(self.tables[name])
        return self._stats[name]

    def col_stats(self, name: str, col: str) -> ColumnStats:
        """Per-column statistics, sketched on first use and cached — only
        columns a plan actually consults (join keys, filter columns, group
        keys) ever pay for a sketch; payload columns of wide tables don't."""
        key = (name, col)
        if key not in self._col_stats:
            self._col_stats[key] = collect_column_stats(self.tables[name][col])
        return self._col_stats[key]

    def selectivity(self, name: str, predicates: tuple) -> float:
        """JOINT selectivity of a predicate chain over one base-row sample.
        Evaluating the conjunction on aligned samples (sample_column uses
        the same stride for every column) captures predicate correlation
        that multiplying per-predicate selectivities would miss."""
        key = (name, tuple(predicates))
        if key not in self._sel:
            t = self.tables[name]
            mask = None
            for col, op, value in predicates:
                m = FILTER_OP_FNS[op](sample_column(t[col]), value)
                mask = m if mask is None else (mask & m)
            self._sel[key] = (1.0 if mask is None
                              else float(jnp.mean(mask.astype(jnp.float32))))
        return self._sel[key]

    def max_multiplicity(self, origin: tuple[str, str],
                         preds: tuple = ()) -> float:
        """EXACT maximum per-key row count of a (filtered) base column —
        decides whether an m:n join's build side fits PHJ's padded
        co-partition blocks or must use sort-merge. Device-side: sorted
        (key, valid) pairs + validity prefix sums, one scalar transfer."""
        key = (origin, tuple(preds))
        if key not in self._mult:
            keys, mask = self._masked_keys(origin, preds)
            sk, valid = jax.lax.sort((keys, mask.astype(jnp.int32)), num_keys=1)
            cum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(valid)])
            lo = jnp.searchsorted(sk, sk, side="left")
            hi = jnp.searchsorted(sk, sk, side="right")
            per = jnp.take(cum, hi) - jnp.take(cum, lo)
            self._mult[key] = float(jnp.max(jnp.where(valid > 0, per, 0)))
        return self._mult[key]

    def is_unique(self, name: str, col: str) -> bool:
        """Exact (not sketched) key-uniqueness check, cached; device-side
        (one sort + adjacent-equal reduce, scalar transfer). The optimizer
        uses this to prove a join side is a PK side: a distinct-count sketch
        can be a few percent off, which is the difference between a correct
        pk_fk plan and one that silently drops duplicate matches."""
        key = (name, col)
        if key not in self._unique:
            s = jnp.sort(self.tables[name][col])
            self._unique[key] = not bool(jnp.any(s[1:] == s[:-1]))
        return self._unique[key]

    def match_ratio(self, build_origin: tuple[str, str],
                    probe_origin: tuple[str, str],
                    probe_predicates: tuple = ()) -> float:
        """Memoized join selectivity. `probe_predicates` — a chain of
        (column, op, value) filters over the probe base table — is applied
        to the probe-side row sample before the membership test, so a
        filter correlated with match likelihood (e.g. range-restricting the
        key itself) yields the post-filter match ratio instead of the base
        one. Without this, base-mr x filter-sel double-counts the
        restriction and the join capacity silently truncates."""
        key = (build_origin, probe_origin, tuple(probe_predicates))
        if key not in self._mr:
            probe_t = self.tables[probe_origin[0]]
            bk = jnp.sort(self.tables[build_origin[0]][build_origin[1]])
            pk = sample_column(probe_t[probe_origin[1]])
            mask = jnp.ones(pk.shape, bool)
            for col, op, value in probe_predicates:
                mask &= FILTER_OP_FNS[op](sample_column(probe_t[col]), value)
            self._mr[key] = _membership_ratio(bk, pk, mask)
        return self._mr[key]

    def _masked_keys(self, origin: tuple[str, str], predicates: tuple):
        t = self.tables[origin[0]]
        keys = t[origin[1]]
        mask = jnp.ones(keys.shape, bool)
        for col, op, value in predicates:
            mask &= FILTER_OP_FNS[op](t[col], value)
        return keys, mask

    def mn_output_rows(self, a_origin: tuple[str, str],
                       b_origin: tuple[str, str],
                       a_preds: tuple = (), b_preds: tuple = ()) -> float:
        """EXACT m:n join output cardinality between two base columns,
        with each side's pushed-down filter chain applied — sum over keys
        of count_a(k) * count_b(k) over the SURVIVING rows. Device-side:
        sort B's (key, valid) pairs, prefix-sum the validity flags, and
        range-count per A element; one scalar transfer. Both the
        independence estimate (n_a*n_b/distinct) and uniform retention
        scaling undershoot by orders of magnitude on correlated
        multiplicity/filters, silently truncating the join output through
        the static capacity."""
        # canonicalize each (origin, preds) side together — the count is
        # symmetric, but preds must stay attached to their own side
        key = tuple(sorted(((a_origin, tuple(a_preds)),
                            (b_origin, tuple(b_preds)))))
        if key not in self._mn_rows:
            a, ma = self._masked_keys(a_origin, a_preds)
            b, mb = self._masked_keys(b_origin, b_preds)
            sb, valid_b = jax.lax.sort((b, mb.astype(jnp.int32)), num_keys=1)
            cum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(valid_b)])
            lo = jnp.searchsorted(sb, a, side="left")
            hi = jnp.searchsorted(sb, a, side="right")
            per_a = (jnp.take(cum, hi) - jnp.take(cum, lo)).astype(jnp.float32)
            self._mn_rows[key] = float(jnp.sum(jnp.where(ma, per_a, 0.0)))
        return self._mn_rows[key]


# ---------------------------------------------------------------------------
# JoinStats synthesis — what the Fig. 18 trees + cost model consume
# ---------------------------------------------------------------------------
def synthesize_join_stats(
    *,
    n_build: int,
    n_probe: int,
    build_payload_cols: int,
    probe_payload_cols: int,
    match_ratio: float,
    zipf: float,
    key_dtype,
    payload_dtypes=(),
) -> JoinStats:
    """Build the planner's workload descriptor from estimated quantities —
    the piece callers previously hand-wrote."""
    key_bytes = np.dtype(key_dtype).itemsize
    payload_bytes = max(
        [np.dtype(d).itemsize for d in payload_dtypes] or [key_bytes]
    )
    return JoinStats(
        n_r=int(n_build),
        n_s=int(n_probe),
        r_payload_cols=int(build_payload_cols),
        s_payload_cols=int(probe_payload_cols),
        match_ratio=float(match_ratio),
        zipf=float(zipf),
        key_bytes=int(key_bytes),
        payload_bytes=int(payload_bytes),
    )

"""Physical-plan interpreter over `core` operators — jit-compatible.

All plan structure (operator order, algorithms, capacities) is Python-side
and static; only the tables flow through as traced pytrees, so the whole
plan compiles as one XLA program:

    compiled = jax.jit(lambda tables: execute(plan.root, tables))

Every operator follows the repo's static-shape contract (DESIGN.md §2):
it consumes and produces `(Table-with-capacity, valid_count)` pairs. Rows
at index >= count are padding; before each key-consuming operator the key
column is re-masked to KEY_SENTINEL so padding can never match or form a
group. Filters compact survivors to the front, which preserves the
clustering GFTR relies on (`primitives.compact` is stable).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core import group_aggregate, join, phj_groupjoin
from repro.core import primitives as prim
from repro.core.groupby import groupby_partition_checked
from repro.core.groupjoin import groupjoin_checked
from repro.core.hash_join import phj_join_checked
from repro.core.table import KEY_SENTINEL, Table, concat_tables
from repro.obs import metrics
from repro.resilience import escalation, faults

from . import membudget
from . import physical as P
from .logical import FILTER_OP_FNS

# Programming errors must surface, not trigger a degraded re-plan: a retried
# plan would either hit the same bug or silently mask it (DESIGN.md §13).
_NON_DEGRADABLE = (TypeError, KeyError, AttributeError, IndexError)

# Checked mode: capacity-sensitive operators run through their resilience
# ladders (phj_join_checked / groupby_partition_checked / groupjoin_checked)
# instead of the plain drivers, so a plan whose capacities were misestimated
# escalates and records EscalationReports rather than silently truncating.
# Ladders read overflow flags host-side, so this is only legal in EAGER
# execution — `run(jit=False)` and the tracer's validation pass set it; the
# jitted fast path never does (its protection is the degrade-once retry).
_CHECKED = contextvars.ContextVar("repro_executor_checked", default=False)


@contextlib.contextmanager
def checked_mode():
    token = _CHECKED.set(True)
    try:
        yield
    finally:
        _CHECKED.reset(token)


def _can_check(*arrays) -> bool:
    """Checked mode is armed AND the inputs are concrete. The ladders'
    overflow checks are host-side bool()s on device scalars, impossible on
    tracers — an eager `run(jit=False)` wrapped in an OUTER jax.jit (the
    benchmarks do this to time the interpreted plan as one executable)
    must fall back to the plain drivers: the identical computation the
    jit path compiles, protected by the degrade-once retry instead."""
    return _CHECKED.get() and not any(
        isinstance(a, jax.core.Tracer) for a in arrays)


class Materialized:
    """Pseudo plan node wrapping an already-computed ``(Table, count)``
    pair. The per-node tracer (repro.obs.trace) substitutes these for a
    node's children so `execute` times exactly one operator while its
    inputs arrive as traced jit arguments. Untraced execution never
    constructs one."""

    __slots__ = ("value",)

    def __init__(self, value):
        self.value = value

    def children(self):
        return ()


def _valid_mask(table: Table, count) -> jax.Array:
    return jnp.arange(table.num_rows, dtype=jnp.int32) < count


def _mask_key(table: Table, count, key: str) -> Table:
    """Force padding rows' key to KEY_SENTINEL so joins/group-bys drop them."""
    k = table[key]
    masked = jnp.where(_valid_mask(table, count), k,
                       jnp.asarray(KEY_SENTINEL, k.dtype))
    return table.with_columns(**{key: masked})


def execute(node: P.PhysNode, tables: Mapping[str, Table], counts=None):
    """Interpret the plan bottom-up. Returns (Table, valid_count).

    `counts` (optional ``{table_name: valid_count}``) is the serving
    layer's capacity-bucketing hook (DESIGN.md §14): tables padded up to a
    shared capacity bucket flow through with their TRUE valid counts as
    traced scalars, so one compiled executable serves every dataset that
    pads to the same bucket. Without it, a scan's whole table is valid —
    the one-shot contract every existing call site relies on."""
    if isinstance(node, Materialized):
        return node.value
    if isinstance(node, P.PScan):
        t = tables[node.table]
        if counts is not None and node.table in counts:
            return t, jnp.asarray(counts[node.table], jnp.int32)
        return t, jnp.asarray(t.num_rows, jnp.int32)
    if isinstance(node, P.PFilter):
        return _filter(node, tables, counts)
    if isinstance(node, P.PProject):
        t, count = execute(node.child, tables, counts)
        return t.select(node.columns), count
    if isinstance(node, P.PJoin):
        return _join(node, tables, counts)
    if isinstance(node, P.PGroupBy):
        return _group_by(node, tables, counts)
    if isinstance(node, P.PGroupJoin):
        return _group_join(node, tables, counts)
    if isinstance(node, P.POrderByLimit):
        return _order_by(node, tables, counts)
    raise TypeError(f"unknown physical node {type(node).__name__}")


def _filter(node: P.PFilter, tables, counts=None):
    t, count = execute(node.child, tables, counts)
    mask = FILTER_OP_FNS[node.op](t[node.column], node.value) & _valid_mask(t, count)
    names = t.column_names
    outs, new_count = prim.compact(mask, [t[n] for n in names], node.capacity)
    return Table(dict(zip(names, outs))), new_count


def _join(node: P.PJoin, tables, counts=None):
    bt, b_count = execute(node.build, tables, counts)
    pt, p_count = execute(node.probe, tables, counts)
    bt = _mask_key(bt, b_count, node.build_key)
    pt = _mask_key(pt, p_count, node.probe_key)
    # core.join wants one shared key name: align build's key to the probe's
    if node.build_key != node.probe_key:
        bt = bt.rename({node.build_key: node.probe_key})
    if node.algorithm == "phj" and _can_check(bt[node.probe_key],
                                              pt[node.probe_key]):
        out, count = phj_join_checked(
            bt, pt, key=node.probe_key, pattern=node.pattern,
            out_size=node.capacity, mode=node.mode,
        )
    else:
        out, count = join(
            bt, pt, key=node.probe_key, algorithm=node.algorithm,
            pattern=node.pattern, out_size=node.capacity, mode=node.mode,
        )
    if node.build_key != node.probe_key:
        # restore the equal-valued alias column (schema contract)
        out = out.with_columns(**{node.build_key: out[node.probe_key]})
    return out, count


def _group_by(node: P.PGroupBy, tables, counts=None):
    t, count = execute(node.child, tables, counts)
    t = _mask_key(t, count, node.key)
    sel = t.select((node.key,) + tuple(c for c, _ in node.aggs))
    if node.strategy == "partition" and _can_check(sel[node.key]):
        return groupby_partition_checked(
            sel, key=node.key, aggs=dict(node.aggs),
            num_groups=node.capacity, **dict(node.agg_kw),
        )
    return group_aggregate(
        sel, key=node.key, aggs=dict(node.aggs), num_groups=node.capacity,
        strategy=node.strategy, **dict(node.agg_kw),
    )


def _group_join(node: P.PGroupJoin, tables, counts=None):
    """Fused join + grouped aggregation: the probe's matches feed the
    accumulator directly (core.groupjoin), so only the key, group-key, and
    aggregate-input columns are ever touched — the join output never
    exists."""
    bt, b_count = execute(node.build, tables, counts)
    pt, p_count = execute(node.probe, tables, counts)
    bt = _mask_key(bt, b_count, node.build_key)
    pt = _mask_key(pt, p_count, node.probe_key)
    key = node.probe_key
    if node.build_key != key:
        bt = bt.rename({node.build_key: key})
    agg_cols = [c for c, _ in node.aggs]
    b_need = dict.fromkeys([key] + [c for c in agg_cols if c in bt])
    p_need = dict.fromkeys([key, node.probe_group_key]
                           + [c for c in agg_cols if c in pt])
    if _can_check(bt[key], pt[key]):
        out, count = groupjoin_checked(
            bt.select(tuple(b_need)), pt.select(tuple(p_need)), key=key,
            group_key=node.probe_group_key, aggs=dict(node.aggs),
            num_groups=node.capacity, agg_strategy=node.agg_strategy,
            agg_kw=dict(node.agg_kw) or None,
        )
    else:
        out, count = phj_groupjoin(
            bt.select(tuple(b_need)), pt.select(tuple(p_need)), key=key,
            group_key=node.probe_group_key, aggs=dict(node.aggs),
            num_groups=node.capacity, agg_strategy=node.agg_strategy,
            agg_kw=dict(node.agg_kw) or None,
        )
    if node.group_key != node.probe_group_key:
        # logical schema names the group column after the GroupBy key (the
        # equal-valued build-key alias); restore it
        out = out.rename({node.probe_group_key: node.group_key})
    return out, count


def _order_by(node: P.POrderByLimit, tables, counts=None):
    t, count = execute(node.child, tables, counts)
    k = t[node.key]
    if node.descending:
        # bitwise complement reverses integer order without the INT_MIN
        # overflow of arithmetic negation; floats negate safely
        k = ~k if jnp.issubdtype(k.dtype, jnp.integer) else -k
    # validity is the primary sort key, so padding rows land strictly after
    # every valid row no matter what values they carry
    invalid = (~_valid_mask(t, count)).astype(jnp.int32)
    iota = jnp.arange(t.num_rows, dtype=jnp.int32)
    _, _, perm = jax.lax.sort((invalid, k, iota), num_keys=2, is_stable=True)
    # slice the permutation before gathering: top-k needs a capacity-length
    # gather, not a full-table copy of every column
    out = t.take(perm[:node.capacity])
    return out, jnp.minimum(count, node.capacity)


# ---------------------------------------------------------------------------
# contract audit: the compiled side of priced-vs-compiled (DESIGN.md §11)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NodeAudit:
    """One physical node judged against its priced contract. `own_budget`
    is the node's incremental primitive budget: its subtree's trace minus
    its children's subtree traces, so a join is never charged for the sort
    its order-by child pays."""
    node: P.PhysNode
    contract: object  # analysis.OperatorContract
    report: object  # analysis.AuditReport of the node's SUBTREE
    own_budget: object  # analysis.PrimitiveBudget of the node alone
    violations: list


@dataclasses.dataclass
class PlanAudit:
    entries: list  # NodeAudit, preorder from the root
    root_report: object  # whole-plan AuditReport

    @property
    def violations(self) -> list:
        return [v for e in self.entries for v in e.violations]

    def by_node(self) -> dict:
        return {id(e.node): e for e in self.entries}

    def as_dict(self) -> dict:
        return {
            "peak_live_bytes": self.root_report.peak_live_bytes,
            "budget": self.root_report.budget.as_dict(),
            "nodes": [{
                "node": type(e.node).__name__,
                "contract": e.contract.describe(),
                "compiled": e.own_budget.as_dict(),
                "violations": [f"{type(v).__name__}: {v}"
                               for v in e.violations],
            } for e in self.entries],
        }


def _scan_names(node: P.PhysNode) -> set:
    if isinstance(node, P.PScan):
        return {node.table}
    names: set = set()
    for child in node.children():
        names |= _scan_names(child)
    return names


def audit(plan: "P.PhysicalPlan",
          tables: Mapping[str, Table] | None = None) -> PlanAudit:
    """Trace every plan subtree, attribute each node's incremental
    primitive budget, and judge it against the node's declared contract
    (`analysis.contracts.contract_for_node`). The subtree traces use only
    the tables that subtree scans, so the liveness watermark of a fused
    group-join reflects *its* inputs — the checkable form of 'the join
    output never materialized'."""
    from repro.analysis import contracts as C
    from repro.analysis import jaxpr_audit as A

    metrics.counter("engine.contract_audits").inc()
    tables = dict(tables if tables is not None else plan.catalog.tables)
    reports: dict = {}

    def trace(node: P.PhysNode):
        sub = {n: tables[n] for n in sorted(_scan_names(node))}
        closed = jax.make_jaxpr(lambda tb: execute(node, tb))(sub)
        return A.audit_jaxpr(closed)

    entries: list[NodeAudit] = []

    def visit(node: P.PhysNode):
        rep = trace(node)
        reports[id(node)] = rep
        contract = C.contract_for_node(node)
        entry = NodeAudit(node=node, contract=contract, report=rep,
                          own_budget=None, violations=[])
        entries.append(entry)  # preorder: parent precedes children
        own = rep.budget
        for child in node.children():
            visit(child)
            own = own - reports[id(child)].budget
        entry.own_budget = own
        entry.violations = C.check(contract, rep, own)

    visit(plan.root)
    return PlanAudit(entries=entries, root_report=reports[id(plan.root)])


def run(plan: "P.PhysicalPlan", tables: Mapping[str, Table] | None = None,
        *, jit: bool = True, trace: bool = False, trace_iters: int = 1,
        trace_warmup: int = 1, counts=None):
    """Execute a PhysicalPlan. `tables` defaults to the catalog's; pass new
    same-shape tables to reuse one compiled plan across datasets. The jitted
    executor is cached on the plan, so repeated `run()` calls trace and
    compile once.

    `counts` ({table_name: valid_count}) enables capacity bucketing
    (DESIGN.md §14): the counts ride as traced int32 scalars into a
    SEPARATE cached executable (`plan.compiled_bucketed`), so one compiled
    plan serves every dataset padded to its capacity buckets — the
    count-free `plan.compiled` artifact and its jaxpr (pinned by
    tests/test_obs.py) are untouched.

    With ``trace=True`` the plan runs node by node under the span tracer
    (repro.obs.trace) and returns ``(table, count, QueryTrace)`` — per-node
    device-synced wall times, rows/bytes, and predicted-vs-measured
    residuals. Tracing is strictly opt-in: the untraced path below is the
    exact pre-trace code path (no Span allocation, identical whole-plan
    jaxpr — pinned by tests/test_obs.py).

    Graceful degradation (DESIGN.md §13): if the plan raises at trace or
    run time — an `EscalationExhausted` ladder, a kernel arm that failed
    past its xla fallback, a fault-injected `raise:executor.run` — the
    executor re-plans ONCE via `physical.degrade_plan` (doubled
    capacities, sort/smj strategies) and reruns. Programming errors
    (`_NON_DEGRADABLE`) and failures of an already-degraded plan re-raise
    untouched."""
    if trace:
        if counts is not None:
            raise ValueError("trace=True does not support counts= (the "
                             "span tracer materializes per-node inputs)")
        from repro.obs.trace import trace_execute

        return trace_execute(plan, tables, iters=trace_iters,
                             warmup=trace_warmup)
    tables = dict(tables if tables is not None else plan.catalog.tables)

    def attempt(p: "P.PhysicalPlan"):
        faults.check_site("executor.run")
        faults.check_oom("executor.run")
        if p.morsel_factor > 1:
            # memory rung (DESIGN.md §15): out-of-core morsel driver
            return run_morsels(p, tables, counts=counts, jit=jit)
        if not jit:
            # eager runs are the diagnostic path: capacity-sensitive nodes
            # go through their resilience ladders and record reports
            with checked_mode():
                return execute(p.root, tables, counts)
        if counts is not None:
            if p.compiled_bucketed is None:
                p.compiled_bucketed = jax.jit(
                    lambda tb, ct: execute(p.root, tb, ct))
                metrics.counter("engine.plans_compiled").inc()
            else:
                metrics.counter("engine.plan_cache_hits").inc()
            ct = {k: jnp.asarray(v, jnp.int32) for k, v in counts.items()}
            return p.compiled_bucketed(tables, ct)
        if p.compiled is None:
            p.compiled = jax.jit(lambda tb: execute(p.root, tb))
            metrics.counter("engine.plans_compiled").inc()
        else:
            metrics.counter("engine.plan_cache_hits").inc()
        return p.compiled(tables)

    try:
        return attempt(plan)
    except _NON_DEGRADABLE:
        raise
    except Exception as e:  # noqa: BLE001 — everything else degrades once
        if plan.degraded:
            raise
        reason = f"{type(e).__name__}: {e}"[:120]
        if plan.degraded_plan is None:
            # allocation failures route onto the MEMORY rung when the plan
            # is splittable — a smaller working set, never the default
            # rung's doubled capacities (DESIGN.md §15)
            if (membudget.is_memory_error(e)
                    and P.morsel_axis(plan.root) is not None):
                plan.degraded_plan = P.degrade_plan(plan, reason, memory=True)
            else:
                plan.degraded_plan = P.degrade_plan(plan, reason)
        metrics.counter("resilience.plan_degradations").inc()
        escalation.record_degradation("executor", reason)
        return attempt(plan.degraded_plan)


# ---------------------------------------------------------------------------
# morsel-driven out-of-core execution (DESIGN.md §15)
# ---------------------------------------------------------------------------
def run_morsels(plan: "P.PhysicalPlan",
                tables: Mapping[str, Table] | None = None, *,
                counts=None, factor: int | None = None, jit: bool = True):
    """Execute `plan` out-of-core: split the morsel axis (the probe spine's
    base scan, `physical.morsel_axis`) into `factor` equal chunks, run the
    capacity-scaled per-morsel clone (`physical.morsel_plan`) over each
    chunk through ONE compiled bucketed executable — chunk validity rides
    in as a traced count scalar, so every morsel reuses the same
    compilation — and recombine host-side: concat for row-shaped roots,
    a partial-aggregate merge for group roots (sum/count/min/max
    re-reduce; mean = merged sum / merged count, the exact `_finalize`
    expression). Returns (Table, valid_count) shaped exactly like
    whole-plan `run`."""
    factor = int(factor if factor is not None else plan.morsel_factor)
    if factor < 2:
        raise ValueError(f"morsel factor must be >= 2, got {factor}")
    axis = P.morsel_axis(plan.root)
    if axis is None:
        raise ValueError("plan has no morsel axis (not splittable)")
    tables = dict(tables if tables is not None else plan.catalog.tables)
    axis_table = tables[axis]
    rows = axis_table.num_rows
    total = int(counts[axis]) if counts is not None and axis in counts else rows
    mp = P.morsel_plan(plan, factor, rows=rows)
    m = P.morsel_rows(rows, factor)
    padded = axis_table.pad_to(m * factor)
    base_counts = dict(counts) if counts is not None else {}
    parts = []
    for i in range(factor):
        cnt = min(max(total - i * m, 0), m)
        if cnt == 0 and i > 0:
            continue  # past the valid tail; morsel 0 always runs so an
            # empty input still yields a well-formed empty result
        chunk = Table({n: v[i * m:(i + 1) * m]
                       for n, v in padded.columns.items()})
        mtables = dict(tables)
        mtables[axis] = chunk
        mcounts = dict(base_counts)
        mcounts[axis] = cnt
        metrics.counter("engine.morsel_runs").inc()
        parts.append(run(mp, mtables, jit=jit, counts=mcounts))
    return _recombine(plan.root, parts)


def _recombine(root: P.PhysNode, parts: list):
    """Merge per-morsel results into the whole-plan (Table, count)."""
    sliced = [(t.head(int(c)), int(c)) for t, c in parts]
    if isinstance(root, (P.PGroupBy, P.PGroupJoin)):
        return _merge_partials(root, sliced)
    # row-shaped root (join/filter/project/scan spine): morsels partition
    # the probe, so valid rows concatenate — total is the whole-plan count
    # and fits the root capacity whenever the whole plan would have
    total = sum(c for _, c in sliced)
    if total > root.capacity:
        raise ValueError(
            f"morsel recombine overflow: {total} rows exceed the root "
            f"capacity {root.capacity}")
    cat = concat_tables([t for t, _ in sliced])
    return cat.pad_to(root.capacity), jnp.asarray(total, jnp.int32)


def _merge_partials(root, sliced):
    """Re-reduce per-morsel partial aggregates (the `partial_agg_plan`
    rewrite) into final aggregates, bit-identical to the whole-plan
    result: integer sums/counts/min/max are associative, and mean divides
    the merged sum by the merged count with the exact `_finalize`
    expression (`acc / max(count,1).astype(acc.dtype)`)."""
    key = root.key if isinstance(root, P.PGroupBy) else root.group_key
    partial, count_col = P.partial_agg_plan(root)
    combine = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}
    cat = concat_tables([t for t, _ in sliced])
    merged, count = group_aggregate(
        cat, key=key,
        aggs={f"{c}_{pop}": combine[pop] for c, pop in partial},
        num_groups=root.capacity, strategy="sort",
    )

    def final(c, op):
        if op == "mean":
            s = merged[f"{c}_sum_sum"]
            n = merged[f"{count_col}_count_sum"]
            return s / jnp.maximum(n, 1).astype(s.dtype)
        pop = dict(partial)[c]
        return merged[f"{c}_{pop}_{combine[pop]}"]

    out = {key: merged[key]}
    out.update({f"{c}_{op}": final(c, op) for c, op in root.aggs})
    return Table(out).select(root.columns), count


def plan_peak_bytes(plan: "P.PhysicalPlan",
                    tables: Mapping[str, Table] | None = None,
                    counts=None) -> int:
    """The plan's whole-program peak-live-bytes watermark (the byte the
    memory governor admits against), from a single root trace — the cheap
    subset of `audit()` (which traces every subtree to attribute per-node
    budgets). With `counts`, traces the bucketed form the serving layer
    actually runs."""
    from repro.analysis import jaxpr_audit as A

    tables = dict(tables if tables is not None else plan.catalog.tables)
    if counts is not None:
        ct = {k: jnp.asarray(v, jnp.int32) for k, v in counts.items()}
        closed = jax.make_jaxpr(
            lambda tb, c: execute(plan.root, tb, c))(tables, ct)
    else:
        closed = jax.make_jaxpr(lambda tb: execute(plan.root, tb))(tables)
    return int(A.audit_jaxpr(closed).peak_live_bytes)

"""repro.engine — a cost-based relational query engine over the join /
group-by operator library (the paper's "query optimizer" layer, built out).

Four modules close the loop from declarative query to device execution:

  logical    dataclass plan IR + fluent builder (scan/filter/join/...)
  stats      table statistics & cardinality estimation (distinct sketches,
             match-ratio and zipf estimates from device-side samples) —
             synthesizes the `JoinStats` the planner consumes
  physical   optimizer: greedy join ordering on estimated cardinalities,
             Fig. 18 algorithm/pattern selection + §5.4 cost model per
             join, group-by strategy choice, static capacity propagation;
             `explain()` renders choices + predicted cost
  executor   jit-compatible interpreter running the physical plan over
             `Table`s

Typical use::

    from repro.engine import Catalog, scan, optimize

    cat = Catalog({"fact": fact, "dim0": dim0, "dim1": dim1})
    q = (scan("fact")
         .join(scan("dim0"), left_key="fk0", right_key="k0")
         .join(scan("dim1"), left_key="fk1", right_key="k1")
         .group_by("fk0", payload="sum"))
    plan = optimize(q, cat)          # engine-estimated stats, no JoinStats
    print(plan.explain())            # per-op algorithm/pattern + cost
    result, count = plan.run()       # executes under jax.jit
"""
from .executor import execute, plan_peak_bytes, run, run_morsels
from .logical import Filter, GroupBy, Join, OrderByLimit, Plan, Project, Scan, output_columns, scan
from .membudget import MemoryBudget, MemoryBudgetExceeded, detect_budget_bytes, is_memory_error
from .physical import Optimizer, PhysicalPlan, calibrated_profile, morsel_axis, morsel_plan, optimize
from .stats import (Catalog, ColumnStats, TableStats, collect_table_stats, estimate_distinct,
                    estimate_match_ratio, estimate_selectivity, estimate_zipf,
                    synthesize_join_stats)

__all__ = [
    "Plan", "Scan", "Filter", "Project", "Join", "GroupBy", "OrderByLimit",
    "scan", "output_columns",
    "Catalog", "ColumnStats", "TableStats", "collect_table_stats",
    "estimate_distinct", "estimate_match_ratio", "estimate_zipf",
    "estimate_selectivity", "synthesize_join_stats",
    "Optimizer", "PhysicalPlan", "optimize", "calibrated_profile",
    "morsel_axis", "morsel_plan",
    "execute", "run", "run_morsels", "plan_peak_bytes",
    "MemoryBudget", "MemoryBudgetExceeded", "detect_budget_bytes",
    "is_memory_error",
]

"""Physical planner: logical plan + estimated statistics -> executable plan.

The optimizer closes the loop the paper leaves to "the query optimizer":

  * **Join ordering** — maximal Join subtrees are flattened into a join
    graph and re-ordered greedily on estimated output cardinality (smallest
    intermediate first), emitting a left-deep tree.
  * **Build-side selection** — the side whose key is *provably* unique
    (exact base-column check + no upstream fan-out, see `_key_is_unique`)
    becomes the build/PK side; if neither side qualifies the join runs in
    m:n mode, which is correct for any multiplicity.
  * **Algorithm + pattern per join** — the paper's Fig. 18 decision tree
    (`core.planner.choose_algorithm`) over a `JoinStats` synthesized from
    the statistics layer (no hand-written descriptors), with the §5.4
    primitive-profile cost model pricing each phase.
  * **Group-by strategy** — `core.groupby.choose_groupby_strategy` on
    estimated group cardinality, key-domain density, and skew.
  * **Capacity propagation** — every operator gets a static output
    capacity (estimate x safety margin, rounded up) so the executor stays
    jit-compatible end to end.

`PhysicalPlan.explain()` renders the tree with per-operator choice,
estimated rows, capacity, and predicted cost; `PhysicalPlan.run()` hands
the plan to `engine.executor`.

The cost model profile is **calibrated by default** from timed device
microbenchmarks (`PrimitiveProfile.measure()`, cached per process), with
the hard-coded v5e constants as fallback if measurement fails.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

import numpy as np

from repro.core.groupby import PARTITION_ROW_BLOCK, choose_groupby_strategy
from repro.core.hash_join import BUILD_BLOCK
from repro.core.planner import (JoinStats, PrimitiveProfile, choose_algorithm, choose_smj_pattern,
                                predict_groupby_time, predict_groupjoin_time, predict_join_time)

from . import logical as L
from . import stats as S

# in-process profile cache, keyed by (backend fingerprint, calibration n):
# a later call with a different n must re-measure, not silently reuse the
# first profile (pass structure is n-independent but measured bandwidths
# are not, and tests calibrate at several sizes)
_PROFILE_CACHE: dict = {}


def calibrated_profile(n: int = 1 << 16) -> PrimitiveProfile:
    """Measured primitive profile, cached per (backend, n) in-process AND
    persisted across processes in the calibration store (CALIBRATION.json,
    keyed by backend fingerprint — repro.obs.calibration): the second
    process on the same backend loads the stored constants instead of
    re-running the microbenchmarks. Falls back to the built-in v5e
    constants when the microbenchmarks cannot run (never persisted — a
    fallback must not masquerade as a measurement)."""
    from repro.obs import calibration as cal

    try:
        fp = cal.backend_fingerprint()
    except Exception:  # noqa: BLE001 — no backend at all
        fp = "unknown"
    key = (fp, n)
    if key in _PROFILE_CACHE:
        return _PROFILE_CACHE[key]
    store = None
    try:
        store = cal.CalibrationStore()
        prof = store.get_profile(fp, n)
    except (ValueError, OSError):  # bad REPRO_CALIBRATION_PATH etc.
        prof = None
    if prof is None:
        try:
            prof = PrimitiveProfile.measure(n=n)
            if store is not None:
                try:
                    store.put_profile(fp, n, prof)
                    store.save()
                except OSError:
                    pass  # read-only checkout: calibration stays in-process
        except Exception:  # noqa: BLE001 — any device/timer failure
            return _PROFILE_CACHE.setdefault(key, PrimitiveProfile())
    return _PROFILE_CACHE.setdefault(key, prof)


def _round_capacity(est: float, safety: float, lo: int = 64,
                    hi: int | None = None) -> int:
    cap = max(int(math.ceil(est * safety)), lo)
    cap = -(-cap // 64) * 64  # multiple of 64 keeps shapes lane-friendly
    if hi is not None:
        cap = min(cap, max(hi, lo))
    return cap


class LazyStats:
    """Lazy column-stats mapping: resolves a column to `stats.ColumnStats`
    on first access and caches it. Keeps wide tables cheap — only columns a
    plan consults (keys, filter columns) ever get sketched."""

    def __init__(self, resolve, columns):
        self._resolve = resolve
        self._cols = frozenset(columns)
        self._cache = {}

    def get(self, col, default=None):
        if col not in self._cols:
            return default
        if col not in self._cache:
            self._cache[col] = self._resolve(col)
        return self._cache[col] if self._cache[col] is not None else default

    def __contains__(self, col):
        return self.get(col) is not None

    def __getitem__(self, col):
        v = self.get(col)
        if v is None:
            raise KeyError(col)
        return v


# ---------------------------------------------------------------------------
# Physical nodes
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PhysNode:
    est_rows: float
    capacity: int
    cost: float  # predicted seconds for this operator alone
    columns: tuple[str, ...]
    col_stats: dict  # column -> stats.ColumnStats (propagated estimates)
    origins: dict  # column -> (base_table, base_column) | None
    # uniqueness bookkeeping for sound pk_fk classification:
    #   may_repeat   — columns whose rows may have been duplicated by an
    #                  upstream join fan-out (base uniqueness no longer holds)
    #   known_unique — columns distinct-valued by construction (group keys)
    may_repeat: frozenset = frozenset()
    known_unique: frozenset = frozenset()

    def children(self) -> tuple["PhysNode", ...]:
        return ()

    def describe(self) -> str:
        raise NotImplementedError


@dataclasses.dataclass
class PScan(PhysNode):
    table: str = ""

    def describe(self):
        return f"Scan[{self.table}] rows={int(self.est_rows)}"


@dataclasses.dataclass
class PFilter(PhysNode):
    child: PhysNode = None
    column: str = ""
    op: str = "=="
    value: float = 0.0
    selectivity: float = 1.0

    def children(self):
        return (self.child,)

    def describe(self):
        return (f"Filter[{self.column} {self.op} {self.value}] "
                f"sel~{self.selectivity:.2f} est~{int(self.est_rows)} "
                f"cap={self.capacity} cost={self.cost*1e6:.0f}us")


@dataclasses.dataclass
class PProject(PhysNode):
    child: PhysNode = None

    def children(self):
        return (self.child,)

    def describe(self):
        return f"Project[{', '.join(self.columns)}]"


@dataclasses.dataclass
class PJoin(PhysNode):
    build: PhysNode = None
    probe: PhysNode = None
    build_key: str = ""
    probe_key: str = ""
    out_key: str = ""
    mode: str = "pk_fk"
    algorithm: str = "phj"
    pattern: str = "gftr"
    rationale: str = ""
    join_stats: JoinStats | None = None
    phase_times: dict | None = None

    def children(self):
        return (self.build, self.probe)

    def describe(self):
        tag = f"{self.algorithm.upper()}-{'OM' if self.pattern == 'gftr' else 'UM'}"
        return (f"Join[{tag} {self.mode}] key={self.out_key} "
                f"mr~{self.join_stats.match_ratio:.2f} est~{int(self.est_rows)} "
                f"cap={self.capacity} cost={self.cost*1e6:.0f}us "
                f"why: {self.rationale}")


@dataclasses.dataclass
class PGroupBy(PhysNode):
    child: PhysNode = None
    key: str = ""
    aggs: tuple = ()
    strategy: str = "sort"
    agg_kw: tuple = ()  # extra group_aggregate kwargs (multiplicity-scaled block)
    rationale: str = ""
    regret: str = ""  # residual-store regret flag (obs.residuals), "" if none

    def children(self):
        return (self.child,)

    def describe(self):
        a = ", ".join(f"{op}({c})" for c, op in self.aggs)
        flag = f" {self.regret}" if self.regret else ""
        return (f"GroupBy[{self.strategy}] key={self.key} aggs=({a}) "
                f"groups~{int(self.est_rows)} cap={self.capacity} "
                f"cost={self.cost*1e6:.0f}us why: {self.rationale}{flag}")


@dataclasses.dataclass
class PGroupJoin(PhysNode):
    """Fused join + grouped aggregation (core.groupjoin.phj_groupjoin):
    the probe feeds a group-keyed accumulator directly, the joined row is
    never materialized. Emitted by the fusion pass when a GroupBy sits on a
    provably pk_fk join, the group key and every aggregate input survive
    the join, and the cost model prices the fusion below the unfused
    join + group-by pair. Capacity is the GROUP-domain estimate (like
    PGroupBy), never the join-output capacity."""
    build: PhysNode = None
    probe: PhysNode = None
    build_key: str = ""
    probe_key: str = ""
    group_key: str = ""  # output column name (the logical GroupBy key)
    probe_group_key: str = ""  # probe-side column actually grouped on
    aggs: tuple = ()
    agg_strategy: str = "sort"
    agg_kw: tuple = ()  # extra accumulator kwargs (multiplicity-scaled block)
    rationale: str = ""
    regret: str = ""  # residual-store regret flag (obs.residuals), "" if none
    join_stats: JoinStats | None = None
    phase_times: dict | None = None

    def children(self):
        return (self.build, self.probe)

    def describe(self):
        a = ", ".join(f"{op}({c})" for c, op in self.aggs)
        flag = f" {self.regret}" if self.regret else ""
        return (f"GroupJoin[phj+{self.agg_strategy} pk_fk] "
                f"key={self.group_key} aggs=({a}) "
                f"groups~{int(self.est_rows)} cap={self.capacity} "
                f"cost={self.cost*1e6:.0f}us why: {self.rationale}{flag}")


@dataclasses.dataclass
class POrderByLimit(PhysNode):
    child: PhysNode = None
    key: str = ""
    limit: int = 0
    descending: bool = False

    def children(self):
        return (self.child,)

    def describe(self):
        d = "desc" if self.descending else "asc"
        return (f"OrderByLimit[{self.key} {d} limit={self.limit}] "
                f"cost={self.cost*1e6:.0f}us")


@dataclasses.dataclass
class PhysicalPlan:
    root: PhysNode
    catalog: "S.Catalog"
    total_cost: float
    compiled: object = dataclasses.field(default=None, repr=False, compare=False)
    # count-parameterized executable for the serving layer's capacity
    # bucketing (DESIGN.md §14): same plan, but scan valid-counts arrive as
    # traced int32 scalars so one compilation serves any dataset padded to
    # this plan's capacity buckets. Cached separately so the count-free
    # `compiled` artifact (and its jaxpr, pinned by tests/test_obs.py)
    # never changes shape.
    compiled_bucketed: object = dataclasses.field(
        default=None, repr=False, compare=False)
    # "" normally; "DEGRADED[reason]" when executor.run re-planned this plan
    # after an escalation exhaustion / kernel failure (DESIGN.md §13)
    degraded: str = ""
    # the one-shot degraded re-plan, cached so repeated run() calls reuse
    # its compiled executable instead of re-degrading
    degraded_plan: "PhysicalPlan | None" = dataclasses.field(
        default=None, repr=False, compare=False)
    # Memory governor (DESIGN.md §15): factor > 1 routes executor.run
    # through the morsel-driven out-of-core driver — the probe/input side
    # splits into `morsel_factor` power-of-two chunks, each run through
    # ONE compiled bucketed executable, recombined host-side. Set by the
    # memory rung of degrade_plan and by the serving layer's byte-budget
    # admission; 1 = whole-plan execution.
    morsel_factor: int = 1
    # factor -> capacity-scaled per-morsel clone (see morsel_plan), cached
    # so every morsel of every request reuses one compiled executable
    morsel_plans: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def explain(self, verify: bool = False, tables: Mapping | None = None,
                actuals=None) -> str:
        """Render the plan tree. With `verify=True`, trace every subtree,
        print each node's priced contract next to its compiled primitive
        budget (DESIGN.md §11), and raise the first
        `analysis.ContractViolation` if any compiled budget diverges from
        what the cost model priced — the rendered plan rides along in the
        exception message.

        With `actuals=` (a `repro.obs.QueryTrace` from running THIS plan
        traced), annotate every plan line with the node's predicted vs
        measured time and the measured/modeled residual, flagging >2x
        divergences — the measured side of priced-vs-compiled (§12)."""
        lines = [f"physical plan  predicted_total={self.total_cost*1e6:.0f}us"]
        if self.degraded:
            lines.append(f"  {self.degraded}")
        plan_audit = None
        if verify:
            from . import executor

            plan_audit = executor.audit(self, tables)
        by_node = plan_audit.by_node() if plan_audit else {}
        spans = actuals.by_path() if actuals is not None else {}

        def walk(node, prefix, is_last, label="", path=()):
            branch = "└─ " if is_last else "├─ "
            lab = f"{label}: " if label else ""
            lines.append(prefix + branch + lab + node.describe())
            ext = "   " if is_last else "│  "
            entry = by_node.get(id(node))
            if entry is not None:
                compiled = entry.own_budget.describe() or "none"
                status = "DIVERGED" if entry.violations else "ok"
                lines.append(
                    f"{prefix}{ext}     priced[{entry.contract.describe()}] "
                    f"compiled[{compiled}] "
                    f"peak-live={entry.report.peak_live_bytes/1024:.0f}KiB "
                    f"{status}")
            if isinstance(node, PJoin):
                # per-join memory ledger: the paper's §4.4 phase model
                # (core.memmodel, Tables 1-2) next to the jaxpr liveness
                # watermark when verify=True — the two cross-check each
                # other (model: GFTR peak <= GFUR peak at equal rows)
                from repro.core import memmodel

                n = max(node.build.capacity, node.probe.capacity)
                model = {p: memmodel.peak_memory_bytes(p, n, 4)
                         for p in ("gftr", "gfur")}
                mem = (f"{prefix}{ext}     mem: model["
                       f"gftr={model['gftr']/1024:.0f}KiB "
                       f"gfur={model['gfur']/1024:.0f}KiB] "
                       f"pattern={node.pattern}")
                if entry is not None:
                    mem += (f" audited-peak="
                            f"{entry.report.peak_live_bytes/1024:.0f}KiB")
                lines.append(mem)
            span = spans.get(path)
            if span is not None:
                if span.residual is not None:
                    res = f"residual[{span.residual:.2f}x]"
                    if span.residual >= 2.0 or span.residual <= 0.5:
                        res += " ** >2x DIVERGENCE **"
                else:
                    res = "residual[-]"
                lines.append(
                    f"{prefix}{ext}     predicted[{span.predicted_s*1e6:.0f}us] "
                    f"measured[{span.wall_s*1e6:.0f}us] {res}")
            kids = node.children()
            labels = (
                ("build", "probe") if isinstance(node, (PJoin, PGroupJoin))
                else ("",) * len(kids)
            )
            for i, (k, klab) in enumerate(zip(kids, labels)):
                walk(k, prefix + ext, i == len(kids) - 1, klab, path + (i,))

        walk(self.root, "", True)
        # escalation footer: ladder reports recorded while `actuals` ran
        # (trace_execute windows repro.resilience's report ring), so a plan
        # whose checked drivers escalated shows the attempt path next to
        # the measured times they cost
        for rep in getattr(actuals, "escalations", ()) or ():
            lines.append(f"  escalation: {rep.summary()}")
        rendered = "\n".join(lines)
        if plan_audit is not None and plan_audit.violations:
            first = plan_audit.violations[0]
            raise type(first)(f"{first}\n{rendered}")
        return rendered

    def run(self, tables: Mapping | None = None, *, jit: bool = True,
            trace: bool = False, trace_iters: int = 1,
            trace_warmup: int = 1, counts=None):
        """Execute over `tables` (default: the catalog's). Returns
        (Table, valid_count) — or (Table, valid_count, QueryTrace) with
        ``trace=True`` (per-node spans, see repro.obs.trace). `counts`
        ({table: valid_count}) activates the bucketed executable — see
        executor.run."""
        from . import executor

        return executor.run(self, tables, jit=jit, trace=trace,
                            trace_iters=trace_iters,
                            trace_warmup=trace_warmup, counts=counts)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
class Optimizer:
    def __init__(self, catalog: "S.Catalog", *, profile: PrimitiveProfile | None = None,
                 safety: float = 1.5, measure_profile: bool = True,
                 force_join: tuple[str, str] | None = None,
                 residuals=None):
        self.catalog = catalog
        self.profile = profile or (
            calibrated_profile() if measure_profile else PrimitiveProfile()
        )
        self.safety = safety
        self.force_join = force_join
        # measured/modeled residual feedback (obs.residuals.ResidualStore);
        # None -> lazily load this backend's store from CALIBRATION.json.
        # Advisory only: residuals annotate plans with a regret flag when
        # last run's measurements say the predicted winner lost by >2x —
        # they never flip a choice (the stored ratios may come from
        # different shapes than this query's).
        self._residuals = residuals

    def _residual_store(self):
        if self._residuals is None:
            try:
                from repro.obs.calibration import load_residuals

                self._residuals = load_residuals()
            except Exception:  # noqa: BLE001 — obs must never break planning
                from repro.obs.residuals import ResidualStore

                self._residuals = ResidualStore()
        return self._residuals

    def _regret(self, op: str, chosen: str, chosen_cost: float,
                alternatives: dict) -> str:
        """Regret flag for a strategy choice: replay it with each
        candidate's predicted time scaled by the residual store's
        measured/modeled EWMA (obs.residuals.regret_check)."""
        try:
            from repro.obs.residuals import regret_check

            choices = dict(alternatives)
            choices[chosen] = chosen_cost
            return regret_check(self._residual_store(), op, choices, chosen)
        except Exception:  # noqa: BLE001 — obs must never break planning
            return ""

    # -- entry --------------------------------------------------------------
    def optimize(self, plan: L.Plan) -> PhysicalPlan:
        # validate the whole tree up front (raises on bad references)
        L.output_columns(plan, self.catalog.schemas())
        root = self._build(plan)
        total = self._sum_cost(root)
        return PhysicalPlan(root=root, catalog=self.catalog, total_cost=total)

    def _sum_cost(self, node: PhysNode) -> float:
        return node.cost + sum(self._sum_cost(c) for c in node.children())

    # -- per-node construction ----------------------------------------------
    def _build(self, node: L.Plan) -> PhysNode:
        if isinstance(node, L.Scan):
            return self._scan(node)
        if isinstance(node, L.Filter):
            return self._filter(node)
        if isinstance(node, L.Project):
            return self._project(node)
        if isinstance(node, L.Join):
            return self._join_tree(node)
        if isinstance(node, L.GroupBy):
            return self._group_by(node)
        if isinstance(node, L.OrderByLimit):
            return self._order_by(node)
        raise TypeError(f"unknown plan node {type(node).__name__}")

    def _scan(self, node: L.Scan) -> PScan:
        t = self.catalog.tables[node.table]
        name = node.table
        return PScan(
            est_rows=float(t.num_rows), capacity=t.num_rows, cost=0.0,
            columns=tuple(t.column_names),
            col_stats=LazyStats(lambda c: self.catalog.col_stats(name, c),
                                t.column_names),
            origins={c: (name, c) for c in t.column_names},
            table=name,
        )

    def _filter(self, node: L.Filter) -> PFilter:
        child = self._build(node.child)
        origin = child.origins.get(node.column)
        chain = self._scan_chain(child)
        if (origin is not None and chain is not None
                and chain[0] == origin[0]):
            # Scan->Filter* chain: size from the JOINT selectivity of the
            # whole chain on one aligned base sample — independent
            # per-predicate estimates multiply correlated predicates into
            # an underestimate that would truncate survivors.
            preds = chain[1] + ((node.column, node.op, node.value),)
            joint = self.catalog.selectivity(chain[0], preds)
            base_rows = float(self.catalog.tables[chain[0]].num_rows)
            est = base_rows * joint
            sel = est / max(child.est_rows, 1.0)
            cap = _round_capacity(est, self.safety, hi=child.capacity)
        else:
            # The child reshaped the row distribution (join/group-by) or
            # the column is derived: a base-table sample is wrong-weighted
            # (e.g. groups vs rows under skew), so it may guide cost and
            # ordering but must NOT shrink the capacity — compact would
            # silently drop survivors beyond it.
            if origin is not None:
                col = self.catalog.tables[origin[0]][origin[1]]
                sel = S.estimate_selectivity(col, node.op, node.value)
            else:
                sel = 0.33
            est = child.est_rows * sel
            cap = child.capacity
        # one streaming pass over all columns (mask + compact)
        nbytes = child.capacity * 4 * max(len(child.columns), 1)
        cost = 2 * nbytes / self.profile.seq_bw
        return PFilter(
            est_rows=est, capacity=cap, cost=cost, columns=child.columns,
            col_stats=child.col_stats, origins=child.origins,
            may_repeat=child.may_repeat, known_unique=child.known_unique,
            child=child, column=node.column, op=node.op, value=node.value,
            selectivity=sel,
        )

    def _project(self, node: L.Project) -> PProject:
        child = self._build(node.child)
        cols = frozenset(node.columns)
        return PProject(
            est_rows=child.est_rows, capacity=child.capacity, cost=0.0,
            columns=tuple(node.columns),
            col_stats=LazyStats(child.col_stats.get, node.columns),
            origins={c: child.origins.get(c) for c in node.columns},
            may_repeat=child.may_repeat & cols,
            known_unique=child.known_unique & cols,
            child=child,
        )

    # -- joins: flatten, greedy-order, pick algorithms ----------------------
    def _join_tree(self, node: L.Join) -> PhysNode:
        rels, edges = self._flatten(node)
        phys = [self._build(r) for r in rels]
        if not edges:
            return phys[0]
        # greedy: cheapest edge first, then cheapest extension of the
        # connected intermediate
        est_cache = {}

        def edge_est(i, cur, j, e):
            key = (i, id(cur), j)
            if key not in est_cache:
                est_cache[key] = self._estimate_join(cur, phys[j], e)
            return est_cache[key]

        remaining = list(range(len(edges)))
        # seed: globally cheapest edge (the chosen edge's oriented spec is
        # reused by _make_join rather than recomputed)
        seeds = {ei: self._estimate_join(phys[edges[ei][0]],
                                         phys[edges[ei][1]], edges[ei])
                 for ei in remaining}
        seed = min(remaining, key=lambda ei: seeds[ei][0])
        li, ri, lk, rk, mode = edges[seed]
        cur = self._make_join(spec=seeds[seed][1])
        joined = {li, ri}
        remaining.remove(seed)
        while remaining:
            best, best_est = None, None
            for ei in remaining:
                li, ri, lk, rk, mode = edges[ei]
                if li in joined:
                    est = edge_est(ei, cur, ri, (li, ri, lk, rk, mode))
                elif ri in joined:
                    est = edge_est(ei, cur, li, (li, ri, lk, rk, mode))
                else:
                    continue
                if best_est is None or est[0] < best_est[0]:
                    best, best_est = ei, est
            if best is None:  # cannot happen: a Join tree's edge set is connected
                raise ValueError("disconnected join graph")
            li, ri = edges[best][0], edges[best][1]
            remaining.remove(best)
            cur = self._make_join(spec=best_est[1])
            joined.add(ri if li in joined else li)
        return cur

    def _flatten(self, node: L.Plan):
        """Maximal Join subtree -> (leaf relations, edges). Edge =
        (left_rel_idx, right_rel_idx, left_key, right_key, mode)."""
        schemas = self.catalog.schemas()
        if not isinstance(node, L.Join):
            return [node], []
        lrels, ledges = self._flatten(node.left)
        rrels, redges = self._flatten(node.right)
        off = len(lrels)
        edges = ledges + [(a + off, b + off, lk, rk, m)
                          for a, b, lk, rk, m in redges]
        rels = lrels + rrels

        def owner(rel_list, base, key):
            for i, r in enumerate(rel_list):
                if key in L.output_columns(r, schemas):
                    return base + i
            raise KeyError(f"join key {key!r} not found in any input relation")

        li = owner(lrels, 0, node.left_key)
        ri = owner(rrels, off, node.right_key)
        edges.append((li, ri, node.left_key, node.right_key, node.mode))
        return rels, edges

    def _estimate_join(self, a: PhysNode, b: PhysNode, edge):
        """(estimated output rows, oriented spec) for joining phys nodes a
        (carrying edge key ka) and b (carrying kb)."""
        li, ri, lk, rk, mode = edge
        ka = lk if lk in a.columns else rk
        kb = rk if rk in b.columns else lk
        spec = self._orient(a, ka, b, kb, mode)
        return spec["est"], spec

    def _key_is_unique(self, node: PhysNode, col: str) -> bool:
        """PROOF, not estimate, that `col` is distinct-valued in `node`:
        either unique by construction (group key), or its base column is
        exactly unique (Catalog.is_unique) and no upstream join fan-out
        duplicated the rows carrying it. A sketch-based guess here would
        silently drop duplicate matches through the pk_fk path."""
        if col in node.known_unique:
            return True
        if col in node.may_repeat:
            return False
        origin = node.origins.get(col)
        return origin is not None and self.catalog.is_unique(*origin)

    def _scan_chain(self, node: PhysNode):
        """If `node` is a pure Scan -> Filter*/Project* chain over one base
        table (no row duplication or truncation), return (table, predicate
        chain) so estimators can push the predicates into base-row samples;
        else None."""
        preds = []
        cur = node
        while True:
            if isinstance(cur, PScan):
                return cur.table, tuple(preds)
            if isinstance(cur, PFilter):
                preds.append((cur.column, cur.op, cur.value))
                cur = cur.child
            elif isinstance(cur, PProject):
                cur = cur.child
            else:
                return None

    def _orient(self, a: PhysNode, ka: str, b: PhysNode, kb: str, mode: str):
        """Decide build vs probe side + estimate match ratio / output."""
        a_u, b_u = self._key_is_unique(a, ka), self._key_is_unique(b, kb)
        if mode == "pk_fk" and not (a_u or b_u):
            raise ValueError(
                f"join forced to pk_fk but neither key column ({ka!r}, {kb!r}) "
                "is provably unique")
        if mode == "mn" or not (a_u or b_u):
            mode_r = "mn"
            build, bk, probe, pk = ((a, ka, b, kb)
                                    if a.est_rows <= b.est_rows
                                    else (b, kb, a, ka))
        else:
            mode_r = "pk_fk"
            if a_u and b_u:
                build, bk, probe, pk = ((a, ka, b, kb)
                                        if a.est_rows <= b.est_rows
                                        else (b, kb, a, ka))
            elif a_u:
                build, bk, probe, pk = a, ka, b, kb
            else:
                build, bk, probe, pk = b, kb, a, ka

        o_b, o_p = build.origins.get(bk), probe.origins.get(pk)
        if o_b is not None and o_p is not None:
            # Push the probe side's filter chain into the sample when it is
            # a plain Scan->Filter* chain: a predicate correlated with match
            # likelihood then yields the POST-filter match ratio instead of
            # base-mr x selectivity (which double-counts the restriction
            # and under-sizes the output).
            chain = self._scan_chain(probe)
            preds = chain[1] if chain is not None and chain[0] == o_p[0] else ()
            mr = self.catalog.match_ratio(o_b, o_p, preds)
            # A filtered build side can only LOSE keys, so the unscaled mr
            # is an upper bound — safe for capacity, slightly conservative
            # for ordering. (Scaling by row retention is wrong for GroupBy
            # builds; scaling distinct by selectivity is wrong for
            # duplicated keys — both under-size the output.)
        else:
            mr = 0.8  # derived key columns: assume mostly-matching
        mr = min(max(mr, 0.0), 1.0)
        p_stats = probe.col_stats.get(pk)
        zipf = p_stats.zipf if p_stats is not None else 0.0
        if mode_r == "pk_fk":
            est = probe.est_rows * mr
        else:
            # m:n sizing must be an upper bound, or the static capacity
            # silently truncates. Three regimes per side:
            #   Scan->Filter* chain  -> exact masked count is computable
            #   anything else        -> the side may have been fanned out,
            #                           so base-table counts UNDERcount;
            #                           bound via the other side's exact
            #                           max multiplicity, or fully
            #                           pessimistically when neither is
            #                           provable.
            def side_chain(n, origin):
                ch = self._scan_chain(n)
                ok = (ch is not None and origin is not None
                      and ch[0] == origin[0])
                return ch[1] if ok else None

            b_preds = side_chain(build, o_b)
            p_preds = side_chain(probe, o_p)
            if b_preds is not None and p_preds is not None:
                est = self.catalog.mn_output_rows(o_b, o_p, b_preds, p_preds)
            elif b_preds is not None:
                est = probe.est_rows * self.catalog.max_multiplicity(o_b, b_preds)
            elif p_preds is not None:
                est = build.est_rows * self.catalog.max_multiplicity(o_p, p_preds)
            else:
                est = build.est_rows * probe.est_rows  # worst case
        return dict(build=build, build_key=bk, probe=probe, probe_key=pk,
                    mode=mode_r, match_ratio=mr, zipf=zipf, est=est)

    def _make_join(self, a: PhysNode = None, b: PhysNode = None,
                   lk: str = None, rk: str = None, mode: str = "auto",
                   spec: dict | None = None) -> PJoin:
        if spec is None:
            ka = lk if lk in a.columns else rk
            kb = rk if rk in b.columns else lk
            spec = self._orient(a, ka, b, kb, mode)
        build, probe = spec["build"], spec["probe"]
        bk, pk = spec["build_key"], spec["probe_key"]
        jstats = S.synthesize_join_stats(
            n_build=max(int(build.est_rows), 1),
            n_probe=max(int(probe.est_rows), 1),
            build_payload_cols=len(build.columns) - 1,
            probe_payload_cols=len(probe.columns) - 1,
            match_ratio=spec["match_ratio"],
            zipf=spec["zipf"],
            key_dtype=self._dtype_of(build, bk),
            payload_dtypes=[self._dtype_of(n, c)
                            for n in (build, probe)
                            for c in n.columns if c not in (bk, pk)],
        )
        if self.force_join is not None:
            alg, pattern = self.force_join
            rationale = "forced baseline"
        else:
            alg, pattern, rationale = choose_algorithm(jstats)
            if spec["mode"] == "mn" and alg == "phj":
                # PHJ pads each build co-partition to BUILD_BLOCK rows, and
                # duplicates of one key co-hash no matter the fan-out: a
                # heavier per-key multiplicity overflows the block and
                # silently drops matches. Merge join has no such bound.
                chain = self._scan_chain(build)
                o_bk = build.origins.get(bk)
                if (chain is not None and o_bk is not None
                        and chain[0] == o_bk[0]):
                    mult = self.catalog.max_multiplicity(o_bk, chain[1])
                else:
                    mult = float("inf")  # not provable: be safe
                if mult > BUILD_BLOCK:
                    alg = "smj"
                    pattern, _ = choose_smj_pattern(jstats)
                    rationale = (
                        f"m:n build multiplicity {mult:.0f} exceeds PHJ's "
                        f"{BUILD_BLOCK}-row co-partition block -> SMJ")
        phases = predict_join_time(jstats, alg, pattern, self.profile)
        est = spec["est"]
        hi = probe.capacity if spec["mode"] == "pk_fk" else None
        cap = _round_capacity(est, self.safety, hi=hi)
        # Output schema: probe-side key name carries the join key; the
        # build-side key name stays as an equal-valued alias (see
        # logical.output_columns). Payload names must be disjoint.
        out_key = pk
        shared = set(build.columns) & set(probe.columns)
        allowed = {bk} if bk == pk else set()
        if shared - allowed:
            raise ValueError(f"join column name collision: {sorted(shared - allowed)}")
        columns = tuple(probe.columns) + tuple(
            c for c in build.columns if c not in shared
        )
        origins = {}
        for side in (build, probe):
            for c in side.columns:
                origins[c] = side.origins.get(c)
        # BOTH key columns now carry the probe-surviving key values, so both
        # must trace to the probe's base column — leaving the alias pointed
        # at the (unique) build base column would let a later join "prove"
        # the duplicated values unique and drop matches via pk_fk.
        origins[out_key] = probe.origins.get(pk)
        origins[bk] = probe.origins.get(pk)

        # both key columns now hold the matched (probe-surviving) key values
        def _resolve(c, _b=build, _p=probe, _bk=bk, _pk=pk):
            if c in (_pk, _bk):
                ks = _p.col_stats.get(_pk)
                return ks if ks is not None else _b.col_stats.get(_bk)
            if c in _b.columns:
                return _b.col_stats.get(c)
            return _p.col_stats.get(c)

        col_stats = LazyStats(_resolve, columns)
        # uniqueness propagation: pk_fk emits <= 1 row per probe row, so
        # probe-side columns keep their uniqueness; build rows can fan out.
        # The build-key alias carries the probe key's values/multiplicity.
        if spec["mode"] == "pk_fk":
            may_repeat = (probe.may_repeat
                          | (frozenset(build.columns) - {bk}))
            known_unique = probe.known_unique & frozenset(probe.columns)
            if pk in probe.known_unique:
                known_unique |= {bk}
            elif pk in probe.may_repeat:
                may_repeat |= {bk}
        else:
            may_repeat = frozenset(columns)
            known_unique = frozenset()
        return PJoin(
            est_rows=est, capacity=cap, cost=phases["total"], columns=columns,
            col_stats=col_stats, origins=origins,
            may_repeat=may_repeat, known_unique=known_unique,
            build=build, probe=probe, build_key=bk, probe_key=pk,
            out_key=out_key, mode=spec["mode"], algorithm=alg, pattern=pattern,
            rationale=rationale, join_stats=jstats, phase_times=phases,
        )

    def _dtype_of(self, node: PhysNode, col: str):
        origin = node.origins.get(col)
        if origin is not None:
            return self.catalog.tables[origin[0]][origin[1]].dtype
        return "int32"

    # -- group-by / order-by ------------------------------------------------
    def _groupby_choice(self, src: PhysNode, key: str):
        """Group-by strategy, PR-3 partition guard, and accumulator sizing
        over `src`'s rows/statistics — shared by PGroupBy and the fusion
        pass (which applies it to the join's PROBE side: masking unmatched
        rows only removes rows, so every proof below still holds there).

        Returns (strategy, rationale, est_groups, cap, ks, agg_kw) — agg_kw
        is a tuple of extra group_aggregate kwargs (the multiplicity-scaled
        partition block) the executor forwards verbatim."""
        ks = src.col_stats.get(key)
        est_groups = min(ks.distinct if ks else src.est_rows, src.est_rows)
        # scatter indexes the accumulator BY key value and partition radix-
        # buckets hashed key bits: only provably integer keys qualify
        # (int32-casting floats would merge groups). Base-table origin is the
        # primary proof; for derived keys the propagated ColumnStats carries
        # the sketched dtype kind.
        origin = src.origins.get(key)
        integer_key = (origin is not None and np.issubdtype(
            np.dtype(self.catalog.tables[origin[0]][origin[1]].dtype),
            np.integer)) or (origin is None and ks is not None and ks.integer)
        strategy, rationale = choose_groupby_strategy(
            int(src.est_rows), est_groups,
            key_min=ks.min if ks else None,
            key_max=ks.max if ks else None,
            zipf=ks.zipf if ks else 0.0,
            integer_key=integer_key,
        )
        if strategy == "partition":
            # The executor runs the plain (jit-safe) partition path, which
            # silently drops a partition's overhang past its padded block —
            # and a single key's rows co-hash no matter the fan-out. Sampled
            # zipf/distinct sketches can miss one heavy key, so demand the
            # same PROOF the m:n join guard uses: an exact max-multiplicity
            # bound from the base table. Not provable (derived/fanned-out
            # key) or too heavy -> fall back to the always-exact sort.
            chain = self._scan_chain(src)
            if (chain is not None and origin is not None
                    and chain[0] == origin[0]):
                mult = self.catalog.max_multiplicity(origin, chain[1])
            else:
                mult = float("inf")
            # Bound: the layout targets E[partition rows] <= row_block/2,
            # and a key's duplicates co-hash, so multiplicity m inflates the
            # partition-size variance by m. The executor scales the block to
            # PARTITION_ROW_BLOCK * m (below), which keeps the overflow tail
            # at the m-clustered Poisson's 2x-mean point (~e^-0.386*block/2m,
            # vanishing for block/m >= 128) — but only a PROVEN bound makes
            # that sizing sound, and past 8 the padded slot space stops
            # paying for itself (matching the chooser's rows/groups < 8
            # routing threshold).
            if mult > PARTITION_ROW_BLOCK // 16:
                strategy = "sort"
                rationale = (
                    f"high cardinality, but max key multiplicity "
                    f"{'unprovable' if mult == float('inf') else f'{mult:.0f}'}"
                    f" exceeds the partition block's {PARTITION_ROW_BLOCK // 16}"
                    "-row safety bound -> exact sort")
        agg_kw = ()
        if strategy == "partition":
            # Scale the padded block with the PROVEN multiplicity: a key's m
            # duplicates land in one partition, so block/m must stay >= 128
            # for the overflow tail to vanish. The layout keeps
            # E[rows/partition] <= block/2 either way, so the slot space the
            # blocked passes stream over stays ~2-4x n regardless of m.
            m = 1 << max(int(mult) - 1, 0).bit_length()  # next pow2 >= mult
            if m > 1:
                agg_kw = (("row_block", PARTITION_ROW_BLOCK * m),)
        if strategy == "scatter":
            # scatter needs the accumulator to cover the dense domain
            cap = _round_capacity(float(ks.max) + 1, 1.0)
        else:
            cap = _round_capacity(est_groups, self.safety)
        return strategy, rationale, est_groups, cap, ks, agg_kw

    def _group_by(self, node: L.GroupBy) -> PGroupBy:
        child = self._build(node.child)
        strategy, rationale, est_groups, cap, ks, agg_kw = (
            self._groupby_choice(child, node.key))
        # price the geometry the executor will actually run — agg_kw carries
        # the multiplicity-scaled partition block
        cost = predict_groupby_time(child.capacity, len(node.aggs), strategy,
                                    self.profile,
                                    row_block=dict(agg_kw).get("row_block"))
        # Fusion pass: a GroupBy directly over a provably pk_fk join can
        # fold the aggregation into the probe (core.groupjoin) and skip the
        # join materialization round trip entirely. Price both plans; keep
        # whichever the cost model favors, and surface the decision either
        # way so explain() shows it.
        fused = self._try_fuse_group_join(node, child,
                                          unfused_cost=child.cost + cost)
        if fused is not None:
            if fused.cost < child.cost + cost:
                # regret check vs the rejected unfused plan, with BOTH
                # sides residual-corrected (the unfused side splits into
                # the join's and the accumulator's own stored ratios)
                try:
                    store = self._residual_store()
                    unfused_c = (
                        child.cost * store.correction(
                            "join", f"{child.algorithm}/{child.pattern}")
                        + cost * store.correction("groupby", strategy))
                except Exception:  # noqa: BLE001
                    unfused_c = child.cost + cost
                fused.regret = self._regret(
                    "groupjoin", f"phj+{fused.agg_strategy}", fused.cost,
                    {"join+groupby": unfused_c})
                return fused
            rationale += (
                f"; fusion rejected: GroupJoin {fused.cost*1e6:.0f}us >= "
                f"join+group-by {(child.cost + cost)*1e6:.0f}us")
        # regret flag: replay the strategy choice with residual-corrected
        # costs — flags (never flips) a chooser whose predicted winner
        # lost by >2x in this backend's residual store
        regret = self._regret(
            "groupby", strategy, cost,
            {s: predict_groupby_time(child.capacity, len(node.aggs), s,
                                     self.profile)
             for s in ("sort", "partition", "partition_hash")
             if s != strategy})
        col_stats = {node.key: ks} if ks else {}
        return PGroupBy(
            est_rows=min(est_groups, cap), capacity=cap, cost=cost,
            columns=(node.key,) + tuple(f"{c}_{op}" for c, op in node.aggs),
            col_stats=col_stats,
            origins={node.key: child.origins.get(node.key)},
            known_unique=frozenset({node.key}),  # one row per group
            child=child, key=node.key, aggs=tuple(node.aggs),
            strategy=strategy, agg_kw=agg_kw, rationale=rationale,
            regret=regret,
        )

    def _try_fuse_group_join(self, node: L.GroupBy, child: PhysNode,
                             unfused_cost: float) -> "PGroupJoin | None":
        """PGroupJoin candidate for GroupBy(Join(...)): the group key and
        every aggregate input must survive the join, and the join must be
        provably pk_fk (the fused probe takes one match per probe row; an
        m:n fan-out would silently drop aggregate contributions). Returns
        None when the pattern doesn't match; the CALLER prices the
        candidate against the unfused plan — `unfused_cost` only feeds the
        rationale string."""
        if self.force_join is not None or not isinstance(child, PJoin):
            return None
        if child.mode != "pk_fk" or child.algorithm != "phj":
            return None
        build, probe = child.build, child.probe
        bk, pk = child.build_key, child.probe_key
        # group key must be probe-side; the build-key alias carries the same
        # probe-surviving values, so it qualifies via the probe key. A probe
        # column SHADOWING the build-key name cannot reach here: the join
        # name-collision check (logical.output_columns / _make_join) rejects
        # that plan outright when bk != pk, and when bk == pk the two
        # branches below coincide.
        if node.key in probe.columns:
            probe_gk = node.key
        elif node.key == bk:
            probe_gk = pk
        else:
            return None
        # aggregate inputs survive on one side (the bk alias is excluded:
        # its values live on the probe side under a different name)
        for c, _ in node.aggs:
            if c not in probe.columns and (c not in build.columns or c == bk):
                return None

        # strategy + capacity from the shared chooser, applied to the PROBE
        # side: the accumulator is GROUP-domain sized (never join-output
        # sized), and the integer-key / PR-3 partition-multiplicity proofs
        # transfer unchanged — masking unmatched rows only removes rows
        strategy, _, est_groups, cap, ks, agg_kw = self._groupby_choice(
            probe, probe_gk)
        build_aggs = sum(1 for c, _ in node.aggs if c not in probe.columns)
        phases = predict_groupjoin_time(
            child.join_stats, len(node.aggs), strategy, self.profile,
            group_key_carried=(probe_gk == pk), build_aggs=build_aggs,
            agg_row_block=dict(agg_kw).get("row_block"))
        rationale = (
            f"fused: probe feeds the accumulator, join never materialized; "
            f"GroupJoin {phases['total']*1e6:.0f}us vs join+group-by "
            f"{unfused_cost*1e6:.0f}us")
        return PGroupJoin(
            est_rows=min(est_groups, cap), capacity=cap,
            cost=phases["total"],
            columns=(node.key,) + tuple(f"{c}_{op}" for c, op in node.aggs),
            col_stats={node.key: ks} if ks else {},
            origins={node.key: probe.origins.get(probe_gk)},
            known_unique=frozenset({node.key}),  # one row per group
            build=build, probe=probe, build_key=bk, probe_key=pk,
            group_key=node.key, probe_group_key=probe_gk,
            aggs=tuple(node.aggs), agg_strategy=strategy, agg_kw=agg_kw,
            rationale=rationale, join_stats=child.join_stats,
            phase_times=phases,
        )

    def _order_by(self, node: L.OrderByLimit) -> POrderByLimit:
        child = self._build(node.child)
        cap = min(node.limit, child.capacity)
        cost = self.profile.sort_cost(child.capacity, 4, 4 * len(child.columns))
        return POrderByLimit(
            est_rows=min(child.est_rows, node.limit), capacity=cap, cost=cost,
            columns=child.columns, col_stats=child.col_stats,
            origins=dict(child.origins), may_repeat=child.may_repeat,
            known_unique=child.known_unique, child=child, key=node.key,
            limit=node.limit, descending=node.descending,
        )


# ---------------------------------------------------------------------------
# morsel-driven out-of-core execution (DESIGN.md §15)
# ---------------------------------------------------------------------------
def _subtree_scans(node: PhysNode) -> list:
    """All scan table names in `node`'s subtree (with repeats)."""
    if isinstance(node, PScan):
        return [node.table]
    names: list = []
    for child in node.children():
        names += _subtree_scans(child)
    return names


def morsel_axis(root: PhysNode) -> str | None:
    """Name of the scan table the morsel driver may split, or None when the
    plan is not splittable.

    The axis is the PROBE spine's base scan: walking root -> probe/child,
    every probe row is independent (filters, projections, and joins against
    whole off-spine build sides commute with splitting the probe), so
    running the plan per probe-chunk and recombining is exact. Not
    splittable: a group-by/group-join anywhere but the root (its output
    feeds more plan — partials would leak upward), an order-by-limit
    (top-k is not a per-chunk concat), or an axis table that also appears
    on a build side (self-join: splitting one occurrence but not the other
    changes the result)."""
    off_spine: list = []
    node = root
    if isinstance(node, PGroupBy):
        node = node.child
    elif isinstance(node, PGroupJoin):
        off_spine += _subtree_scans(node.build)
        node = node.probe
    while True:
        if isinstance(node, (PGroupBy, PGroupJoin, POrderByLimit)):
            return None
        if isinstance(node, (PFilter, PProject)):
            node = node.child
        elif isinstance(node, PJoin):
            off_spine += _subtree_scans(node.build)
            node = node.probe
        elif isinstance(node, PScan):
            return None if node.table in off_spine else node.table
        else:
            return None


def morsel_rows(rows: int, factor: int) -> int:
    """Per-morsel axis rows for splitting `rows` into `factor` chunks:
    ceil-divided, lane-rounded, never below the 64-row floor."""
    m = -(-max(int(rows), 1) // int(factor))
    return max(-(-m // 64) * 64, 64)


def partial_agg_plan(node: PhysNode):
    """Partial-aggregate rewrite for running a root group node per-morsel:
    ``(partial_aggs, count_col)``.

    Each original aggregate maps to a recombinable partial (sum/count/
    min/max pass through; mean becomes a sum partial). `count_col` is the
    column whose ``<col>_count`` partial carries the per-group row count
    that mean finalization divides by — `count` is column-independent
    (it counts the group's rows), so any column free of a conflicting
    partial works; the group key is preferred. None when no mean
    aggregate. Raises ValueError when no conflict-free rewrite exists
    (the plan is then not morsel-splittable)."""
    if isinstance(node, PGroupBy):
        key, avail = node.key, tuple(node.child.columns)
    elif isinstance(node, PGroupJoin):
        # build_key is renamed to the probe key inside the fused driver, so
        # it cannot carry a partial; every other input column survives
        key = node.probe_group_key
        avail = tuple(node.probe.columns) + tuple(
            c for c in node.build.columns
            if c not in node.probe.columns and c != node.build_key)
    else:
        raise TypeError(f"not a group node: {type(node).__name__}")
    partial: dict = {}
    for c, op in node.aggs:
        pop = "sum" if op == "mean" else op
        if partial.get(c, pop) != pop:
            raise ValueError(
                f"column {c!r} needs both {partial[c]!r} and {pop!r} "
                "partials; plan is not morsel-splittable")
        partial[c] = pop
    count_col = None
    if any(op == "mean" for _, op in node.aggs):
        count_col = next((c for c, pop in partial.items() if pop == "count"),
                         None)
        if count_col is None:
            count_col = next(
                (c for c in (key,) + avail if c not in partial), None)
            if count_col is None:
                raise ValueError(
                    "no free column to carry the count partial for mean; "
                    "plan is not morsel-splittable")
            partial[count_col] = "count"
    return tuple(partial.items()), count_col


def morsel_plan(plan: PhysicalPlan, factor: int,
                rows: int | None = None) -> PhysicalPlan:
    """Per-morsel clone of `plan` for one chunk of ``morsel_rows(rows,
    factor)`` axis rows (rows defaults to the catalog's axis table).

    Spine capacities whose output is row-bounded by the chunk shrink to
    the chunk size — filters and pk_fk joins emit at most one row per
    probe row, so ``min(capacity, m)`` is exact; m:n joins and anything
    above them keep full capacity. A root group node's aggregates are
    rewritten to their recombinable partials (`partial_agg_plan`) with
    capacity UNCHANGED: scatter accumulators are domain-indexed and any
    morsel may see every group. Clones are cached on
    ``plan.morsel_plans`` keyed by (factor, m), so every morsel of every
    request reuses one compiled bucketed executable."""
    axis = morsel_axis(plan.root)
    if axis is None:
        raise ValueError("plan has no morsel axis (not splittable)")
    if rows is None:
        rows = plan.catalog.tables[axis].num_rows
    m = morsel_rows(rows, factor)
    key = (int(factor), m)
    cached = plan.morsel_plans.get(key)
    if cached is not None:
        return cached

    def clone(node: PhysNode):
        """(clone, bounded) — bounded: output rows <= m by construction
        (a row-nonincreasing chain from the axis scan)."""
        if isinstance(node, PScan):
            return node, node.table == axis
        if isinstance(node, PFilter):
            child, bounded = clone(node.child)
            changes = {"child": child} if child is not node.child else {}
            if bounded:
                changes["capacity"] = min(node.capacity, m)
            return (dataclasses.replace(node, **changes) if changes
                    else node), bounded
        if isinstance(node, PProject):
            child, bounded = clone(node.child)
            out = (dataclasses.replace(node, child=child)
                   if child is not node.child else node)
            return out, bounded
        if isinstance(node, PJoin):
            build, _ = clone(node.build)
            probe, p_bounded = clone(node.probe)
            bounded = p_bounded and node.mode == "pk_fk"
            changes = {}
            if build is not node.build:
                changes["build"] = build
            if probe is not node.probe:
                changes["probe"] = probe
            if bounded:
                changes["capacity"] = min(node.capacity, m)
            return (dataclasses.replace(node, **changes) if changes
                    else node), bounded
        if isinstance(node, (PGroupBy, PGroupJoin)):
            # only legal at the root (morsel_axis guarantees)
            partial, _ = partial_agg_plan(node)
            if isinstance(node, PGroupBy):
                child, _ = clone(node.child)
                cols = (node.key,) + tuple(f"{c}_{op}" for c, op in partial)
                return dataclasses.replace(
                    node, child=child, aggs=partial, columns=cols), False
            build, _ = clone(node.build)
            probe, _ = clone(node.probe)
            cols = (node.group_key,) + tuple(
                f"{c}_{op}" for c, op in partial)
            return dataclasses.replace(
                node, build=build, probe=probe, aggs=partial,
                columns=cols), False
        return node, False

    root, _ = clone(plan.root)
    mp = PhysicalPlan(root=root, catalog=plan.catalog,
                      total_cost=plan.total_cost / factor,
                      degraded=f"MORSEL[{factor}]")
    plan.morsel_plans[key] = mp
    return mp


# ---------------------------------------------------------------------------
# graceful degradation (DESIGN.md §13): the executor's one-shot re-plan
# ---------------------------------------------------------------------------
def degrade_plan(plan: PhysicalPlan, reason: str, *,
                 memory: bool = False) -> PhysicalPlan:
    """A conservative clone of `plan` for executor.run's single retry after
    an escalation exhaustion or operator failure: every data-bearing
    capacity doubles (lane-rounded — wrong estimates are the common failure
    mode), group-bys and fused group-joins fall to the always-exact 'sort'
    strategy, and PHJ joins fall to sort-merge (exact for any key
    multiplicity). The clone shares the catalog but never the compiled
    executable, and is annotated `DEGRADED[reason]` for explain().

    ``memory=True`` selects the MEMORY rung instead (DESIGN.md §15): an
    allocation failure must get a SMALLER working set, never the doubled
    capacities of the default rung. The clone shares the root and the
    morsel-plan cache and doubles ``morsel_factor`` (2 on first entry), so
    executor.run routes it through the morsel-driven out-of-core driver.
    Raises ValueError when the plan has no morsel axis — the caller must
    check `morsel_axis` first (an unsplittable plan's memory failure is
    terminal)."""
    if memory:
        if morsel_axis(plan.root) is None:
            raise ValueError("plan has no morsel axis (not splittable)")
        factor = max(plan.morsel_factor * 2, 2)
        return PhysicalPlan(
            root=plan.root, catalog=plan.catalog,
            total_cost=plan.total_cost,
            degraded=f"DEGRADED[{reason}] MORSEL[x{factor}]",
            morsel_factor=factor, morsel_plans=plan.morsel_plans)

    def clone(node: PhysNode) -> PhysNode:
        changes: dict = {}
        if isinstance(node, (PFilter, PProject, PGroupBy, POrderByLimit)):
            changes["child"] = clone(node.child)
        elif isinstance(node, (PJoin, PGroupJoin)):
            changes["build"] = clone(node.build)
            changes["probe"] = clone(node.probe)
        # OrderByLimit's capacity IS the limit (growing it would return
        # extra rows); Scan/Project capacities mirror their input
        if isinstance(node, (PFilter, PJoin, PGroupBy, PGroupJoin)):
            changes["capacity"] = -(-node.capacity * 2 // 64) * 64
        if isinstance(node, PGroupBy) and node.strategy != "sort":
            changes.update(strategy="sort", agg_kw=(),
                           rationale=node.rationale + "; degraded -> sort")
        if isinstance(node, PGroupJoin) and node.agg_strategy != "sort":
            changes.update(agg_strategy="sort", agg_kw=())
        if isinstance(node, PJoin) and node.algorithm == "phj":
            changes.update(algorithm="smj",
                           rationale=node.rationale + "; degraded -> smj")
        return dataclasses.replace(node, **changes) if changes else node

    return PhysicalPlan(root=clone(plan.root), catalog=plan.catalog,
                        total_cost=plan.total_cost,
                        degraded=f"DEGRADED[{reason}]")


def optimize(plan: L.Plan, catalog: "S.Catalog", *,
             profile: PrimitiveProfile | None = None, safety: float = 1.5,
             measure_profile: bool = True,
             force_join: tuple[str, str] | None = None,
             residuals=None) -> PhysicalPlan:
    """Optimize a logical plan against a catalog. See module docstring."""
    return Optimizer(catalog, profile=profile, safety=safety,
                     measure_profile=measure_profile, force_join=force_join,
                     residuals=residuals).optimize(plan)

"""Per-device byte budget and in-flight reservation ledger (DESIGN.md §15).

The paper's §4.4 point is that memory, not time, bounds the solvable
problem size on a GPU; this module is the governor that makes the serving
layer obey that bound. A `MemoryBudget` holds the device's byte budget —
backend-detected (`detect_budget_bytes`), overridable with
``REPRO_MEM_BUDGET_BYTES`` — plus a tagged reservation ledger for work in
flight. Admission control (serve/query.py) buys a *bytes ticket* next to
its seconds ticket: a plan's audited `peak_live_bytes` must fit
``budget - reserved`` before it may run, and the ledger guarantees the
sum of in-flight peaks never exceeds the budget.

The env override follows the repo's read-time-validation convention
(`REPRO_PALLAS_INTERPRET` in kernels/common.py): the variable is parsed
and validated on every `detect_budget_bytes()` call — never frozen at
import — and an unrecognized value raises ValueError naming what is
allowed.

`is_memory_error` is the classifier the executor and server share to
decide whether a failure should degrade onto the morsel rung
(physical.degrade_plan(memory=True)) instead of the capacity-doubling
rung: allocation failures get a SMALLER working set, not a bigger one.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_MEM_BUDGET_BYTES"

# Hosts whose backend reports no byte limit (CPU jax returns no
# memory_stats) get an effectively-unbounded budget: the governor must not
# change behavior where memory was never the constraint. Tests and the
# chaos harness force small budgets explicitly.
FALLBACK_BUDGET_BYTES = 64 << 30

# Substrings that mark a backend runtime error as an allocation failure.
_OOM_MARKERS = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory",
                "OOM", "Failed to allocate")


class MemoryBudgetExceeded(MemoryError):
    """A plan can NEVER fit the budget — not even at the smallest morsel
    factor (or it has no morsel axis at all). The typed rejection error:
    the server turns it into ``error="rejected"`` instead of crashing or
    retrying something that cannot succeed."""

    def __init__(self, need_bytes: int, budget_bytes: int, detail: str = ""):
        self.need_bytes = int(need_bytes)
        self.budget_bytes = int(budget_bytes)
        msg = (f"plan needs {self.need_bytes} bytes but the device budget "
               f"is {self.budget_bytes} bytes")
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


def is_memory_error(e: BaseException) -> bool:
    """True when `e` is an allocation failure: a MemoryError subclass
    (including the injected `faults.OOMInjected`) or a backend runtime
    error whose message carries an OOM marker (XLA raises
    RESOURCE_EXHAUSTED through XlaRuntimeError). Used to route failures
    onto the morsel rung instead of the capacity-doubling rung."""
    if isinstance(e, MemoryError):
        return True
    text = f"{type(e).__name__}: {e}"
    return any(marker in text for marker in _OOM_MARKERS)


def detect_budget_bytes() -> int:
    """This process's per-device byte budget.

    ``REPRO_MEM_BUDGET_BYTES`` (a positive integer, parsed and validated
    per call — the read-time convention) wins when set; otherwise the
    first local device's reported ``bytes_limit`` (TPU/GPU backends);
    otherwise FALLBACK_BUDGET_BYTES (CPU backends report no limit)."""
    env = os.environ.get(ENV_VAR)
    if env is not None:
        try:
            val = int(env.strip())
        except ValueError:
            val = -1
        if val <= 0:
            raise ValueError(
                f"{ENV_VAR}={env!r} is not a recognized value; allowed: a "
                "positive integer byte count (e.g. 1073741824 for 1 GiB)")
        return val
    try:
        import jax

        stats = jax.local_devices()[0].memory_stats()
        if stats and stats.get("bytes_limit", 0) > 0:
            return int(stats["bytes_limit"])
    except Exception:  # noqa: BLE001 — no backend / no stats: fall back
        pass
    return FALLBACK_BUDGET_BYTES


class MemoryBudget:
    """Byte budget + tagged in-flight reservation ledger.

    Reservation lifecycle (DESIGN.md §15): `try_reserve(tag, nbytes)` at
    admission (False when the ticket does not fit ``budget - reserved`` —
    the caller defers, it never over-commits), `release(tag)` when the
    tagged work leaves the system on ANY path (success, failure,
    deadline eviction). Tags are idempotent: re-reserving a live tag
    replaces its ticket; releasing an unknown tag is a no-op, so every
    exit path can release unconditionally. `peak_reserved` is the
    high-water mark the chaos harness pins against the budget."""

    def __init__(self, total_bytes: int | None = None):
        self.total = int(total_bytes if total_bytes is not None
                         else detect_budget_bytes())
        if self.total <= 0:
            raise ValueError(f"budget must be positive, got {self.total}")
        self._ledger: dict[str, int] = {}
        self.peak_reserved = 0

    @property
    def reserved(self) -> int:
        return sum(self._ledger.values())

    def available(self) -> int:
        return self.total - self.reserved

    def fits(self, nbytes: int) -> bool:
        return int(nbytes) <= self.available()

    def try_reserve(self, tag: str, nbytes: int) -> bool:
        """Reserve `nbytes` under `tag` iff it fits the remaining budget.
        Returns False (ledger untouched) otherwise — never raises, never
        over-commits."""
        nbytes = int(nbytes)
        held = self._ledger.get(tag, 0)
        if nbytes - held > self.available():
            return False
        self._ledger[tag] = nbytes
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return True

    def release(self, tag: str) -> int:
        """Drop `tag`'s reservation; returns the bytes freed (0 if the
        tag was not held — release is safe on every exit path)."""
        return self._ledger.pop(tag, 0)

    def __repr__(self):
        return (f"MemoryBudget(total={self.total}, reserved={self.reserved},"
                f" tags={len(self._ledger)})")

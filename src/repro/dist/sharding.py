"""Logical-axis sharding rules and the mesh trace context (DESIGN.md §6).

Model code never names mesh axes. Parameters declare *logical* axes in their
templates (params.P) and activations are constrained through `shard_act`
with logical names; a `ShardingRules` table maps logical -> mesh axes.
Changing the distribution strategy (FSDP on/off, sequence sharding, expert
parallelism, the flat-DP variant) is a rule-table edit, never a model edit —
the paxml-style "sharding rules as data" idiom.

Every mapping applies a divisibility fallback: a tensor dim that does not
divide the product of its mapped mesh axes is replicated instead (reduced
CPU configs have tiny head counts; production meshes have 16-wide axes).
Within one tensor, the first logical axis to claim a mesh axis wins and
later claims are dropped (e.g. attention scores constrain both 'kv_heads'
and 'seq'; under sequence sharding both map to 'model' and 'kv_heads', being
first, takes it — head-parallel attention).

`sharding_ctx` installs (mesh, rules) for the duration of a trace;
`shard_act` is a no-op outside a context, so the same model code runs
single-device tests and 512-chip dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.params import axis_spec, specs_from_template


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Two logical->mesh tables: `param` for weight templates, `act` for
    activation constraints. Values are a mesh axis name, a tuple of mesh
    axis names (2D sharding), or None (replicate)."""

    param: dict[str, Any]
    act: dict[str, Any]


def default_rules(*, multi_pod: bool = False, seq_shard: bool = False,
                  fsdp: bool = True) -> ShardingRules:
    """The DESIGN.md §6 strategy: DP over ('pod','data'), FSDP parameter
    sharding over 'data', TP over 'model'; `seq_shard` adds sequence
    parallelism for train/prefill activations (decode keeps seq unsharded —
    one token has no seq dim to split)."""
    dp = ("pod", "data") if multi_pod else ("data",)
    fs = "data" if fsdp else None
    param = {
        "embed": fs,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "inner": "model",
        "conv": None,
        "experts": None,
        "expert_embed": fs,
        "expert_mlp": "model",
        "layers": None,  # scanned stack dim: always unsharded
    }
    act = {
        "batch": dp,
        "tokens": dp,  # flattened (b*s) dim of MoE dispatch
        "seq": "model" if seq_shard else None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "inner": "model",
        "vocab": "model",
    }
    return ShardingRules(param=param, act=act)


def _mesh_axis_size(mesh, ax) -> int:
    """Product of the sizes of `ax` (None | name | tuple of names); axes not
    present in the mesh count as 1."""
    if ax is None:
        return 1
    shape = dict(mesh.shape)
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= shape.get(a, 1)
        return n
    return shape.get(ax, 1)


def named_shardings(template, mesh, rules: ShardingRules):
    """NamedSharding pytree for a parameter template (P leaves), via the
    same divisibility-fallback spec builder used for counting/init."""
    specs = specs_from_template(template, rules.param, dict(mesh.shape))
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


# ---------------------------------------------------------------------------
# Trace context
# ---------------------------------------------------------------------------
_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_sharding_ctx",
                                                      default=None)


@contextlib.contextmanager
def sharding_ctx(mesh, rules: ShardingRules):
    """Install (mesh, rules) for the enclosed trace. Re-entrant; the inner
    context wins."""
    token = _CTX.set((mesh, rules))
    try:
        yield (mesh, rules)
    finally:
        _CTX.reset(token)


def current_ctx():
    """The active (mesh, rules) pair, or None outside any sharding_ctx."""
    return _CTX.get()


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------
def shard_act(x, axes):
    """Constrain activation `x` to the current context's mapping of logical
    `axes` (tuple of logical names / None, one per dim). No-op outside a
    sharding_ctx, so model code is mesh-agnostic."""
    ctx = current_ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        raise ValueError(f"shard_act: {len(axes)} axes for rank-{x.ndim} array")
    spec = axis_spec(x.shape, axes, rules.act, dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

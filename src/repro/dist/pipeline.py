"""Pipeline parallelism over a mesh axis (DESIGN.md §6).

GPipe-style schedule: the layer stack is split into S contiguous stages,
one per device along the pipeline mesh axis; the batch is split into M
microbatches that stream through the stages, with activations handed to the
next stage by collective-permute each tick. Total ticks = M + S - 1; bubble
fraction = (S-1)/(M+S-1).

Forward-only (the serving/inference pipeline). The stage function is
user-supplied so the same scheduler runs toy stacks (tests) and full
transformer blocks.
"""
from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec


def split_layers_to_stages(ws, n_stages: int):
    """Split stacked per-layer weights (pytree with a leading (L, ...) layer
    dim on every leaf) into `n_stages` contiguous stages: (S, L//S, ...).
    The stage count must divide the layer count evenly — stages must be
    load-balanced or the pipeline ticks at the slowest stage's rate."""

    def _split(w):
        L = w.shape[0]
        if L % n_stages:
            raise ValueError(f"{L} layers do not split into {n_stages} stages")
        return w.reshape((n_stages, L // n_stages) + w.shape[1:])

    return jax.tree_util.tree_map(_split, ws)


def _sequential(stage_fn, stages, x):
    """Reference schedule: every stage on the full batch, in order."""
    n_stages = jax.tree_util.tree_leaves(stages)[0].shape[0]
    for s in range(n_stages):
        x = stage_fn(jax.tree_util.tree_map(lambda w: w[s], stages), x)
    return x


def pipeline_forward(stage_fn, stages, x, *, mesh=None, axis=None,
                     n_micro: int = 1):
    """Run `stage_fn(stage_weights, x_micro)` as a pipeline.

    stages: pytree with leading (S, ...) stage dim (split_layers_to_stages).
    x:      (B, ...) batch; B must divide into n_micro microbatches.
    mesh/axis: the mesh axis hosting the stages. S must equal the axis size;
    otherwise (or with no mesh) the sequential reference schedule runs —
    same math, no parallelism — so callers need no topology case-split.

    Matches the sequential schedule exactly up to f32 reassociation
    (asserted to 1e-5 in tests/test_distributed.py).
    """
    n_stages = jax.tree_util.tree_leaves(stages)[0].shape[0]
    if mesh is None or axis is None or dict(mesh.shape).get(axis, 1) != n_stages:
        return _sequential(stage_fn, stages, x)

    B = x.shape[0]
    if B % n_micro:
        raise ValueError(f"batch {B} does not split into {n_micro} microbatches")
    mb = B // n_micro
    xm = x.reshape((n_micro, mb) + x.shape[1:])
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def run(stage_ws, xm_loc):
        # stage_ws leaves arrive as (1, L//S, ...) — this device's stage.
        ws = jax.tree_util.tree_map(lambda w: w[0], stage_ws)
        stage = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xm_loc[0])          # activation in flight
        out = jnp.zeros_like(xm_loc)               # valid on the last stage
        for t in range(n_micro + n_stages - 1):
            # stage s works on microbatch t-s this tick; stage 0 pulls fresh
            # input, later stages consume the permuted activation. Ticks
            # outside [0, n_micro) compute garbage that is never stored.
            inp = jnp.where(stage == 0, xm_loc[min(t, n_micro - 1)], state)
            y = stage_fn(ws, inp)
            m = t - (n_stages - 1)
            if 0 <= m < n_micro:
                out = out.at[m].set(jnp.where(stage == n_stages - 1, y, out[m]))
            state = jax.lax.ppermute(y, axis, perm=fwd)
        # replicate the last stage's outputs to every device
        return jax.lax.psum(jnp.where(stage == n_stages - 1, out, 0.0), axis)

    spec_stage = jax.tree_util.tree_map(lambda _: PartitionSpec(axis), stages)
    fn = shard_map(run, mesh=mesh,
                   in_specs=(spec_stage, PartitionSpec()),
                   out_specs=PartitionSpec(),
                   check_rep=False)
    y = fn(stages, xm)
    return y.reshape((B,) + y.shape[2:])

"""Compressed data-parallel gradient collectives (DESIGN.md §6).

int8 uniform quantization with error feedback (EF-SGD / 1-bit-Adam family):
each device quantizes (grad + carried error) to int8 + one f32 scale per
tensor, all-reduces the dequantized value over the DP axis, and carries the
local quantization residual into the next step. EF keeps the *accumulated*
error bounded, so SGD converges to the true optimum where plain quantized
SGD stalls at a quantization-noise floor (tests/test_train_substrate.py).

Wire cost: 1 byte/param + 4 bytes/tensor vs 4 bytes/param — the 4x DP
bandwidth knob for the multi-pod mesh, where the ('pod','data') all-reduce
crosses the slow inter-pod links (roofline collective term).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
from jax.experimental.shard_map import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from .sharding import _mesh_axis_size


class EFState(NamedTuple):
    """Per-device error-feedback residuals, one f32 leaf per gradient leaf."""

    error: Any


def init_ef_state(grads) -> EFState:
    return EFState(error=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def quantize_int8(x):
    """Symmetric uniform int8 quantization. Returns (q int8, scale f32 scalar)
    with x ~= q * scale and |x - q*scale| <= scale/2 (round-to-nearest)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_decompress(grads, ef: EFState):
    """One local compression round-trip with error feedback: quantize
    (grad + error), return the dequantized gradient and the new residual.
    This is the per-device half of compressed_psum_dp, usable single-device
    (tests) or composed with any reduction."""
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, ef.error)
    decoded = jax.tree_util.tree_map(
        lambda c: dequantize_int8(*quantize_int8(c)), corrected)
    new_err = jax.tree_util.tree_map(lambda c, d: c - d, corrected, decoded)
    return decoded, EFState(error=new_err)


def compressed_psum_dp(grads, ef: EFState, mesh, *, axis="data"):
    """Mean-all-reduce `grads` over mesh `axis` with int8 EF compression.

    `axis` is one mesh axis name or a tuple of them — the multi-pod DP
    reduction is axis=('pod', 'data'). Axes absent from the mesh (or of
    size 1) are dropped, so one call site serves every mesh layout.

    Returns (mean_grads f32, new EFState). Inputs are taken as replicated
    pytrees (each device contributes its copy — on a DP mesh that copy is
    the device's local gradient); on replicated input the result reproduces
    the input to within one int8 quantization step, since every device
    quantizes identically and the mean of identical dequantized values is
    the dequantized value itself (tests/test_distributed.py).
    """
    names = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
    names = tuple(a for a in names if _mesh_axis_size(mesh, a) > 1)
    n = _mesh_axis_size(mesh, names)

    def local(g, e):
        dec, new_ef = ef_compress_decompress(g, EFState(error=e))
        summed = jax.tree_util.tree_map(
            lambda d: jax.lax.psum(d, names) / n, dec) if names else dec
        return summed, new_ef.error

    rep = jax.tree_util.tree_map(lambda _: PartitionSpec(), grads)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(rep, rep), out_specs=(rep, rep),
                   check_rep=False)
    out, new_err = fn(grads, ef.error)
    return out, EFState(error=new_err)

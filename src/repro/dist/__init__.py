"""Distribution substrate: logical-axis sharding rules, compressed
collectives, and pipeline parallelism.

Three modules (DESIGN.md §6):

  sharding     ShardingRules (logical->mesh axis tables), the mesh+rules
               trace context, and shard_act activation constraints.
  collectives  int8-quantized DP all-reduce with error feedback.
  pipeline     GPipe-style microbatch pipeline over a mesh axis.
"""
from . import collectives, pipeline, sharding  # noqa: F401

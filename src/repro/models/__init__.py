"""Architecture zoo: templates, forward/loss, decode, FLOPs accounting."""

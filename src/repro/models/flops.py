"""Analytic FLOPs / HBM-bytes / collective-bytes model per (arch x shape).

Why analytic: XLA's compiled cost_analysis() on the dry-run counts a scanned
layer body ONCE (verified empirically; see EXPERIMENTS.md §Method), so raw
HLO_FLOPs understate scanned programs by ~L x. We therefore compute exact
matmul-level FLOPs from the architecture config (we control every einsum in
the model code), and cross-check (a) the per-layer value against the HLO dot
ops parsed out of the while body (launch/roofline.py), and (b) MODEL_FLOPS =
6·N·D against the total.

Conventions:
  * train FLOPs = fwd x (1 + 2 [bwd] + 1 [remat recompute inside scan]) for
    scanned blocks, fwd x 3 for unscanned (embed/head).
  * all matmuls are 2mnk; attention scores/AV are counted explicitly
    (the 6ND rule misses them at long context).
  * bytes/collectives are per *device* per step under the DESIGN.md §6
    sharding (FSDP over data, TP over model, DP over pod x data).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import num_params


@dataclasses.dataclass
class CostEstimate:
    flops_total: float  # whole step, all chips
    flops_layer_fwd: float  # one scanned-unit forward (for HLO cross-check)
    model_flops: float  # 6*N*D(active) reference
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    notes: dict


def _attn_flops(b, s, cfg: ArchConfig, kv_len=None):
    """qkvo projections + scores + AV for one layer, forward."""
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    kv_len = kv_len or s
    if cfg.sliding_window:
        kv_len = min(kv_len, cfg.sliding_window)
    proj = 2 * b * s * d * (H * hd + 2 * KV * hd + H * hd)
    scores = 2 * b * H * s * kv_len * hd * 2  # QK^T and AV
    return proj + scores


def _mlp_flops(b, s, d, f, kind):
    mats = 3 if kind == "swiglu" else 2
    return 2 * b * s * d * f * mats


def _moe_flops(b, s, cfg: ArchConfig):
    m = cfg.moe
    tok = b * s
    cap_tok = tok * m.top_k  # capacity-bounded routed tokens
    routed = 2 * cap_tok * cfg.d_model * m.d_expert * 3
    router = 2 * tok * cfg.d_model * m.num_experts
    shared = 2 * tok * cfg.d_model * m.shared_d_ff * 3 if m.num_shared_experts else 0
    return routed + router + shared


def _ssm_flops(b, s, cfg: ArchConfig):
    c = cfg.ssm
    d = cfg.d_model
    inner = c.expand * d
    nheads = inner // c.head_dim
    n = c.state_dim
    Q = min(c.chunk, s)
    proj = 2 * b * s * d * (2 * inner + 2 * n + nheads) + 2 * b * s * inner * d
    # intra-chunk quadratic + state path
    intra = 2 * b * s * Q * (n + nheads * c.head_dim)
    state = 2 * b * s * nheads * c.head_dim * n * 2
    return proj + intra + state


def _xlstm_pair_flops(b, s, cfg: ArchConfig):
    x = cfg.xlstm
    d = cfg.d_model
    inner = int(x.proj_factor_mlstm * d)
    nh = x.num_heads
    dk = inner // nh
    # mLSTM: up/down + qkv + quadratic
    m = 2 * b * s * d * (2 * inner) + 2 * b * s * inner * d
    m += 2 * b * s * inner * 3 * dk * nh // nh  # qkv projections (inner->inner)
    m += 2 * b * nh * s * s * dk * 2
    # sLSTM: gates W + R recurrent + out + mlp
    hd = d // nh
    sl = 2 * b * s * d * d * 4 + 2 * b * s * nh * hd * hd * 4
    sl += 2 * b * s * d * d + 2 * b * s * d * int(x.proj_factor_slstm * d) * 2
    return m + sl


def layer_fwd_flops(cfg: ArchConfig, b: int, s: int) -> float:
    """Forward FLOPs of one scanned unit."""
    fam = cfg.family
    if fam == "dense":
        return _attn_flops(b, s, cfg) + _mlp_flops(b, s, cfg.d_model, cfg.d_ff, cfg.act)
    if fam == "moe":
        return _attn_flops(b, s, cfg) + _moe_flops(b, s, cfg)
    if fam == "ssm":
        return _xlstm_pair_flops(b, s, cfg)
    if fam == "hybrid":
        grp = cfg.shared_attn_every * _ssm_flops(b, s, cfg)
        grp += _attn_flops(b, s, cfg) + _mlp_flops(b, s, cfg.d_model, cfg.d_ff, cfg.act)
        return grp
    if fam == "vlm":
        selfs = (cfg.cross_attn_every - 1) * (
            _attn_flops(b, s, cfg) + _mlp_flops(b, s, cfg.d_model, cfg.d_ff, cfg.act)
        )
        cross = _attn_flops(b, s, cfg, kv_len=cfg.vision_tokens) + _mlp_flops(
            b, s, cfg.d_model, cfg.d_ff, cfg.act
        )
        return selfs + cross
    if fam == "audio":
        dec = (
            _attn_flops(b, s, cfg)
            + _attn_flops(b, s, cfg, kv_len=cfg.encoder_len)
            + _mlp_flops(b, s, cfg.d_model, cfg.d_ff, cfg.act)
        )
        return dec
    raise ValueError(fam)


def _num_scan_units(cfg: ArchConfig) -> int:
    if cfg.family in ("dense", "moe"):
        return cfg.num_layers
    if cfg.family == "ssm":
        return cfg.num_layers // cfg.xlstm.slstm_every
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.shared_attn_every
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_every
    if cfg.family == "audio":
        return cfg.num_layers
    raise ValueError(cfg.family)


def _active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (MoE: top-k + shared only)."""
    n = num_params(cfg)
    if cfg.moe is None:
        return n
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_inactive = (m.num_experts - m.top_k) * per_expert * cfg.num_layers
    return n - n_inactive


def estimate(cfg: ArchConfig, shape: ShapeSpec, mesh_shape: dict,
             *, param_bytes: int = 2, opt_bytes: int = 12,
             remat_factor: float = 4.0) -> CostEstimate:
    b, s = shape.global_batch, shape.seq_len
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    N = num_params(cfg)
    Nact = _active_params(cfg)
    units = _num_scan_units(cfg)
    d = cfg.d_model

    if shape.kind == "train":
        lf = layer_fwd_flops(cfg, b, s)
        embed_head = 2 * b * s * d * cfg.padded_vocab * (1 if cfg.tie_embeddings else 1)
        if cfg.family == "audio":
            enc_f = cfg.encoder_layers * (
                _attn_flops(b, cfg.encoder_len, cfg)
                + _mlp_flops(b, cfg.encoder_len, d, cfg.d_ff, cfg.act)
            )
        else:
            enc_f = 0.0
        fwd = units * lf + embed_head + enc_f
        total = units * lf * remat_factor + (embed_head + enc_f) * 3
        model_flops = 6.0 * Nact * b * s
        # HBM per device: params/grads/opt + remat activation traffic
        p_loc = N / chips
        hbm = p_loc * param_bytes * 2  # read params, write updated
        hbm += p_loc * 4 * 2  # grads f32 accumulate rw (approx)
        hbm += p_loc * opt_bytes * 2  # opt state rw
        act = b * s * d * 2 / dp  # one residual stream per layer boundary
        hbm += act * units * 4  # ckpt write + read + recompute rw
        hbm += b * s * cfg.padded_vocab * 2 / dp * 2  # logits rw
        # collectives per device:
        #   FSDP all-gather params (fwd+bwd+remat = 3x) + grad reduce-scatter
        #   + DP all-reduce across pod axis
        fsdp = mesh_shape.get("data", 1)
        coll = 0.0
        if fsdp > 1:
            coll += 3 * (N / tp) * param_bytes * (fsdp - 1) / fsdp / fsdp  # AG per dev
            coll += (N / tp) * 4 * (fsdp - 1) / fsdp / fsdp  # grad RS (f32)
        if mesh_shape.get("pod", 1) > 1:
            pods = mesh_shape["pod"]
            coll += 2 * (N / (tp * fsdp)) * 4 * (pods - 1) / pods  # cross-pod AR
        if tp > 1:
            # 2 activation all-reduces per unit fwd (+2 bwd, +2 remat)
            ar = b * s * d * 2 / dp * (tp - 1) / tp
            coll += 6 * units * ar
        notes = {"kind": "train"}
    else:
        # decode (and prefill handled as forward-only train-like below)
        if shape.kind == "prefill":
            lf = layer_fwd_flops(cfg, b, s)
            embed_head = 2 * b * s * d * cfg.padded_vocab
            total = units * lf + embed_head
            model_flops = 2.0 * Nact * b * s
            p_loc = N / chips
            hbm = p_loc * param_bytes + b * s * d * 2 / dp * units
            coll = 0.0
            if mesh_shape.get("data", 1) > 1:
                coll += (N / tp) * param_bytes / mesh_shape.get("data", 1)
            if tp > 1:
                coll += 2 * units * b * s * d * 2 / dp * (tp - 1) / tp
            notes = {"kind": "prefill"}
            return CostEstimate(total, lf, model_flops, hbm, coll, notes)
        # decode: one token per sequence against cache of length s
        kv_len = min(s, cfg.sliding_window) if cfg.sliding_window else s
        total = 2.0 * Nact * b  # param matmuls
        cache_bytes = 0.0
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            # attention cache read per layer
            attn_layers = {
                "dense": cfg.num_layers,
                "moe": cfg.num_layers,
                "vlm": cfg.num_layers,
                "audio": cfg.num_layers,
                "hybrid": cfg.num_layers // max(cfg.shared_attn_every, 1),
            }[cfg.family]
            total += 2.0 * b * attn_layers * cfg.num_kv_heads * cfg.hd * kv_len * 2
            cache_bytes += attn_layers * b * kv_len * cfg.num_kv_heads * cfg.hd * 2 * 2
        if cfg.family == "hybrid":
            inner = cfg.ssm.expand * d
            nheads = inner // cfg.ssm.head_dim
            cache_bytes += cfg.num_layers * b * nheads * cfg.ssm.head_dim * cfg.ssm.state_dim * 4
        if cfg.family == "ssm":
            x = cfg.xlstm
            inner = int(x.proj_factor_mlstm * d)
            dk = inner // x.num_heads
            cache_bytes += (cfg.num_layers // x.slstm_every) * b * x.num_heads * dk * dk * 4
        model_flops = 2.0 * Nact * b
        p_loc = N / chips
        hbm = p_loc * param_bytes + cache_bytes / chips
        coll = 0.0
        if tp > 1:
            coll += 2 * _num_scan_units(cfg) * b * d * 2 * (tp - 1) / tp
        lf = 0.0
        notes = {"kind": "decode", "kv_len": kv_len}
    return CostEstimate(total, lf, model_flops, hbm, coll, notes)

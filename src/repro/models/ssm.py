"""Mamba2 (SSD) block — chunked parallel form for train/prefill, recurrent
state update for decode (zamba2 family).

The chunked SSD algorithm splits the sequence into chunks of Q steps:
intra-chunk contributions are a masked (decay-weighted) attention-like
quadratic form (MXU-friendly), inter-chunk state is carried by a short scan
over chunks. Decode keeps (conv window, SSM state) only — O(1) per token,
which is what qualifies the family for the long_500k cell.

Simplifications vs the released model (documented): single B/C group
(ngroups=1), no dt/A/D per-group structure beyond per-head scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .params import P


def ssm_tmpl(d: int, cfg):
    inner = cfg.expand * d
    nheads = inner // cfg.head_dim
    n = cfg.state_dim
    conv_ch = inner + 2 * n
    return {
        "in_proj": P((d, 2 * inner + 2 * n + nheads), ("embed", "inner")),
        "conv_w": P((cfg.conv_width, conv_ch), ("conv", "inner")),
        "conv_b": P((conv_ch,), ("inner",), "zeros"),
        "A_log": P((nheads,), (None,), "zeros"),
        "D": P((nheads,), (None,), "ones"),
        "dt_bias": P((nheads,), (None,), "zeros"),
        "norm_scale": P((inner,), ("inner",), "ones"),
        "out_proj": P((inner, d), ("inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (b, s, c); w: (k, c). If state (b, k-1, c)
    is given, runs in streaming mode and returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    ys = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    y = jax.nn.silu(ys + b)
    if state is None:
        return y
    return y, xp[:, -(k - 1) :, :]


def _split(p, x, cfg, d):
    inner = cfg.expand * d
    n = cfg.state_dim
    nheads = inner // cfg.head_dim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :inner]
    xbc = zxbcdt[..., inner : inner + inner + 2 * n]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt, inner, n, nheads


def apply_ssm(p, x, cfg):
    """Training/prefill. x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    z, xbc, dt, inner, n, nheads = _split(p, x, cfg, d)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :inner]
    B = xbc[..., inner : inner + n]
    C = xbc[..., inner + n :]
    hdim = cfg.head_dim
    Q = min(cfg.chunk, s)
    if s % Q:
        raise ValueError(f"seq {s} not divisible by chunk {Q}")
    nc = s // Q
    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)  # (b, s, h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (h,)
    dA = dt * A  # log-decay per step, (b, s, h)
    u = (xs.reshape(b, s, nheads, hdim).astype(jnp.float32)) * dt[..., None]

    # chunked views
    dA_c = dA.reshape(b, nc, Q, nheads)
    u_c = u.reshape(b, nc, Q, nheads, hdim)
    B_c = B.reshape(b, nc, Q, n).astype(jnp.float32)
    C_c = C.reshape(b, nc, Q, n).astype(jnp.float32)
    L = jnp.cumsum(dA_c, axis=2)  # (b, nc, Q, h) inclusive log decay

    # intra-chunk: Y[j] = sum_{i<=j} exp(L_j - L_i) (C_j . B_i) u_i
    seg = L[:, :, :, None, :] - L[:, :, None, :, :]  # (b,nc,j,i,h)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcjn,bcin->bcji", C_c, B_c)  # (b,nc,Q,Q)
    W = CB[..., None] * M  # (b,nc,j,i,h)
    W = shard_act(W, ("batch", None, None, None, "heads"))
    y_intra = jnp.einsum("bcjih,bcihp->bcjhp", W, u_c)
    y_intra = shard_act(y_intra, ("batch", None, None, "heads", None))

    # chunk-end states: S_c = sum_i exp(L_Q - L_i) u_i B_i^T  (h,p,n)
    decay_to_end = jnp.exp(L[:, :, -1:, :] - L)  # (b,nc,Q,h)
    S = jnp.einsum("bcih,bcihp,bcin->bchpn", decay_to_end, u_c, B_c)
    S = shard_act(S, ("batch", None, "heads", None, None))

    # inter-chunk scan: H_{c+1} = exp(L_Q^c) H_c + S_c
    a_chunk = jnp.exp(L[:, :, -1, :])  # (b,nc,h)

    def step(H, inp):
        a, Sc = inp
        Hn = a[:, :, None, None] * H + Sc
        return Hn, H  # emit state at chunk *start*

    H0 = jnp.zeros((b, nheads, hdim, n), jnp.float32)
    _, H_starts = jax.lax.scan(
        step, H0, (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S, 1, 0))
    )
    H_starts = jnp.moveaxis(H_starts, 0, 1)  # (b, nc, h, p, n)

    # inter contribution: Y[j] += C_j . (exp(L_j) H_start)
    H_starts = shard_act(H_starts, ("batch", None, "heads", None, None))
    y_inter = jnp.einsum("bcjn,bcjh,bchpn->bcjhp", C_c, jnp.exp(L), H_starts)

    y = (y_intra + y_inter).reshape(b, s, nheads, hdim)
    xs_h = xs.reshape(b, s, nheads, hdim).astype(jnp.float32)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs_h
    y = y.reshape(b, s, inner).astype(x.dtype)
    # gated RMSNorm then out projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    y = y * p["norm_scale"]
    out = y @ p["out_proj"]
    return shard_act(out, ("batch", "seq", "embed"))


def init_ssm_cache(b: int, d: int, cfg, dtype):
    inner = cfg.expand * d
    n = cfg.state_dim
    nheads = inner // cfg.head_dim
    conv_ch = inner + 2 * n
    return {
        "conv": jnp.zeros((b, cfg.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((b, nheads, cfg.head_dim, n), jnp.float32),
    }


def apply_ssm_decode(p, x, cache, cfg):
    """Single-token decode. x: (b, 1, d). Returns (y, new_cache)."""
    b, _, d = x.shape
    z, xbc, dt, inner, n, nheads = _split(p, x, cfg, d)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xs = xbc[..., :inner]
    B = xbc[:, 0, inner : inner + n].astype(jnp.float32)  # (b, n)
    C = xbc[:, 0, inner + n :].astype(jnp.float32)
    hdim = cfg.head_dim
    dt = jax.nn.softplus(dt + p["dt_bias"])[:, 0].astype(jnp.float32)  # (b, h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)  # (b, h)
    u = xs.reshape(b, nheads, hdim).astype(jnp.float32) * dt[..., None]
    H = cache["ssm"] * a[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", u, B)
    y = jnp.einsum("bhpn,bn->bhp", H, C)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.reshape(b, nheads, hdim)
    y = y.reshape(b, 1, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    y = (y * p["norm_scale"]) @ p["out_proj"]
    return y, {"conv": conv_state, "ssm": H}

"""Model assembly: every assigned architecture family behind one API.

    template(cfg)                      parameter template (P leaves)
    init_params(cfg, rng, dtype)       real parameters
    forward(cfg, params, batch)        (logits, aux_loss)         [train/prefill]
    loss_fn(cfg, params, batch)        (loss, metrics)
    cache_shapes(cfg, b, w, dtype)     decode-cache ShapeDtypeStructs
    init_cache(cfg, params, b, w, batch, dtype)   real cache (cross-KV filled)
    decode_step(cfg, params, cache, token, pos)   (logits, new_cache)
    input_specs(cfg, shape, ...)       dry-run ShapeDtypeStructs per cell

Layer stacks are scanned over stacked parameters with jax.checkpoint
(remat) around the block body; heterogeneous stacks (xLSTM pairs, zamba2
mamba-groups + shared attention, vision self/cross groups) scan over their
repeat unit. Decode scans carry the per-layer cache through the same
structure.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from . import xlstm as XL
from .params import P, count_params, init_from_template, stack


# ===========================================================================
# Templates
# ===========================================================================
def _attn_layer_tmpl(cfg: ArchConfig):
    d = cfg.d_model
    t = {
        "ln1": L.norm_tmpl(cfg.norm, d),
        "attn": L.attn_tmpl(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd),
        "ln2": L.norm_tmpl(cfg.norm, d),
    }
    if cfg.moe is not None:
        t["moe"] = MOE.moe_tmpl(d, cfg.moe)
    else:
        t["mlp"] = L.mlp_tmpl(cfg.act, d, cfg.d_ff)
    return t


def _cross_layer_tmpl(cfg: ArchConfig):
    d = cfg.d_model
    return {
        "ln1": L.norm_tmpl(cfg.norm, d),
        "xattn": L.attn_tmpl(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd),
        "gate_attn": P((1,), (None,), "zeros"),
        "ln2": L.norm_tmpl(cfg.norm, d),
        "mlp": L.mlp_tmpl(cfg.act, d, cfg.d_ff),
        "gate_mlp": P((1,), (None,), "zeros"),
    }


def _encdec_dec_layer_tmpl(cfg: ArchConfig):
    d = cfg.d_model
    return {
        "ln1": L.norm_tmpl(cfg.norm, d),
        "attn": L.attn_tmpl(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd),
        "ln2": L.norm_tmpl(cfg.norm, d),
        "xattn": L.attn_tmpl(d, cfg.num_heads, cfg.num_kv_heads, cfg.hd),
        "ln3": L.norm_tmpl(cfg.norm, d),
        "mlp": L.mlp_tmpl(cfg.act, d, cfg.d_ff),
    }


def template(cfg: ArchConfig):
    d, V = cfg.d_model, cfg.padded_vocab
    t: dict[str, Any] = {"embed": L.embed_tmpl(V, d), "ln_f": L.norm_tmpl(cfg.norm, d)}
    if not cfg.tie_embeddings:
        t["head"] = L.head_tmpl(d, V)

    fam = cfg.family
    if fam in ("dense", "moe"):
        t["layers"] = stack(_attn_layer_tmpl(cfg), cfg.num_layers)
    elif fam == "ssm" and cfg.xlstm is not None:  # xLSTM
        n_pairs = cfg.num_layers // cfg.xlstm.slstm_every
        pair = {"mlstm": XL.mlstm_tmpl(d, cfg.xlstm), "slstm": XL.slstm_tmpl(d, cfg.xlstm)}
        t["pairs"] = stack(pair, n_pairs)
    elif fam == "hybrid":  # zamba2: mamba groups + one shared attn block
        n_groups = cfg.num_layers // cfg.shared_attn_every
        group = stack(SSM.ssm_tmpl(d, cfg.ssm), cfg.shared_attn_every)
        t["groups"] = stack(group, n_groups)
        t["shared_attn"] = _attn_layer_tmpl(cfg)  # single copy, reused per group
    elif fam == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        group = {
            "self": stack(_attn_layer_tmpl(cfg), cfg.cross_attn_every - 1),
            "cross": _cross_layer_tmpl(cfg),
        }
        t["groups"] = stack(group, n_groups)
    elif fam == "audio":  # whisper backbone: enc self-attn + dec self/cross
        enc_cfg = cfg.replace(moe=None)
        t["enc_layers"] = stack(_attn_layer_tmpl(enc_cfg), cfg.encoder_layers)
        t["enc_ln_f"] = L.norm_tmpl(cfg.norm, d)
        t["dec_layers"] = stack(_encdec_dec_layer_tmpl(cfg), cfg.num_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return t


def init_params(cfg: ArchConfig, rng, dtype=jnp.float32):
    return init_from_template(template(cfg), rng, dtype)


def num_params(cfg: ArchConfig) -> int:
    return count_params(template(cfg))


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================
def _dense_layer_apply(cfg: ArchConfig, p, x, *, causal=True, positions=None):
    theta = cfg.rope_theta if cfg.family != "audio" else None
    h = L.apply_norm(cfg.norm, p["ln1"], x)
    x = x + L.apply_self_attn(
        p["attn"], h, n_kv=cfg.num_kv_heads, theta=theta,
        window=cfg.sliding_window, causal=causal, positions=positions,
    )
    h = L.apply_norm(cfg.norm, p["ln2"], x)
    if "moe" in p:
        y, aux = MOE.apply_moe(p["moe"], h, cfg.moe)
        return x + y, aux
    return x + L.apply_mlp(cfg.act, p["mlp"], h), jnp.float32(0.0)


def _cross_layer_apply(cfg: ArchConfig, p, x, kv_src):
    h = L.apply_norm(cfg.norm, p["ln1"], x)
    a = L.apply_cross_attn(p["xattn"], h, kv_src, n_kv=cfg.num_kv_heads)
    x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * a
    h = L.apply_norm(cfg.norm, p["ln2"], x)
    x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * L.apply_mlp(cfg.act, p["mlp"], h)
    return x


def _scan(body, x, xs, remat=True):
    """remat: False | True (full recompute) | "dots" (save matmul outputs —
    trades HBM for a 4x->3x backward FLOPs multiplier; §Perf)."""
    if remat == "dots":
        f = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        f = jax.checkpoint(body)
    else:
        f = body

    def wrapped(carry, inp):
        return f(carry, inp)

    return jax.lax.scan(wrapped, x, xs)


def _embed(cfg: ArchConfig, params, tokens):
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    from repro.dist.sharding import shard_act

    return shard_act(x, ("batch", "seq", "embed"))


def _logits(cfg: ArchConfig, params, x):
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    else:
        logits = x @ params["head"]["w"]
    # mask vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        iota = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(iota >= cfg.vocab_size, -1e30, logits)
    from repro.dist.sharding import shard_act

    return shard_act(logits, ("batch", "seq", "vocab"))


def forward(cfg: ArchConfig, params, batch, *, remat=True):
    """Returns (logits (b, s, V), aux_loss scalar)."""
    fam = cfg.family
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    aux0 = jnp.float32(0.0)

    if fam in ("dense", "moe"):
        def body(carry, lp):
            h, aux = carry
            h, a = _dense_layer_apply(cfg, lp, h)
            return (h, aux + a), None

        (x, aux0), _ = _scan(body, (x, aux0), params["layers"], remat)

    elif fam == "ssm" and cfg.xlstm is not None:
        # pre-norm residual around each mLSTM / sLSTM block
        def body(h, lp):
            hn = _rms(h)
            h = h + XL.apply_mlstm(lp["mlstm"], hn, cfg.xlstm)
            hn = _rms(h)
            y, _st = XL.apply_slstm(lp["slstm"], hn, cfg.xlstm)
            return h + y, None

        x, _ = _scan(body, x, params["pairs"], remat)

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, gp):
            def mamba_body(hh, lp):
                return hh + SSM.apply_ssm(lp, _rms(hh), cfg.ssm), None

            h, _ = jax.lax.scan(mamba_body, h, gp)
            h, _a = _dense_layer_apply(cfg, shared, h)
            return h, None

        x, _ = _scan(group_body, x, params["groups"], remat)

    elif fam == "vlm":
        kv_src = batch["vision_emb"].astype(x.dtype)

        def group_body(h, gp):
            def self_body(hh, lp):
                hh, _a = _dense_layer_apply(cfg, lp, hh)
                return hh, None

            h, _ = jax.lax.scan(self_body, h, gp["self"])
            h = _cross_layer_apply(cfg, gp["cross"], h, kv_src)
            return h, None

        x, _ = _scan(group_body, x, params["groups"], remat)

    elif fam == "audio":
        enc = batch["enc_emb"].astype(x.dtype)
        enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(x.dtype)

        def enc_body(h, lp):
            h, _a = _dense_layer_apply(cfg, lp, h, causal=False)
            return h, None

        enc, _ = _scan(enc_body, enc, params["enc_layers"], remat)
        enc = L.apply_norm(cfg.norm, params["enc_ln_f"], enc)

        x = x + L.sinusoidal_positions(tokens.shape[1], cfg.d_model).astype(x.dtype)

        def dec_body(h, lp):
            hn = L.apply_norm(cfg.norm, lp["ln1"], h)
            h = h + L.apply_self_attn(
                lp["attn"], hn, n_kv=cfg.num_kv_heads, theta=None, causal=True
            )
            hn = L.apply_norm(cfg.norm, lp["ln2"], h)
            h = h + L.apply_cross_attn(lp["xattn"], hn, enc, n_kv=cfg.num_kv_heads)
            hn = L.apply_norm(cfg.norm, lp["ln3"], h)
            h = h + L.apply_mlp(cfg.act, lp["mlp"], hn)
            return h, None

        x, _ = _scan(dec_body, x, params["dec_layers"], remat)
    else:
        raise ValueError(fam)

    return _logits(cfg, params, x), aux0


def _rms(x, eps=1e-5):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)).astype(x.dtype)


def loss_fn(cfg: ArchConfig, params, batch, *, remat=True):
    """Next-token CE. batch['tokens']: (b, s+1)."""
    tokens = batch["tokens"]
    inp = dict(batch)
    inp["tokens"] = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits, aux = forward(cfg, params, inp, remat=remat)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = -jnp.mean(ll)
    return ce + aux, {"ce": ce, "aux": aux}


# ===========================================================================
# Decode
# ===========================================================================
def _cache_len(cfg: ArchConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_len)
    return max_len


def _cache_layout(cfg: ArchConfig, b: int, max_len: int, dtype, emit):
    """Single source of truth for decode-cache leaves: emit(shape, dtype,
    logical_axes) is called per leaf; used for both ShapeDtypeStructs and
    sharding specs."""
    d, kv, hd = cfg.d_model, cfg.num_kv_heads, cfg.hd
    W = _cache_len(cfg, max_len)
    kvc = lambda n, w=W, extra=(): {
        "k": emit((n,) + extra + (b, w, kv, hd), dtype,
                  ("layers",) + (None,) * len(extra)
                  + ("batch", None, "kv_heads", "head_dim")),
        "v": emit((n,) + extra + (b, w, kv, hd), dtype,
                  ("layers",) + (None,) * len(extra)
                  + ("batch", None, "kv_heads", "head_dim")),
    }
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {"kv": kvc(cfg.num_layers)}
    if fam == "ssm" and cfg.xlstm is not None:
        n_pairs = cfg.num_layers // cfg.xlstm.slstm_every
        inner = int(cfg.xlstm.proj_factor_mlstm * d)
        nh = cfg.xlstm.num_heads
        dk = inner // nh
        hd_s = d // nh
        return {
            "mlstm": {
                "C": emit((n_pairs, b, nh, dk, dk), jnp.float32,
                          ("layers", "batch", "heads", None, None)),
                "n": emit((n_pairs, b, nh, dk), jnp.float32,
                          ("layers", "batch", "heads", None)),
                "m": emit((n_pairs, b, nh), jnp.float32, ("layers", "batch", "heads")),
                "conv": emit((n_pairs, b, 3, inner), dtype,
                             ("layers", "batch", None, "inner")),
            },
            "slstm": tuple(
                emit((n_pairs, b, nh, hd_s), jnp.float32 if i < 3 else dtype,
                     ("layers", "batch", "heads", None))
                for i in range(4)
            ),
        }
    if fam == "hybrid":
        n_groups = cfg.num_layers // cfg.shared_attn_every
        inner = cfg.ssm.expand * d
        nheads = inner // cfg.ssm.head_dim
        conv_ch = inner + 2 * cfg.ssm.state_dim
        return {
            "ssm": {
                "conv": emit(
                    (n_groups, cfg.shared_attn_every, b, cfg.ssm.conv_width - 1, conv_ch),
                    dtype, ("layers", None, "batch", None, "inner")),
                "ssm": emit(
                    (n_groups, cfg.shared_attn_every, b, nheads, cfg.ssm.head_dim,
                     cfg.ssm.state_dim),
                    jnp.float32, ("layers", None, "batch", "heads", None, None)),
            },
            "attn_kv": kvc(n_groups),
        }
    if fam == "vlm":
        n_groups = cfg.num_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        return {
            "self_kv": {
                "k": emit((n_groups, per, b, W, kv, hd), dtype,
                          ("layers", None, "batch", None, "kv_heads", "head_dim")),
                "v": emit((n_groups, per, b, W, kv, hd), dtype,
                          ("layers", None, "batch", None, "kv_heads", "head_dim")),
            },
            "cross_kv": {
                "k": emit((n_groups, b, cfg.vision_tokens, kv, hd), dtype,
                          ("layers", "batch", None, "kv_heads", "head_dim")),
                "v": emit((n_groups, b, cfg.vision_tokens, kv, hd), dtype,
                          ("layers", "batch", None, "kv_heads", "head_dim")),
            },
        }
    if fam == "audio":
        return {
            "self_kv": kvc(cfg.num_layers),
            "cross_kv": {
                "k": emit((cfg.num_layers, b, cfg.encoder_len, kv, hd), dtype,
                          ("layers", "batch", None, "kv_heads", "head_dim")),
                "v": emit((cfg.num_layers, b, cfg.encoder_len, kv, hd), dtype,
                          ("layers", "batch", None, "kv_heads", "head_dim")),
            },
        }
    raise ValueError(fam)


def cache_shapes(cfg: ArchConfig, b: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the decode cache."""
    return _cache_layout(cfg, b, max_len, dtype,
                         lambda shape, dt, axes: jax.ShapeDtypeStruct(shape, dt))


class AxesLeaf:
    """Pytree *leaf* wrapping a logical-axes tuple (plain tuples would be
    flattened as containers and break treedef alignment with cache_shapes)."""

    def __init__(self, axes):
        self.axes = tuple(axes)

    def __repr__(self):
        return f"AxesLeaf{self.axes}"


def cache_axes(cfg: ArchConfig, b: int, max_len: int, dtype=jnp.bfloat16):
    """Logical-axis pytree matching cache_shapes (for sharding specs)."""
    return _cache_layout(cfg, b, max_len, dtype,
                         lambda shape, dt, axes: AxesLeaf(axes))


def init_cache(cfg: ArchConfig, params, b: int, max_len: int, batch=None,
               dtype=jnp.bfloat16):
    """Zero cache; for cross-attention families, precomputes cross K/V from
    the stub embeddings in `batch` (vision_emb / enc_emb)."""
    shapes = cache_shapes(cfg, b, max_len, dtype)
    cache = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    if cfg.family == "vlm":
        kv_src = batch["vision_emb"].astype(dtype)

        def xkv(gp):
            k = jnp.einsum("btd,dhk->bthk", kv_src, gp["cross"]["xattn"]["wk"])
            v = jnp.einsum("btd,dhk->bthk", kv_src, gp["cross"]["xattn"]["wv"])
            return k, v

        ks, vs = jax.vmap(xkv)(params["groups"])
        cache["cross_kv"] = {"k": ks.astype(dtype), "v": vs.astype(dtype)}
    if cfg.family == "audio":
        enc = batch["enc_emb"].astype(dtype)
        enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(dtype)

        def enc_body(h, lp):
            h, _ = _dense_layer_apply(cfg, lp, h, causal=False)
            return h, None

        enc, _ = jax.lax.scan(lambda h, lp: enc_body(h, lp), enc, params["enc_layers"])
        enc = L.apply_norm(cfg.norm, params["enc_ln_f"], enc)

        def xkv(lp):
            k = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"])
            v = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"])
            return k, v

        ks, vs = jax.vmap(xkv)(params["dec_layers"])
        cache["cross_kv"] = {"k": ks.astype(dtype), "v": vs.astype(dtype)}
    return cache


def _attn_decode_block(cfg, lp, x, kv, pos):
    theta = cfg.rope_theta if cfg.family != "audio" else None
    h = L.apply_norm(cfg.norm, lp["ln1"], x)
    a, kv2 = L.apply_self_attn_decode(
        lp["attn"], h, kv, pos, n_kv=cfg.num_kv_heads, theta=theta
    )
    x = x + a
    h = L.apply_norm(cfg.norm, lp["ln2"], x)
    if "moe" in lp:
        y, _aux = MOE.apply_moe(lp["moe"], h, cfg.moe)
        x = x + y
    else:
        x = x + L.apply_mlp(cfg.act, lp["mlp"], h)
    return x, kv2


def _cross_decode(cfg, p_attn, x, ck, cv):
    """Cross attention against precomputed K/V."""
    n_heads = p_attn["wq"].shape[1]
    n_rep = n_heads // cfg.num_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p_attn["wq"])
    mask = jnp.ones((x.shape[0], 1, 1, ck.shape[1]), bool)
    out = L._sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask, n_rep)
    return jnp.einsum("bshk,hkd->bsd", out, p_attn["wo"])


def decode_step(cfg: ArchConfig, params, cache, token, pos):
    """token: (b,) int32; pos: scalar int32 (slot-synchronous) or (b,) int32
    (continuous batching, per-sequence positions).
    Returns (logits (b, V), cache)."""
    x = jnp.take(params["embed"]["table"], token[:, None], axis=0)
    fam = cfg.family

    if fam in ("dense", "moe"):
        def body(h, inp):
            lp, kv = inp
            h, kv2 = _attn_decode_block(cfg, lp, h, kv, pos)
            return h, kv2

        x, kv2 = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
        cache = {"kv": kv2}

    elif fam == "ssm" and cfg.xlstm is not None:
        def body(h, inp):
            lp, mc, sc = inp
            hn = _rms(h)
            y, mc2 = XL.apply_mlstm_decode(lp["mlstm"], hn, mc, cfg.xlstm)
            h = h + y
            hn = _rms(h)
            y, sc2 = XL.apply_slstm_decode(lp["slstm"], hn, cfg.xlstm, sc)
            return h + y, (mc2, sc2)

        x, (mc2, sc2) = jax.lax.scan(body, x, (params["pairs"], cache["mlstm"], cache["slstm"]))
        cache = {"mlstm": mc2, "slstm": sc2}

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, inp):
            gp, sc, akv = inp

            def mamba_body(hh, inp2):
                lp, c = inp2
                y, c2 = SSM.apply_ssm_decode(lp, _rms(hh), c, cfg.ssm)
                return hh + y, c2

            h, sc2 = jax.lax.scan(mamba_body, h, (gp, sc))
            h, akv2 = _attn_decode_block(cfg, shared, h, akv, pos)
            return h, (sc2, akv2)

        x, (sc2, akv2) = jax.lax.scan(
            group_body, x, (params["groups"], cache["ssm"], cache["attn_kv"])
        )
        cache = {"ssm": sc2, "attn_kv": akv2}

    elif fam == "vlm":
        def group_body(h, inp):
            gp, skv, ck, cv = inp

            def self_body(hh, inp2):
                lp, kv = inp2
                hh, kv2 = _attn_decode_block(cfg, lp, hh, kv, pos)
                return hh, kv2

            h, skv2 = jax.lax.scan(self_body, h, (gp["self"], skv))
            cp = gp["cross"]
            hn = L.apply_norm(cfg.norm, cp["ln1"], h)
            a = _cross_decode(cfg, cp["xattn"], hn, ck, cv)
            h = h + jnp.tanh(cp["gate_attn"].astype(h.dtype)) * a
            hn = L.apply_norm(cfg.norm, cp["ln2"], h)
            h = h + jnp.tanh(cp["gate_mlp"].astype(h.dtype)) * L.apply_mlp(cfg.act, cp["mlp"], hn)
            return h, skv2

        x, skv2 = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["self_kv"], cache["cross_kv"]["k"], cache["cross_kv"]["v"]),
        )
        cache = {"self_kv": skv2, "cross_kv": cache["cross_kv"]}

    elif fam == "audio":
        pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
        x = x + L.sinusoidal_at(pos_vec[:, None], cfg.d_model).astype(x.dtype)

        def body(h, inp):
            lp, kv, ck, cv = inp
            hn = L.apply_norm(cfg.norm, lp["ln1"], h)
            a, kv2 = L.apply_self_attn_decode(
                lp["attn"], hn, kv, pos, n_kv=cfg.num_kv_heads, theta=None
            )
            h = h + a
            hn = L.apply_norm(cfg.norm, lp["ln2"], h)
            h = h + _cross_decode(cfg, lp["xattn"], hn, ck, cv)
            hn = L.apply_norm(cfg.norm, lp["ln3"], h)
            h = h + L.apply_mlp(cfg.act, lp["mlp"], hn)
            return h, kv2

        x, kv2 = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["self_kv"],
             cache["cross_kv"]["k"], cache["cross_kv"]["v"]),
        )
        cache = {"self_kv": kv2, "cross_kv": cache["cross_kv"]}
    else:
        raise ValueError(fam)

    logits = _logits(cfg, params, x)[:, 0]
    return logits, cache


# ===========================================================================
# Dry-run input specs
# ===========================================================================
def input_specs(cfg: ArchConfig, shape: ShapeSpec, *, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((b, s + 1) if shape.kind == "train" else (b, s), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_emb"] = sds((b, cfg.vision_tokens, cfg.d_model), dtype)
        if cfg.family == "audio":
            batch["enc_emb"] = sds((b, cfg.encoder_len, cfg.d_model), dtype)
        return batch
    # decode: one new token against a cache of length seq_len
    return {
        "token": sds((b,), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": cache_shapes(cfg, b, s, dtype),
    }

"""Parameter templates: one source of truth for init AND sharding.

A model declares its parameters as a nested dict of `P` leaves, each carrying
(shape, logical_axes, init). From the same template we derive:

  * initialized parameter pytrees (init_from_template)
  * PartitionSpec pytrees (specs_from_template + repro.dist.sharding rules)
  * parameter counts / byte counts (for the roofline & memory analysis)

Stacked (scanned) layers wrap a per-layer template with `stack(tmpl, L)`,
which prepends a (L,) 'layers' axis — always unsharded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf declaration."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float | None = None  # override fan-in scale

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape/axes mismatch: {self.shape} vs {self.axes}")


def stack(template: Any, n: int) -> Any:
    """Prepend a scanned 'layers' dimension to every leaf."""

    def _s(leaf: P) -> P:
        return P((n,) + leaf.shape, ("layers",) + leaf.axes, leaf.init, leaf.scale)

    return jax.tree_util.tree_map(_s, template, is_leaf=lambda x: isinstance(x, P))


def _init_leaf(leaf: P, key, dtype):
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    # fan-in scaled normal; 'embed' uses unit normal scaled by 1/sqrt(d_last)
    if leaf.scale is not None:
        scale = leaf.scale
    elif leaf.init == "embed":
        scale = 1.0
    elif leaf.init == "small":
        scale = 0.02
    else:
        fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, leaf.shape, jnp.float32) * scale).astype(dtype)


def init_from_template(template: Any, rng: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(
        template, is_leaf=lambda x: isinstance(x, P)
    )
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(l, k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_from_template(template: Any, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (for dry-run lowering without allocation)."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype),
        template,
        is_leaf=lambda x: isinstance(x, P),
    )


def axis_spec(shape, axes, rules: dict[str, Any], mesh_shape: dict[str, int]):
    """Map one tensor's logical axes -> a PartitionSpec under a rule table.

    The single spec builder shared by parameter templates and activation
    constraints (dist.sharding.shard_act). Fallbacks, in order, per dim:
    axes absent from the mesh or of size 1 are dropped; within a tensor the
    first logical axis to claim a mesh axis wins; a dim that does not divide
    its mapped axes is replicated (tuple mappings greedily drop trailing
    axes until the dim divides)."""
    from jax.sharding import PartitionSpec

    out, used = [], set()
    for dim, name in zip(shape, axes):
        ax = rules.get(name) if name else None
        if isinstance(ax, (tuple, list)):  # 2D sharding, e.g. expert FFN dims
            cand = tuple(a for a in ax if a not in used and mesh_shape.get(a, 1) > 1)
            while cand:
                size = 1
                for a in cand:
                    size *= mesh_shape[a]
                if dim % size == 0:
                    break
                cand = cand[:-1]
            if cand:
                out.append(cand if len(cand) > 1 else cand[0])
                used.update(cand)
            else:
                out.append(None)
            continue
        size = mesh_shape.get(ax, 1) if ax is not None else 1
        if ax is None or ax in used or size <= 1 or dim % size != 0:
            out.append(None)
        else:
            out.append(ax)
            used.add(ax)
    return PartitionSpec(*out)


def specs_from_template(template: Any, rules: dict[str, str | None],
                        mesh_shape: dict[str, int]):
    """Map logical axes -> mesh axes with divisibility fallback (replicate
    any dim that does not divide its mesh axis)."""
    return jax.tree_util.tree_map(
        lambda leaf: axis_spec(leaf.shape, leaf.axes, rules, mesh_shape),
        template, is_leaf=lambda x: isinstance(x, P))


def count_params(template: Any) -> int:
    leaves = jax.tree_util.tree_leaves(template, is_leaf=lambda x: isinstance(x, P))
    return sum(math.prod(l.shape) for l in leaves)

"""xLSTM blocks: mLSTM (matrix memory, parallel quadratic form for training,
O(1) recurrent decode) and sLSTM (scalar memory, true recurrence via scan).

Stabilized exponential gating follows the xLSTM paper: all gate algebra runs
in log space with a running max stabilizer m, and the training-time parallel
form of mLSTM is the masked quadratic

    D[t,i] = F_t - F_i + ipre_i   (i <= t),  F = cumsum(log sigmoid(fpre))
    h_t    = sum_i exp(D-m_t) (q_t.k_i) v_i / max(|sum_i exp(D-m_t) q.k|, e^{-m_t})

which matches the decode recurrence exactly (verified by parity tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .params import P


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_tmpl(d: int, cfg):
    inner = int(cfg.proj_factor_mlstm * d)
    nh = cfg.num_heads
    dk = inner // nh
    return {
        "up": P((d, 2 * inner), ("embed", "inner")),
        "conv_w": P((4, inner), ("conv", "inner")),
        "conv_b": P((inner,), ("inner",), "zeros"),
        "wq": P((inner, nh, dk), ("inner", "heads", "head_dim")),
        "wk": P((inner, nh, dk), ("inner", "heads", "head_dim")),
        "wv": P((inner, nh, dk), ("inner", "heads", "head_dim")),
        "wgate": P((inner, nh, 2), ("inner", "heads", None), "small"),
        "gate_b": P((nh, 2), ("heads", None), "zeros"),
        "norm_scale": P((inner,), ("inner",), "ones"),
        "down": P((inner, d), ("inner", "embed")),
    }


def _conv_silu(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    y = jax.nn.silu(y + b)
    return (y, xp[:, -(k - 1) :, :]) if state is not None else y


def _mlstm_qkvg(p, x, cfg, d, conv_state=None):
    inner = int(cfg.proj_factor_mlstm * d)
    nh = cfg.num_heads
    dk = inner // nh
    up = x @ p["up"]
    xin, z = up[..., :inner], up[..., inner:]
    if conv_state is None:
        xc = _conv_silu(xin, p["conv_w"], p["conv_b"])
        new_state = None
    else:
        xc, new_state = _conv_silu(xin, p["conv_w"], p["conv_b"], conv_state)
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"]) / jnp.sqrt(dk).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xin, p["wv"])
    g = (jnp.einsum("bsd,dhg->bshg", xc, p["wgate"]).astype(jnp.float32)
         + p["gate_b"].astype(jnp.float32))
    ipre, fpre = g[..., 0], g[..., 1]
    return q, k, v, ipre, fpre, z, new_state, inner, nh, dk


MLSTM_CHUNK = 256  # chunked path kicks in above this sequence length


def apply_mlstm(p, x, cfg):
    """Training/prefill. Quadratic parallel form for short sequences; the
    chunked form (intra-chunk quadratic + inter-chunk (C, n, m) carry — same
    structure as SSD) for long ones, bounding score memory at
    (b, Q, Q, h) per chunk (EXPERIMENTS.md §Perf iteration 1)."""
    if x.shape[1] > MLSTM_CHUNK:
        return _apply_mlstm_chunked(p, x, cfg, MLSTM_CHUNK)
    return _apply_mlstm_quadratic(p, x, cfg)


def _apply_mlstm_quadratic(p, x, cfg):
    b, s, d = x.shape
    q, k, v, ipre, fpre, z, _, inner, nh, dk = _mlstm_qkvg(p, x, cfg, d)
    logf = jax.nn.log_sigmoid(fpre)  # (b, s, h)
    F = jnp.cumsum(logf, axis=1)
    Dm = F[:, :, None, :] - F[:, None, :, :] + ipre[:, None, :, :]  # (b, t, i, h)
    tri = jnp.tril(jnp.ones((s, s), bool))[None, :, :, None]
    Dm = jnp.where(tri, Dm, -jnp.inf)
    m = jnp.max(Dm, axis=2)  # (b, t, h)
    w = jnp.exp(Dm - m[:, :, None, :])  # (b, t, i, h)
    qk = jnp.einsum("bthk,bihk->btih", q.astype(jnp.float32), k.astype(jnp.float32))
    S = w * qk
    denom = jnp.maximum(jnp.abs(S.sum(axis=2)), jnp.exp(-m))  # (b, t, h)
    hout = jnp.einsum("btih,bihk->bthk", S, v.astype(jnp.float32)) / denom[..., None]
    hout = hout.reshape(b, s, inner).astype(x.dtype)
    hf = hout.astype(jnp.float32)
    hout = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    hout = hout * p["norm_scale"] * jax.nn.silu(z)
    return shard_act(hout @ p["down"], ("batch", "seq", "embed"))


def _apply_mlstm_chunked(p, x, cfg, Q: int):
    """Chunked parallel mLSTM. Derivation mirrors the decode recurrence:
    within chunk c, D[j,i] = F_j - F_i + ipre_i; the inter-chunk carry is the
    stabilized (C, n, m) state; m_j = max(intra max, F_j + m_prev)."""
    b, s, d = x.shape
    q, k, v, ipre, fpre, z, _, inner, nh, dk = _mlstm_qkvg(p, x, cfg, d)
    if s % Q:
        pad = Q - s % Q
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        ipre = jnp.pad(ipre, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        fpre = jnp.pad(fpre, ((0, 0), (0, pad), (0, 0)))
    sp = q.shape[1]
    nc = sp // Q
    qc = q.reshape(b, nc, Q, nh, dk).astype(jnp.float32)
    kc = k.reshape(b, nc, Q, nh, dk).astype(jnp.float32)
    vc = v.reshape(b, nc, Q, nh, dk).astype(jnp.float32)
    ic = ipre.reshape(b, nc, Q, nh)
    logf = jax.nn.log_sigmoid(fpre).reshape(b, nc, Q, nh)
    F = jnp.cumsum(logf, axis=2)  # in-chunk inclusive log decay

    # intra-chunk stabilizer/base quantities
    Dm = F[:, :, :, None, :] - F[:, :, None, :, :] + ic[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Dm = jnp.where(tri, Dm, -jnp.inf)
    m_intra = jnp.max(Dm, axis=3)  # (b, nc, Q, h)

    def chunk_body(carry, inp):
        C_p, n_p, m_p = carry  # (b,h,dk,dk), (b,h,dk), (b,h)
        qj, kj, vj, Fj, Dmj, m_in, icj = inp
        # stabilizer: intra vs carry path
        m_j = jnp.maximum(m_in, Fj + m_p[:, None, :])  # (b, Q, h)
        w = jnp.exp(Dmj - m_j[:, :, None, :])  # (b, j, i, h)
        qk = jnp.einsum("bjhk,bihk->bjih", qj, kj)
        Sw = w * qk
        num = jnp.einsum("bjih,bihk->bjhk", Sw, vj)
        den = Sw.sum(axis=2)  # (b, j, h)
        carry_scale = jnp.exp(Fj + m_p[:, None, :] - m_j)  # (b, Q, h)
        num = num + carry_scale[..., None] * jnp.einsum("bjhk,bhkv->bjhv", qj, C_p)
        den = den + carry_scale * jnp.einsum("bjhk,bhk->bjh", qj, n_p)
        h_j = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]
        # carry update to end of chunk
        FQ = Fj[:, -1:, :]  # (b,1,h)
        m_end_intra = jnp.max(FQ - Fj + icj, axis=1)  # (b, h)
        m_new = jnp.maximum(FQ[:, 0] + m_p, m_end_intra)
        wi = jnp.exp(FQ - Fj + icj - m_new[:, None, :])  # (b, Q, h)
        C_new = jnp.exp(FQ[:, 0] + m_p - m_new)[:, :, None, None] * C_p + jnp.einsum(
            "bih,bihk,bihv->bhkv", wi, kj, vj
        )
        n_new = jnp.exp(FQ[:, 0] + m_p - m_new)[:, :, None] * n_p + jnp.einsum(
            "bih,bihk->bhk", wi, kj
        )
        return (C_new, n_new, m_new), h_j

    C0 = jnp.zeros((b, nh, dk, dk), jnp.float32)
    n0 = jnp.zeros((b, nh, dk), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (qc, kc, vc, F, Dm, m_intra, ic)
    )
    _, hs = jax.lax.scan(chunk_body, (C0, n0, m0), xs)
    hout = jnp.moveaxis(hs, 0, 1).reshape(b, sp, inner)[:, :s].astype(x.dtype)
    hf = hout.astype(jnp.float32)
    hout = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    hout = hout * p["norm_scale"] * jax.nn.silu(z[:, :s] if z.shape[1] != s else z)
    return shard_act(hout @ p["down"], ("batch", "seq", "embed"))


def init_mlstm_cache(b: int, d: int, cfg, dtype):
    inner = int(cfg.proj_factor_mlstm * d)
    nh = cfg.num_heads
    dk = inner // nh
    return {
        "C": jnp.zeros((b, nh, dk, dk), jnp.float32),
        "n": jnp.zeros((b, nh, dk), jnp.float32),
        "m": jnp.full((b, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((b, 3, inner), dtype),
    }


def apply_mlstm_decode(p, x, cache, cfg):
    b, _, d = x.shape
    q, k, v, ipre, fpre, z, conv_state, inner, nh, dk = _mlstm_qkvg(
        p, x, cfg, d, cache["conv"]
    )
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (b, h, dk)
    ipre, fpre = ipre[:, 0], fpre[:, 0]  # (b, h)
    logf = jax.nn.log_sigmoid(fpre)
    m_new = jnp.maximum(logf + cache["m"], ipre)
    fs = jnp.exp(logf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(ipre - m_new)[..., None]
    C = fs[..., None] * cache["C"] + is_[..., None] * jnp.einsum("bhk,bhv->bhkv", k, v)
    n = fs * cache["n"] + is_ * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), jnp.exp(-m_new))
    hout = (num / den[..., None]).reshape(b, 1, inner).astype(x.dtype)
    hf = hout.astype(jnp.float32)
    hout = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    hout = hout * p["norm_scale"] * jax.nn.silu(z)
    y = hout @ p["down"]
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_tmpl(d: int, cfg):
    nh = cfg.num_heads
    hd = d // nh
    f = int(cfg.proj_factor_slstm * d)
    return {
        "W": P((d, nh, hd, 4), ("embed", "heads", "head_dim", None)),
        "R": P((nh, hd, hd, 4), ("heads", "head_dim", None, None), "small"),
        "b": P((nh, hd, 4), ("heads", "head_dim", None), "zeros"),
        "out_norm": P((d,), ("embed",), "ones"),
        "out_proj": P((d, d), ("embed", "embed")),
        "mlp_wi": P((d, f), ("embed", "mlp")),
        "mlp_wd": P((f, d), ("mlp", "embed")),
    }


def _slstm_cell(p, xt, state):
    """xt: (b, nh, hd, 4) pre-activations from input; state (c, n, m, h)."""
    c, n, m, h = state
    pre = xt + jnp.einsum("bhd,hdkf->bhkf", h, p["R"]) + p["b"]
    zt = jnp.tanh(pre[..., 0])
    it = pre[..., 1].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(pre[..., 2].astype(jnp.float32))
    ot = jax.nn.sigmoid(pre[..., 3])
    m_new = jnp.maximum(logf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * zt.astype(jnp.float32)
    n_new = f_s * n + i_s
    h_new = (ot.astype(jnp.float32) * c_new / jnp.maximum(n_new, 1e-6)).astype(zt.dtype)
    return c_new, n_new, m_new, h_new


def init_slstm_state(b: int, d: int, cfg, dtype):
    nh = cfg.num_heads
    hd = d // nh
    return (
        jnp.zeros((b, nh, hd), jnp.float32),
        jnp.zeros((b, nh, hd), jnp.float32),
        jnp.full((b, nh, hd), -1e30, jnp.float32),
        jnp.zeros((b, nh, hd), dtype),
    )


def apply_slstm(p, x, cfg, state=None):
    """x: (b, s, d). Scan over time (true recurrence). Returns (y, state)."""
    b, s, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    if state is None:
        state = init_slstm_state(b, d, cfg, x.dtype)
    xw = jnp.einsum("bsd,dhkf->bshkf", x, p["W"])  # f = 4 gates

    def step(st, xt):
        st2 = _slstm_cell(p, xt, st)
        return st2, st2[3]

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xw, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    hf = h.astype(jnp.float32)
    h = (hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-5)).astype(x.dtype)
    h = (h * p["out_norm"]) @ p["out_proj"]
    h = h + jax.nn.gelu(h @ p["mlp_wi"]) @ p["mlp_wd"]
    return shard_act(h, ("batch", "seq", "embed")), state


def apply_slstm_decode(p, x, cfg, state):
    y, state = apply_slstm(p, x, cfg, state)
    return y, state

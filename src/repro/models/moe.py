"""Mixture-of-Experts layer with two dispatch strategies (DESIGN.md §3).

Token->expert dispatch *is* the paper's relational pattern: tokens are rows,
the routed expert id is the key, and the expert computation wants rows
grouped (clustered) by key.

  dispatch="einsum"  GFUR-analogue baseline: a dense (T, E, C) one-hot
                     dispatch/combine einsum (Switch-Transformer style).
                     Bytes/FLOPs scale with T*E*C — at production scale this
                     does not even fit in HBM (see EXPERIMENTS.md), the same
                     way unclustered materialization dominates GPU joins.

  dispatch="sort"    GFTR pattern: stable radix-partition of the (token,
                     expert) assignments by expert id (repro.core
                     primitives), contiguous per-expert blocks, grouped
                     matmuls, and an inverse-permutation (clustered) gather
                     on the combine side. O(T*k*D) data movement.

Both honor a static capacity C per expert (overflow dropped, standard MoE
practice) and an auxiliary load-balance loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import primitives as prim
from repro.dist.sharding import shard_act

from .params import P


def moe_tmpl(d: int, cfg):
    t = {
        "router": P((d, cfg.num_experts), ("embed", "experts"), "small"),
        "wg": P((cfg.num_experts, d, cfg.d_expert), ("experts", "expert_embed", "expert_mlp")),
        "wu": P((cfg.num_experts, d, cfg.d_expert), ("experts", "expert_embed", "expert_mlp")),
        "wd": P((cfg.num_experts, cfg.d_expert, d), ("experts", "expert_mlp", "expert_embed")),
    }
    if cfg.num_shared_experts:
        t["shared"] = {
            "wg": P((d, cfg.shared_d_ff), ("embed", "mlp")),
            "wu": P((d, cfg.shared_d_ff), ("embed", "mlp")),
            "wd": P((cfg.shared_d_ff, d), ("mlp", "embed")),
        }
    return t


def _capacity(T: int, k: int, E: int, cf: float, multiple: int = 512) -> int:
    c = int(T * k / E * cf) + 1
    return max(multiple, -(-c // multiple) * multiple)


def _route(p, x2, k: int):
    """Returns (expert_idx (T,k), gates (T,k), aux_loss)."""
    logits = (x2 @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    fe = onehot.mean(axis=0)
    aux = E * jnp.sum(fe * me)
    return expert_idx.astype(jnp.int32), gates.astype(x2.dtype), aux


def _expert_ffn(xin, wg, wu, wd):
    """xin: (E, C, D) -> (E, C, D), grouped SwiGLU. No sharding constraints
    here: this runs under vmap in the grouped path (constraints live on the
    group dim in _dispatch_sort_grouped)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg)) * jnp.einsum(
        "ecd,edf->ecf", xin, wu
    )
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _plan_sort(expert_idx, E: int, C: int):
    """Integer dispatch plan for one token group (vmapped at scale).

    Returns (blk_tok (E, C), slot_a (t*k,), keep_a (t*k,)): the padded-
    partition layout of hash_join applied to token->expert assignments
    (transformation phase = stable partition by expert id)."""
    t, k = expert_idx.shape
    n = t * k
    eflat = expert_idx.reshape(-1)
    tok = jnp.arange(n, dtype=jnp.int32) // k
    perm, off, _sz = prim.partition_permutation(eflat, E)
    sorted_e = jnp.take(eflat, perm)
    sorted_tok = jnp.take(tok, perm)
    pos_in_e = jnp.arange(n, dtype=jnp.int32) - jnp.take(off, sorted_e).astype(jnp.int32)
    keep = pos_in_e < C
    blk_tok = (
        jnp.full((E, C), -1, jnp.int32)
        .at[jnp.where(keep, sorted_e, E), jnp.where(keep, pos_in_e, 0)]
        .set(sorted_tok, mode="drop")
    )
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    slot = sorted_e * C + jnp.minimum(pos_in_e, C - 1)
    slot_a = jnp.take(slot, inv)
    keep_a = jnp.take(keep, inv)
    return blk_tok, slot_a, keep_a


def _gather_rows(x, idx):
    """out[i] = x[idx[i]] with idx == -1 -> 0 (one token group)."""
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    return jnp.where((idx >= 0).reshape(idx.shape + (1,) * (x.ndim - 1)),
                     jnp.take(x, safe, axis=0), 0)


def _dispatch_sort(p, x2, expert_idx, gates, C: int):
    """GFTR-pattern dispatch, single group (tests / no-mesh path)."""
    T, D = x2.shape
    E = p["wg"].shape[0]
    k = expert_idx.shape[1]
    blk_tok, slot_a, keep_a = _plan_sort(expert_idx, E, C)
    xin = _gather_rows(x2, blk_tok.reshape(-1)).reshape(E, C, D)
    out = _expert_ffn(xin, p["wg"], p["wu"], p["wd"])
    ya = _gather_rows(out.reshape(E * C, D), jnp.where(keep_a, slot_a, -1))
    y = (ya.reshape(T, k, D) * gates[..., None]).sum(axis=1)
    return y.astype(x2.dtype)


def _dispatch_sort_grouped(p, x2, expert_idx, gates, *, k: int, E: int,
                           cf: float, groups: int):
    """Hierarchical GFTR dispatch: tokens split into `groups` shard-local
    blocks (the paper's probe-side sub-partitioning applied to MoE); every
    tensor op is batched over the sharded group dim and pinned with an
    explicit constraint so GSPMD never replicates token arrays
    (EXPERIMENTS.md §Perf iteration 2)."""
    T, D = x2.shape
    t_loc = T // groups
    C_loc = _capacity(t_loc, k, E, cf, multiple=128)
    xg = shard_act(x2.reshape(groups, t_loc, D), ("tokens", None, "embed"))
    eg = expert_idx.reshape(groups, t_loc, k)
    blk, slot_a, keep_a = jax.vmap(lambda e: _plan_sort(e, E, C_loc))(eg)
    xin = jax.vmap(_gather_rows)(xg, blk.reshape(groups, -1))
    xin = shard_act(xin.reshape(groups, E, C_loc, D), ("tokens", None, None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xin, p["wu"]
    )
    h = shard_act(h, ("tokens", None, None, "mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    out = shard_act(out, ("tokens", None, None, None))
    ya = jax.vmap(_gather_rows)(out.reshape(groups, E * C_loc, D),
                                jnp.where(keep_a, slot_a, -1))
    ya = shard_act(ya, ("tokens", None, None))  # (G, t_loc*k, D)
    gg = gates.reshape(groups, t_loc, k)
    y = (ya.reshape(groups, t_loc, k, D) * gg[..., None]).sum(axis=2)
    y = shard_act(y, ("tokens", None, "embed"))
    return y.reshape(T, D).astype(x2.dtype)


def _dispatch_einsum(p, x2, expert_idx, gates, C: int):
    """Dense one-hot dispatch/combine (GFUR-analogue baseline)."""
    T, D = x2.shape
    E = p["wg"].shape[0]
    k = expert_idx.shape[1]
    n = T * k
    eflat = expert_idx.reshape(-1)
    tok = jnp.arange(n, dtype=jnp.int32) // k
    # position of each assignment within its expert (stable order)
    oh = jax.nn.one_hot(eflat, E, dtype=jnp.int32)  # (n, E)
    excl = jnp.cumsum(oh, axis=0) - oh  # exclusive running count per expert
    pos = jnp.take_along_axis(excl, eflat[:, None], axis=1)[:, 0]
    keep = pos < C
    disp = jnp.zeros((T, E, C), x2.dtype)
    disp = disp.at[tok, eflat, jnp.minimum(pos, C - 1)].add(keep.astype(x2.dtype))
    comb = jnp.zeros((T, E, C), x2.dtype)
    comb = comb.at[tok, eflat, jnp.minimum(pos, C - 1)].add(
        (gates.reshape(-1) * keep).astype(x2.dtype)
    )
    xin = jnp.einsum("tec,td->ecd", disp, x2)
    out = _expert_ffn(xin, p["wg"], p["wu"], p["wd"])
    y = jnp.einsum("tec,ecd->td", comb, out)
    return y.astype(x2.dtype)


def _num_token_groups(T: int) -> int:
    """Shard-local group count for hierarchical dispatch: the total number
    of shards along the 'tokens' axes (1 outside a mesh context)."""
    from repro.dist import sharding as SH

    ctx = SH.current_ctx()
    if ctx is None:
        return 1
    mesh, rules = ctx
    ax = rules.act.get("tokens")
    if isinstance(ax, tuple):
        ax = tuple(a for a in ax if a in mesh.shape)
    g = SH._mesh_axis_size(mesh, ax)
    return g if g > 1 and T % g == 0 and T // g >= 8 else 1


def apply_moe(p, x, moe_cfg):
    """x: (b, s, d). Returns (y, aux_loss)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    expert_idx, gates, aux = _route(p, x2, moe_cfg.top_k)
    C = _capacity(b * s, moe_cfg.top_k, moe_cfg.num_experts, moe_cfg.capacity_factor)
    if moe_cfg.dispatch == "sort":
        groups = _num_token_groups(b * s)
        if groups > 1:
            fn = jax.checkpoint(functools.partial(
                _dispatch_sort_grouped, k=moe_cfg.top_k, E=moe_cfg.num_experts,
                cf=moe_cfg.capacity_factor, groups=groups))
            y = fn(p, x2, expert_idx, gates)
        else:
            y = _dispatch_sort(p, x2, expert_idx, gates, C)
    elif moe_cfg.dispatch == "einsum":
        y = _dispatch_einsum(p, x2, expert_idx, gates, C)
    else:
        raise ValueError(moe_cfg.dispatch)
    if moe_cfg.num_shared_experts:
        sh = p["shared"]
        xs2 = shard_act(x2, ("tokens", "embed"))
        hs = jax.nn.silu(xs2 @ sh["wg"]) * (xs2 @ sh["wu"])
        hs = shard_act(hs, ("tokens", "mlp"))
        y = y + shard_act(hs @ sh["wd"], ("tokens", "embed"))
    return y.reshape(b, s, d), aux * moe_cfg.router_aux_coef

"""Shared transformer layers: norms, RoPE, GQA/SWA/cross attention, MLPs.

Functional style: parameters are plain pytrees declared by `*_tmpl` template
functions (see params.py) and consumed by `apply_*` functions. Activation
sharding is constrained through repro.dist.sharding.shard_act (no-op outside
a mesh context).

Attention decode uses a ring-buffer KV cache of capacity W: slot = pos % W.
With W = max_len this is a dense cache; with W = sliding_window it is the
O(window) cache that makes SWA archs eligible for the long_500k cell
(DESIGN.md §5). RoPE is applied at insert time with absolute positions, so
ring wrap-around needs no re-rotation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act

from .params import P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_tmpl(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": P((d,), ("embed",), "ones")}
    if kind == "layernorm":
        return {"scale": P((d,), ("embed",), "ones"), "bias": P((d,), ("embed",), "zeros")}
    if kind == "nonparam_ln":  # OLMo: non-parametric LayerNorm
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (self, GQA, optional sliding window; cross)
# ---------------------------------------------------------------------------
def attn_tmpl(d: int, n_heads: int, n_kv: int, hd: int):
    return {
        "wq": P((d, n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((n_heads, hd, d), ("heads", "head_dim", "embed")),
    }


def _sdpa(q, k, v, mask, n_rep: int):
    """q: (b, sq, h, hd); k/v: (b, sk, kv, hd); mask broadcast (b, 1, sq, sk)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    q = q.reshape(b, sq, kv, n_rep, hd)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q, k).astype(jnp.float32)
    scores = shard_act(scores, ("batch", "kv_heads", None, "seq", None))
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v)
    return out.reshape(b, sq, h, hd)


BLOCKWISE_SEQ_THRESHOLD = 2048  # above this, use online-softmax chunking
BLOCKWISE_KV_CHUNK = 1024


def _blockwise_sdpa(q, k, v, positions, *, n_rep, causal, window,
                    kv_chunk=BLOCKWISE_KV_CHUNK):
    """Flash-style attention: scan over KV chunks with running
    (max, denom, acc) online softmax. Peak score memory is
    (b, heads, s_q, kv_chunk) instead of (b, heads, s_q, s_kv) — this is
    what bounds the prefill_32k / train_4k memory term (EXPERIMENTS.md
    §Perf iteration 1)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    sk = k.shape[1]
    pad = -sk % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // kv_chunk
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, sq, kvh, n_rep, hd)
    qpos = positions  # (b, sq)
    scale = 1.0 / jnp.sqrt(hd)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, ci = inp  # (b, kv_chunk, kvh, hd), chunk index
        kpos = ci * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb).astype(jnp.float32) * scale
        s = shard_act(s, ("batch", "kv_heads", None, "seq", None))
        mask = kpos[None, None, None, None, :] < sk  # padding
        if causal:
            mask &= kpos[None, None, None, None, :] <= qpos[:, None, None, :, None]
        if window is not None:
            mask &= kpos[None, None, None, None, :] > qpos[:, None, None, :, None] - window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p_.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p_.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    body = jax.checkpoint(body)  # nested remat: recompute per-chunk scores
    # in backward instead of saving (b, heads, sq, kv_chunk) probabilities
    # per chunk (EXPERIMENTS.md §Perf iteration 3)
    m0 = shard_act(jnp.full((b, kvh, n_rep, sq), -1e30, jnp.float32),
                   ("batch", "kv_heads", None, "seq"))
    l0 = shard_act(jnp.zeros((b, kvh, n_rep, sq), jnp.float32),
                   ("batch", "kv_heads", None, "seq"))
    a0 = shard_act(jnp.zeros((b, kvh, n_rep, sq, hd), jnp.float32),
                   ("batch", "kv_heads", None, "seq", None))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def apply_self_attn(p, x, *, n_kv: int, theta: float, window: int | None = None,
                    causal: bool = True, positions=None):
    """Training/prefill path. x: (b, s, d). Sequences past
    BLOCKWISE_SEQ_THRESHOLD use the online-softmax chunked path."""
    b, s, d = x.shape
    n_heads = p["wq"].shape[1]
    n_rep = n_heads // n_kv
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    if theta is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    if s > BLOCKWISE_SEQ_THRESHOLD:
        out = _blockwise_sdpa(q, k, v, positions, n_rep=n_rep, causal=causal,
                              window=window)
    else:
        qp = positions[:, :, None]
        kp = positions[:, None, :]
        mask = jnp.ones((b, s, s), bool) if not causal else (kp <= qp)
        if window is not None:
            mask &= kp > qp - window
        out = _sdpa(q, k, v, mask[:, None], n_rep)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return shard_act(y, ("batch", "seq", "embed"))


def apply_cross_attn(p, x, kv_src, *, n_kv: int):
    """Cross attention: queries from x (b,s,d), keys/values from kv_src
    (b, t, d) (encoder frames / vision patches). No RoPE, no mask."""
    n_heads = p["wq"].shape[1]
    n_rep = n_heads // n_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    mask = jnp.ones((x.shape[0], 1, x.shape[1], kv_src.shape[1]), bool)
    out = _sdpa(q, k, v, mask, n_rep)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_kv_cache(b: int, w: int, n_kv: int, hd: int, dtype):
    return {
        "k": jnp.zeros((b, w, n_kv, hd), dtype),
        "v": jnp.zeros((b, w, n_kv, hd), dtype),
    }


def apply_self_attn_decode(p, x, cache, pos, *, n_kv: int, theta: float):
    """Single-token decode with ring-buffer cache. x: (b, 1, d); pos is a
    scalar int32 (slot-synchronous decode / dry-run) or an int32 (b,) vector
    (continuous batching: every sequence at its own position).
    Returns (y, new_cache)."""
    b, _, d = x.shape
    pos_vec = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    n_heads = p["wq"].shape[1]
    n_rep = n_heads // n_kv
    W = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    # Decode attention layout must match the cache layout, or GSPMD
    # reshards the entire KV cache every step (a multi-GB all-gather per
    # token — EXPERIMENTS.md §Perf cell 3). When kv_heads divides the TP
    # axis the cache is head-sharded and head-parallel attention is free;
    # otherwise pin everything batch-only (redundant model-axis compute is
    # negligible at 1 token/step).
    from repro.dist.sharding import current_ctx

    ctx = current_ctx()
    head_parallel = True
    if ctx is not None:
        tp = dict(ctx[0].shape).get("model", 1)
        head_parallel = n_kv % tp == 0
    if not head_parallel:
        q = shard_act(q, ("batch", None, None, None))
        k = shard_act(k, ("batch", None, None, None))
        v = shard_act(v, ("batch", None, None, None))
    posv = pos_vec[:, None]
    if theta is not None:
        q = rope(q, posv, theta)
        k = rope(k, posv, theta)  # absolute-position RoPE at insert time
    slot = jnp.mod(pos_vec, W)  # (b,) per-sequence ring slot
    bidx = jnp.arange(b, dtype=jnp.int32)
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    # slot i holds timestep t_i = pos - ((pos - i) mod W); valid iff t_i >= 0
    i = jnp.arange(W, dtype=jnp.int32)
    t_i = pos_vec[:, None] - jnp.mod(pos_vec[:, None] - i[None, :], W)
    mask = (t_i >= 0)[:, None, None, :]
    out = _sdpa(q, ck, cv, mask, n_rep)
    if not head_parallel:
        # keep the AV product batch-sharded too, or wo's head sharding
        # back-propagates into the einsum and regathers the V cache
        out = shard_act(out, ("batch", None, None, None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_tmpl(kind: str, d: int, f: int):
    if kind == "swiglu":
        return {
            "wg": P((d, f), ("embed", "mlp")),
            "wu": P((d, f), ("embed", "mlp")),
            "wd": P((f, d), ("mlp", "embed")),
        }
    return {"wi": P((d, f), ("embed", "mlp")), "wd": P((f, d), ("mlp", "embed"))}


def apply_mlp(kind: str, p, x):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = shard_act(h, ("batch", "seq", "mlp"))
    return shard_act(h @ p["wd"], ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def embed_tmpl(v: int, d: int):
    return {"table": P((v, d), ("vocab", "embed"), "embed", scale=0.02)}


def head_tmpl(d: int, v: int):
    return {"w": P((d, v), ("embed", "vocab"))}


def sinusoidal_positions(max_len: int, d: int):
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((max_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return pe


def sinusoidal_at(positions: "jax.Array", d: int):
    """Sinusoidal embedding rows for arbitrary (possibly traced) positions.
    positions: (...,) int -> (..., d) f32."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    half = ang.shape[-1]
    out = jnp.zeros(positions.shape + (d,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang[..., : (d + 1) // 2]))
    return out

"""mixtral-8x7b [moe]: 8 experts top-2, SWA [arXiv:2401.04088; hf].
32L d4096 32H (kv8) d_ff=14336 vocab=32000, sliding window 4096."""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    sliding_window=4096, rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336),
    source="arXiv:2401.04088", remark="8 experts top-2, SWA",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512, sliding_window=16,
                         moe=MoEConfig(num_experts=4, top_k=2, d_expert=128))

"""llama-3.2-vision-11b [vlm]: cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. 40L d4096 32H (kv8)
d_ff=14336 vocab=128256; gated cross-attention every 5th layer; the vision
frontend is a STUB (input_specs provides precomputed patch embeddings of
1601 tokens projected to d_model)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    cross_attn_every=5, vision_tokens=1601, rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision", remark="cross-attn image layers",
)

REDUCED = CONFIG.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512, cross_attn_every=2,
                         vision_tokens=16)

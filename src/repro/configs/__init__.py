"""Architecture configs: full-scale + CPU-reduced variants (configs.base)."""

"""olmo-1b [dense]: non-parametric LN [arXiv:2402.00838; hf].
16L d2048 16H (kv16) d_ff=8192 vocab=50304."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=8192, vocab_size=50304,
    norm="nonparam_ln", act="swiglu", tie_embeddings=True, rope_theta=10_000.0,
    source="arXiv:2402.00838", remark="non-parametric LN",
)

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                         d_ff=128, vocab_size=512)
